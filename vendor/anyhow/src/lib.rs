//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The build must succeed on machines with no crates.io access (the tier-1
//! gate runs offline), so the error crate is vendored as a path dependency.
//! Only the surface this workspace actually uses is implemented: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] macros, the blanket
//! `From<E: std::error::Error>` conversion, and `{:#}` source-chain
//! formatting. Context/downcasting/backtraces are intentionally absent.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A lightweight dynamic error: a message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string(), source: None }
    }

    /// The root message (without the source chain).
    pub fn message(&self) -> &str {
        &self.msg
    }

    /// Iterate the source chain (for `{:#}` / `{:?}` rendering).
    fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next: Option<&(dyn StdError + 'static)> =
            self.source.as_deref().map(|e| e as &(dyn StdError + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

// The blanket conversion `?` relies on. `Error` itself deliberately does NOT
// implement `std::error::Error`, which is what makes this impl coherent
// (same trick as the real anyhow crate).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for cause in self.chain() {
                let cause = cause.to_string();
                // the message already embeds the direct source's text (see
                // `From` above); skip exact duplicates to avoid "x: x"
                if cause != self.msg {
                    write!(f, ": {cause}")?;
                }
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<String> =
            self.chain().map(|c| c.to_string()).filter(|c| c != &self.msg).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`] built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn macros_format() {
        let x = 7;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 7");
        let e = anyhow!("bad {} of {}", "kind", 3);
        assert_eq!(e.to_string(), "bad kind of 3");
        fn f() -> Result<()> {
            bail!("nope {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
    }

    #[test]
    fn alternate_format_is_stable() {
        let e = Error::from(io_err());
        // message text embeds the source already; `{:#}` must not duplicate
        assert_eq!(format!("{e:#}"), "disk on fire");
    }
}
