//! API-shaped stub of the offline `xla-rs` PJRT toolchain.
//!
//! The real crate (PJRT CPU client + HLO compilation) is only present in the
//! baked toolchain image and is not redistributable here. This stub exposes
//! the same type/method surface so that `--features xla` *type-checks* on
//! any machine; every runtime entry point returns an explanatory error (or
//! panics via the caller's `.expect`). To run the artifact-backed PJRT
//! backend for real, drop the actual `xla-rs` crate into `vendor/xla/`.
//!
//! The default (native) backend never touches this crate.

use std::fmt;

/// Error type matching the real crate's `std::error::Error` shape.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} is unavailable — vendor/xla is an API stub; install the real \
         offline xla-rs toolchain in vendor/xla to use the PJRT backend"
    )))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host-side literal (stub: holds nothing).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        stub("Literal::create_from_shape_and_untyped_data")
    }

    pub fn scalar<T>(_v: T) -> Literal {
        Literal(())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        stub("Literal::to_vec")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        stub("Literal::array_shape")
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        stub("Literal::decompose_tuple")
    }
}

#[derive(Debug)]
pub struct ArrayShape(Vec<i64>);

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.0
    }
}

#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub("HloModuleProto::from_text_file")
    }
}

#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[derive(Debug, Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub("PjRtClient::compile")
    }
}

#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute")
    }
}
