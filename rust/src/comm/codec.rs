//! Pluggable wire codecs for the rendezvous collectives (Flash
//! Communication-style low-bit allreduce, PAPERS.md).
//!
//! A [`Codec`] describes how each rank's partial tensor crosses the modeled
//! link during an AllReduce. [`Codec::Fp32`] is the default passthrough: the
//! reduction is bitwise-identical to the historical fp32 path and the link is
//! charged `numel * 4` bytes. [`Codec::Int8`] / [`Codec::Int4`] model
//! per-block scale-and-quantize compression: every [`QUANT_BLOCK`]-element
//! block of a rank's contribution is scaled by its absmax, rounded to a
//! symmetric `b`-bit grid (127 levels for int8, 7 for int4), and dequantized
//! on arrival — the *values* that enter the reduction are the
//! quantize-dequantize roundtrip, and the *bytes* charged to the interconnect
//! are the compressed payload plus one f32 scale per block (see
//! [`Codec::wire_bytes`]).
//!
//! Determinism contract (docs/ARCHITECTURE.md, "Communication layer"): the
//! encode step is a pure elementwise f32 transform applied independently to
//! each rank's partial, and the reduction still sums in fixed rank order
//! `0..tp`. Both runtimes — the sequential oracle
//! ([`CollectiveEngine::allreduce`]) and the threaded rendezvous
//! last-depositor ([`SharedCollective::deposit`]) — run the identical
//! transform-then-sum sequence, so for every codec the threaded logits are
//! bitwise-identical to the sequential logits (`runtime_determinism.rs`
//! extends per-codec rather than dying). Quantization *drift vs the fp32
//! oracle* is measured, not hidden: `tests/codec_divergence.rs` reports
//! max/mean logit drift per architecture per codec.
//!
//! What is in scope: `ReduceOp::Sum` rendezvous rounds and the sequential
//! AllReduce, i.e. the per-layer attention/MLP output reductions that
//! dominate TP communication. Out of scope, deliberately: `TakeRank0`
//! (Upperbound's deleted collective — free and unmetered, nothing crosses a
//! link), the tp=1 degenerate case (no wire), and the final lm-head
//! AllGather (one op per forward, blocking, its payload is vocab logits
//! where quantization would directly perturb sampling).
//!
//! [`CollectiveEngine::allreduce`]: super::collective::CollectiveEngine::allreduce
//! [`SharedCollective::deposit`]: super::rendezvous::SharedCollective::deposit

use anyhow::{bail, Result};

use crate::model::HostTensor;

/// Elements per quantization block: one f32 absmax scale is stored (and
/// charged to the wire) per block of this many elements.
pub const QUANT_BLOCK: usize = 64;

/// Wire format for a rank's AllReduce contribution.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub enum Codec {
    /// Uncompressed passthrough — bitwise-identical to the pre-codec path.
    #[default]
    Fp32,
    /// Per-block absmax scale + symmetric 8-bit grid (127 levels).
    Int8,
    /// Per-block absmax scale + symmetric 4-bit grid (7 levels), two
    /// elements per byte on the wire.
    Int4,
}

impl Codec {
    pub fn parse(s: &str) -> Result<Codec> {
        Ok(match s {
            "fp32" => Codec::Fp32,
            "int8" => Codec::Int8,
            "int4" => Codec::Int4,
            _ => bail!("unknown codec {s:?} (fp32|int8|int4)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Codec::Fp32 => "fp32",
            Codec::Int8 => "int8",
            Codec::Int4 => "int4",
        }
    }

    /// Encoded size of `numel` elements whose uncompressed element width is
    /// `elem_bytes` (4 for the engine's f32 tensors, 2 for the perfmodel's
    /// bf16 activations). Quantized payloads are element-width independent:
    /// int8 is one byte per element, int4 packs two elements per byte, and
    /// both carry one f32 scale per [`QUANT_BLOCK`]-element block.
    pub fn wire_bytes_for(&self, numel: usize, elem_bytes: usize) -> usize {
        let scales = numel.div_ceil(QUANT_BLOCK) * 4;
        match self {
            Codec::Fp32 => numel * elem_bytes,
            Codec::Int8 => numel + scales,
            Codec::Int4 => numel.div_ceil(2) + scales,
        }
    }

    /// Encoded size of `numel` f32 elements — what the engine's collectives
    /// charge to [`CommStats::bytes_moved`] and the modeled link.
    ///
    /// [`CommStats::bytes_moved`]: super::collective::CommStats::bytes_moved
    pub fn wire_bytes(&self, numel: usize) -> usize {
        self.wire_bytes_for(numel, 4)
    }

    /// Apply the quantize→dequantize wire roundtrip to one rank's partial,
    /// in place. `Fp32` is a literal no-op. The transform is elementwise and
    /// branch-free per element (`round` + `clamp` on finite inputs), so it is
    /// bitwise-deterministic regardless of which thread runs it. An all-zero
    /// block is left untouched (its absmax scale would be 0; a real encoder
    /// writes scale=0 and decodes zeros — same values, no division).
    pub fn transport(&self, t: &mut HostTensor) {
        let levels: f32 = match self {
            Codec::Fp32 => return,
            Codec::Int8 => 127.0,
            Codec::Int4 => 7.0,
        };
        for block in t.data.chunks_mut(QUANT_BLOCK) {
            let mut absmax = 0.0f32;
            for &x in block.iter() {
                let a = x.abs();
                if a > absmax {
                    absmax = a;
                }
            }
            if absmax == 0.0 {
                continue;
            }
            let scale = absmax / levels;
            for x in block.iter_mut() {
                *x = (*x / scale).round().clamp(-levels, levels) * scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: Vec<f32>) -> HostTensor {
        HostTensor::new(vec![v.len()], v)
    }

    #[test]
    fn parse_and_name_roundtrip() {
        for s in ["fp32", "int8", "int4"] {
            assert_eq!(Codec::parse(s).unwrap().name(), s);
        }
        assert!(Codec::parse("bf16").is_err());
        assert_eq!(Codec::default(), Codec::Fp32);
    }

    #[test]
    fn fp32_transport_is_bitwise_identity() {
        let data: Vec<f32> = (0..200).map(|i| (i as f32 - 100.5) * 0.37).collect();
        let mut x = t(data.clone());
        Codec::Fp32.transport(&mut x);
        let before: Vec<u32> = data.iter().map(|v| v.to_bits()).collect();
        let after: Vec<u32> = x.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn quantization_error_bounded_by_one_step() {
        for codec in [Codec::Int8, Codec::Int4] {
            let levels = if codec == Codec::Int8 { 127.0f32 } else { 7.0 };
            let data: Vec<f32> = (0..QUANT_BLOCK).map(|i| (i as f32 * 0.713).sin() * 3.0).collect();
            let mut x = t(data.clone());
            codec.transport(&mut x);
            let absmax = data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let step = absmax / levels;
            for (orig, deq) in data.iter().zip(&x.data) {
                assert!(
                    (orig - deq).abs() <= step * 0.5 + 1e-6,
                    "{codec:?}: {orig} -> {deq} (step {step})"
                );
            }
        }
    }

    #[test]
    fn int4_is_coarser_than_int8() {
        let data: Vec<f32> = (0..QUANT_BLOCK).map(|i| (i as f32 * 0.917).cos() * 5.0).collect();
        let err = |codec: Codec| {
            let mut x = t(data.clone());
            codec.transport(&mut x);
            data.iter().zip(&x.data).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
        };
        assert!(err(Codec::Int4) > err(Codec::Int8));
        assert!(err(Codec::Int8) > 0.0);
    }

    #[test]
    fn blocks_are_scaled_independently() {
        // Block 0 holds huge values, block 1 tiny ones: per-block scaling
        // must keep the tiny block's relative error small instead of
        // flushing it to zero under the huge block's absmax.
        let mut data = vec![1000.0f32; QUANT_BLOCK];
        data.extend(vec![0.001f32; QUANT_BLOCK]);
        let mut x = t(data);
        Codec::Int8.transport(&mut x);
        assert!((x.data[0] - 1000.0).abs() < 1.0);
        assert!((x.data[QUANT_BLOCK] - 0.001).abs() < 1e-5);
    }

    #[test]
    fn zero_block_stays_zero_without_nan() {
        let mut x = t(vec![0.0; QUANT_BLOCK + 3]);
        Codec::Int4.transport(&mut x);
        assert!(x.data.iter().all(|v| *v == 0.0 && v.is_finite()));
    }

    #[test]
    fn transport_is_deterministic() {
        let data: Vec<f32> = (0..300).map(|i| ((i * 7919) % 997) as f32 - 498.0).collect();
        let mut a = t(data.clone());
        let mut b = t(data);
        Codec::Int4.transport(&mut a);
        Codec::Int4.transport(&mut b);
        let bits = |h: &HostTensor| h.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b));
    }

    #[test]
    fn wire_bytes_accounting() {
        // 128 elems = 2 blocks -> 2 f32 scales.
        assert_eq!(Codec::Fp32.wire_bytes(128), 512);
        assert_eq!(Codec::Int8.wire_bytes(128), 128 + 8);
        assert_eq!(Codec::Int4.wire_bytes(128), 64 + 8);
        // ragged tail: 65 elems = 2 blocks, int4 packs to ceil(65/2).
        assert_eq!(Codec::Int8.wire_bytes(65), 65 + 8);
        assert_eq!(Codec::Int4.wire_bytes(65), 33 + 8);
        // bf16 base (perfmodel): fp32 passthrough charges the raw message.
        assert_eq!(Codec::Fp32.wire_bytes_for(128, 2), 256);
        assert_eq!(Codec::Int8.wire_bytes_for(128, 2), 128 + 8);
        // compression is real for every message >= one block
        for numel in [64usize, 8192, 8192 * 4] {
            assert!(Codec::Int8.wire_bytes(numel) < Codec::Fp32.wire_bytes(numel));
            assert!(Codec::Int4.wire_bytes(numel) < Codec::Int8.wire_bytes(numel));
            assert!(Codec::Int8.wire_bytes_for(numel, 2) < Codec::Fp32.wire_bytes_for(numel, 2));
        }
    }
}
