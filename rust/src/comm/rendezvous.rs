//! Rendezvous collectives for the threaded rank runtime.
//!
//! Under the threaded runtime every simulated TP rank runs on its own worker
//! thread, so an AllReduce is a real synchronization point: each rank
//! *deposits* its partial tensor, the last depositor reduces all partials in
//! deterministic rank order (0, 1, ..., tp-1 — exactly the order the
//! sequential [`CollectiveEngine`] sums in, preserving the bitwise
//! reproducibility contract of `allreduce_sums_in_rank_order`), and the
//! modeled link deadline starts ticking from that rendezvous instant — the
//! same "collective cannot start before the last rank arrives" semantics as
//! NCCL. Ranks then [`wait`] the result; compute they issue between deposit
//! and wait genuinely overlaps the modeled link time on a sibling core.
//!
//! Wire codec: before a `Sum` reduction each partial takes the configured
//! [`Codec`]'s quantize→dequantize roundtrip, and the modeled link is charged
//! the *encoded* byte count — identical transform, order, and accounting as
//! the sequential engine, so every codec preserves the threaded==sequential
//! bitwise contract (see `comm/codec.rs`).
//!
//! Exposed-time accounting: the per-round exposed wait is the *maximum*
//! across ranks (the critical path), folded incrementally into the shared
//! [`CommStats`] as ranks finish waiting — so `hidden_fraction` keeps the
//! same meaning it has under the sequential runtime, where each collective
//! is waited exactly once.
//!
//! Failure semantics: no code path here panics on a poisoned lock. A rank
//! that panicked mid-collective leaves the `std::sync::Mutex` poisoned;
//! every other rank surfaces that as a propagated `Err` (which the
//! threaded runtime turns into a per-request error event) rather than a
//! cascading panic, and [`SharedCollective::poison`] recovers the guard
//! with `into_inner` so the wake-everyone path works even then. No peer
//! rank is ever left blocked on a rendezvous that cannot complete.
//!
//! [`CollectiveEngine`]: super::collective::CollectiveEngine
//! [`wait`]: SharedCollective::wait

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::codec::Codec;
use super::collective::CommStats;
use super::handle::spin_sleep;
use super::interconnect::Interconnect;
use crate::model::HostTensor;

/// Lock a mutex, mapping a poisoned lock (some rank panicked while
/// holding it) to a propagated error instead of a panic of our own.
fn lock_or_err<'a, T>(
    m: &'a Mutex<T>,
    what: &str,
) -> Result<std::sync::MutexGuard<'a, T>> {
    m.lock()
        .map_err(|_| anyhow::anyhow!("{what} mutex poisoned: a rank panicked mid-collective"))
}

/// What the rendezvous computes once all ranks have deposited.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    /// Deterministic sum in rank order 0..tp — the AllReduce contract.
    Sum,
    /// Broadcast rank 0's partial, free and unmetered. This is the
    /// Upperbound architecture's "deleted" collective: the sequential oracle
    /// keeps one shared residual fed by rank 0's partials, so the threaded
    /// ranks rendezvous on rank 0's tensor to stay bitwise in step — but no
    /// communication is modeled or counted, matching the paper's "removes
    /// all communication operations".
    TakeRank0,
}

/// One in-flight collective round, keyed by sequence number. Every rank
/// issues the same schedule, so per-worker sequence counters line up without
/// any central coordination.
struct Round {
    op: ReduceOp,
    parts: Vec<Option<HostTensor>>,
    deposited: usize,
    result: Option<Arc<HostTensor>>,
    /// Modeled completion instant; meaningful once `result` is set.
    ready_at: Instant,
    /// Ranks that finished waiting (the round retires at `tp`).
    waited: usize,
    /// Largest exposed wait recorded so far (critical-path accounting).
    exposed_max: Duration,
}

impl Round {
    fn new(tp: usize, op: ReduceOp) -> Round {
        Round {
            op,
            parts: (0..tp).map(|_| None).collect(),
            deposited: 0,
            result: None,
            ready_at: Instant::now(),
            waited: 0,
            exposed_max: Duration::ZERO,
        }
    }
}

struct Inner {
    rounds: HashMap<u64, Round>,
    /// Set on any worker error: wakes all waiters with the failure instead
    /// of deadlocking ranks blocked on a rendezvous that will never fill.
    poisoned: Option<String>,
}

/// The rendezvous collective shared by all rank worker threads.
pub struct SharedCollective {
    tp: usize,
    interconnect: Interconnect,
    codec: Codec,
    stats: Arc<Mutex<CommStats>>,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl SharedCollective {
    pub fn new(
        tp: usize,
        interconnect: Interconnect,
        codec: Codec,
        stats: Arc<Mutex<CommStats>>,
    ) -> SharedCollective {
        SharedCollective {
            tp,
            interconnect,
            codec,
            stats,
            inner: Mutex::new(Inner { rounds: HashMap::new(), poisoned: None }),
            cv: Condvar::new(),
        }
    }

    pub fn tp(&self) -> usize {
        self.tp
    }

    /// Deposit rank `rank`'s partial for collective round `seq`. The last
    /// depositor performs the reduction (rank order 0..tp) and anchors the
    /// modeled link deadline at the rendezvous instant. Non-blocking.
    pub fn deposit(&self, rank: usize, seq: u64, part: HostTensor, op: ReduceOp) -> Result<()> {
        if rank >= self.tp {
            bail!("rank {rank} out of range for tp={}", self.tp);
        }
        let mut g = lock_or_err(&self.inner, "collective")?;
        if let Some(msg) = &g.poisoned {
            bail!("collective poisoned: {msg}");
        }
        let tp = self.tp;
        let round = g.rounds.entry(seq).or_insert_with(|| Round::new(tp, op));
        if round.op != op {
            bail!("round {seq}: rank {rank} op {op:?} mismatches {:?}", round.op);
        }
        if round.parts[rank].is_some() {
            bail!("round {seq}: rank {rank} deposited twice");
        }
        if let Some(first) = round.parts.iter().flatten().next() {
            if first.shape != part.shape {
                bail!("round {seq}: shape mismatch {:?} vs {:?}", part.shape, first.shape);
            }
        }
        round.parts[rank] = Some(part);
        round.deposited += 1;
        let taken: Option<Vec<HostTensor>> = if round.deposited == tp {
            // every slot filled (deposited == tp), so take() cannot miss
            Some(round.parts.iter_mut().map(|p| p.take().expect("deposited slot empty")).collect())
        } else {
            None
        };
        drop(g); // reduce outside the lock: sibling rounds keep rendezvousing

        if let Some(parts) = taken {
            // From here until publish, sibling ranks are blocked in wait() on
            // this round. Any early error return MUST poison the collective
            // first, or those peers hang forever on a result that never comes.
            let mut parts = parts.into_iter();
            let result = match op {
                ReduceOp::Sum => {
                    let mut acc = parts.next().expect("tp >= 1");
                    if tp > 1 {
                        // tp=1 never touches a wire — the codec must not
                        // perturb it (matches the sequential engine).
                        self.codec.transport(&mut acc);
                    }
                    for mut p in parts {
                        self.codec.transport(&mut p);
                        for (a, b) in acc.data.iter_mut().zip(&p.data) {
                            *a += b;
                        }
                    }
                    acc
                }
                ReduceOp::TakeRank0 => parts.next().expect("tp >= 1"),
            };
            let modeled = match op {
                ReduceOp::Sum => {
                    let raw = result.numel() * 4;
                    let bytes =
                        if tp > 1 { self.codec.wire_bytes(result.numel()) } else { raw };
                    let d = Duration::from_secs_f64(self.interconnect.allreduce_time(bytes, tp));
                    let (intra, cross) = self.interconnect.allreduce_tier_bytes(bytes, tp);
                    match self.stats.lock() {
                        Ok(mut s) => {
                            s.allreduce_count += 1;
                            s.bytes_moved += bytes;
                            s.bytes_raw += raw;
                            s.bytes_intra += intra;
                            s.bytes_cross += cross;
                            s.charge_modeled(d);
                        }
                        Err(_) => {
                            let msg = "stats mutex poisoned: a rank panicked mid-collective";
                            self.poison(msg);
                            bail!("{msg}");
                        }
                    }
                    d
                }
                ReduceOp::TakeRank0 => Duration::ZERO,
            };
            // Publish: the deadline is anchored after the reduction, exactly
            // like the sequential engine's CommHandle (the sum is "device
            // work", the deadline models only the link).
            let mut g = match self.inner.lock() {
                Ok(g) => g,
                Err(_) => {
                    let msg = "collective mutex poisoned: a rank panicked mid-collective";
                    self.poison(msg); // recovers the guard via into_inner
                    bail!("{msg}");
                }
            };
            let Some(round) = g.rounds.get_mut(&seq) else {
                let msg = format!("round {seq} vanished before publish");
                g.poisoned.get_or_insert_with(|| msg.clone());
                self.cv.notify_all();
                bail!("{msg}");
            };
            round.ready_at = Instant::now() + modeled;
            round.result = Some(Arc::new(result));
            self.cv.notify_all();
        }
        Ok(())
    }

    /// Block rank `rank` until round `seq` has rendezvoused *and* its
    /// modeled link deadline has passed. Returns the reduced tensor and this
    /// rank's exposed (non-overlapped) wait.
    pub fn wait(&self, rank: usize, seq: u64) -> Result<(Arc<HostTensor>, Duration)> {
        if rank >= self.tp {
            bail!("rank {rank} out of range for tp={}", self.tp);
        }
        let mut g = lock_or_err(&self.inner, "collective")?;
        let (result, ready_at) = loop {
            if let Some(msg) = &g.poisoned {
                bail!("collective poisoned: {msg}");
            }
            if let Some(round) = g.rounds.get(&seq) {
                if let Some(r) = &round.result {
                    break (r.clone(), round.ready_at);
                }
            }
            g = match self.cv.wait(g) {
                Ok(g) => g,
                Err(_) => {
                    bail!("collective mutex poisoned: a rank panicked mid-collective")
                }
            };
        };
        drop(g); // sleep outside the lock: sibling rounds keep rendezvousing

        let now = Instant::now();
        let exposed = if now < ready_at {
            let d = ready_at - now;
            spin_sleep(d);
            d
        } else {
            Duration::ZERO
        };

        let mut g = lock_or_err(&self.inner, "collective")?;
        let Some(round) = g.rounds.get_mut(&seq) else {
            // A peer retired the round early only if bookkeeping broke;
            // nobody is blocked on us, so a plain error is safe here.
            bail!("round {seq} retired before all ranks waited");
        };
        if exposed > round.exposed_max {
            // incrementally raise the recorded per-round exposed time to the
            // max across ranks — the collective's critical-path exposure
            if round.op == ReduceOp::Sum {
                let delta = exposed - round.exposed_max;
                lock_or_err(&self.stats, "stats")?.charge_exposed(delta);
            }
            round.exposed_max = exposed;
        }
        round.waited += 1;
        if round.waited == self.tp {
            g.rounds.remove(&seq);
        }
        Ok((result, exposed))
    }

    /// Mark the collective as failed and wake every blocked rank. Used by a
    /// worker that errors mid-forward so siblings blocked in [`wait`] fail
    /// fast instead of deadlocking.
    ///
    /// [`wait`]: SharedCollective::wait
    pub fn poison(&self, msg: &str) {
        // Must succeed even when a panicking rank poisoned the std mutex —
        // this is the path that un-wedges everyone else.
        let mut g = match self.inner.lock() {
            Ok(g) => g,
            Err(e) => e.into_inner(),
        };
        if g.poisoned.is_none() {
            g.poisoned = Some(msg.to_string());
        }
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::interconnect::Fabric;
    use std::thread;

    fn coll(tp: usize, fabric: Fabric) -> Arc<SharedCollective> {
        Arc::new(SharedCollective::new(
            tp,
            Interconnect::new(fabric),
            Codec::Fp32,
            Arc::new(Mutex::new(CommStats::default())),
        ))
    }

    fn t(v: &[f32]) -> HostTensor {
        HostTensor::new(vec![v.len()], v.to_vec())
    }

    #[test]
    fn sums_in_rank_order_across_threads() {
        let c = coll(3, Fabric::Local);
        let mut handles = Vec::new();
        for rank in 0..3usize {
            let c = c.clone();
            handles.push(thread::spawn(move || {
                let part = t(&[10f32.powi(rank as i32), 2.0 * 10f32.powi(rank as i32)]);
                c.deposit(rank, 0, part, ReduceOp::Sum).unwrap();
                let (out, _) = c.wait(rank, 0).unwrap();
                out.data.clone()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![111.0, 222.0]);
        }
    }

    #[test]
    fn take_rank0_broadcasts_and_is_unmetered() {
        let stats = Arc::new(Mutex::new(CommStats::default()));
        let c = Arc::new(SharedCollective::new(
            2,
            Interconnect::new(Fabric::Custom(2000, 1)),
            Codec::Fp32,
            stats.clone(),
        ));
        let c2 = c.clone();
        let h = thread::spawn(move || {
            c2.deposit(1, 0, t(&[9.0]), ReduceOp::TakeRank0).unwrap();
            let (out, _) = c2.wait(1, 0).unwrap();
            out.data.clone()
        });
        c.deposit(0, 0, t(&[5.0]), ReduceOp::TakeRank0).unwrap();
        let (out, _) = c.wait(0, 0).unwrap();
        assert_eq!(out.data, vec![5.0]);
        assert_eq!(h.join().unwrap(), vec![5.0]);
        let s = stats.lock().unwrap();
        assert_eq!(s.allreduce_count, 0);
        assert_eq!(s.modeled_total, Duration::ZERO);
    }

    #[test]
    fn stats_count_once_per_round() {
        let stats = Arc::new(Mutex::new(CommStats::default()));
        let c = Arc::new(SharedCollective::new(
            2,
            Interconnect::new(Fabric::Local),
            Codec::Fp32,
            stats.clone(),
        ));
        let c2 = c.clone();
        let h = thread::spawn(move || {
            c2.deposit(1, 0, t(&[1.0; 8]), ReduceOp::Sum).unwrap();
            c2.wait(1, 0).unwrap();
        });
        c.deposit(0, 0, t(&[1.0; 8]), ReduceOp::Sum).unwrap();
        c.wait(0, 0).unwrap();
        h.join().unwrap();
        let s = stats.lock().unwrap();
        assert_eq!(s.allreduce_count, 1);
        assert_eq!(s.bytes_moved, 32);
    }

    #[test]
    fn poison_wakes_blocked_waiters() {
        let c = coll(2, Fabric::Local);
        let c2 = c.clone();
        let h = thread::spawn(move || {
            c2.deposit(0, 0, t(&[1.0]), ReduceOp::Sum).unwrap();
            c2.wait(0, 0) // blocks: rank 1 never deposits
        });
        std::thread::sleep(Duration::from_millis(20));
        c.poison("rank 1 exploded");
        let res = h.join().unwrap();
        assert!(res.is_err());
        assert!(res.unwrap_err().to_string().contains("rank 1 exploded"));
        // and later deposits fail fast too
        assert!(c.deposit(1, 0, t(&[1.0]), ReduceOp::Sum).is_err());
    }

    #[test]
    fn rejects_double_deposit_and_bad_shapes() {
        let c = coll(2, Fabric::Local);
        c.deposit(0, 0, t(&[1.0, 2.0]), ReduceOp::Sum).unwrap();
        assert!(c.deposit(0, 0, t(&[1.0, 2.0]), ReduceOp::Sum).is_err());
        assert!(c.deposit(1, 0, t(&[1.0]), ReduceOp::Sum).is_err());
        assert!(c.deposit(1, 1, t(&[1.0]), ReduceOp::TakeRank0).is_ok());
        // op mismatch on an open round
        assert!(c.deposit(0, 1, t(&[1.0]), ReduceOp::Sum).is_err());
    }

    #[test]
    fn deadline_is_charged_from_the_rendezvous() {
        // 2ms modeled latency: the waiting rank should expose ~all of it
        // when it waits immediately after the rendezvous completes.
        let c = coll2ms();
        let c2 = c.clone();
        let h = thread::spawn(move || {
            c2.deposit(1, 0, t(&[1.0; 64]), ReduceOp::Sum).unwrap();
            let (_, exposed) = c2.wait(1, 0).unwrap();
            exposed
        });
        c.deposit(0, 0, t(&[1.0; 64]), ReduceOp::Sum).unwrap();
        let (_, exposed) = c.wait(0, 0).unwrap();
        let other = h.join().unwrap();
        assert!(
            exposed >= Duration::from_millis(1) || other >= Duration::from_millis(1),
            "{exposed:?} / {other:?}"
        );
    }

    fn coll2ms() -> Arc<SharedCollective> {
        Arc::new(SharedCollective::new(
            2,
            Interconnect::new(Fabric::Custom(2000, 1)),
            Codec::Fp32,
            Arc::new(Mutex::new(CommStats::default())),
        ))
    }

    #[test]
    fn quantized_rendezvous_matches_sequential_engine_bitwise() {
        use crate::comm::collective::CollectiveEngine;
        for codec in [Codec::Fp32, Codec::Int8, Codec::Int4] {
            let parts: Vec<HostTensor> = (0..3)
                .map(|r| {
                    t(&(0..70)
                        .map(|i| ((i * 13 + r * 7) % 29) as f32 - 14.0)
                        .collect::<Vec<_>>())
                })
                .collect();
            let seq = CollectiveEngine::with_codec(3, Interconnect::new(Fabric::Local), codec);
            let (oracle, _) = seq.allreduce(parts.clone()).unwrap().wait();

            let stats = Arc::new(Mutex::new(CommStats::default()));
            let c = Arc::new(SharedCollective::new(
                3,
                Interconnect::new(Fabric::Local),
                codec,
                stats.clone(),
            ));
            let mut handles = Vec::new();
            for (rank, part) in parts.into_iter().enumerate() {
                let c = c.clone();
                handles.push(thread::spawn(move || {
                    c.deposit(rank, 0, part, ReduceOp::Sum).unwrap();
                    let (out, _) = c.wait(rank, 0).unwrap();
                    out.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                }));
            }
            let oracle_bits: Vec<u32> = oracle.data.iter().map(|v| v.to_bits()).collect();
            for h in handles {
                assert_eq!(h.join().unwrap(), oracle_bits, "{codec:?}");
            }
            let s = stats.lock().unwrap();
            assert_eq!(s.bytes_moved, codec.wire_bytes(70), "{codec:?}");
            assert_eq!(s.bytes_raw, 70 * 4);
        }
    }
}
