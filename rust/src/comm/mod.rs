//! Communication layer: interconnect cost models, the collective engine
//! (real sum-reduction across rank partials + simulated link latency),
//! pluggable wire codecs (fp32 passthrough / int8 / int4 per-block
//! quantization), async completion handles that make the Ladder overlap
//! measurable, and the rendezvous collective the threaded rank runtime
//! synchronizes on. See docs/ARCHITECTURE.md, "Communication layer".

pub mod codec;
pub mod collective;
pub mod handle;
pub mod interconnect;
pub mod rendezvous;

pub use codec::Codec;
pub use collective::{CollectiveEngine, CommPhase, CommStats};
pub use handle::CommHandle;
pub use interconnect::{Fabric, Interconnect, TwoTier};
pub use rendezvous::{ReduceOp, SharedCollective};
