//! Communication layer: interconnect cost models, the collective engine
//! (real sum-reduction across rank partials + simulated link latency), and
//! async completion handles that make the Ladder overlap measurable.

pub mod collective;
pub mod handle;
pub mod interconnect;

pub use collective::{CollectiveEngine, CommStats};
pub use handle::CommHandle;
pub use interconnect::{Fabric, Interconnect};
