//! Communication layer: interconnect cost models, the collective engine
//! (real sum-reduction across rank partials + simulated link latency),
//! async completion handles that make the Ladder overlap measurable, and
//! the rendezvous collective the threaded rank runtime synchronizes on.

pub mod collective;
pub mod handle;
pub mod interconnect;
pub mod rendezvous;

pub use collective::{CollectiveEngine, CommStats};
pub use handle::CommHandle;
pub use interconnect::{Fabric, Interconnect};
pub use rendezvous::{ReduceOp, SharedCollective};
