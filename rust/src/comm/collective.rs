//! The collective engine: real deterministic sum-reduction across rank
//! partials, with modeled link time charged via [`CommHandle`] deadlines.
//!
//! Statistics distinguish *total* modeled comm time from *exposed* comm time
//! (the part `wait()` actually had to sleep) — the exposed/total ratio is the
//! direct measure of how much latency the Ladder schedule hides (paper
//! Fig. 6's NCCL-blocking-vs-overlapped story, as a number).

use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::codec::Codec;
use super::handle::CommHandle;
use super::interconnect::Interconnect;
use super::rendezvous::SharedCollective;
use crate::model::HostTensor;

/// Which forward phase collectives are currently attributed to. The engine
/// flips the marker at the top of each forward (forwards are synchronous, so
/// the marker never races the collectives it labels).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub enum CommPhase {
    #[default]
    Prefill,
    Decode,
}

/// Aggregate comm statistics (shared across a generation run).
#[derive(Debug, Default, Clone)]
pub struct CommStats {
    pub allreduce_count: usize,
    pub allgather_count: usize,
    /// Bytes charged to the modeled link — the *encoded* payload when a
    /// quantizing [`Codec`] is active.
    pub bytes_moved: usize,
    /// Uncompressed payload bytes (`numel * 4` per collective). Equal to
    /// `bytes_moved` under the default fp32 codec; the `bytes_raw /
    /// bytes_moved` ratio is the realized compression factor.
    pub bytes_raw: usize,
    /// Encoded bytes carried by intra-node links (= all of `bytes_moved` on
    /// a flat fabric; the reduce-scatter/allgather ring traffic on a
    /// two-tier fabric — see `Interconnect::allreduce_tier_bytes`).
    pub bytes_intra: usize,
    /// Encoded bytes carried by cross-node links (0 on a flat fabric).
    pub bytes_cross: usize,
    pub modeled_total: Duration,
    pub exposed_total: Duration,
    /// Per-phase slices of the modeled/exposed ledgers, keyed by the phase
    /// marker active when each collective ran.
    pub prefill_modeled: Duration,
    pub prefill_exposed: Duration,
    pub decode_modeled: Duration,
    pub decode_exposed: Duration,
    /// Current attribution marker (set via `CollectiveEngine::set_phase`).
    pub phase: CommPhase,
}

impl CommStats {
    /// Fraction of modeled comm time hidden behind compute, clamped to 0..1.
    /// Exposed time is measured with real sleeps, so OS scheduling jitter
    /// can push it slightly past the modeled total — that must read as
    /// "nothing hidden", never as a negative fraction.
    pub fn hidden_fraction(&self) -> f64 {
        Self::hidden(self.modeled_total, self.exposed_total)
    }

    /// Hidden fraction of collectives issued during prefill forwards.
    pub fn hidden_fraction_prefill(&self) -> f64 {
        Self::hidden(self.prefill_modeled, self.prefill_exposed)
    }

    /// Hidden fraction of collectives issued during decode forwards — the
    /// phase the ladder/overlap schedules target.
    pub fn hidden_fraction_decode(&self) -> f64 {
        Self::hidden(self.decode_modeled, self.decode_exposed)
    }

    fn hidden(modeled: Duration, exposed: Duration) -> f64 {
        if modeled.is_zero() {
            return 0.0;
        }
        (1.0 - exposed.as_secs_f64() / modeled.as_secs_f64()).clamp(0.0, 1.0)
    }

    /// Charge one collective's modeled time to the total and current-phase
    /// ledgers.
    pub(crate) fn charge_modeled(&mut self, modeled: Duration) {
        self.modeled_total += modeled;
        match self.phase {
            CommPhase::Prefill => self.prefill_modeled += modeled,
            CommPhase::Decode => self.decode_modeled += modeled,
        }
    }

    /// Charge measured exposed wait time to the total and current-phase
    /// ledgers.
    pub(crate) fn charge_exposed(&mut self, exposed: Duration) {
        self.exposed_total += exposed;
        match self.phase {
            CommPhase::Prefill => self.prefill_exposed += exposed,
            CommPhase::Decode => self.decode_exposed += exposed,
        }
    }
}

/// Engine performing collectives over the N simulated ranks.
///
/// Statistics live behind an `Arc` so the threaded runtime's rendezvous
/// collective (created with [`CollectiveEngine::rendezvous`]) reports into
/// the same ledger as the coordinator-side AllGather.
pub struct CollectiveEngine {
    pub tp: usize,
    pub interconnect: Interconnect,
    codec: Codec,
    stats: Arc<Mutex<CommStats>>,
}

impl CollectiveEngine {
    pub fn new(tp: usize, interconnect: Interconnect) -> CollectiveEngine {
        CollectiveEngine::with_codec(tp, interconnect, Codec::default())
    }

    pub fn with_codec(tp: usize, interconnect: Interconnect, codec: Codec) -> CollectiveEngine {
        CollectiveEngine { tp, interconnect, codec, stats: Arc::new(Mutex::new(CommStats::default())) }
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Lock the stats ledger from a fallible collective, mapping a
    /// poisoned mutex (a sibling rank panicked mid-collective) to an
    /// error the serve loop can fail one request with — same contract as
    /// `rendezvous::lock_or_err`, instead of a cascading panic.
    fn stats_lock(&self) -> Result<MutexGuard<'_, CommStats>> {
        self.stats
            .lock()
            .map_err(|_| anyhow!("comm stats mutex poisoned: a rank panicked mid-collective"))
    }

    /// Lock the stats ledger from an infallible accessor. The counters
    /// are plain data (no invariant spans the panic point), so recovering
    /// the guard is safe — the poison-recovery pattern of
    /// `comm/rendezvous.rs`.
    fn stats_recover(&self) -> MutexGuard<'_, CommStats> {
        self.stats.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Build the worker-facing rendezvous collective sharing this engine's
    /// interconnect model, wire codec, and stats ledger.
    pub fn rendezvous(&self) -> Arc<SharedCollective> {
        Arc::new(SharedCollective::new(self.tp, self.interconnect, self.codec, self.stats.clone()))
    }

    /// Launch an AllReduce over per-rank partial tensors. Each partial takes
    /// the codec's quantize→dequantize wire roundtrip, then the sum is
    /// performed now (deterministic rank order: 0,1,2,...); the handle
    /// completes at the modeled link deadline, which is charged the
    /// *encoded* byte count.
    pub fn allreduce(&self, partials: Vec<HostTensor>) -> Result<CommHandle> {
        if partials.len() != self.tp {
            bail!("allreduce got {} partials for tp={}", partials.len(), self.tp);
        }
        let mut iter = partials.into_iter();
        let Some(mut acc) = iter.next() else {
            bail!("allreduce needs at least one partial (tp >= 1)");
        };
        if self.tp > 1 {
            // tp=1 never touches a wire — the codec must not perturb it.
            self.codec.transport(&mut acc);
        }
        for mut p in iter {
            if p.shape != acc.shape {
                bail!("allreduce shape mismatch: {:?} vs {:?}", p.shape, acc.shape);
            }
            self.codec.transport(&mut p);
            for (a, b) in acc.data.iter_mut().zip(&p.data) {
                *a += b;
            }
        }
        let raw = acc.numel() * 4;
        let bytes = if self.tp > 1 { self.codec.wire_bytes(acc.numel()) } else { raw };
        let modeled = Duration::from_secs_f64(self.interconnect.allreduce_time(bytes, self.tp));
        let (intra, cross) = self.interconnect.allreduce_tier_bytes(bytes, self.tp);
        {
            let mut s = self.stats_lock()?;
            s.allreduce_count += 1;
            s.bytes_moved += bytes;
            s.bytes_raw += raw;
            s.bytes_intra += intra;
            s.bytes_cross += cross;
            s.charge_modeled(modeled);
        }
        Ok(if self.tp == 1 {
            CommHandle::ready(acc)
        } else {
            CommHandle::new(acc, modeled)
        })
    }

    /// AllGather along the last axis (lm-head vocab shards). Blocking (it is
    /// the last op before sampling; nothing to overlap with). Always fp32 on
    /// the wire: the payload is vocab logits, where quantization would
    /// perturb sampling directly — the codec applies only to AllReduce.
    pub fn allgather_concat(&self, shards: Vec<HostTensor>) -> Result<HostTensor> {
        if shards.len() != self.tp {
            bail!("allgather got {} shards for tp={}", shards.len(), self.tp);
        }
        let shape = shards[0].shape.clone();
        let rows: usize = shape[..shape.len() - 1].iter().product();
        let cols = shape[shape.len() - 1];
        let bytes = rows * cols * 4;
        let modeled =
            Duration::from_secs_f64(self.interconnect.allgather_time(bytes, self.tp));
        let mut out = Vec::with_capacity(rows * cols * self.tp);
        for r in 0..rows {
            for s in &shards {
                if s.shape != shape {
                    bail!("allgather shape mismatch");
                }
                out.extend_from_slice(&s.data[r * cols..(r + 1) * cols]);
            }
        }
        let mut new_shape = shape;
        *new_shape
            .last_mut()
            .ok_or_else(|| anyhow!("allgather shards must be shaped (rank >= 1)"))? =
            cols * self.tp;
        let handle = if self.tp == 1 {
            CommHandle::ready(HostTensor::new(new_shape, out))
        } else {
            CommHandle::new(HostTensor::new(new_shape, out), modeled)
        };
        let (t, exposed) = handle.wait();
        let (intra, cross) = self.interconnect.allgather_tier_bytes(bytes * self.tp, self.tp);
        let mut s = self.stats_lock()?;
        s.allgather_count += 1;
        s.bytes_moved += bytes * self.tp;
        s.bytes_raw += bytes * self.tp;
        s.bytes_intra += intra;
        s.bytes_cross += cross;
        s.charge_modeled(modeled);
        s.charge_exposed(exposed);
        Ok(t)
    }

    /// Record the exposed wait time returned by a `CommHandle::wait`.
    pub fn record_exposed(&self, exposed: Duration) {
        self.stats_recover().charge_exposed(exposed);
    }

    /// Flip the phase marker collectives are attributed to (prefill/decode
    /// ledger slices). Called by the engine at the top of each forward.
    pub fn set_phase(&self, phase: CommPhase) {
        self.stats_recover().phase = phase;
    }

    pub fn stats(&self) -> CommStats {
        self.stats_recover().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats_recover() = CommStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::interconnect::Fabric;

    fn t(v: &[f32]) -> HostTensor {
        HostTensor::new(vec![v.len()], v.to_vec())
    }

    fn engine(tp: usize) -> CollectiveEngine {
        CollectiveEngine::new(tp, Interconnect::new(Fabric::Local))
    }

    #[test]
    fn allreduce_sums_in_rank_order() {
        let e = engine(3);
        let h = e.allreduce(vec![t(&[1., 2.]), t(&[10., 20.]), t(&[100., 200.])]).unwrap();
        let (out, _) = h.wait();
        assert_eq!(out.data, vec![111., 222.]);
        assert_eq!(e.stats().allreduce_count, 1);
    }

    #[test]
    fn allreduce_single_rank_is_identity() {
        let e = engine(1);
        let (out, exposed) = e.allreduce(vec![t(&[3., 4.])]).unwrap().wait();
        assert_eq!(out.data, vec![3., 4.]);
        assert_eq!(exposed, Duration::ZERO);
    }

    #[test]
    fn allreduce_rejects_wrong_count_or_shape() {
        let e = engine(2);
        assert!(e.allreduce(vec![t(&[1.])]).is_err());
        let bad = vec![t(&[1., 2.]), HostTensor::new(vec![1, 2], vec![1., 2.])];
        assert!(e.allreduce(bad).is_err());
    }

    #[test]
    fn allgather_interleaves_rows() {
        let e = engine(2);
        let a = HostTensor::new(vec![2, 2], vec![1., 2., 5., 6.]);
        let b = HostTensor::new(vec![2, 2], vec![3., 4., 7., 8.]);
        let out = e.allgather_concat(vec![a, b]).unwrap();
        assert_eq!(out.shape, vec![2, 4]);
        assert_eq!(out.data, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
    }

    #[test]
    fn stats_track_bytes() {
        let e = engine(2);
        e.allreduce(vec![t(&[0.; 8]), t(&[0.; 8])]).unwrap().wait();
        assert_eq!(e.stats().bytes_moved, 32);
        assert_eq!(e.stats().bytes_raw, 32);
    }

    #[test]
    fn fp32_codec_is_bitwise_identical_to_default() {
        let parts: Vec<HostTensor> =
            (0..3).map(|r| t(&[(r as f32 + 0.3) * 1.7, -0.913 * r as f32, 1e-4])).collect();
        let (a, _) = engine(3).allreduce(parts.clone()).unwrap().wait();
        let e = CollectiveEngine::with_codec(3, Interconnect::new(Fabric::Local), Codec::Fp32);
        let (b, _) = e.allreduce(parts).unwrap().wait();
        let bits = |h: &HostTensor| h.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b));
        assert_eq!(e.stats().bytes_moved, e.stats().bytes_raw);
    }

    #[test]
    fn quantized_allreduce_sums_transported_partials() {
        // The reduction must equal: transport each partial, then sum in rank
        // order — not "sum then transport".
        let parts: Vec<HostTensor> =
            (0..2).map(|r| t(&(0..70).map(|i| (i as f32 - 35.0) * (r as f32 + 0.5)).collect::<Vec<_>>())).collect();
        let e = CollectiveEngine::with_codec(2, Interconnect::new(Fabric::Local), Codec::Int8);
        let (out, _) = e.allreduce(parts.clone()).unwrap().wait();
        let mut expect = parts;
        for p in &mut expect {
            Codec::Int8.transport(p);
        }
        let mut acc = expect.remove(0);
        for (a, b) in acc.data.iter_mut().zip(&expect[0].data) {
            *a += b;
        }
        assert_eq!(
            out.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            acc.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn quantized_codec_charges_compressed_bytes() {
        let parts = vec![t(&[1.0; 128]), t(&[2.0; 128])];
        let e = CollectiveEngine::with_codec(2, Interconnect::new(Fabric::Local), Codec::Int4);
        e.allreduce(parts).unwrap().wait();
        let s = e.stats();
        assert_eq!(s.bytes_raw, 128 * 4);
        assert_eq!(s.bytes_moved, Codec::Int4.wire_bytes(128));
        assert!(s.bytes_moved < s.bytes_raw);
    }

    #[test]
    fn single_rank_skips_the_codec() {
        let vals = [0.1234f32, -9.87, 3.3];
        let e = CollectiveEngine::with_codec(1, Interconnect::new(Fabric::Local), Codec::Int4);
        let (out, _) = e.allreduce(vec![t(&vals)]).unwrap().wait();
        assert_eq!(
            out.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn hidden_fraction_clamps_to_unit_interval() {
        // OS jitter can make measured exposed time exceed the modeled total;
        // the fraction must clamp to 0 rather than go negative.
        let s = CommStats {
            modeled_total: Duration::from_micros(100),
            exposed_total: Duration::from_micros(150),
            ..CommStats::default()
        };
        assert_eq!(s.hidden_fraction(), 0.0);
        let s = CommStats {
            modeled_total: Duration::from_micros(100),
            exposed_total: Duration::ZERO,
            ..CommStats::default()
        };
        assert_eq!(s.hidden_fraction(), 1.0);
    }

    #[test]
    fn tier_ledger_splits_on_two_tier_fabric() {
        let flat = engine(2);
        flat.allreduce(vec![t(&[0.; 8]), t(&[0.; 8])]).unwrap().wait();
        let s = flat.stats();
        assert_eq!(s.bytes_intra, s.bytes_moved);
        assert_eq!(s.bytes_cross, 0);

        let ic = Interconnect::parse("two_tier:local:slow:1").unwrap();
        let e = CollectiveEngine::new(2, ic);
        e.allreduce(vec![t(&[0.; 8]), t(&[0.; 8])]).unwrap().wait();
        let s = e.stats();
        assert_eq!(s.bytes_intra, 0);
        assert_eq!(s.bytes_cross, 32);
    }

    #[test]
    fn phase_marker_slices_the_ledgers() {
        let e = CollectiveEngine::new(2, Interconnect::new(Fabric::Custom(500, 1)));
        e.set_phase(CommPhase::Prefill);
        let h = e.allreduce(vec![t(&[1.0; 16]), t(&[1.0; 16])]).unwrap();
        e.record_exposed(h.wait().1);
        e.set_phase(CommPhase::Decode);
        let h = e.allreduce(vec![t(&[1.0; 16]), t(&[1.0; 16])]).unwrap();
        e.record_exposed(h.wait().1);
        let s = e.stats();
        assert!(s.prefill_modeled > Duration::ZERO);
        assert!(s.decode_modeled > Duration::ZERO);
        assert_eq!(s.prefill_modeled + s.decode_modeled, s.modeled_total);
        assert_eq!(s.prefill_exposed + s.decode_exposed, s.exposed_total);
    }

    #[test]
    fn exposed_latency_recorded_when_blocking() {
        let e = CollectiveEngine::new(2, Interconnect::new(Fabric::Custom(2000, 1)));
        let h = e.allreduce(vec![t(&[1.0; 64]), t(&[1.0; 64])]).unwrap();
        let (_, exposed) = h.wait();
        e.record_exposed(exposed);
        assert!(e.stats().exposed_total >= Duration::from_millis(1));
        assert!(e.stats().hidden_fraction() < 0.5);
    }
}
