//! Async completion handles for collectives.
//!
//! The reduction itself is performed eagerly on the host (it is part of this
//! testbed's "device" work), but the modeled link time is charged as a
//! deadline: `wait()` sleeps until the modeled completion instant. Compute
//! issued between `launch` and `wait` therefore genuinely hides the link
//! time — on any core count — exactly like NCCL's comm stream hides behind
//! CUDA compute in the paper's Figure 6 traces.

use std::time::{Duration, Instant};

use crate::model::HostTensor;

/// Handle to an in-flight AllReduce/AllGather.
#[derive(Debug)]
pub struct CommHandle {
    /// The reduced tensor (already computed; semantically "arrives" at
    /// `ready_at`).
    result: HostTensor,
    launched_at: Instant,
    ready_at: Instant,
    /// Modeled link duration (for stats).
    pub modeled: Duration,
}

impl CommHandle {
    pub fn new(result: HostTensor, modeled: Duration) -> CommHandle {
        let now = Instant::now();
        CommHandle { result, launched_at: now, ready_at: now + modeled, modeled }
    }

    /// An already-complete handle (TP=1 / upper-bound paths).
    pub fn ready(result: HostTensor) -> CommHandle {
        let now = Instant::now();
        CommHandle { result, launched_at: now, ready_at: now, modeled: Duration::ZERO }
    }

    /// Block until the modeled completion time; returns the reduced tensor
    /// and the *exposed* (non-overlapped) wait duration.
    pub fn wait(self) -> (HostTensor, Duration) {
        let now = Instant::now();
        let exposed = if now < self.ready_at {
            let d = self.ready_at - now;
            spin_sleep(d);
            d
        } else {
            Duration::ZERO
        };
        (self.result, exposed)
    }

    /// True if the modeled transfer has already completed.
    pub fn is_ready(&self) -> bool {
        Instant::now() >= self.ready_at
    }

    /// Time since launch (for traces).
    pub fn age(&self) -> Duration {
        Instant::now() - self.launched_at
    }

    /// (launch, modeled-completion) instants — the link-occupancy span for
    /// execution traces.
    pub fn span(&self) -> (Instant, Instant) {
        (self.launched_at, self.ready_at)
    }
}

/// Sleep with sub-millisecond fidelity: OS sleep (park) for the bulk, then
/// spin only the final ~50us. Plain `thread::sleep` has ~50-100us jitter
/// which would swamp the microsecond-scale comm times of the tiny testbed
/// configs, so deadlines short enough for that jitter to dominate are
/// busy-waited exactly as before — but anything longer parks, because under
/// the threaded rank runtime a rank burning a core on a modeled deadline
/// steals cycles from sibling ranks' compute.
pub(crate) fn spin_sleep(d: Duration) {
    /// Busy-wait tail after the park (absorbs scheduler wakeup latency).
    const SPIN_WINDOW: Duration = Duration::from_micros(50);
    /// Below this, OS sleep jitter dominates the deadline: pure spin.
    const MIN_PARK: Duration = Duration::from_micros(300);
    let target = Instant::now() + d;
    if d > MIN_PARK {
        std::thread::sleep(d - SPIN_WINDOW);
    }
    while Instant::now() < target {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> HostTensor {
        HostTensor::new(vec![2], vec![1.0, 2.0])
    }

    #[test]
    fn blocking_wait_exposes_full_latency() {
        let h = CommHandle::new(t(), Duration::from_millis(5));
        let (out, exposed) = h.wait();
        assert_eq!(out.data, vec![1.0, 2.0]);
        assert!(exposed >= Duration::from_millis(4), "{exposed:?}");
    }

    #[test]
    fn overlapped_wait_exposes_nothing() {
        let h = CommHandle::new(t(), Duration::from_millis(3));
        std::thread::sleep(Duration::from_millis(5)); // "compute"
        assert!(h.is_ready());
        let (_, exposed) = h.wait();
        assert_eq!(exposed, Duration::ZERO);
    }

    #[test]
    fn ready_handle_is_instant() {
        let (_, exposed) = CommHandle::ready(t()).wait();
        assert_eq!(exposed, Duration::ZERO);
    }
}
