//! Interconnect cost models (the hardware substitution for NVLink / PCIe /
//! InfiniBand fabrics the paper benchmarks on).
//!
//! AllReduce cost uses the standard alpha-beta model. For a ring AllReduce
//! over `n` devices and message size `B` bytes:
//!
//!   t = 2 (n-1) * alpha_hop + 2 (n-1)/n * B / bw
//!
//! With SHARP (in-switch reduction, paper's NVLink runs set
//! NCCL_NVLS_ENABLE=1) the latency term collapses to a one-shot:
//!
//!   t = alpha_sharp + B / bw
//!
//! Fabric constants follow public H100/DGX specs; what matters for the
//! reproduction is the comm/compute *ratio* per fabric class, not the
//! absolute numbers (see DESIGN.md substitutions).

/// A fabric class the paper benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Fabric {
    /// NVLink 4 (+SHARP): 450 GB/s per-GPU bandwidth, sub-10us latency.
    NvLink,
    /// PCIe Gen5 fallback (paper's "No NVLink", NCCL_P2P_DISABLE=1).
    Pcie,
    /// Cross-node InfiniBand (NDR 400): used by the paper's 405B TP16 runs.
    InfiniBand,
    /// Single-device: communication is the identity (zero cost).
    Local,
    /// Custom (latency_us, bandwidth_GBps) — for sweeps/ablations.
    Custom(u32, u32),
}

/// Cross-node tier of a hierarchical (two-tier) topology: ranks are grouped
/// into nodes of `gpus_per_node`, joined intra-node by the host
/// [`Interconnect`]'s own link and across nodes by `cross`.
#[derive(Debug, Clone, Copy)]
pub struct TwoTier {
    /// Fabric class of the cross-node links.
    pub cross: Fabric,
    /// Ranks per node (the intra-tier group size).
    pub gpus_per_node: usize,
}

/// Cost model for one fabric.
#[derive(Debug, Clone, Copy)]
pub struct Interconnect {
    pub fabric: Fabric,
    /// Per-hop latency (seconds).
    pub alpha: f64,
    /// Algorithm bandwidth per device (bytes/second).
    pub bandwidth: f64,
    /// One-shot in-switch reduction (SHARP) instead of ring.
    pub sharp: bool,
    /// Hierarchical topology: when set, collectives over more ranks than
    /// one node decompose into reduce-scatter (intra) -> allreduce (cross)
    /// -> allgather (intra). `None` = flat single-tier fabric.
    pub two_tier: Option<TwoTier>,
}

impl Interconnect {
    pub fn new(fabric: Fabric) -> Interconnect {
        match fabric {
            // alpha is the *end-to-end* NCCL small-message AllReduce
            // latency (protocol + launch), not the wire latency: ~18us for
            // NVLS/SHARP one-shot on 8 GPUs, ~60us via shared-memory
            // fallback with P2P disabled (the paper's "No NVLink"), ~25us
            // per hop over NDR InfiniBand.
            Fabric::NvLink => Interconnect {
                fabric,
                alpha: 18e-6,
                bandwidth: 450e9,
                sharp: true,
                two_tier: None,
            },
            Fabric::Pcie => Interconnect {
                fabric,
                alpha: 5e-6,
                bandwidth: 40e9,
                sharp: false,
                two_tier: None,
            },
            Fabric::InfiniBand => Interconnect {
                fabric,
                alpha: 25e-6,
                bandwidth: 45e9,
                sharp: false,
                two_tier: None,
            },
            Fabric::Local => Interconnect {
                fabric,
                alpha: 0.0,
                bandwidth: f64::INFINITY,
                sharp: true,
                two_tier: None,
            },
            Fabric::Custom(lat_us, bw_gbps) => Interconnect {
                fabric,
                alpha: lat_us as f64 * 1e-6,
                bandwidth: bw_gbps as f64 * 1e9,
                sharp: false,
                two_tier: None,
            },
        }
    }

    /// Attach a cross-node tier: `self`'s own link becomes the intra-node
    /// fabric of a [`TwoTier`] hierarchy.
    pub fn with_two_tier(mut self, cross: Fabric, gpus_per_node: usize) -> Interconnect {
        self.two_tier = Some(TwoTier { cross, gpus_per_node });
        self
    }

    /// Does the hierarchical decomposition apply for an `n`-rank collective?
    /// (A two-tier fabric with every rank on one node — or a group size
    /// that doesn't tile `n` — degrades to the flat intra link.)
    fn tiers(&self, n: usize) -> Option<(TwoTier, usize)> {
        let tt = self.two_tier?;
        if tt.gpus_per_node >= 1 && n % tt.gpus_per_node == 0 && n / tt.gpus_per_node > 1 {
            Some((tt, n / tt.gpus_per_node))
        } else {
            None
        }
    }

    /// Modeled AllReduce duration for `bytes` over `n` devices.
    ///
    /// On a flat fabric this is the alpha-beta model described above. On a
    /// two-tier fabric spanning more than one node it is the hierarchical
    /// decomposition:
    ///
    ///   reduce-scatter intra (g ranks, B)  ->  allreduce cross
    ///   (nodes, B/g shards)  ->  allgather intra (g ranks, B/g per rank)
    pub fn allreduce_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        if let Some((tt, nodes)) = self.tiers(n) {
            let g = tt.gpus_per_node;
            let shard = bytes / g;
            // ring reduce-scatter and allgather of B over g ranks move the
            // same (g-1) hops of B/g per rank — identical cost
            let intra_phase = self.flat_allgather_time(shard, g);
            let cross = Interconnect::new(tt.cross).flat_allreduce_time(shard, nodes);
            return 2.0 * intra_phase + cross;
        }
        self.flat_allreduce_time(bytes, n)
    }

    fn flat_allreduce_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 || matches!(self.fabric, Fabric::Local) {
            return 0.0;
        }
        let b = bytes as f64;
        if self.sharp {
            // one-shot in-switch reduction (NVLS/SHARP)
            self.alpha + b / self.bandwidth
        } else {
            // latency: tree depth (NCCL picks tree/SHM for small messages,
            // not the 2(n-1)-hop ring); bandwidth: ring algbw factor
            let hops = (n - 1) as f64;
            hops * self.alpha + 2.0 * hops / n as f64 * b / self.bandwidth
        }
    }

    /// Modeled AllGather duration (lm-head vocab shards). Two-tier fabrics
    /// gather intra-node first, then exchange node aggregates cross-node.
    pub fn allgather_time(&self, bytes_per_rank: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        if let Some((tt, nodes)) = self.tiers(n) {
            let g = tt.gpus_per_node;
            let intra = self.flat_allgather_time(bytes_per_rank, g);
            let cross =
                Interconnect::new(tt.cross).flat_allgather_time(bytes_per_rank * g, nodes);
            return intra + cross;
        }
        self.flat_allgather_time(bytes_per_rank, n)
    }

    fn flat_allgather_time(&self, bytes_per_rank: usize, n: usize) -> f64 {
        if n <= 1 || matches!(self.fabric, Fabric::Local) {
            return 0.0;
        }
        let hops = (n - 1) as f64;
        hops * self.alpha + hops * bytes_per_rank as f64 / self.bandwidth
    }

    /// Per-tier link traffic of one `n`-rank AllReduce carrying `bytes` of
    /// payload: `(bytes_intra, bytes_cross)`. Flat fabrics charge the whole
    /// payload to the intra tier. Two-tier fabrics charge the intra tier
    /// the reduce-scatter + allgather ring traffic (`2 (g-1)/g B`) and the
    /// cross tier the shard the node aggregates exchange (`B/g`).
    pub fn allreduce_tier_bytes(&self, bytes: usize, n: usize) -> (usize, usize) {
        if let Some((tt, _nodes)) = self.tiers(n) {
            let g = tt.gpus_per_node;
            let intra = 2 * (g - 1) * bytes / g;
            let cross = bytes / g;
            return (intra, cross);
        }
        (bytes, 0)
    }

    /// Per-tier link traffic of one `n`-rank AllGather of `total_bytes`
    /// gathered payload: all intra on a flat fabric; on a two-tier fabric
    /// the intra ring carries `(g-1)/g` of it and the cross exchange
    /// `(nodes-1)/nodes`.
    pub fn allgather_tier_bytes(&self, total_bytes: usize, n: usize) -> (usize, usize) {
        if let Some((tt, nodes)) = self.tiers(n) {
            let g = tt.gpus_per_node;
            let intra = (g - 1) * total_bytes / g;
            let cross = (nodes - 1) * total_bytes / nodes;
            return (intra, cross);
        }
        (total_bytes, 0)
    }

    pub fn name(&self) -> String {
        let base = Self::fabric_name(self.fabric);
        match self.two_tier {
            Some(tt) => format!(
                "two_tier({base},{},gpn={})",
                Self::fabric_name(tt.cross),
                tt.gpus_per_node
            ),
            None => base,
        }
    }

    fn fabric_name(fabric: Fabric) -> String {
        match fabric {
            Fabric::NvLink => "nvlink".into(),
            Fabric::Pcie => "pcie".into(),
            Fabric::InfiniBand => "infiniband".into(),
            Fabric::Local => "local".into(),
            Fabric::Custom(3000, 1) => "slow".into(),
            Fabric::Custom(l, b) => format!("custom({l}us,{b}GB/s)"),
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Interconnect> {
        if let Some(spec) = s.strip_prefix("custom:") {
            return Self::parse_custom(spec);
        }
        if let Some(spec) = s.strip_prefix("two_tier:") {
            return Self::parse_two_tier(spec);
        }
        Ok(Interconnect::new(Self::parse_named(s).map_err(|_| {
            anyhow::anyhow!(
                "unknown fabric {s:?} (nvlink|pcie|infiniband|local|slow|\
                 custom:<lat_us>:<gbps>|two_tier:<intra>:<cross>:<gpus_per_node>)"
            )
        })?))
    }

    fn parse_named(s: &str) -> anyhow::Result<Fabric> {
        Ok(match s {
            "nvlink" => Fabric::NvLink,
            "pcie" | "no-nvlink" => Fabric::Pcie,
            "infiniband" | "ib" => Fabric::InfiniBand,
            "local" | "none" => Fabric::Local,
            // "slow": a fabric whose latency is commensurate with this
            // CPU testbed's module times (ms-scale), so architecture
            // comparisons on the real engine show the paper's shape the
            // way GPU-scale modules vs NCCL latencies do.
            "slow" => Fabric::Custom(3000, 1),
            _ => anyhow::bail!("not a named fabric"),
        })
    }

    /// Parse the `<lat_us>:<gbps>` tail of a `custom:` fabric spec
    /// (`Fabric::Custom` for sweeps/ablations, e.g. `custom:250:32` = 250us
    /// per-hop latency at 32 GB/s).
    fn parse_custom(spec: &str) -> anyhow::Result<Interconnect> {
        let parts: Vec<&str> = spec.split(':').collect();
        let (lat, bw) = match parts.as_slice() {
            [lat, bw] => (*lat, *bw),
            _ => anyhow::bail!(
                "custom fabric needs exactly two fields, custom:<lat_us>:<gbps> — got \"custom:{spec}\""
            ),
        };
        let lat_us: u32 = lat.parse().map_err(|_| {
            anyhow::anyhow!("custom fabric latency {lat:?} is not a whole number of microseconds")
        })?;
        let bw_gbps: u32 = bw.parse().map_err(|_| {
            anyhow::anyhow!("custom fabric bandwidth {bw:?} is not a whole number of GB/s")
        })?;
        if bw_gbps == 0 {
            anyhow::bail!("custom fabric bandwidth must be at least 1 GB/s");
        }
        Ok(Interconnect::new(Fabric::Custom(lat_us, bw_gbps)))
    }

    /// Parse the `<intra>:<cross>:<gpus_per_node>` tail of a `two_tier:`
    /// fabric spec, e.g. `two_tier:nvlink:infiniband:8` = NVLink inside
    /// each 8-GPU node, InfiniBand between nodes. The tier fabrics must be
    /// named presets — a `custom:` spec contains colons and would be
    /// ambiguous inside the colon-separated fields.
    fn parse_two_tier(spec: &str) -> anyhow::Result<Interconnect> {
        let parts: Vec<&str> = spec.split(':').collect();
        let (intra, cross, gpn) = match parts.as_slice() {
            [intra, cross, gpn] => (*intra, *cross, *gpn),
            _ => anyhow::bail!(
                "two_tier fabric needs exactly three fields, \
                 two_tier:<intra>:<cross>:<gpus_per_node> — got \"two_tier:{spec}\" \
                 (tier fabrics are named presets: nvlink|pcie|infiniband|local|slow)"
            ),
        };
        let tier = |s: &str, which: &str| {
            Self::parse_named(s).map_err(|_| {
                anyhow::anyhow!(
                    "unknown two_tier {which} fabric {s:?} — named presets only \
                     (nvlink|pcie|infiniband|local|slow)"
                )
            })
        };
        let intra_fabric = tier(intra, "intra")?;
        let cross_fabric = tier(cross, "cross")?;
        let gpus_per_node: usize = gpn.parse().map_err(|_| {
            anyhow::anyhow!("two_tier gpus_per_node {gpn:?} is not a whole number")
        })?;
        if gpus_per_node == 0 {
            anyhow::bail!("two_tier gpus_per_node must be at least 1");
        }
        Ok(Interconnect::new(intra_fabric).with_two_tier(cross_fabric, gpus_per_node))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_is_free() {
        let ic = Interconnect::new(Fabric::Local);
        assert_eq!(ic.allreduce_time(1 << 20, 8), 0.0);
    }

    #[test]
    fn single_rank_is_free() {
        let ic = Interconnect::new(Fabric::NvLink);
        assert_eq!(ic.allreduce_time(1 << 20, 1), 0.0);
    }

    #[test]
    fn pcie_slower_than_nvlink() {
        let nv = Interconnect::new(Fabric::NvLink);
        let pcie = Interconnect::new(Fabric::Pcie);
        let bytes = 8192 * 4 * 4; // a typical decode message
        assert!(pcie.allreduce_time(bytes, 8) > nv.allreduce_time(bytes, 8));
    }

    #[test]
    fn monotone_in_bytes_and_ranks() {
        let ic = Interconnect::new(Fabric::Pcie);
        assert!(ic.allreduce_time(2 << 20, 8) > ic.allreduce_time(1 << 20, 8));
        assert!(ic.allreduce_time(1 << 20, 8) > ic.allreduce_time(1 << 20, 2));
    }

    #[test]
    fn parse_roundtrip() {
        assert!(Interconnect::parse("nvlink").is_ok());
        assert!(Interconnect::parse("warp-drive").is_err());
    }

    #[test]
    fn parse_custom_spec() {
        let ic = Interconnect::parse("custom:250:32").unwrap();
        assert_eq!(ic.fabric, Fabric::Custom(250, 32));
        assert_eq!(ic.alpha, 250e-6);
        assert_eq!(ic.bandwidth, 32e9);
        assert!(!ic.sharp);
        // zero latency is a valid ablation; zero bandwidth is not
        assert!(Interconnect::parse("custom:0:1").is_ok());
        assert!(Interconnect::parse("custom:5:0").is_err());
    }

    #[test]
    fn parse_custom_errors_are_targeted() {
        let err = |s: &str| Interconnect::parse(s).unwrap_err().to_string();
        assert!(err("custom:5").contains("exactly two fields"), "{}", err("custom:5"));
        assert!(err("custom:5:1:9").contains("exactly two fields"));
        assert!(err("custom:fast:1").contains("latency"));
        assert!(err("custom:5:wide").contains("bandwidth"));
        assert!(err("custom:-1:1").contains("latency"));
    }

    #[test]
    fn parse_two_tier_spec() {
        let ic = Interconnect::parse("two_tier:nvlink:infiniband:8").unwrap();
        assert_eq!(ic.fabric, Fabric::NvLink);
        let tt = ic.two_tier.unwrap();
        assert_eq!(tt.cross, Fabric::InfiniBand);
        assert_eq!(tt.gpus_per_node, 8);
        assert_eq!(ic.name(), "two_tier(nvlink,infiniband,gpn=8)");
        // gpn=1 is valid: every rank its own node, all traffic cross-tier
        let solo = Interconnect::parse("two_tier:local:slow:1").unwrap();
        assert_eq!(solo.two_tier.unwrap().gpus_per_node, 1);
    }

    #[test]
    fn parse_two_tier_errors_are_targeted() {
        let err = |s: &str| Interconnect::parse(s).unwrap_err().to_string();
        assert!(err("two_tier:nvlink:ib").contains("exactly three fields"));
        assert!(err("two_tier:nvlink:ib:8:9").contains("exactly three fields"));
        assert!(err("two_tier:warp:ib:8").contains("intra"));
        assert!(err("two_tier:nvlink:warp:8").contains("cross"));
        assert!(err("two_tier:nvlink:ib:eight").contains("whole number"));
        assert!(err("two_tier:nvlink:ib:0").contains("at least 1"));
        // a nested custom spec breaks the field count, not silently parses
        assert!(err("two_tier:custom:5:1:8").contains("exactly three fields"));
    }

    #[test]
    fn hierarchical_allreduce_between_flat_fabrics() {
        let bytes = 1 << 20;
        let flat_nv = Interconnect::new(Fabric::NvLink);
        let flat_ib = Interconnect::new(Fabric::InfiniBand);
        let two = Interconnect::parse("two_tier:nvlink:infiniband:8").unwrap();
        let h = two.allreduce_time(bytes, 16);
        // hierarchical: cheaper than pushing everything over IB, dearer
        // than a single-node NVLink collective
        assert!(h < flat_ib.allreduce_time(bytes, 16), "h={h}");
        assert!(h > flat_nv.allreduce_time(bytes, 16), "h={h}");
        // within one node the cross tier never engages
        assert_eq!(two.allreduce_time(bytes, 8), flat_nv.allreduce_time(bytes, 8));
    }

    #[test]
    fn two_tier_gpn1_is_pure_cross() {
        // the measured-sweep testbed: tp=2, each rank its own node,
        // local intra + slow cross == flat slow end to end
        let two = Interconnect::parse("two_tier:local:slow:1").unwrap();
        let slow = Interconnect::parse("slow").unwrap();
        let bytes = 64 * 4;
        assert_eq!(two.allreduce_time(bytes, 2), slow.allreduce_time(bytes, 2));
        assert!(two.allreduce_time(bytes, 2) > 0.0);
        assert_eq!(two.allreduce_tier_bytes(bytes, 2), (0, bytes));
    }

    #[test]
    fn tier_bytes_split() {
        let flat = Interconnect::new(Fabric::Pcie);
        assert_eq!(flat.allreduce_tier_bytes(1024, 8), (1024, 0));
        assert_eq!(flat.allreduce_tier_bytes(1024, 1), (1024, 0));
        let two = Interconnect::parse("two_tier:nvlink:infiniband:4").unwrap();
        let (intra, cross) = two.allreduce_tier_bytes(1024, 8);
        // RS+AG ring traffic intra, one shard cross
        assert_eq!(intra, 2 * 3 * 1024 / 4);
        assert_eq!(cross, 1024 / 4);
        // collective confined to one node: all intra
        assert_eq!(two.allreduce_tier_bytes(1024, 4), (1024, 0));
    }

    #[test]
    fn two_tier_allgather_is_hierarchical() {
        let two = Interconnect::parse("two_tier:nvlink:infiniband:8").unwrap();
        let flat_nv = Interconnect::new(Fabric::NvLink);
        assert!(two.allgather_time(4096, 16) > flat_nv.allgather_time(4096, 16));
        assert_eq!(two.allgather_time(4096, 8), flat_nv.allgather_time(4096, 8));
    }
}
