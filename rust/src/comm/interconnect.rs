//! Interconnect cost models (the hardware substitution for NVLink / PCIe /
//! InfiniBand fabrics the paper benchmarks on).
//!
//! AllReduce cost uses the standard alpha-beta model. For a ring AllReduce
//! over `n` devices and message size `B` bytes:
//!
//!   t = 2 (n-1) * alpha_hop + 2 (n-1)/n * B / bw
//!
//! With SHARP (in-switch reduction, paper's NVLink runs set
//! NCCL_NVLS_ENABLE=1) the latency term collapses to a one-shot:
//!
//!   t = alpha_sharp + B / bw
//!
//! Fabric constants follow public H100/DGX specs; what matters for the
//! reproduction is the comm/compute *ratio* per fabric class, not the
//! absolute numbers (see DESIGN.md substitutions).

/// A fabric class the paper benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Fabric {
    /// NVLink 4 (+SHARP): 450 GB/s per-GPU bandwidth, sub-10us latency.
    NvLink,
    /// PCIe Gen5 fallback (paper's "No NVLink", NCCL_P2P_DISABLE=1).
    Pcie,
    /// Cross-node InfiniBand (NDR 400): used by the paper's 405B TP16 runs.
    InfiniBand,
    /// Single-device: communication is the identity (zero cost).
    Local,
    /// Custom (latency_us, bandwidth_GBps) — for sweeps/ablations.
    Custom(u32, u32),
}

/// Cost model for one fabric.
#[derive(Debug, Clone, Copy)]
pub struct Interconnect {
    pub fabric: Fabric,
    /// Per-hop latency (seconds).
    pub alpha: f64,
    /// Algorithm bandwidth per device (bytes/second).
    pub bandwidth: f64,
    /// One-shot in-switch reduction (SHARP) instead of ring.
    pub sharp: bool,
}

impl Interconnect {
    pub fn new(fabric: Fabric) -> Interconnect {
        match fabric {
            // alpha is the *end-to-end* NCCL small-message AllReduce
            // latency (protocol + launch), not the wire latency: ~18us for
            // NVLS/SHARP one-shot on 8 GPUs, ~60us via shared-memory
            // fallback with P2P disabled (the paper's "No NVLink"), ~25us
            // per hop over NDR InfiniBand.
            Fabric::NvLink => Interconnect {
                fabric,
                alpha: 18e-6,
                bandwidth: 450e9,
                sharp: true,
            },
            Fabric::Pcie => Interconnect {
                fabric,
                alpha: 5e-6,
                bandwidth: 40e9,
                sharp: false,
            },
            Fabric::InfiniBand => Interconnect {
                fabric,
                alpha: 25e-6,
                bandwidth: 45e9,
                sharp: false,
            },
            Fabric::Local => Interconnect {
                fabric,
                alpha: 0.0,
                bandwidth: f64::INFINITY,
                sharp: true,
            },
            Fabric::Custom(lat_us, bw_gbps) => Interconnect {
                fabric,
                alpha: lat_us as f64 * 1e-6,
                bandwidth: bw_gbps as f64 * 1e9,
                sharp: false,
            },
        }
    }

    /// Modeled AllReduce duration for `bytes` over `n` devices.
    pub fn allreduce_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 || matches!(self.fabric, Fabric::Local) {
            return 0.0;
        }
        let b = bytes as f64;
        if self.sharp {
            // one-shot in-switch reduction (NVLS/SHARP)
            self.alpha + b / self.bandwidth
        } else {
            // latency: tree depth (NCCL picks tree/SHM for small messages,
            // not the 2(n-1)-hop ring); bandwidth: ring algbw factor
            let hops = (n - 1) as f64;
            hops * self.alpha + 2.0 * hops / n as f64 * b / self.bandwidth
        }
    }

    /// Modeled AllGather duration (lm-head vocab shards).
    pub fn allgather_time(&self, bytes_per_rank: usize, n: usize) -> f64 {
        if n <= 1 || matches!(self.fabric, Fabric::Local) {
            return 0.0;
        }
        let hops = (n - 1) as f64;
        hops * self.alpha + hops * bytes_per_rank as f64 / self.bandwidth
    }

    pub fn name(&self) -> String {
        match self.fabric {
            Fabric::NvLink => "nvlink".into(),
            Fabric::Pcie => "pcie".into(),
            Fabric::InfiniBand => "infiniband".into(),
            Fabric::Local => "local".into(),
            Fabric::Custom(l, b) => format!("custom({l}us,{b}GB/s)"),
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Interconnect> {
        if let Some(spec) = s.strip_prefix("custom:") {
            return Self::parse_custom(spec);
        }
        Ok(Interconnect::new(match s {
            "nvlink" => Fabric::NvLink,
            "pcie" | "no-nvlink" => Fabric::Pcie,
            "infiniband" | "ib" => Fabric::InfiniBand,
            "local" | "none" => Fabric::Local,
            // "slow": a fabric whose latency is commensurate with this
            // CPU testbed's module times (ms-scale), so architecture
            // comparisons on the real engine show the paper's shape the
            // way GPU-scale modules vs NCCL latencies do.
            "slow" => Fabric::Custom(3000, 1),
            _ => anyhow::bail!(
                "unknown fabric {s:?} (nvlink|pcie|infiniband|local|slow|custom:<lat_us>:<gbps>)"
            ),
        }))
    }

    /// Parse the `<lat_us>:<gbps>` tail of a `custom:` fabric spec
    /// (`Fabric::Custom` for sweeps/ablations, e.g. `custom:250:32` = 250us
    /// per-hop latency at 32 GB/s).
    fn parse_custom(spec: &str) -> anyhow::Result<Interconnect> {
        let parts: Vec<&str> = spec.split(':').collect();
        let (lat, bw) = match parts.as_slice() {
            [lat, bw] => (*lat, *bw),
            _ => anyhow::bail!(
                "custom fabric needs exactly two fields, custom:<lat_us>:<gbps> — got \"custom:{spec}\""
            ),
        };
        let lat_us: u32 = lat.parse().map_err(|_| {
            anyhow::anyhow!("custom fabric latency {lat:?} is not a whole number of microseconds")
        })?;
        let bw_gbps: u32 = bw.parse().map_err(|_| {
            anyhow::anyhow!("custom fabric bandwidth {bw:?} is not a whole number of GB/s")
        })?;
        if bw_gbps == 0 {
            anyhow::bail!("custom fabric bandwidth must be at least 1 GB/s");
        }
        Ok(Interconnect::new(Fabric::Custom(lat_us, bw_gbps)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_is_free() {
        let ic = Interconnect::new(Fabric::Local);
        assert_eq!(ic.allreduce_time(1 << 20, 8), 0.0);
    }

    #[test]
    fn single_rank_is_free() {
        let ic = Interconnect::new(Fabric::NvLink);
        assert_eq!(ic.allreduce_time(1 << 20, 1), 0.0);
    }

    #[test]
    fn pcie_slower_than_nvlink() {
        let nv = Interconnect::new(Fabric::NvLink);
        let pcie = Interconnect::new(Fabric::Pcie);
        let bytes = 8192 * 4 * 4; // a typical decode message
        assert!(pcie.allreduce_time(bytes, 8) > nv.allreduce_time(bytes, 8));
    }

    #[test]
    fn monotone_in_bytes_and_ranks() {
        let ic = Interconnect::new(Fabric::Pcie);
        assert!(ic.allreduce_time(2 << 20, 8) > ic.allreduce_time(1 << 20, 8));
        assert!(ic.allreduce_time(1 << 20, 8) > ic.allreduce_time(1 << 20, 2));
    }

    #[test]
    fn parse_roundtrip() {
        assert!(Interconnect::parse("nvlink").is_ok());
        assert!(Interconnect::parse("warp-drive").is_err());
    }

    #[test]
    fn parse_custom_spec() {
        let ic = Interconnect::parse("custom:250:32").unwrap();
        assert_eq!(ic.fabric, Fabric::Custom(250, 32));
        assert_eq!(ic.alpha, 250e-6);
        assert_eq!(ic.bandwidth, 32e9);
        assert!(!ic.sharp);
        // zero latency is a valid ablation; zero bandwidth is not
        assert!(Interconnect::parse("custom:0:1").is_ok());
        assert!(Interconnect::parse("custom:5:0").is_err());
    }

    #[test]
    fn parse_custom_errors_are_targeted() {
        let err = |s: &str| Interconnect::parse(s).unwrap_err().to_string();
        assert!(err("custom:5").contains("exactly two fields"), "{}", err("custom:5"));
        assert!(err("custom:5:1:9").contains("exactly two fields"));
        assert!(err("custom:fast:1").contains("latency"));
        assert!(err("custom:5:wide").contains("bandwidth"));
        assert!(err("custom:-1:1").contains("latency"));
    }
}
