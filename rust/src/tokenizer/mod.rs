//! Byte-level tokenizer with optional greedy BPE merges.
//!
//! The serving examples need a real text <-> token path; vocab layout:
//! ids 0..255 are raw bytes, id 256 is BOS, 257 is EOS, and ids 258.. are
//! learned BPE merges (trained greedily from a corpus). Configs with
//! `vocab == 256` use the plain byte mapping (no specials/merges) so that
//! every id is valid for the tiny test models.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

pub const BOS: i32 = 256;
pub const EOS: i32 = 257;
const FIRST_MERGE: i32 = 258;

/// Byte-level BPE tokenizer.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub vocab: usize,
    /// merge list in training order: (left, right) -> new id FIRST_MERGE+i
    merges: Vec<(i32, i32)>,
    merge_rank: HashMap<(i32, i32), usize>,
}

impl Tokenizer {
    /// Plain byte tokenizer clipped to `vocab` (ids >= vocab map to
    /// `byte % vocab` so tiny-vocab test models stay in range).
    pub fn bytes_only(vocab: usize) -> Tokenizer {
        Tokenizer { vocab, merges: Vec::new(), merge_rank: HashMap::new() }
    }

    /// Train `n_merges` greedy BPE merges from a corpus.
    pub fn train(corpus: &str, vocab: usize) -> Result<Tokenizer> {
        if vocab < 258 {
            bail!("BPE training needs vocab >= 258 (got {vocab})");
        }
        let n_merges = vocab - FIRST_MERGE as usize;
        let mut ids: Vec<i32> = corpus.bytes().map(|b| b as i32).collect();
        let mut merges = Vec::with_capacity(n_merges);
        for m in 0..n_merges {
            let mut counts: HashMap<(i32, i32), usize> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            // deterministic argmax: highest count, then smallest pair
            let best = counts
                .iter()
                .max_by_key(|(pair, &c)| (c, std::cmp::Reverse(**pair)))
                .map(|(p, c)| (*p, *c));
            let Some((pair, count)) = best else { break };
            if count < 2 {
                break;
            }
            let new_id = FIRST_MERGE + m as i32;
            merges.push(pair);
            ids = apply_merge(&ids, pair, new_id);
        }
        let merge_rank = merges.iter().enumerate().map(|(i, p)| (*p, i)).collect();
        Ok(Tokenizer { vocab, merges, merge_rank })
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut ids: Vec<i32> = text
            .bytes()
            .map(|b| (b as i32) % self.vocab.min(256) as i32)
            .collect();
        // apply merges in rank order until fixpoint
        loop {
            let mut best: Option<(usize, usize)> = None; // (rank, pos)
            for (pos, w) in ids.windows(2).enumerate() {
                if let Some(&rank) = self.merge_rank.get(&(w[0], w[1])) {
                    if best.map_or(true, |(r, _)| rank < r) {
                        best = Some((rank, pos));
                    }
                }
            }
            match best {
                Some((rank, _)) => {
                    let pair = self.merges[rank];
                    ids = apply_merge(&ids, pair, FIRST_MERGE + rank as i32);
                }
                None => break,
            }
        }
        ids
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::with_capacity(ids.len());
        for &id in ids {
            self.push_bytes(id, &mut bytes);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// The EOS id requests default to, when this vocab has one (the plain
    /// byte mapping used by the tiny test configs has no specials).
    pub fn eos_id(&self) -> Option<i32> {
        (self.vocab > EOS as usize).then_some(EOS)
    }

    /// Start an incremental decode stream (one per in-flight request).
    /// Clones this tokenizer once; callers with many streams should share
    /// an `Arc<Tokenizer>` and use [`DecodeStream::new`] directly.
    pub fn decode_stream(&self) -> DecodeStream {
        DecodeStream::new(Arc::new(self.clone()))
    }

    fn push_bytes(&self, id: i32, out: &mut Vec<u8>) {
        if id < 256 {
            out.push(id as u8);
        } else if id == BOS || id == EOS {
            // specials render as nothing
        } else {
            let idx = (id - FIRST_MERGE) as usize;
            if let Some(&(l, r)) = self.merges.get(idx) {
                self.push_bytes(l, out);
                self.push_bytes(r, out);
            } else {
                out.push(b'?');
            }
        }
    }
}

/// Incremental detokenizer: feed token ids one at a time, get back exactly
/// the text each id appends. A token can end mid-way through a multi-byte
/// UTF-8 character (byte-level vocab) — those trailing bytes are held back
/// until the next token completes them, so concatenating every delta (plus
/// [`DecodeStream::finish`]) reproduces [`Tokenizer::decode`] byte-for-byte,
/// replacement characters included.
#[derive(Debug, Clone)]
pub struct DecodeStream {
    tok: Arc<Tokenizer>,
    pending: Vec<u8>,
}

impl DecodeStream {
    /// A stream over a shared tokenizer (no per-stream deep clone).
    pub fn new(tok: Arc<Tokenizer>) -> DecodeStream {
        DecodeStream { tok, pending: Vec::new() }
    }

    /// Decode one more token; returns the completed text it contributes.
    pub fn push(&mut self, id: i32) -> String {
        self.tok.push_bytes(id, &mut self.pending);
        let mut out = String::new();
        loop {
            match std::str::from_utf8(&self.pending) {
                Ok(s) => {
                    out.push_str(s);
                    self.pending.clear();
                    break;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    out.push_str(std::str::from_utf8(&self.pending[..valid]).unwrap());
                    match e.error_len() {
                        // Genuinely invalid bytes: substitute, keep going —
                        // the same maximal-subpart rule `from_utf8_lossy`
                        // applies in `Tokenizer::decode`.
                        Some(n) => {
                            out.push('\u{fffd}');
                            self.pending.drain(..valid + n);
                        }
                        // Incomplete trailing sequence: hold it for the
                        // next token.
                        None => {
                            self.pending.drain(..valid);
                            break;
                        }
                    }
                }
            }
        }
        out
    }

    /// Flush any held-back incomplete sequence (end of generation).
    pub fn finish(&mut self) -> String {
        let s = String::from_utf8_lossy(&self.pending).into_owned();
        self.pending.clear();
        s
    }
}

fn apply_merge(ids: &[i32], pair: (i32, i32), new_id: i32) -> Vec<i32> {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && ids[i] == pair.0 && ids[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_roundtrip() {
        let t = Tokenizer::bytes_only(256);
        let s = "hello, ladder residual!";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn tiny_vocab_wraps() {
        let t = Tokenizer::bytes_only(64);
        for id in t.encode("Zebra!") {
            assert!(id < 64);
        }
    }

    #[test]
    fn bpe_roundtrips_and_compresses() {
        let corpus = "the cat sat on the mat. the cat sat on the hat. ".repeat(20);
        let t = Tokenizer::train(&corpus, 300).unwrap();
        let s = "the cat sat on the mat";
        let ids = t.encode(s);
        assert_eq!(t.decode(&ids), s);
        assert!(ids.len() < s.len(), "{} !< {}", ids.len(), s.len());
    }

    #[test]
    fn bpe_encode_is_deterministic() {
        let corpus = "abab abab abab".repeat(10);
        let t = Tokenizer::train(&corpus, 300).unwrap();
        assert_eq!(t.encode("ababab"), t.encode("ababab"));
    }

    #[test]
    fn train_rejects_small_vocab() {
        assert!(Tokenizer::train("abc", 100).is_err());
    }

    #[test]
    fn eos_only_with_specials() {
        assert_eq!(Tokenizer::bytes_only(256).eos_id(), None);
        let corpus = "the cat sat on the mat. ".repeat(20);
        assert_eq!(Tokenizer::train(&corpus, 300).unwrap().eos_id(), Some(EOS));
    }

    /// Stream deltas must concatenate to exactly the batch decode.
    fn assert_stream_matches(t: &Tokenizer, ids: &[i32]) {
        let mut stream = t.decode_stream();
        let mut acc = String::new();
        for &id in ids {
            acc.push_str(&stream.push(id));
        }
        acc.push_str(&stream.finish());
        assert_eq!(acc, t.decode(ids), "ids {ids:?}");
    }

    #[test]
    fn decode_stream_matches_batch_decode() {
        let t = Tokenizer::bytes_only(256);
        // multi-byte chars arrive one byte (= one token) at a time
        assert_stream_matches(&t, &t.encode("héllo wörld — ünïcode ✓"));
        assert_stream_matches(&t, &t.encode("ascii only"));
        assert_stream_matches(&t, &[]);
    }

    #[test]
    fn decode_stream_holds_incomplete_utf8() {
        let t = Tokenizer::bytes_only(256);
        let mut s = t.decode_stream();
        // 'é' = 0xC3 0xA9: first byte alone must produce no text yet
        assert_eq!(s.push(0xC3), "");
        assert_eq!(s.push(0xA9), "é");
        assert_eq!(s.finish(), "");
    }

    #[test]
    fn decode_stream_substitutes_invalid_bytes() {
        let t = Tokenizer::bytes_only(256);
        // 0xFF is never valid; a dangling lead byte flushes on finish
        assert_stream_matches(&t, &[0xFF, b'a' as i32, 0xC3]);
        let mut s = t.decode_stream();
        assert_eq!(s.push(0xFF), "\u{fffd}");
        assert_eq!(s.push(0xC3), "");
        assert_eq!(s.finish(), "\u{fffd}");
    }

    #[test]
    fn decode_stream_bpe_and_specials() {
        let corpus = "the cat sat on the mat. the cat sat on the hat. ".repeat(20);
        let t = Tokenizer::train(&corpus, 300).unwrap();
        let mut ids = t.encode("the cat sat on the mat");
        ids.push(EOS); // specials contribute no text
        assert_stream_matches(&t, &ids);
    }
}
