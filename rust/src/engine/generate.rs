//! Generation loop: prefill + decode with sampling, timing each phase the
//! way the paper reports (prefill latency, decode latency, tokens/sec).

use std::time::{Duration, Instant};

use anyhow::Result;

use super::tpengine::TpEngine;
use crate::comm::CommStats;
use crate::model::HostTensor;
use crate::util::rng::Rng;

/// Token sampling strategy.
#[derive(Debug, Clone)]
pub enum Sampler {
    Greedy,
    /// top-k with temperature, seeded.
    TopK { k: usize, temperature: f64, seed: u64 },
}

impl Sampler {
    /// Sample one token per batch row from logits [B, V].
    pub fn sample(&self, logits: &HostTensor, rng: &mut Rng) -> Vec<i32> {
        let b = logits.shape[0];
        let v = logits.shape[1];
        (0..b)
            .map(|bi| {
                let row = &logits.data[bi * v..(bi + 1) * v];
                match self {
                    Sampler::Greedy => argmax(row) as i32,
                    Sampler::TopK { k, temperature, .. } => {
                        let mut idx: Vec<usize> = (0..v).collect();
                        idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
                        idx.truncate((*k).max(1));
                        let weights: Vec<f64> = idx
                            .iter()
                            .map(|&i| ((row[i] as f64) / temperature.max(1e-6)).exp())
                            .collect();
                        idx[rng.categorical(&weights)] as i32
                    }
                }
            })
            .collect()
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

/// Timing + comm report for one generation run (the paper's Table 2 row).
#[derive(Debug, Clone)]
pub struct GenerateReport {
    pub tokens: Vec<Vec<i32>>,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    pub decode_steps: usize,
    pub comm: CommStats,
    /// Which rank runtime produced the timings ("sequential"/"threaded").
    pub runtime: &'static str,
}

impl GenerateReport {
    pub fn tokens_per_sec(&self) -> f64 {
        let total = (self.prefill_time + self.decode_time).as_secs_f64();
        (self.tokens.len() * self.tokens[0].len()) as f64 / total
    }

    pub fn decode_tok_per_sec(&self) -> f64 {
        (self.tokens.len() * self.decode_steps) as f64 / self.decode_time.as_secs_f64()
    }
}

/// Static-batch generation (the paper's benchmark setting: all rows share a
/// prompt length, generate `gen_len` tokens together).
pub fn generate(
    engine: &mut TpEngine,
    prompts: &[Vec<i32>],
    gen_len: usize,
    sampler: &Sampler,
) -> Result<GenerateReport> {
    assert_eq!(prompts.len(), engine.batch);
    engine.comm.reset_stats();
    let prompt_len = prompts[0].len();
    let bucket = engine.pick_bucket(prompt_len)?;
    let mut rng = Rng::new(match sampler {
        Sampler::TopK { seed, .. } => *seed,
        _ => 0,
    });

    // pad prompts into the bucket
    let mut tokens = vec![0i32; engine.batch * bucket];
    let mut true_lens = vec![0usize; engine.batch];
    for (b, p) in prompts.iter().enumerate() {
        tokens[b * bucket..b * bucket + p.len()].copy_from_slice(p);
        true_lens[b] = p.len();
    }

    let t0 = Instant::now();
    let logits = engine.prefill(&tokens, bucket, &true_lens)?;
    let prefill_time = t0.elapsed();

    let mut out: Vec<Vec<i32>> = vec![Vec::with_capacity(gen_len); engine.batch];
    let mut next = sampler.sample(&logits, &mut rng);
    for (b, &t) in next.iter().enumerate() {
        out[b].push(t);
    }

    let t1 = Instant::now();
    for _ in 1..gen_len {
        let logits = engine.decode(&next)?;
        next = sampler.sample(&logits, &mut rng);
        for (b, &t) in next.iter().enumerate() {
            out[b].push(t);
        }
    }
    let decode_time = t1.elapsed();

    Ok(GenerateReport {
        tokens: out,
        prefill_time,
        decode_time,
        decode_steps: gen_len - 1,
        comm: engine.comm.stats(),
        runtime: engine.runtime.name(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits(rows: &[&[f32]]) -> HostTensor {
        let b = rows.len();
        let v = rows[0].len();
        HostTensor::new(vec![b, v], rows.concat())
    }

    #[test]
    fn greedy_picks_argmax() {
        let l = logits(&[&[0.1, 3.0, -1.0], &[5.0, 0.0, 0.0]]);
        let out = Sampler::Greedy.sample(&l, &mut Rng::new(0));
        assert_eq!(out, vec![1, 0]);
    }

    #[test]
    fn topk_stays_in_top_k() {
        let l = logits(&[&[0.0, 10.0, 9.0, -5.0]]);
        let s = Sampler::TopK { k: 2, temperature: 1.0, seed: 7 };
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let t = s.sample(&l, &mut rng)[0];
            assert!(t == 1 || t == 2, "{t}");
        }
    }

    #[test]
    fn topk_low_temperature_is_greedy() {
        let l = logits(&[&[0.0, 2.0, 1.9]]);
        let s = Sampler::TopK { k: 3, temperature: 0.01, seed: 1 };
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            assert_eq!(s.sample(&l, &mut rng)[0], 1);
        }
    }
}
