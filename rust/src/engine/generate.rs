//! Generation loop: prefill + decode with sampling, timing each phase the
//! way the paper reports (prefill latency, decode latency, tokens/sec).

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::kv::{BlockAllocator, KvLayout};
use super::tpengine::TpEngine;
use crate::comm::CommStats;
use crate::model::HostTensor;
use crate::util::rng::Rng;

/// Token sampling strategy.
#[derive(Debug, Clone)]
pub enum Sampler {
    Greedy,
    /// top-k with temperature, seeded.
    TopK { k: usize, temperature: f64, seed: u64 },
}

impl Sampler {
    /// Sample one token per batch row from logits [B, V].
    pub fn sample(&self, logits: &HostTensor, rng: &mut Rng) -> Vec<i32> {
        let b = logits.shape[0];
        let v = logits.shape[1];
        (0..b)
            .map(|bi| {
                let row = &logits.data[bi * v..(bi + 1) * v];
                match self {
                    Sampler::Greedy => argmax(row) as i32,
                    Sampler::TopK { k, temperature, .. } => {
                        let idx = top_k_indices(row, *k);
                        let weights: Vec<f64> = idx
                            .iter()
                            .map(|&i| ((row[i] as f64) / temperature.max(1e-6)).exp())
                            .collect();
                        idx[rng.categorical(&weights)] as i32
                    }
                }
            })
            .collect()
    }
}

/// The `k` highest-logit indices in descending logit order (ties broken by
/// lower index, i.e. exactly what a stable full-vocab descending sort
/// yields) — but via `select_nth_unstable`, so a decode step costs
/// O(V + k log k) per slot instead of O(V log V).
fn top_k_indices(row: &[f32], k: usize) -> Vec<usize> {
    let v = row.len();
    if v == 0 {
        return Vec::new();
    }
    let k = k.clamp(1, v);
    // logit desc, index asc: a total order, so the selected set and its
    // final ordering are deterministic even through the unstable partition
    let order = |&a: &usize, &b: &usize| row[b].partial_cmp(&row[a]).unwrap().then(a.cmp(&b));
    let mut idx: Vec<usize> = (0..v).collect();
    if k < v {
        idx.select_nth_unstable_by(k - 1, order);
        idx.truncate(k);
    }
    idx.sort_unstable_by(order);
    idx
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

/// Timing + comm report for one generation run (the paper's Table 2 row).
#[derive(Debug, Clone)]
pub struct GenerateReport {
    pub tokens: Vec<Vec<i32>>,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    pub decode_steps: usize,
    pub comm: CommStats,
    /// Which rank runtime produced the timings ("sequential"/"threaded").
    pub runtime: &'static str,
}

impl GenerateReport {
    /// End-to-end throughput: **generated** tokens (prompt and padding rows
    /// never count — the numerator is the sum of per-row generated lengths)
    /// over the prefill + decode wall clock.
    pub fn tokens_per_sec(&self) -> f64 {
        let generated: usize = self.tokens.iter().map(Vec::len).sum();
        let total = (self.prefill_time + self.decode_time).as_secs_f64();
        generated as f64 / total.max(1e-12)
    }

    /// Decode-phase throughput: decode-step tokens over the decode wall
    /// clock (the prefill-sampled token is excluded from both).
    pub fn decode_tok_per_sec(&self) -> f64 {
        (self.tokens.len() * self.decode_steps) as f64 / self.decode_time.as_secs_f64().max(1e-12)
    }
}

/// Static-batch generation (the paper's benchmark setting: all rows share a
/// prompt length, generate `gen_len` tokens together). Works on both KV
/// layouts: slab engines run the batched prefill + decode, paged engines
/// allocate a throwaway page table per slot and route through the paged
/// modules — producing bitwise-identical tokens (every kernel is row-local
/// and keys are visited in logical order).
pub fn generate(
    engine: &mut TpEngine,
    prompts: &[Vec<i32>],
    gen_len: usize,
    sampler: &Sampler,
) -> Result<GenerateReport> {
    assert_eq!(prompts.len(), engine.batch);
    engine.comm.reset_stats();
    let prompt_len = prompts[0].len();
    let bucket = engine.pick_bucket(prompt_len)?;
    let mut rng = Rng::new(match sampler {
        Sampler::TopK { seed, .. } => *seed,
        _ => 0,
    });

    let paged = match engine.kv_layout() {
        KvLayout::Slab => None,
        KvLayout::Paged { page_size, pages } => {
            if prompt_len + gen_len > engine.cfg.max_seq {
                bail!(
                    "paged generate: {} prompt + {gen_len} generated tokens exceed max_seq {}",
                    prompt_len,
                    engine.cfg.max_seq
                );
            }
            let mut alloc = BlockAllocator::new(pages, page_size, engine.kv_page_bytes());
            for (b, p) in prompts.iter().enumerate() {
                alloc.admit(b as u64, p.len(), p.len() + gen_len)?;
            }
            Some(alloc)
        }
    };

    let b_count = engine.batch;
    let t0 = Instant::now();
    let logits = match &paged {
        None => {
            // pad prompts into the bucket
            let mut tokens = vec![0i32; b_count * bucket];
            let mut true_lens = vec![0usize; b_count];
            for (b, p) in prompts.iter().enumerate() {
                tokens[b * bucket..b * bucket + p.len()].copy_from_slice(p);
                true_lens[b] = p.len();
            }
            engine.prefill(&tokens, bucket, &true_lens)?
        }
        Some(alloc) => {
            // per-slot paged prefill; rows are gathered back into [B, V] so
            // sampling consumes the RNG in the same order as the slab path
            let mut rows = Vec::new();
            let mut v = 0;
            for (b, p) in prompts.iter().enumerate() {
                let table = &alloc.table(b as u64).expect("admitted above").pages;
                let row = engine.prefill_chunk_slot(b, p, 0, table)?;
                v = row.len();
                rows.extend(row);
            }
            HostTensor::new(vec![b_count, v], rows)
        }
    };
    let prefill_time = t0.elapsed();

    let mut out: Vec<Vec<i32>> = vec![Vec::with_capacity(gen_len); b_count];
    let mut next = sampler.sample(&logits, &mut rng);
    for (b, &t) in next.iter().enumerate() {
        out[b].push(t);
    }

    let t1 = Instant::now();
    let max_pages = engine.kv_max_pages_per_seq();
    let mut alloc = paged;
    for step in 1..gen_len {
        let logits = match &mut alloc {
            None => engine.decode(&next)?,
            Some(alloc) => {
                let mut tables = vec![-1i32; b_count * max_pages];
                for (b, p) in prompts.iter().enumerate() {
                    // the incoming token writes position prompt_len+step-1
                    alloc.ensure(b as u64, p.len() + step)?;
                    let row = &mut tables[b * max_pages..(b + 1) * max_pages];
                    alloc.fill_table_row(b as u64, row)?;
                }
                engine.decode_paged(&next, &vec![true; b_count], tables, max_pages)?
            }
        };
        next = sampler.sample(&logits, &mut rng);
        for (b, &t) in next.iter().enumerate() {
            out[b].push(t);
        }
    }
    let decode_time = t1.elapsed();

    Ok(GenerateReport {
        tokens: out,
        prefill_time,
        decode_time,
        decode_steps: gen_len - 1,
        comm: engine.comm.stats(),
        runtime: engine.runtime.name(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits(rows: &[&[f32]]) -> HostTensor {
        let b = rows.len();
        let v = rows[0].len();
        HostTensor::new(vec![b, v], rows.concat())
    }

    #[test]
    fn greedy_picks_argmax() {
        let l = logits(&[&[0.1, 3.0, -1.0], &[5.0, 0.0, 0.0]]);
        let out = Sampler::Greedy.sample(&l, &mut Rng::new(0));
        assert_eq!(out, vec![1, 0]);
    }

    #[test]
    fn topk_stays_in_top_k() {
        let l = logits(&[&[0.0, 10.0, 9.0, -5.0]]);
        let s = Sampler::TopK { k: 2, temperature: 1.0, seed: 7 };
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let t = s.sample(&l, &mut rng)[0];
            assert!(t == 1 || t == 2, "{t}");
        }
    }

    #[test]
    fn topk_low_temperature_is_greedy() {
        let l = logits(&[&[0.0, 2.0, 1.9]]);
        let s = Sampler::TopK { k: 3, temperature: 0.01, seed: 1 };
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            assert_eq!(s.sample(&l, &mut rng)[0], 1);
        }
    }

    /// The replaced O(V log V) selection: full-vocab stable descending sort.
    fn top_k_sorted(row: &[f32], k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..row.len()).collect();
        idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
        idx.truncate(k.max(1));
        idx
    }

    #[test]
    fn topk_selection_matches_sorted_path() {
        let mut rng = Rng::new(0xfeed);
        for v in [1usize, 2, 7, 64, 500] {
            for k in [1usize, 2, 5, 64, 1000] {
                let row: Vec<f32> = (0..v).map(|_| rng.normal() as f32).collect();
                assert_eq!(
                    top_k_indices(&row, k),
                    top_k_sorted(&row, k),
                    "v={v} k={k}"
                );
            }
        }
    }

    #[test]
    fn topk_selection_breaks_ties_like_stable_sort() {
        // duplicated logits everywhere: stable sort keeps lower indices
        // first within a tie class, and so must the select_nth path
        let row = [1.0f32, 3.0, 3.0, 1.0, 3.0, 0.0, 1.0, 3.0];
        for k in 1..=row.len() {
            assert_eq!(top_k_indices(&row, k), top_k_sorted(&row, k), "k={k}");
        }
    }

    #[test]
    fn topk_sampling_identical_to_legacy_rng_stream() {
        // same seed, same logits: the select_nth sampler must consume the
        // RNG identically to the sorted implementation it replaced
        let mut rng = Rng::new(3);
        let row: Vec<f32> = (0..200).map(|_| rng.normal() as f32).collect();
        let l = HostTensor::new(vec![1, row.len()], row.clone());
        let s = Sampler::TopK { k: 10, temperature: 0.8, seed: 11 };
        let mut ra = Rng::new(11);
        let mut rb = Rng::new(11);
        for _ in 0..50 {
            let fast = s.sample(&l, &mut ra)[0];
            // legacy draw, inlined
            let idx = top_k_sorted(&row, 10);
            let w: Vec<f64> = idx.iter().map(|&i| ((row[i] as f64) / 0.8).exp()).collect();
            let slow = idx[rb.categorical(&w)] as i32;
            assert_eq!(fast, slow);
        }
    }

    fn report(
        tokens: Vec<Vec<i32>>,
        prefill_ms: u64,
        decode_ms: u64,
        steps: usize,
    ) -> GenerateReport {
        GenerateReport {
            tokens,
            prefill_time: Duration::from_millis(prefill_ms),
            decode_time: Duration::from_millis(decode_ms),
            decode_steps: steps,
            comm: CommStats::default(),
            runtime: "sequential",
        }
    }

    #[test]
    fn tokens_per_sec_counts_generated_only() {
        // 2 rows x 4 generated tokens over 2s total: prompt length and
        // padding never enter the numerator
        let r = report(vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]], 500, 1500, 3);
        assert!((r.tokens_per_sec() - 4.0).abs() < 1e-9, "{}", r.tokens_per_sec());
        // ragged rows count their true generated lengths
        let r = report(vec![vec![1, 2, 3], vec![4]], 0, 1000, 2);
        assert!((r.tokens_per_sec() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn report_rates_survive_degenerate_runs() {
        // zero rows / zero time must not panic or divide by zero
        let r = report(Vec::new(), 0, 0, 0);
        assert_eq!(r.tokens_per_sec(), 0.0);
        assert_eq!(r.decode_tok_per_sec(), 0.0);
    }
}
