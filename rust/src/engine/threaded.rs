//! The threaded rank runtime: one long-lived worker thread per simulated TP
//! rank, coordinated over channels, synchronized through the rendezvous
//! collective.
//!
//! Backend instances are not `Send` (the xla backend's PJRT handles are
//! `Rc`-based and thread-local; the native backend simply follows the same
//! discipline), so nothing backend-shaped crosses a thread boundary: each
//! worker rebuilds its own [`Exec`] from the engine's [`BackendSpec`] and
//! its own [`RankState`] (uploaded weight shards + KV cache) from the
//! host-side [`WeightStore`], which is plain `Send` data. The coordinator
//! ([`super::TpEngine`]) broadcasts the embedded residual activation to the
//! workers as an `Arc<HostTensor>`; each worker uploads it once per module
//! call on its own thread — the sequential engine performs that upload `tp`
//! times per module on one core.
//!
//! Determinism contract: every worker executes the *same* per-rank schedule
//! the sequential engine would (same module sequence, same collective
//! sequence), and every collective reduces in rank order 0..tp regardless of
//! arrival order. Threaded logits are therefore bitwise identical to the
//! sequential oracle's — asserted per architecture by the
//! `runtime_determinism` integration test.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, Result};

use super::kv::{KvLayout, PagedFwd};
use super::overlap::{self, ChunkFwd, OverlapMode};
use super::rank::{Phase, RankState, Rows};
use super::{add_assign, BlockSel};
use crate::comm::rendezvous::{ReduceOp, SharedCollective};
use crate::model::{Arch, HostTensor, WeightStore};
use crate::runtime::{BackendSpec, Exec};

/// Coordinator -> worker commands. One `Forward` per engine prefill/decode;
/// the worker replies with its LM-head vocab shard.
enum Cmd {
    Forward {
        x0: Arc<HostTensor>,
        phase: Phase,
        lens: Option<Vec<i32>>,
        rows: Rows,
        /// Page-table view for paged-layout engines (shared, read-only).
        paged: Option<Arc<PagedFwd>>,
        /// Per-row last positions to slice before the LM head.
        last: Vec<usize>,
    },
    /// Clear a slot; the second field is its written length (slab layouts
    /// zero exactly that prefix, paged layouts ignore it).
    Release(usize, usize),
    /// Duplicate pool page `src` into `dst` on this rank (paged layouts;
    /// the prefix cache's copy-on-write step). FIFO ordering puts the copy
    /// before any later `Forward` that reads `dst`.
    CopyPage(u32, u32),
    /// Serialize one pool page and send it back on the dedicated reply
    /// channel (the disk spill tier reading a page's bytes). Synchronous:
    /// the coordinator blocks on the reply, so the page cannot change
    /// under the read.
    ReadPage { page: u32, reply: mpsc::Sender<Result<Vec<f32>>> },
    /// Restore one pool page from its serialized bytes (the disk tier's
    /// upload path). Fire-and-forget like `CopyPage`: FIFO ordering puts
    /// the write before any later `Forward` that reads the page, and a
    /// worker-side failure poisons the collective.
    WritePage(u32, Arc<Vec<f32>>),
    Shutdown,
}

/// Worker -> coordinator replies.
enum Reply {
    Shard(Result<HostTensor>),
}

/// Handle to the per-rank worker threads owned by a threaded `TpEngine`.
///
/// Error semantics: a forward error (or panic) on any rank poisons the
/// rendezvous collective, failing every in-flight and future collective —
/// the engine is dead and must be rebuilt. Mid-forward failures leave rank
/// KV caches and sequence counters in inconsistent states, so (as with the
/// sequential engine after a mid-forward PJRT error) there is deliberately
/// no resurrection path.
pub struct ThreadedRuntime {
    tp: usize,
    cmds: Vec<mpsc::Sender<Cmd>>,
    replies: Vec<mpsc::Receiver<Reply>>,
    workers: Vec<thread::JoinHandle<()>>,
    coll: Arc<SharedCollective>,
}

impl ThreadedRuntime {
    /// Spawn one worker per rank. Workers rebuild their backend from the
    /// spec and shard the (`Arc`-shared) weights themselves, so backend
    /// setup and weight upload happen concurrently across ranks at startup
    /// too.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        spec: BackendSpec,
        weights: &WeightStore,
        tp: usize,
        arch: Arch,
        batch: usize,
        layout: KvLayout,
        overlap: OverlapMode,
        coll: Arc<SharedCollective>,
    ) -> Result<ThreadedRuntime> {
        // one shared host copy for all workers, dropped when the last
        // worker finishes uploading its shards
        let weights = Arc::new(weights.clone());
        let mut cmds = Vec::with_capacity(tp);
        let mut replies = Vec::with_capacity(tp);
        let mut workers = Vec::with_capacity(tp);
        for rank in 0..tp {
            let (cmd_tx, cmd_rx) = mpsc::channel();
            let (rep_tx, rep_rx) = mpsc::channel();
            let spec = spec.clone();
            let weights = weights.clone();
            let coll_w = coll.clone();
            let handle = thread::Builder::new()
                .name(format!("tp-rank-{rank}"))
                .spawn(move || {
                    worker_main(
                        rank, tp, batch, arch, layout, overlap, spec, weights, coll_w, cmd_rx,
                        rep_tx,
                    )
                })
                .map_err(|e| anyhow!("spawn rank {rank} worker: {e}"))?;
            cmds.push(cmd_tx);
            replies.push(rep_rx);
            workers.push(handle);
        }
        Ok(ThreadedRuntime { tp, cmds, replies, workers, coll })
    }

    /// Broadcast one forward pass to all ranks and collect their LM-head
    /// shards in rank order (deterministic AllGather input order).
    pub fn forward(
        &self,
        x0: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
        last: &[usize],
    ) -> Result<Vec<HostTensor>> {
        let x0 = Arc::new(x0);
        let paged = paged.map(|p| Arc::new(p.clone()));
        for (rank, tx) in self.cmds.iter().enumerate() {
            tx.send(Cmd::Forward {
                x0: x0.clone(),
                phase,
                lens: lens.map(<[i32]>::to_vec),
                rows,
                paged: paged.clone(),
                last: last.to_vec(),
            })
            .map_err(|_| anyhow!("rank {rank} worker hung up"))?;
        }
        let mut shards = Vec::with_capacity(self.tp);
        let mut first_err: Option<anyhow::Error> = None;
        for (rank, rx) in self.replies.iter().enumerate() {
            match rx.recv() {
                Ok(Reply::Shard(Ok(shard))) => shards.push(shard),
                Ok(Reply::Shard(Err(e))) => {
                    first_err.get_or_insert(anyhow!("rank {rank}: {e}"));
                }
                Err(_) => {
                    // worker thread is gone (its panic guard poisons the
                    // collective, but poison again in case it died before
                    // the guard was armed) — unblock any waiting siblings
                    self.coll.poison(&format!("rank {rank} worker died"));
                    first_err.get_or_insert(anyhow!("rank {rank} worker died"));
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(shards),
        }
    }

    /// Clear slot state on every rank (request finished/evicted); `written`
    /// is the slot's tracked length so slab layouts zero only the prefix
    /// that was actually touched. Channel FIFO ordering guarantees the
    /// clear lands before any later `Forward`.
    pub fn release_slot(&self, slot: usize, written: usize) {
        for tx in &self.cmds {
            let _ = tx.send(Cmd::Release(slot, written));
        }
    }

    /// Duplicate pool page `src` into `dst` on every rank (the prefix
    /// cache's copy-on-write step). Fire-and-forget like `release_slot`:
    /// the coordinator validates the page ids up front, and a worker-side
    /// failure poisons the collective so the next forward fails loudly
    /// instead of reading a half-copied page.
    pub fn copy_page(&self, src: u32, dst: u32) -> Result<()> {
        for (rank, tx) in self.cmds.iter().enumerate() {
            tx.send(Cmd::CopyPage(src, dst))
                .map_err(|_| anyhow!("rank {rank} worker hung up"))?;
        }
        Ok(())
    }

    /// Serialize pool page `page` on every rank, rank-ordered (the disk
    /// spill tier's download path). Blocks until all ranks reply, so the
    /// caller sees a consistent snapshot.
    pub fn read_page(&self, page: u32) -> Result<Vec<Vec<f32>>> {
        let mut pending = Vec::with_capacity(self.tp);
        for (rank, tx) in self.cmds.iter().enumerate() {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Cmd::ReadPage { page, reply: rtx })
                .map_err(|_| anyhow!("rank {rank} worker hung up"))?;
            pending.push(rrx);
        }
        let mut out = Vec::with_capacity(self.tp);
        let mut first_err: Option<anyhow::Error> = None;
        for (rank, rrx) in pending.into_iter().enumerate() {
            match rrx.recv() {
                Ok(Ok(data)) => out.push(data),
                Ok(Err(e)) => {
                    first_err.get_or_insert(anyhow!("rank {rank} read_page: {e}"));
                }
                Err(_) => {
                    first_err.get_or_insert(anyhow!("rank {rank} worker died"));
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    /// Restore pool page `page` on every rank from per-rank serialized
    /// bytes (the disk tier's upload path). Fire-and-forget like
    /// `copy_page`: FIFO channel ordering lands the write before any later
    /// `Forward`, and a worker-side failure poisons the collective.
    pub fn write_page(&self, page: u32, per_rank: &[Vec<f32>]) -> Result<()> {
        if per_rank.len() != self.tp {
            anyhow::bail!("write_page: {} rank payloads for tp={}", per_rank.len(), self.tp);
        }
        for (rank, (tx, data)) in self.cmds.iter().zip(per_rank).enumerate() {
            tx.send(Cmd::WritePage(page, Arc::new(data.clone())))
                .map_err(|_| anyhow!("rank {rank} worker hung up"))?;
        }
        Ok(())
    }
}

impl Drop for ThreadedRuntime {
    fn drop(&mut self) {
        for tx in &self.cmds {
            let _ = tx.send(Cmd::Shutdown);
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

// ---------------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------------

/// Poisons the collective if its thread unwinds (a panicking rank must not
/// leave siblings blocked forever inside a rendezvous it will never reach).
struct PanicGuard {
    rank: usize,
    coll: Arc<SharedCollective>,
}

impl Drop for PanicGuard {
    fn drop(&mut self) {
        if thread::panicking() {
            self.coll.poison(&format!("rank {} worker panicked", self.rank));
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    rank: usize,
    tp: usize,
    batch: usize,
    arch: Arch,
    layout: KvLayout,
    overlap: OverlapMode,
    spec: BackendSpec,
    weights: Arc<WeightStore>,
    coll: Arc<SharedCollective>,
    cmds: mpsc::Receiver<Cmd>,
    replies: mpsc::Sender<Reply>,
) {
    let _panic_guard = PanicGuard { rank, coll: coll.clone() };
    let mut ctx = match WorkerCtx::new(
        rank,
        tp,
        batch,
        arch,
        layout,
        overlap,
        &spec,
        &weights,
        coll.clone(),
    ) {
        Ok(ctx) => ctx,
        Err(e) => {
            let msg = format!("rank {rank} init failed: {e:#}");
            coll.poison(&msg);
            while let Ok(cmd) = cmds.recv() {
                match cmd {
                    Cmd::Forward { .. } => {
                        if replies.send(Reply::Shard(Err(anyhow!(msg.clone())))).is_err() {
                            break;
                        }
                    }
                    Cmd::Release(..) | Cmd::CopyPage(..) | Cmd::WritePage(..) => {}
                    Cmd::ReadPage { reply, .. } => {
                        // the coordinator blocks on this channel: answer
                        // (or let the drop disconnect it) so it never hangs
                        let _ = reply.send(Err(anyhow!(msg.clone())));
                    }
                    Cmd::Shutdown => break,
                }
            }
            return;
        }
    };
    drop(weights); // shards are uploaded; release this worker's share of the host copy

    while let Ok(cmd) = cmds.recv() {
        match cmd {
            Cmd::Forward { x0, phase, lens, rows, paged, last } => {
                let shard = ctx.forward(
                    (*x0).clone(),
                    phase,
                    lens.as_deref(),
                    rows,
                    paged.as_deref(),
                    &last,
                );
                if let Err(e) = &shard {
                    // wake siblings blocked on a rendezvous this rank will
                    // never reach
                    ctx.coll.poison(&format!("rank {rank}: {e:#}"));
                }
                if replies.send(Reply::Shard(shard)).is_err() {
                    break;
                }
            }
            Cmd::Release(slot, written) => ctx.state.release_slot(slot, written),
            Cmd::CopyPage(src, dst) => {
                if let Err(e) = ctx.state.copy_page(src, dst) {
                    // validated coordinator-side, so this is a corrupt rank:
                    // fail the next collective rather than serve bad KV
                    ctx.coll.poison(&format!("rank {rank} copy_page: {e:#}"));
                }
            }
            Cmd::ReadPage { page, reply } => {
                if reply.send(ctx.state.read_page(page)).is_err() {
                    break; // coordinator gone
                }
            }
            Cmd::WritePage(page, data) => {
                if let Err(e) = ctx.state.write_page(page, &data) {
                    ctx.coll.poison(&format!("rank {rank} write_page: {e:#}"));
                }
            }
            Cmd::Shutdown => break,
        }
    }
}

/// Thread-local state of one rank worker: its own backend instance and
/// rank weights, plus its collective sequence counter. All ranks issue the
/// same schedule, so the counters stay aligned without coordination.
struct WorkerCtx {
    rank: usize,
    tp: usize,
    layers: usize,
    arch: Arch,
    overlap: OverlapMode,
    exec: Exec,
    state: RankState,
    coll: Arc<SharedCollective>,
    seq: u64,
}

impl WorkerCtx {
    #[allow(clippy::too_many_arguments)]
    fn new(
        rank: usize,
        tp: usize,
        batch: usize,
        arch: Arch,
        layout: KvLayout,
        overlap: OverlapMode,
        spec: &BackendSpec,
        weights: &WeightStore,
        coll: Arc<SharedCollective>,
    ) -> Result<WorkerCtx> {
        let exec = spec.build()?;
        let cfg = exec.cfg().clone();
        // need_embed = false: the coordinator's Embedder runs the embed
        // module; workers receive the embedded activation over the channel
        let state = RankState::new(&exec, &cfg, weights, rank, tp, batch, false, layout)?;
        Ok(WorkerCtx { rank, tp, layers: cfg.layers, arch, overlap, exec, state, coll, seq: 0 })
    }

    /// The per-rank counterpart of `TpEngine::forward` + the head shard.
    /// Every worker derives the same split-vs-unsplit decision (and the
    /// same chunk partition) from the broadcast inputs, so the rendezvous
    /// sequence counters stay aligned across ranks with no coordination.
    fn forward(
        &mut self,
        x0: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
        last: &[usize],
    ) -> Result<HostTensor> {
        let final_x = if self.overlap != OverlapMode::None && rows == Rows::All && x0.shape[0] > 1
        {
            let chunks = overlap::split_forward(self.overlap, &x0, lens, paged);
            if chunks.len() > 1 {
                self.forward_chunked(chunks, phase)?
            } else {
                self.forward_one(x0, phase, lens, rows, paged)?
            }
        } else {
            self.forward_one(x0, phase, lens, rows, paged)?
        };
        self.state.lm_head_rows(&self.exec, &final_x, last)
    }

    fn forward_one(
        &mut self,
        x0: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
    ) -> Result<HostTensor> {
        match self.arch {
            Arch::Standard => self.fwd_synced(x0, phase, lens, rows, paged, self.layers),
            Arch::Ladder => self.fwd_synced(x0, phase, lens, rows, paged, 0),
            Arch::Hybrid => self.fwd_synced(x0, phase, lens, rows, paged, self.layers / 2),
            Arch::Parallel => self.fwd_parallel(x0, phase, lens, rows, paged),
            Arch::Desync(n) => self.fwd_desync(x0, phase, lens, rows, paged, n),
            Arch::Upperbound => self.fwd_upperbound(x0, phase, lens, rows, paged),
        }
    }

    /// Rank-local split-batch schedule — the worker-side mirror of
    /// `TpEngine::forward_chunked` (same chunk order, same absorb points,
    /// so the two runtimes stay bitwise identical under every overlap
    /// mode).
    fn forward_chunked(&mut self, chunks: Vec<ChunkFwd>, phase: Phase) -> Result<HostTensor> {
        match self.arch {
            Arch::Standard => self.fwd_synced_chunked(chunks, phase, self.layers),
            Arch::Ladder => self.fwd_synced_chunked(chunks, phase, 0),
            Arch::Hybrid => self.fwd_synced_chunked(chunks, phase, self.layers / 2),
            Arch::Parallel => self.fwd_parallel_chunked(chunks, phase),
            Arch::Desync(n) => self.fwd_desync_chunked(chunks, phase, n),
            Arch::Upperbound => {
                // no communication to hide — chunks run back-to-back
                let mut parts = Vec::with_capacity(chunks.len());
                for c in chunks {
                    parts.push(self.fwd_upperbound(
                        c.x,
                        phase,
                        c.lens.as_deref(),
                        c.rows,
                        c.paged.as_ref(),
                    )?);
                }
                Ok(overlap::concat_chunks(parts))
            }
        }
    }

    /// Deposit this rank's partial for the next collective in the schedule.
    fn launch(&mut self, part: HostTensor, op: ReduceOp) -> Result<u64> {
        let seq = self.seq;
        self.seq += 1;
        self.coll.deposit(self.rank, seq, part, op)?;
        Ok(seq)
    }

    /// Wait a launched collective and add the reduced delta into `x`.
    fn absorb(&mut self, x: &mut HostTensor, seq: u64) -> Result<()> {
        let (delta, _exposed) = self.coll.wait(self.rank, seq)?;
        add_assign(x, &delta);
        Ok(())
    }

    /// Standard / Ladder / Hybrid (rank-local view of Algorithm 1): for
    /// ladder layers the AllReduce is waited only after the next module has
    /// been issued, so the modeled link time runs while this core computes.
    #[allow(clippy::too_many_arguments)]
    fn fwd_synced(
        &mut self,
        mut x: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
        ladder_from: usize,
    ) -> Result<HostTensor> {
        let mut pend_attn: Option<u64> = None;
        let mut pend_mlp: Option<u64> = None;
        for i in 0..self.layers {
            if i >= ladder_from {
                if let Some(seq) = pend_attn.take() {
                    self.absorb(&mut x, seq)?;
                }
                let attn = self.state.attn(&self.exec, i, &x, phase, lens, rows, paged)?;
                let attn_seq = self.launch(attn, ReduceOp::Sum)?;
                if let Some(seq) = pend_mlp.take() {
                    self.absorb(&mut x, seq)?;
                }
                let mlp = self.state.mlp(&self.exec, i, &x)?; // overlaps attn_seq
                let mlp_seq = self.launch(mlp, ReduceOp::Sum)?;
                pend_attn = Some(attn_seq);
                pend_mlp = Some(mlp_seq);
            } else {
                let attn = self.state.attn(&self.exec, i, &x, phase, lens, rows, paged)?;
                let seq = self.launch(attn, ReduceOp::Sum)?;
                self.absorb(&mut x, seq)?;
                let mlp = self.state.mlp(&self.exec, i, &x)?;
                let seq = self.launch(mlp, ReduceOp::Sum)?;
                self.absorb(&mut x, seq)?;
            }
        }
        if let Some(seq) = pend_attn.take() {
            self.absorb(&mut x, seq)?;
        }
        if let Some(seq) = pend_mlp.take() {
            self.absorb(&mut x, seq)?;
        }
        Ok(x)
    }

    /// Chunked Standard/Ladder/Hybrid — the worker-side mirror of
    /// `TpEngine::fwd_synced_chunked`: chunks round-robin through each
    /// (layer, block) step, each absorbing exactly what the unsplit
    /// schedule would absorb before that block, so a chunk's reduce hides
    /// behind the other chunks' module time on this core.
    fn fwd_synced_chunked(
        &mut self,
        chunks: Vec<ChunkFwd>,
        phase: Phase,
        ladder_from: usize,
    ) -> Result<HostTensor> {
        struct Run {
            fw: ChunkFwd,
            pend_attn: Option<u64>,
            pend_mlp: Option<u64>,
        }
        let mut runs: Vec<Run> = chunks
            .into_iter()
            .map(|fw| Run { fw, pend_attn: None, pend_mlp: None })
            .collect();
        for i in 0..self.layers {
            for r in 0..runs.len() {
                let pend = if i > ladder_from {
                    runs[r].pend_attn.take()
                } else {
                    runs[r].pend_mlp.take()
                };
                if let Some(seq) = pend {
                    self.absorb(&mut runs[r].fw.x, seq)?;
                }
                let fw = &runs[r].fw;
                let attn = self.state.attn(
                    &self.exec,
                    i,
                    &fw.x,
                    phase,
                    fw.lens.as_deref(),
                    fw.rows,
                    fw.paged.as_ref(),
                )?;
                runs[r].pend_attn = Some(self.launch(attn, ReduceOp::Sum)?);
            }
            for r in 0..runs.len() {
                let pend = if i >= ladder_from {
                    runs[r].pend_mlp.take()
                } else {
                    runs[r].pend_attn.take()
                };
                if let Some(seq) = pend {
                    self.absorb(&mut runs[r].fw.x, seq)?;
                }
                let mlp = self.state.mlp(&self.exec, i, &runs[r].fw.x)?;
                runs[r].pend_mlp = Some(self.launch(mlp, ReduceOp::Sum)?);
            }
        }
        let mut parts = Vec::with_capacity(runs.len());
        for mut r in runs {
            if let Some(seq) = r.pend_attn.take() {
                self.absorb(&mut r.fw.x, seq)?;
            }
            if let Some(seq) = r.pend_mlp.take() {
                self.absorb(&mut r.fw.x, seq)?;
            }
            parts.push(r.fw.x);
        }
        Ok(overlap::concat_chunks(parts))
    }

    /// PaLM parallel attention+MLP: one blocking reduce per layer.
    fn fwd_parallel(
        &mut self,
        mut x: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
    ) -> Result<HostTensor> {
        for i in 0..self.layers {
            let partial = self.state.fused(&self.exec, i, &x, phase, lens, rows, paged)?;
            let seq = self.launch(partial, ReduceOp::Sum)?;
            self.absorb(&mut x, seq)?;
        }
        Ok(x)
    }

    /// Chunked Parallel: each chunk's fused reduce is deferred to its next
    /// layer so the other chunks' fused blocks overlap it.
    fn fwd_parallel_chunked(&mut self, chunks: Vec<ChunkFwd>, phase: Phase) -> Result<HostTensor> {
        let mut runs: Vec<(ChunkFwd, Option<u64>)> =
            chunks.into_iter().map(|fw| (fw, None)).collect();
        for i in 0..self.layers {
            for r in 0..runs.len() {
                if let Some(seq) = runs[r].1.take() {
                    self.absorb(&mut runs[r].0.x, seq)?;
                }
                let fw = &runs[r].0;
                let partial = self.state.fused(
                    &self.exec,
                    i,
                    &fw.x,
                    phase,
                    fw.lens.as_deref(),
                    fw.rows,
                    fw.paged.as_ref(),
                )?;
                runs[r].1 = Some(self.launch(partial, ReduceOp::Sum)?);
            }
        }
        let mut parts = Vec::with_capacity(runs.len());
        for (mut fw, pend) in runs {
            if let Some(seq) = pend {
                self.absorb(&mut fw.x, seq)?;
            }
            parts.push(fw.x);
        }
        Ok(overlap::concat_chunks(parts))
    }

    /// Desync-nx: this rank's residual stream diverges between retained
    /// reduces; a retained reduce carries `partial + r/tp`, re-synchronizing
    /// all streams to the reduced value.
    #[allow(clippy::too_many_arguments)]
    fn fwd_desync(
        &mut self,
        x0: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
        n: usize,
    ) -> Result<HostTensor> {
        let tp = self.tp as f32;
        let mut r = x0;
        let mut c = 0usize;
        let mut synced = true;
        for i in 0..self.layers {
            for kind in [BlockSel::Attn, BlockSel::Mlp] {
                let mut p = match kind {
                    BlockSel::Attn => {
                        self.state.attn(&self.exec, i, &r, phase, lens, rows, paged)?
                    }
                    BlockSel::Mlp => self.state.mlp(&self.exec, i, &r)?,
                };
                c += 1;
                if c % n == 0 {
                    // retained reduce: message = partial + residual/tp
                    for (a, b) in p.data.iter_mut().zip(&r.data) {
                        *a += b / tp;
                    }
                    let seq = self.launch(p, ReduceOp::Sum)?;
                    let (x, _) = self.coll.wait(self.rank, seq)?;
                    r = (*x).clone();
                    synced = true;
                } else {
                    add_assign(&mut r, &p);
                    synced = false;
                }
            }
        }
        if !synced {
            // final resync (mean) so the head sees one residual
            let msg =
                HostTensor::new(r.shape.clone(), r.data.iter().map(|v| v / tp).collect());
            let seq = self.launch(msg, ReduceOp::Sum)?;
            let (x, _) = self.coll.wait(self.rank, seq)?;
            r = (*x).clone();
        }
        Ok(r)
    }

    /// Chunked Desync-nx: a retained reduce *replaces* the chunk's stream,
    /// so its wait is deferred to the chunk's next block step (covered by
    /// the other chunks' compute) instead of being absorbed additively.
    fn fwd_desync_chunked(
        &mut self,
        chunks: Vec<ChunkFwd>,
        phase: Phase,
        n: usize,
    ) -> Result<HostTensor> {
        let tp = self.tp as f32;
        struct Run {
            fw: ChunkFwd, // fw.x doubles as this rank's residual stream
            c: usize,
            synced: bool,
            pend: Option<u64>,
        }
        let mut runs: Vec<Run> = chunks
            .into_iter()
            .map(|fw| Run { fw, c: 0, synced: true, pend: None })
            .collect();
        for i in 0..self.layers {
            for kind in [BlockSel::Attn, BlockSel::Mlp] {
                for r in 0..runs.len() {
                    if let Some(seq) = runs[r].pend.take() {
                        let (x, _) = self.coll.wait(self.rank, seq)?;
                        runs[r].fw.x = (*x).clone();
                    }
                    let fw = &runs[r].fw;
                    let mut p = match kind {
                        BlockSel::Attn => self.state.attn(
                            &self.exec,
                            i,
                            &fw.x,
                            phase,
                            fw.lens.as_deref(),
                            fw.rows,
                            fw.paged.as_ref(),
                        )?,
                        BlockSel::Mlp => self.state.mlp(&self.exec, i, &fw.x)?,
                    };
                    runs[r].c += 1;
                    if runs[r].c % n == 0 {
                        // retained reduce: message = partial + residual/tp
                        for (a, b) in p.data.iter_mut().zip(&runs[r].fw.x.data) {
                            *a += b / tp;
                        }
                        runs[r].pend = Some(self.launch(p, ReduceOp::Sum)?);
                        runs[r].synced = true;
                    } else {
                        add_assign(&mut runs[r].fw.x, &p);
                        runs[r].synced = false;
                    }
                }
            }
        }
        let mut parts = Vec::with_capacity(runs.len());
        for mut r in runs {
            if let Some(seq) = r.pend.take() {
                let (x, _) = self.coll.wait(self.rank, seq)?;
                r.fw.x = (*x).clone();
            }
            if !r.synced {
                // final resync (mean) so the head sees one residual
                let msg = HostTensor::new(
                    r.fw.x.shape.clone(),
                    r.fw.x.data.iter().map(|v| v / tp).collect(),
                );
                let seq = self.launch(msg, ReduceOp::Sum)?;
                let (x, _) = self.coll.wait(self.rank, seq)?;
                r.fw.x = (*x).clone();
            }
            parts.push(r.fw.x);
        }
        Ok(overlap::concat_chunks(parts))
    }

    /// Upperbound: communication deleted. The ranks still rendezvous on rank
    /// 0's partial (free, unmetered) so every rank's residual stays bitwise
    /// identical to the sequential oracle's single shared stream.
    fn fwd_upperbound(
        &mut self,
        mut x: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
    ) -> Result<HostTensor> {
        for i in 0..self.layers {
            let attn = self.state.attn(&self.exec, i, &x, phase, lens, rows, paged)?;
            let seq = self.launch(attn, ReduceOp::TakeRank0)?;
            self.absorb(&mut x, seq)?;
            let mlp = self.state.mlp(&self.exec, i, &x)?;
            let seq = self.launch(mlp, ReduceOp::TakeRank0)?;
            self.absorb(&mut x, seq)?;
        }
        Ok(x)
    }
}
