//! The TP engine: per-architecture forward scheduling over per-rank modules.
//!
//! This file is the paper's Algorithm 1 (and its Standard / Parallel /
//! Desync / Upperbound counterparts) in executable form. The residual
//! stream lives here as host tensors; every AllReduce goes through the
//! [`CollectiveEngine`] which performs the real reduction and charges the
//! modeled link time as a deadline — so the Ladder schedule's overlap is a
//! genuine wall-clock effect.
//!
//! Two rank runtimes share the numerics (bitwise — see the
//! `runtime_determinism` test):
//!
//! * [`RuntimeKind::Threaded`] (default) — one worker thread per rank,
//!   rendezvous collectives; per-rank module time genuinely overlaps across
//!   cores, so `tp`-way compute no longer serializes onto one thread.
//! * [`RuntimeKind::Sequential`] — the single-threaded reference oracle:
//!   ranks execute in sequence on the caller's thread and per-rank module
//!   time is summed. Kept for engine-vs-engine numeric diffs and tracing.

use std::rc::Rc;

use anyhow::{bail, Result};

use super::kv::{KvLayout, PagedFwd, PagedKvCache};
use super::overlap::{self, ChunkFwd, OverlapMode};
use super::rank::{Embedder, Phase, RankState, Rows};
use super::threaded::ThreadedRuntime;
use super::{add_assign, BlockSel};
use crate::comm::{Codec, CollectiveEngine, CommHandle, CommPhase, Interconnect};
use crate::model::{Arch, HostTensor, LlamaConfig, WeightStore};
use crate::runtime::Exec;

/// Which rank execution runtime an engine uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RuntimeKind {
    /// Single-threaded reference oracle: ranks run in sequence on the
    /// caller's thread; per-rank module time is summed, not overlapped.
    Sequential,
    /// One worker thread per rank with rendezvous collectives (default):
    /// per-rank module time overlaps on sibling cores, the measured
    /// counterpart of the paper's concurrent TP ranks.
    #[default]
    Threaded,
}

impl RuntimeKind {
    pub fn parse(s: &str) -> Result<RuntimeKind> {
        Ok(match s {
            "sequential" | "seq" => RuntimeKind::Sequential,
            "threaded" | "thread" => RuntimeKind::Threaded,
            _ => bail!("unknown runtime {s:?} (sequential|threaded)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RuntimeKind::Sequential => "sequential",
            RuntimeKind::Threaded => "threaded",
        }
    }
}

/// Multi-rank tensor-parallel engine for one (arch, tp, batch) setting.
pub struct TpEngine {
    pub cfg: LlamaConfig,
    pub tp: usize,
    pub arch: Arch,
    pub batch: usize,
    pub runtime: RuntimeKind,
    /// Split-batch overlap mode (`--overlap`): full-batch forwards are cut
    /// into row chunks pipelined through the blocks so one chunk's
    /// AllReduce hides behind another chunk's compute.
    pub overlap: OverlapMode,
    pub comm: CollectiveEngine,
    /// KV storage layout (fixed-slot slabs or the paged pool).
    layout: KvLayout,
    exec: Rc<Exec>,
    /// Sequential runtime's rank states (empty under the threaded runtime,
    /// whose workers own their rank state thread-locally).
    ranks: Vec<RankState>,
    /// Worker threads (threaded runtime only).
    threaded: Option<ThreadedRuntime>,
    /// Coordinator-side embedding runner (threaded runtime only).
    embedder: Option<Embedder>,
    /// Current sequence length per batch slot (continuous batching state).
    pub lens: Vec<i32>,
    buckets: Vec<usize>,
    /// Optional wall-clock execution tracer (Figure 6 counterpart); enable
    /// with [`TpEngine::enable_trace`]. Sequential runtime only — worker
    /// threads do not feed the tracer.
    pub tracer: Option<super::trace::EngineTracer>,
}

impl TpEngine {
    /// Build an engine on the default (threaded) runtime.
    pub fn new(
        exec: Rc<Exec>,
        weights: &WeightStore,
        tp: usize,
        arch: Arch,
        batch: usize,
        interconnect: Interconnect,
    ) -> Result<TpEngine> {
        Self::with_runtime(exec, weights, tp, arch, batch, interconnect, RuntimeKind::default())
    }

    /// Build an engine on an explicit runtime (`--runtime` toggle; the
    /// sequential oracle is kept so numerics can be diffed engine-vs-engine)
    /// with the default fixed-slot KV layout.
    pub fn with_runtime(
        exec: Rc<Exec>,
        weights: &WeightStore,
        tp: usize,
        arch: Arch,
        batch: usize,
        interconnect: Interconnect,
        runtime: RuntimeKind,
    ) -> Result<TpEngine> {
        Self::with_layout(exec, weights, tp, arch, batch, interconnect, runtime, KvLayout::Slab)
    }

    /// Build an engine with an explicit KV layout. `KvLayout::Paged` sizes
    /// every rank's pool to `pages` pages of `page_size` tokens; requests
    /// then route their attention through per-request page tables
    /// ([`TpEngine::prefill_chunk_slot`] / [`TpEngine::decode_paged`]).
    #[allow(clippy::too_many_arguments)]
    pub fn with_layout(
        exec: Rc<Exec>,
        weights: &WeightStore,
        tp: usize,
        arch: Arch,
        batch: usize,
        interconnect: Interconnect,
        runtime: RuntimeKind,
        layout: KvLayout,
    ) -> Result<TpEngine> {
        Self::with_codec(exec, weights, tp, arch, batch, interconnect, runtime, layout, Codec::default())
    }

    /// Full constructor: an explicit collective wire [`Codec`] on top of
    /// [`TpEngine::with_layout`] (`--codec` toggle). The codec applies to
    /// every AllReduce on both runtimes — the sequential oracle encodes in
    /// [`CollectiveEngine::allreduce`], the threaded workers inherit it
    /// through the shared rendezvous collective.
    #[allow(clippy::too_many_arguments)]
    pub fn with_codec(
        exec: Rc<Exec>,
        weights: &WeightStore,
        tp: usize,
        arch: Arch,
        batch: usize,
        interconnect: Interconnect,
        runtime: RuntimeKind,
        layout: KvLayout,
        codec: Codec,
    ) -> Result<TpEngine> {
        Self::with_overlap(
            exec,
            weights,
            tp,
            arch,
            batch,
            interconnect,
            runtime,
            layout,
            codec,
            OverlapMode::default(),
        )
    }

    /// Full constructor: an explicit split-batch [`OverlapMode`] on top of
    /// [`TpEngine::with_codec`] (`--overlap` toggle). Split modes cut every
    /// full-batch forward into row chunks pipelined through the per-layer
    /// blocks — bitwise identical to the unsplit schedule on both runtimes
    /// (see `engine/overlap.rs`).
    #[allow(clippy::too_many_arguments)]
    pub fn with_overlap(
        exec: Rc<Exec>,
        weights: &WeightStore,
        tp: usize,
        arch: Arch,
        batch: usize,
        interconnect: Interconnect,
        runtime: RuntimeKind,
        layout: KvLayout,
        codec: Codec,
        overlap: OverlapMode,
    ) -> Result<TpEngine> {
        let cfg = exec.cfg().clone();
        let sp = exec.serving();
        // compiled-shape backends only have executables for the exported
        // (tp, batch) grid; the native executor is shape-agnostic, so its
        // lists are advisory and only the structural rules below apply
        if sp.compiled_shapes && !sp.tps.contains(&tp) {
            bail!("tp={tp} not exported (available: {:?})", sp.tps);
        }
        if sp.compiled_shapes && !sp.batches.contains(&batch) {
            bail!("batch={batch} not exported (available: {:?})", sp.batches);
        }
        if batch == 0 {
            bail!("batch must be at least 1");
        }
        let buckets = sp.buckets.clone();
        if tp == 0 || cfg.heads % tp != 0 || cfg.kv_heads % tp != 0 {
            bail!("tp={tp} does not divide heads/kv_heads");
        }
        if cfg.ffn % tp != 0 || cfg.vocab % tp != 0 {
            bail!("tp={tp} does not divide ffn/vocab");
        }
        if let KvLayout::Paged { page_size, pages } = layout {
            if page_size == 0 || pages == 0 {
                bail!("paged KV layout needs page_size > 0 and pages > 0");
            }
            if sp.compiled_shapes {
                bail!(
                    "paged KV attention is not in the compiled-shape export set — \
                     use the native backend for paged engines"
                );
            }
        }
        if overlap != OverlapMode::None {
            if sp.compiled_shapes {
                bail!(
                    "--overlap {} splits forwards into sub-batch chunks whose module \
                     shapes are not in the compiled-shape export grid — use the \
                     native backend for overlap engines",
                    overlap.name()
                );
            }
            // split boundaries fall on row edges; the quantized codecs'
            // scale blocks must tile each row exactly or chunked wire
            // payloads would quantize across different block boundaries
            // than the unsplit forward (breaking the bitwise contract)
            let qb = crate::comm::codec::QUANT_BLOCK;
            if codec != Codec::Fp32 && cfg.hidden % qb != 0 {
                bail!(
                    "--overlap with the {} codec needs hidden ({}) divisible by the \
                     {qb}-element quantization block, or chunked reduces would not \
                     be bitwise-identical to unsplit ones",
                    codec.name(),
                    cfg.hidden
                );
            }
        }
        if let Some(tt) = interconnect.two_tier {
            if tt.gpus_per_node == 0 || tp % tt.gpus_per_node != 0 {
                bail!(
                    "two_tier gpus_per_node={} does not divide tp={tp} — every \
                     simulated node must hold the same number of ranks",
                    tt.gpus_per_node
                );
            }
        }
        // Upperbound deletes ALL communication (paper: "removes all
        // communication operations"), including the lm-head AllGather — so
        // its collective engine runs on the free local fabric.
        let interconnect = if matches!(arch, Arch::Upperbound) {
            crate::comm::Interconnect::new(crate::comm::Fabric::Local)
        } else {
            interconnect
        };
        let comm = CollectiveEngine::with_codec(tp, interconnect, codec);
        let (ranks, threaded, embedder) = match runtime {
            RuntimeKind::Sequential => {
                let ranks = (0..tp)
                    .map(|t| RankState::new(&exec, &cfg, weights, t, tp, batch, t == 0, layout))
                    .collect::<Result<Vec<_>>>()?;
                (ranks, None, None)
            }
            RuntimeKind::Threaded => {
                let rt = ThreadedRuntime::spawn(
                    exec.spec().clone(),
                    weights,
                    tp,
                    arch,
                    batch,
                    layout,
                    overlap,
                    comm.rendezvous(),
                )?;
                (Vec::new(), Some(rt), Some(Embedder::new(&exec, weights)?))
            }
        };
        Ok(TpEngine {
            cfg,
            tp,
            arch,
            batch,
            runtime,
            overlap,
            comm,
            layout,
            exec,
            ranks,
            threaded,
            embedder,
            lens: vec![0; batch],
            buckets,
            tracer: None,
        })
    }

    /// The collective wire codec this engine's AllReduces run through.
    pub fn codec(&self) -> Codec {
        self.comm.codec()
    }

    /// Start (or restart) wall-clock tracing of module + AllReduce spans.
    /// Sequential runtime only — worker threads do not feed the tracer, so
    /// enabling it on a threaded engine would silently record nothing.
    pub fn enable_trace(&mut self) -> Result<()> {
        if self.runtime == RuntimeKind::Threaded {
            bail!("tracing requires the sequential runtime (--runtime sequential)");
        }
        self.tracer = Some(super::trace::EngineTracer::new());
        Ok(())
    }

    /// Smallest exported prefill bucket that fits `prompt_len`.
    pub fn pick_bucket(&self, prompt_len: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .filter(|&b| b >= prompt_len)
            .min()
            .ok_or_else(|| {
                anyhow::anyhow!("prompt of {prompt_len} exceeds largest bucket {:?}", self.buckets)
            })
    }

    // ---------------------------------------------------------------------
    // public inference API
    // ---------------------------------------------------------------------

    /// Batched prefill: `tokens` is [B, bucket] (padded); `true_lens[b]` is
    /// each row's real prompt length. Returns last-position logits [B, V].
    pub fn prefill(
        &mut self,
        tokens: &[i32],
        bucket: usize,
        true_lens: &[usize],
    ) -> Result<HostTensor> {
        self.want_slab("prefill")?;
        let b = self.batch;
        if tokens.len() != b * bucket || true_lens.len() != b {
            bail!("prefill shapes: {} tokens, {} lens", tokens.len(), true_lens.len());
        }
        let x0 = self.embed(tokens, b, bucket)?;
        let last: Vec<usize> = true_lens.iter().map(|&l| l - 1).collect();
        let logits = self.run(x0, Phase::Prefill, None, None, &last, None)?;
        for (slot, &l) in true_lens.iter().enumerate() {
            self.lens[slot] = l as i32;
        }
        Ok(logits)
    }

    /// Single-slot prefill into `slot` (continuous batching): `tokens` is
    /// [1, bucket]. Returns last-position logits [V].
    pub fn prefill_slot(
        &mut self,
        slot: usize,
        tokens: &[i32],
        bucket: usize,
        true_len: usize,
    ) -> Result<Vec<f32>> {
        self.want_slab("prefill_slot")?;
        if slot >= self.batch {
            bail!("slot {slot} out of range");
        }
        let x0 = self.embed(tokens, 1, bucket)?;
        let logits = self.run(x0, Phase::Prefill, None, Some(slot), &[true_len - 1], None)?;
        self.lens[slot] = true_len as i32;
        Ok(logits.data)
    }

    /// One decode step for all slots: `tokens` is [B]. Returns logits [B, V]
    /// and advances every slot's length. Inactive slots decode garbage that
    /// is never read (their cache writes land beyond any live region).
    pub fn decode(&mut self, tokens: &[i32]) -> Result<HostTensor> {
        self.want_slab("decode")?;
        let b = self.batch;
        if tokens.len() != b {
            bail!("decode wants {b} tokens, got {}", tokens.len());
        }
        let lens = self.lens.clone();
        let x0 = self.embed(tokens, b, 1)?;
        let last = vec![0usize; b];
        let logits = self.run(x0, Phase::Decode, Some(&lens), None, &last, None)?;
        for l in self.lens.iter_mut() {
            *l += 1;
        }
        Ok(logits)
    }

    /// Prefill one chunk of `slot`'s prompt through the paged pool
    /// (continuous batching with chunked prefill): `tokens` are the chunk's
    /// token ids, `start` its first global position, `table` the request's
    /// page table (which must back `start + tokens.len()` positions).
    /// Returns last-position logits [V] — only meaningful for the final
    /// chunk. Because every kernel is row-local and keys are visited in
    /// logical order, the final chunk's logits are bitwise identical to a
    /// one-shot slab prefill of the whole prompt.
    pub fn prefill_chunk_slot(
        &mut self,
        slot: usize,
        tokens: &[i32],
        start: usize,
        table: &[u32],
    ) -> Result<Vec<f32>> {
        self.want_paged("prefill_chunk_slot")?;
        if slot >= self.batch {
            bail!("slot {slot} out of range");
        }
        if tokens.is_empty() {
            bail!("empty prefill chunk");
        }
        let end = start + tokens.len();
        let KvLayout::Paged { page_size, .. } = self.layout else { unreachable!() };
        if table.len() * page_size < end {
            bail!("page table backs {} tokens, chunk ends at {end}", table.len() * page_size);
        }
        let paged = PagedFwd {
            tables: table.iter().map(|&p| p as i32).collect(),
            max_pages: table.len(),
            start: start as i32,
        };
        let x0 = self.embed(tokens, 1, tokens.len())?;
        let logits =
            self.run(x0, Phase::Prefill, None, Some(slot), &[tokens.len() - 1], Some(&paged))?;
        self.lens[slot] = end as i32;
        Ok(logits.data)
    }

    /// One paged decode step: `tokens` is [B], `active[b]` says which slots
    /// really decode (inactive slots are skipped inside the module — no
    /// page access, no length advance), and `tables` is the `-1`-padded
    /// `[B, max_pages]` page-table matrix. Returns logits [B, V]; inactive
    /// rows are garbage and must not be read.
    pub fn decode_paged(
        &mut self,
        tokens: &[i32],
        active: &[bool],
        tables: Vec<i32>,
        max_pages: usize,
    ) -> Result<HostTensor> {
        self.want_paged("decode_paged")?;
        let b = self.batch;
        if tokens.len() != b || active.len() != b || tables.len() != b * max_pages {
            bail!(
                "decode_paged shapes: {} tokens, {} active, {} table entries for batch {b}",
                tokens.len(),
                active.len(),
                tables.len()
            );
        }
        let mut lens = self.lens.clone();
        for (l, &a) in lens.iter_mut().zip(active) {
            if !a {
                *l = -1;
            }
        }
        let paged = PagedFwd { tables, max_pages, start: 0 };
        let x0 = self.embed(tokens, b, 1)?;
        let last = vec![0usize; b];
        let logits = self.run(x0, Phase::Decode, Some(&lens), None, &last, Some(&paged))?;
        for (slot, &a) in active.iter().enumerate() {
            if a {
                self.lens[slot] += 1;
            }
        }
        Ok(logits)
    }

    /// Duplicate one KV page into another on every rank (all layers, K and
    /// V) — the copy-on-write step behind full-prompt prefix-cache hits:
    /// the shared trailing page is copied into a page the request owns
    /// privately before its final prompt token is re-prefilled over it.
    /// Channel FIFO ordering on the threaded runtime guarantees the copy
    /// lands before any later forward reads `dst`.
    pub fn copy_page(&mut self, src: u32, dst: u32) -> Result<()> {
        self.want_paged("copy_page")?;
        let KvLayout::Paged { pages, .. } = self.layout else { unreachable!() };
        if src as usize >= pages || dst as usize >= pages || src == dst {
            bail!("copy_page: {src} -> {dst} invalid for a {pages}-page pool");
        }
        match self.runtime {
            RuntimeKind::Sequential => {
                for rank in &mut self.ranks {
                    rank.copy_page(src, dst)?;
                }
                Ok(())
            }
            RuntimeKind::Threaded => {
                self.threaded.as_ref().expect("threaded runtime").copy_page(src, dst)
            }
        }
    }

    /// Serialize one KV page on every rank, in rank order — all layers, K
    /// plane then V plane per layer ([`PagedKvCache::read_page`]'s layout).
    /// This is the disk spill tier's download path; it blocks until every
    /// rank has answered, so the snapshot is consistent.
    pub fn read_page(&self, page: u32) -> Result<Vec<Vec<f32>>> {
        self.want_paged("read_page")?;
        let KvLayout::Paged { pages, .. } = self.layout else { unreachable!() };
        if page as usize >= pages {
            bail!("read_page: page {page} out of range for a {pages}-page pool");
        }
        match self.runtime {
            RuntimeKind::Sequential => {
                self.ranks.iter().map(|rank| rank.read_page(page)).collect()
            }
            RuntimeKind::Threaded => {
                self.threaded.as_ref().expect("threaded runtime").read_page(page)
            }
        }
    }

    /// Restore one KV page on every rank from per-rank serialized bytes —
    /// the disk spill tier's upload path, bitwise-exact inverse of
    /// [`TpEngine::read_page`]. Channel FIFO ordering on the threaded
    /// runtime lands the write before any later forward reads the page.
    pub fn write_page(&mut self, page: u32, per_rank: &[Vec<f32>]) -> Result<()> {
        self.want_paged("write_page")?;
        let KvLayout::Paged { pages, .. } = self.layout else { unreachable!() };
        if page as usize >= pages {
            bail!("write_page: page {page} out of range for a {pages}-page pool");
        }
        if per_rank.len() != self.tp {
            bail!("write_page: {} rank payloads for tp={}", per_rank.len(), self.tp);
        }
        match self.runtime {
            RuntimeKind::Sequential => {
                for (rank, data) in self.ranks.iter_mut().zip(per_rank) {
                    rank.write_page(page, data)?;
                }
                Ok(())
            }
            RuntimeKind::Threaded => {
                self.threaded.as_ref().expect("threaded runtime").write_page(page, per_rank)
            }
        }
    }

    /// Geometry fingerprint for the disk spill tier: spill files carry it
    /// in their header, and a store opened by a differently-shaped engine
    /// (other arch, TP degree, layer/head/page geometry) rejects every
    /// file instead of restoring bytes that would be misinterpreted.
    pub fn kv_fingerprint(&self) -> u64 {
        let page_size = match self.layout {
            KvLayout::Slab => 0,
            KvLayout::Paged { page_size, .. } => page_size,
        };
        let desc = format!(
            "{}/tp{}/layers{}/kvh{}/hd{}/hidden{}/ps{page_size}/f32",
            self.arch.name(),
            self.tp,
            self.cfg.layers,
            self.cfg.kv_heads,
            self.cfg.head_dim,
            self.cfg.hidden,
        );
        super::spill::fnv1a64_bytes(desc.as_bytes())
    }

    /// Release a slot (request finished/evicted). Slab layouts zero the
    /// slot's written prefix; paged layouts must **not** touch pool bytes —
    /// the batcher's allocator reclaims unreferenced pages, and pages still
    /// referenced by the prefix tree keep serving cache hits after their
    /// writer is gone.
    pub fn release_slot(&mut self, slot: usize) {
        let written = self.lens[slot].max(0) as usize;
        self.lens[slot] = 0;
        match self.runtime {
            RuntimeKind::Sequential => {
                for rank in &mut self.ranks {
                    rank.release_slot(slot, written);
                }
            }
            RuntimeKind::Threaded => {
                self.threaded.as_ref().expect("threaded runtime").release_slot(slot, written);
            }
        }
    }

    fn want_slab(&self, what: &str) -> Result<()> {
        if self.layout.is_paged() {
            bail!("{what} is a slab-layout entry point; this engine is paged");
        }
        Ok(())
    }

    fn want_paged(&self, what: &str) -> Result<()> {
        if !self.layout.is_paged() {
            bail!("{what} needs a paged engine (KvLayout::Paged)");
        }
        Ok(())
    }

    /// KV bytes one slot occupies across all ranks (batcher admission unit).
    /// Computed from the config — identical to summing each rank's
    /// `KvCache::bytes_per_slot`, and available without a worker round-trip.
    pub fn kv_bytes_per_slot(&self) -> usize {
        super::kv::KvCache::bytes_per_slot_all_ranks(&self.cfg, self.tp)
    }

    /// This engine's KV storage layout.
    pub fn kv_layout(&self) -> KvLayout {
        self.layout
    }

    /// Bytes one KV page occupies across all ranks (paged admission unit).
    pub fn kv_page_bytes(&self) -> usize {
        match self.layout {
            KvLayout::Slab => 0,
            KvLayout::Paged { page_size, .. } => {
                PagedKvCache::page_bytes_all_ranks(&self.cfg, self.tp, page_size)
            }
        }
    }

    /// Pages a maximal (`max_seq`-long) sequence needs — the fixed width of
    /// the per-forward page-table matrix.
    pub fn kv_max_pages_per_seq(&self) -> usize {
        match self.layout {
            KvLayout::Slab => 0,
            KvLayout::Paged { page_size, .. } => self.cfg.max_seq.div_ceil(page_size),
        }
    }

    pub fn exec(&self) -> &Exec {
        &self.exec
    }

    /// Which execution backend this engine runs on ("native" / "xla").
    pub fn backend_name(&self) -> &'static str {
        self.exec.backend_name()
    }

    // ---------------------------------------------------------------------
    // runtime dispatch
    // ---------------------------------------------------------------------

    /// Embed on the coordinator (the activation is then broadcast to the
    /// rank workers under the threaded runtime).
    fn embed(&self, tokens: &[i32], b: usize, s: usize) -> Result<HostTensor> {
        match self.runtime {
            RuntimeKind::Sequential => self.ranks[0].embed(&self.exec, tokens, b, s),
            RuntimeKind::Threaded => {
                self.embedder.as_ref().expect("threaded runtime").embed(&self.exec, tokens, b, s)
            }
        }
    }

    /// Full forward + LM head on the active runtime. `last[b]` is the
    /// position whose logits each row wants; `paged` carries the page-table
    /// view when this engine routes KV through the paged pool.
    fn run(
        &mut self,
        x0: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        slot: Option<usize>,
        last: &[usize],
        paged: Option<&PagedFwd>,
    ) -> Result<HostTensor> {
        // slice the per-tier/per-phase comm ledgers; forwards are fully
        // synchronous so the marker cannot race a collective
        self.comm.set_phase(match phase {
            Phase::Prefill => CommPhase::Prefill,
            Phase::Decode => CommPhase::Decode,
        });
        let rows = match slot {
            Some(s) => Rows::Slot(s),
            None => Rows::All,
        };
        match self.runtime {
            RuntimeKind::Sequential => {
                let finals = self.forward(x0, phase, lens, rows, paged)?;
                self.head(&finals, last)
            }
            RuntimeKind::Threaded => {
                let shards = self
                    .threaded
                    .as_ref()
                    .expect("threaded runtime")
                    .forward(x0, phase, lens, rows, paged, last)?;
                self.comm.allgather_concat(shards)
            }
        }
    }

    // ---------------------------------------------------------------------
    // the per-architecture forward schedules (sequential runtime)
    // ---------------------------------------------------------------------

    /// Run all layers; returns per-rank final residuals.
    fn forward(
        &mut self,
        x0: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
    ) -> Result<Vec<HostTensor>> {
        if self.overlap != OverlapMode::None && rows == Rows::All && x0.shape[0] > 1 {
            let chunks = overlap::split_forward(self.overlap, &x0, lens, paged);
            if chunks.len() > 1 {
                return self.forward_chunked(chunks, phase);
            }
        }
        match self.arch {
            Arch::Standard => self.fwd_synced(x0, phase, lens, rows, paged, self.cfg.layers),
            Arch::Ladder => self.fwd_synced(x0, phase, lens, rows, paged, 0),
            Arch::Hybrid => self.fwd_synced(x0, phase, lens, rows, paged, self.cfg.layers / 2),
            Arch::Parallel => self.fwd_parallel(x0, phase, lens, rows, paged),
            Arch::Desync(n) => self.fwd_desync(x0, phase, lens, rows, paged, n),
            Arch::Upperbound => self.fwd_upperbound(x0, phase, lens, rows, paged),
        }
    }

    /// Split-batch forward: chunks advance round-robin through each
    /// (layer, block) step, so between a chunk launching an AllReduce and
    /// absorbing it every *other* chunk runs one block of compute — the
    /// TokenWeave-style overlap, without touching the architecture. The
    /// per-chunk absorb points replay the unsplit schedule's dataflow
    /// exactly (deferred, never reordered), which keeps every chunk's
    /// residual bitwise identical to its rows in the unsplit forward.
    fn forward_chunked(
        &mut self,
        chunks: Vec<ChunkFwd>,
        phase: Phase,
    ) -> Result<Vec<HostTensor>> {
        let parts = match self.arch {
            Arch::Standard => self.fwd_synced_chunked(chunks, phase, self.cfg.layers)?,
            Arch::Ladder => self.fwd_synced_chunked(chunks, phase, 0)?,
            Arch::Hybrid => self.fwd_synced_chunked(chunks, phase, self.cfg.layers / 2)?,
            Arch::Parallel => self.fwd_parallel_chunked(chunks, phase)?,
            Arch::Desync(n) => self.fwd_desync_chunked(chunks, phase, n)?,
            Arch::Upperbound => {
                // no communication to hide — chunks run back-to-back
                let mut parts = Vec::with_capacity(chunks.len());
                for c in chunks {
                    let mut f = self.fwd_upperbound(
                        c.x,
                        phase,
                        c.lens.as_deref(),
                        c.rows,
                        c.paged.as_ref(),
                    )?;
                    parts.push(f.swap_remove(0));
                }
                parts
            }
        };
        Ok(vec![overlap::concat_chunks(parts); self.tp])
    }

    /// Standard (`ladder_from == layers`), Ladder (`== 0`) and Hybrid
    /// (`== layers/2`) share one loop. For ladder layers the AllReduce of a
    /// module is waited on only *after* the next module has been issued —
    /// paper Algorithm 1 — so the modeled link time runs concurrently with
    /// the next module's execution.
    fn fwd_synced(
        &mut self,
        mut x: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
        ladder_from: usize,
    ) -> Result<Vec<HostTensor>> {
        let layers = self.cfg.layers;
        let mut pend_attn: Option<CommHandle> = None;
        let mut pend_mlp: Option<CommHandle> = None;
        for i in 0..layers {
            if i >= ladder_from {
                // -- ladder block (Alg. 1) --
                if let Some(h) = pend_attn.take() {
                    self.absorb(&mut x, h); // wait prev layer's attn reduce
                }
                let attn = self.run_attn_all(i, &x, phase, lens, rows, paged)?;
                let attn_h = self.comm.allreduce(attn)?; // async
                if let Some(h) = pend_mlp.take() {
                    self.absorb(&mut x, h); // wait prev layer's MLP reduce
                }
                let mlp = self.run_mlp_all(i, &x)?; // overlaps attn_h
                let mlp_h = self.comm.allreduce(mlp)?; // async into next layer
                pend_attn = Some(attn_h);
                pend_mlp = Some(mlp_h);
            } else {
                // -- standard block: blocking reduces --
                let attn = self.run_attn_all(i, &x, phase, lens, rows, paged)?;
                let h = self.comm.allreduce(attn)?;
                self.absorb(&mut x, h);
                let mlp = self.run_mlp_all(i, &x)?;
                let h = self.comm.allreduce(mlp)?;
                self.absorb(&mut x, h);
            }
        }
        if let Some(h) = pend_attn.take() {
            self.absorb(&mut x, h);
        }
        if let Some(h) = pend_mlp.take() {
            self.absorb(&mut x, h);
        }
        Ok(vec![x; self.tp])
    }

    /// PaLM parallel attention+MLP: one blocking reduce per layer.
    fn fwd_parallel(
        &mut self,
        mut x: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
    ) -> Result<Vec<HostTensor>> {
        for i in 0..self.cfg.layers {
            let mut partials = Vec::with_capacity(self.tp);
            for t in 0..self.tp {
                partials.push(self.ranks[t].fused(&self.exec, i, &x, phase, lens, rows, paged)?);
            }
            let h = self.comm.allreduce(partials)?;
            self.absorb(&mut x, h);
        }
        Ok(vec![x; self.tp])
    }

    /// Desync-nx (paper §5): keep every n-th AllReduce; a retained reduce
    /// carries `partial_t + r_t / tp`, re-synchronizing the streams.
    fn fwd_desync(
        &mut self,
        x0: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
        n: usize,
    ) -> Result<Vec<HostTensor>> {
        let tp = self.tp;
        let mut rs: Vec<HostTensor> = vec![x0; tp];
        let mut c = 0usize;
        let mut synced = true;
        for i in 0..self.cfg.layers {
            for kind in [BlockSel::Attn, BlockSel::Mlp] {
                let mut partials = Vec::with_capacity(tp);
                for t in 0..tp {
                    let p = match kind {
                        BlockSel::Attn => {
                            self.ranks[t].attn(&self.exec, i, &rs[t], phase, lens, rows, paged)?
                        }
                        BlockSel::Mlp => self.ranks[t].mlp(&self.exec, i, &rs[t])?,
                    };
                    partials.push(p);
                }
                c += 1;
                if c % n == 0 {
                    // retained reduce: message = partial + residual/tp
                    for (t, p) in partials.iter_mut().enumerate() {
                        for (a, b) in p.data.iter_mut().zip(&rs[t].data) {
                            *a += b / tp as f32;
                        }
                    }
                    let h = self.comm.allreduce(partials)?;
                    if let Some(tr) = &mut self.tracer {
                        let (launch, ready) = h.span();
                        tr.record("allreduce_resync", 1, launch, ready);
                    }
                    let (x, exposed) = h.wait();
                    self.comm.record_exposed(exposed);
                    rs = vec![x; tp];
                    synced = true;
                } else {
                    for (t, p) in partials.into_iter().enumerate() {
                        add_assign(&mut rs[t], &p);
                    }
                    synced = false;
                }
            }
        }
        if !synced {
            // final resync (mean) so the head sees one residual
            let msgs: Vec<HostTensor> = rs
                .iter()
                .map(|r| {
                    let scaled = r.data.iter().map(|v| v / tp as f32).collect();
                    HostTensor::new(r.shape.clone(), scaled)
                })
                .collect();
            let h = self.comm.allreduce(msgs)?;
            let (x, exposed) = h.wait();
            self.comm.record_exposed(exposed);
            rs = vec![x; tp];
        }
        Ok(rs)
    }

    /// Communication deleted entirely (speed ceiling; wrong numerics).
    fn fwd_upperbound(
        &mut self,
        mut x: HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
    ) -> Result<Vec<HostTensor>> {
        for i in 0..self.cfg.layers {
            let attn = self.run_attn_all(i, &x, phase, lens, rows, paged)?;
            add_assign(&mut x, &attn[0]);
            let mlp = self.run_mlp_all(i, &x)?;
            add_assign(&mut x, &mlp[0]);
        }
        Ok(vec![x; self.tp])
    }

    // ---------------------------------------------------------------------
    // split-batch (overlap) chunk schedules — see `engine/overlap.rs`
    // ---------------------------------------------------------------------

    /// Chunked Standard/Ladder/Hybrid. Per chunk, each block absorbs
    /// exactly what the unsplit schedule would have absorbed before it:
    /// attention waits the chunk's previous attn reduce on ladder layers
    /// (the previous mlp reduce on standard layers — and at the
    /// standard→ladder boundary, where it finishes the standard tail), MLP
    /// waits the previous mlp reduce on ladder layers and this layer's
    /// attn reduce on standard layers. Because chunks interleave between a
    /// launch and its absorb, even the Standard architecture's blocking
    /// reduces now hide behind other chunks' compute.
    fn fwd_synced_chunked(
        &mut self,
        chunks: Vec<ChunkFwd>,
        phase: Phase,
        ladder_from: usize,
    ) -> Result<Vec<HostTensor>> {
        struct Run {
            fw: ChunkFwd,
            pend_attn: Option<CommHandle>,
            pend_mlp: Option<CommHandle>,
        }
        let mut runs: Vec<Run> = chunks
            .into_iter()
            .map(|fw| Run { fw, pend_attn: None, pend_mlp: None })
            .collect();
        for i in 0..self.cfg.layers {
            for r in 0..runs.len() {
                let h = if i > ladder_from {
                    runs[r].pend_attn.take()
                } else {
                    runs[r].pend_mlp.take()
                };
                if let Some(h) = h {
                    let run = &mut runs[r];
                    self.absorb(&mut run.fw.x, h);
                }
                let run = &runs[r];
                let attn = self.run_attn_all(
                    i,
                    &run.fw.x,
                    phase,
                    run.fw.lens.as_deref(),
                    run.fw.rows,
                    run.fw.paged.as_ref(),
                )?;
                runs[r].pend_attn = Some(self.comm.allreduce(attn)?);
            }
            for r in 0..runs.len() {
                let h = if i >= ladder_from {
                    runs[r].pend_mlp.take()
                } else {
                    runs[r].pend_attn.take()
                };
                if let Some(h) = h {
                    let run = &mut runs[r];
                    self.absorb(&mut run.fw.x, h);
                }
                let mlp = self.run_mlp_all(i, &runs[r].fw.x)?;
                runs[r].pend_mlp = Some(self.comm.allreduce(mlp)?);
            }
        }
        let mut parts = Vec::with_capacity(runs.len());
        for mut r in runs {
            if let Some(h) = r.pend_attn.take() {
                self.absorb(&mut r.fw.x, h);
            }
            if let Some(h) = r.pend_mlp.take() {
                self.absorb(&mut r.fw.x, h);
            }
            parts.push(r.fw.x);
        }
        Ok(parts)
    }

    /// Chunked Parallel: the per-layer fused reduce is deferred to the
    /// chunk's next layer, so the other chunks' fused blocks overlap it.
    fn fwd_parallel_chunked(
        &mut self,
        chunks: Vec<ChunkFwd>,
        phase: Phase,
    ) -> Result<Vec<HostTensor>> {
        let mut runs: Vec<(ChunkFwd, Option<CommHandle>)> =
            chunks.into_iter().map(|fw| (fw, None)).collect();
        for i in 0..self.cfg.layers {
            for r in 0..runs.len() {
                if let Some(h) = runs[r].1.take() {
                    let run = &mut runs[r];
                    self.absorb(&mut run.0.x, h);
                }
                let mut partials = Vec::with_capacity(self.tp);
                for t in 0..self.tp {
                    let fw = &runs[r].0;
                    partials.push(self.ranks[t].fused(
                        &self.exec,
                        i,
                        &fw.x,
                        phase,
                        fw.lens.as_deref(),
                        fw.rows,
                        fw.paged.as_ref(),
                    )?);
                }
                runs[r].1 = Some(self.comm.allreduce(partials)?);
            }
        }
        let mut parts = Vec::with_capacity(runs.len());
        for (mut fw, pend) in runs {
            if let Some(h) = pend {
                self.absorb(&mut fw.x, h);
            }
            parts.push(fw.x);
        }
        Ok(parts)
    }

    /// Chunked Desync-nx: the rare retained reduce *replaces* a chunk's
    /// per-rank streams, so it cannot be absorbed additively — instead its
    /// wait is deferred to the chunk's next block step (other chunks'
    /// compute covers it), and resolved before anything reads the streams.
    fn fwd_desync_chunked(
        &mut self,
        chunks: Vec<ChunkFwd>,
        phase: Phase,
        n: usize,
    ) -> Result<Vec<HostTensor>> {
        let tp = self.tp;
        struct Run {
            lens: Option<Vec<i32>>,
            paged: Option<PagedFwd>,
            rows: Rows,
            rs: Vec<HostTensor>,
            c: usize,
            synced: bool,
            pend: Option<CommHandle>,
        }
        let mut runs: Vec<Run> = chunks
            .into_iter()
            .map(|fw| Run {
                rs: vec![fw.x; tp],
                lens: fw.lens,
                paged: fw.paged,
                rows: fw.rows,
                c: 0,
                synced: true,
                pend: None,
            })
            .collect();
        for i in 0..self.cfg.layers {
            for kind in [BlockSel::Attn, BlockSel::Mlp] {
                for r in 0..runs.len() {
                    if let Some(h) = runs[r].pend.take() {
                        let x = self.resolve_resync(h);
                        runs[r].rs = vec![x; tp];
                    }
                    let mut partials = Vec::with_capacity(tp);
                    for t in 0..tp {
                        let run = &runs[r];
                        let p = match kind {
                            BlockSel::Attn => self.ranks[t].attn(
                                &self.exec,
                                i,
                                &run.rs[t],
                                phase,
                                run.lens.as_deref(),
                                run.rows,
                                run.paged.as_ref(),
                            )?,
                            BlockSel::Mlp => self.ranks[t].mlp(&self.exec, i, &run.rs[t])?,
                        };
                        partials.push(p);
                    }
                    runs[r].c += 1;
                    if runs[r].c % n == 0 {
                        // retained reduce: message = partial + residual/tp
                        for (t, p) in partials.iter_mut().enumerate() {
                            for (a, b) in p.data.iter_mut().zip(&runs[r].rs[t].data) {
                                *a += b / tp as f32;
                            }
                        }
                        runs[r].pend = Some(self.comm.allreduce(partials)?);
                        runs[r].synced = true;
                    } else {
                        for (t, p) in partials.into_iter().enumerate() {
                            add_assign(&mut runs[r].rs[t], &p);
                        }
                        runs[r].synced = false;
                    }
                }
            }
        }
        let mut parts = Vec::with_capacity(runs.len());
        for mut r in runs {
            if let Some(h) = r.pend.take() {
                let x = self.resolve_resync(h);
                r.rs = vec![x; tp];
            }
            if !r.synced {
                // final resync (mean) so the head sees one residual
                let msgs: Vec<HostTensor> = r
                    .rs
                    .iter()
                    .map(|s| {
                        let scaled = s.data.iter().map(|v| v / tp as f32).collect();
                        HostTensor::new(s.shape.clone(), scaled)
                    })
                    .collect();
                let h = self.comm.allreduce(msgs)?;
                let x = self.resolve_resync(h);
                r.rs = vec![x; tp];
            }
            parts.push(r.rs.swap_remove(0));
        }
        Ok(parts)
    }

    // ---------------------------------------------------------------------
    // helpers
    // ---------------------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn run_attn_all(
        &mut self,
        layer: usize,
        x: &HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
    ) -> Result<Vec<HostTensor>> {
        let t0 = std::time::Instant::now();
        let out: Result<Vec<HostTensor>> = (0..self.tp)
            .map(|t| self.ranks[t].attn(&self.exec, layer, x, phase, lens, rows, paged))
            .collect();
        if let Some(tr) = &mut self.tracer {
            tr.record(&format!("attn{layer}"), 0, t0, std::time::Instant::now());
        }
        out
    }

    fn run_mlp_all(&mut self, layer: usize, x: &HostTensor) -> Result<Vec<HostTensor>> {
        let t0 = std::time::Instant::now();
        let out: Result<Vec<HostTensor>> = (0..self.tp)
            .map(|t| self.ranks[t].mlp(&self.exec, layer, x))
            .collect();
        if let Some(tr) = &mut self.tracer {
            tr.record(&format!("mlp{layer}"), 0, t0, std::time::Instant::now());
        }
        out
    }

    /// Wait a desync retained reduce: unlike [`TpEngine::absorb`] the
    /// result *replaces* the per-rank streams rather than adding into one.
    fn resolve_resync(&mut self, h: CommHandle) -> HostTensor {
        if let Some(tr) = &mut self.tracer {
            let (launch, ready) = h.span();
            tr.record("allreduce_resync", 1, launch, ready);
        }
        let (x, exposed) = h.wait();
        self.comm.record_exposed(exposed);
        x
    }

    /// Wait a handle, record exposed time, add the delta into the residual.
    fn absorb(&mut self, x: &mut HostTensor, h: CommHandle) {
        if let Some(tr) = &mut self.tracer {
            let (launch, ready) = h.span();
            tr.record("allreduce", 1, launch, ready);
        }
        let (delta, exposed) = h.wait();
        self.comm.record_exposed(exposed);
        add_assign(x, &delta);
    }

    /// lm head: slice each row's `last[b]` position, run per-rank head
    /// shards, AllGather the vocab dimension. Returns [B, V].
    fn head(&self, finals: &[HostTensor], last: &[usize]) -> Result<HostTensor> {
        let mut shards = Vec::with_capacity(self.tp);
        for t in 0..self.tp {
            shards.push(self.ranks[t].lm_head_rows(&self.exec, &finals[t], last)?);
        }
        self.comm.allgather_concat(shards)
    }
}
