//! Split-batch overlap scheduling (TokenWeave/ISO-style systems overlap).
//!
//! Instead of changing the *architecture* to decouple compute from the TP
//! AllReduce (Ladder Residual), a forward's batch rows can be split into
//! sub-chunks that are pipelined round-robin through the per-layer blocks:
//! while chunk A's AllReduce sits on the modeled link, chunk B's attention
//! or MLP runs — so even the standard transformer hides collective latency.
//!
//! The chunking is bitwise-exact with respect to the unsplit forward:
//! every kernel in the block (norm, projections, attention over the row's
//! own KV slots, MLP) is row-local, and each chunk's AllReduce sums the
//! same per-rank partials in the same fixed rank order (0..tp) the unsplit
//! path uses. Chunk results are concatenated back in row order before the
//! LM head, which then sees exactly the unsplit activations. See
//! docs/ARCHITECTURE.md, "Sequence-level overlap & hierarchical fabric".
//!
//! Both runtimes implement the same chunk schedule (`engine/tpengine.rs`
//! sequentially with [`CommHandle`] deadlines, `engine/threaded.rs` on the
//! rank workers with rendezvous sequence numbers), so the threaded ==
//! sequential bitwise contract extends to every overlap mode.
//!
//! [`CommHandle`]: crate::comm::CommHandle

use anyhow::Result;

use super::kv::PagedFwd;
use super::rank::Rows;
use crate::model::HostTensor;

/// How a forward's batch rows are split for pipelined execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverlapMode {
    /// Unsplit: one chunk, the original schedule (the bitwise oracle).
    #[default]
    None,
    /// Split the batch rows into (up to) 2 chunks.
    Split2,
    /// Split the batch rows into (up to) 4 chunks.
    Split4,
}

impl OverlapMode {
    /// Requested chunk count (an upper bound: a forward never splits finer
    /// than one row per chunk).
    pub fn chunks(&self) -> usize {
        match self {
            OverlapMode::None => 1,
            OverlapMode::Split2 => 2,
            OverlapMode::Split4 => 4,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OverlapMode::None => "none",
            OverlapMode::Split2 => "split2",
            OverlapMode::Split4 => "split4",
        }
    }

    pub fn parse(s: &str) -> Result<OverlapMode> {
        Ok(match s {
            "none" => OverlapMode::None,
            "split2" => OverlapMode::Split2,
            "split4" => OverlapMode::Split4,
            _ => anyhow::bail!("unknown overlap mode {s:?} (none|split2|split4)"),
        })
    }

    /// Partition `batch` rows into contiguous `(start, count)` chunks, in
    /// row order. Never yields an empty chunk: a batch smaller than the
    /// requested split yields one single-row chunk per row. Larger batches
    /// put the remainder on the leading chunks, so chunk sizes differ by at
    /// most one row.
    ///
    /// Every rank derives the identical partition from the shared batch
    /// size — this is what keeps per-worker rendezvous sequence numbers
    /// aligned without central coordination.
    pub fn partition(&self, batch: usize) -> Vec<(usize, usize)> {
        let chunks = self.chunks().min(batch).max(1);
        let base = batch / chunks;
        let extra = batch % chunks;
        let mut out = Vec::with_capacity(chunks);
        let mut start = 0;
        for c in 0..chunks {
            let count = base + usize::from(c < extra);
            out.push((start, count));
            start += count;
        }
        out
    }
}

/// One sub-chunk of a split forward: the chunk's rows of the residual
/// activation plus the row-sliced per-request state that rides with them.
pub(crate) struct ChunkFwd {
    pub x: HostTensor,
    pub rows: Rows,
    pub lens: Option<Vec<i32>>,
    pub paged: Option<PagedFwd>,
}

/// Slice a full-batch forward into per-chunk views. The residual `x0` is
/// [B, S, H] row-major with the batch dimension leading, so every chunk is
/// one contiguous copy; lens and page tables (also batch-leading,
/// row-major) are row-sliced the same way. Both runtimes call this with
/// identical inputs, so they derive identical chunk schedules.
pub(crate) fn split_forward(
    mode: OverlapMode,
    x0: &HostTensor,
    lens: Option<&[i32]>,
    paged: Option<&PagedFwd>,
) -> Vec<ChunkFwd> {
    let batch = x0.shape[0];
    let row = x0.data.len() / batch;
    mode.partition(batch)
        .into_iter()
        .map(|(start, count)| {
            let mut shape = x0.shape.clone();
            shape[0] = count;
            let x = HostTensor::new(shape, x0.data[start * row..(start + count) * row].to_vec());
            ChunkFwd {
                x,
                rows: Rows::Span(start, count),
                lens: lens.map(|l| l[start..start + count].to_vec()),
                paged: paged.map(|p| PagedFwd {
                    tables: p.tables[start * p.max_pages..(start + count) * p.max_pages].to_vec(),
                    max_pages: p.max_pages,
                    start: p.start,
                }),
            }
        })
        .collect()
}

/// Concatenate per-chunk final residuals back into the unsplit [B, S, H]
/// tensor (chunks are contiguous row ranges in order, so this is a plain
/// append).
pub(crate) fn concat_chunks(mut parts: Vec<HostTensor>) -> HostTensor {
    if parts.len() == 1 {
        return parts.pop().unwrap();
    }
    let mut shape = parts[0].shape.clone();
    shape[0] = parts.iter().map(|p| p.shape[0]).sum();
    let mut data = Vec::with_capacity(parts.iter().map(|p| p.data.len()).sum());
    for p in parts {
        data.extend(p.data);
    }
    HostTensor::new(shape, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in [OverlapMode::None, OverlapMode::Split2, OverlapMode::Split4] {
            assert_eq!(OverlapMode::parse(m.name()).unwrap(), m);
        }
        assert!(OverlapMode::parse("split3").is_err());
    }

    #[test]
    fn partition_covers_rows_in_order() {
        for mode in [OverlapMode::None, OverlapMode::Split2, OverlapMode::Split4] {
            for batch in 1..9usize {
                let parts = mode.partition(batch);
                assert!(parts.len() <= mode.chunks());
                assert!(parts.iter().all(|&(_, c)| c > 0));
                let mut next = 0;
                for &(start, count) in &parts {
                    assert_eq!(start, next);
                    next += count;
                }
                assert_eq!(next, batch, "{mode:?} batch {batch}");
            }
        }
    }

    #[test]
    fn none_is_one_chunk() {
        assert_eq!(OverlapMode::None.partition(4), vec![(0, 4)]);
    }

    #[test]
    fn small_batch_degrades_to_row_chunks() {
        assert_eq!(OverlapMode::Split4.partition(2), vec![(0, 1), (1, 1)]);
        assert_eq!(OverlapMode::Split2.partition(1), vec![(0, 1)]);
    }

    #[test]
    fn remainder_rides_the_leading_chunks() {
        assert_eq!(OverlapMode::Split4.partition(6), vec![(0, 2), (2, 2), (4, 1), (5, 1)]);
    }

    #[test]
    fn split_forward_slices_rows_lens_and_tables() {
        // [4, 2, 3]: row b holds 6 values b*10.0 + i
        let data: Vec<f32> = (0..4).flat_map(|b| (0..6).map(move |i| (b * 10 + i) as f32)).collect();
        let x0 = HostTensor::new(vec![4, 2, 3], data);
        let lens = vec![5i32, 6, 7, 8];
        let paged = PagedFwd { tables: (0..8).collect(), max_pages: 2, start: 3 };
        let chunks = split_forward(OverlapMode::Split2, &x0, Some(&lens), Some(&paged));
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0].rows, Rows::Span(0, 2));
        assert_eq!(chunks[1].rows, Rows::Span(2, 2));
        assert_eq!(chunks[1].x.shape, vec![2, 2, 3]);
        assert_eq!(chunks[1].x.data[0], 20.0);
        assert_eq!(chunks[1].lens.as_deref(), Some(&[7i32, 8][..]));
        let p1 = chunks[1].paged.as_ref().unwrap();
        assert_eq!(p1.tables, vec![4, 5, 6, 7]);
        assert_eq!((p1.max_pages, p1.start), (2, 3));

        // round-trip: concat restores the original tensor bit-for-bit
        let back = concat_chunks(chunks.into_iter().map(|c| c.x).collect());
        assert_eq!(back.shape, x0.shape);
        assert_eq!(back.data, x0.data);
    }
}
