//! Disk tier for the prefix KV cache (`--kv-spill-dir`).
//!
//! The RAM tier (the radix [`PrefixTree`](super::PrefixTree) over the
//! [`BlockAllocator`](super::BlockAllocator) page pool) forgets a chain the
//! moment budget pressure evicts it; every re-arrival of that template then
//! pays a full cold prefill. The spill store is a second, capacity-priced
//! tier underneath it: when the batcher evicts a page it serializes that
//! page's K/V bytes — together with the full token prefix that produced
//! them — into one checksummed file, and a later admission that misses in
//! RAM can probe the disk index, reload the bytes, and resume chunked
//! prefill from the first truly-uncached token.
//!
//! ## On-disk format (one file per page-terminated chain, all little-endian)
//!
//! ```text
//! offset  size        field
//! 0       4           magic "LKVS"
//! 4       4           format version (u32, currently 1)
//! 8       8           config fingerprint (u64, FNV-1a over the engine's
//!                     arch/tp/layer/head/page geometry string — a file
//!                     written by a differently-shaped engine never loads)
//! 16      4           n_tokens (u32): length of the full token prefix
//! 20      4*n         token ids (i32 each)
//! ..      4           n_ranks (u32)
//! ..      8           per-rank payload length in f32 elements (u64)
//! ..      4*r*l       payload: rank-major, each rank's page bytes exactly
//!                     as `PagedKvCache::read_page` returns them
//!                     (layer-major, K plane then V plane, f32)
//! ..      4           CRC-32 (IEEE) over every preceding byte
//! ```
//!
//! A file is keyed by `fnv1a64(token prefix)` and named
//! `{key:016x}.kvp`. Loading re-verifies magic, version, fingerprint,
//! the stored token prefix (a hash collision or truncated write must not
//! serve wrong bytes) and the trailing CRC; any mismatch deletes the file
//! and reports a miss — corruption degrades to a cold prefill, it is never
//! served. `store` is write-to-temp-then-rename so a crash mid-spill
//! leaves no half-written `.kvp` behind (the orphaned `.tmp` is swept on
//! the next `open`).
//!
//! The store enforces `--kv-spill-budget-mb` itself: before admitting a
//! new file it evicts least-recently-used files until the new total fits.
//! `last_used` is process-local (rebuilt in deterministic filename order
//! on `open`), which is enough — the budget is a disk-space valve, not a
//! correctness surface.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"LKVS";
const VERSION: u32 = 1;
const EXT: &str = "kvp";

/// FNV-1a 64-bit over the little-endian bytes of a token sequence. Used
/// both to key spill files by token prefix and (over a config string) as
/// the engine-geometry fingerprint.
pub fn fnv1a64_tokens(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// FNV-1a 64-bit over raw bytes (config fingerprint strings).
pub fn fnv1a64_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// CRC-32 (IEEE 802.3, reflected, init/final 0xFFFFFFFF) — table-driven,
/// built once at first use.
fn crc32(bytes: &[u8]) -> u32 {
    fn table() -> [u32; 256] {
        let mut t = [0u32; 256];
        let mut i = 0;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            t[i] = c;
            i += 1;
        }
        t
    }
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let t = TABLE.get_or_init(table);
    let mut c = 0xffff_ffffu32;
    for &b in bytes {
        c = t[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

struct IndexEntry {
    path: PathBuf,
    bytes: u64,
    last_used: u64,
}

/// The disk tier: an in-memory index over one directory of `.kvp` files.
pub struct SpillStore {
    dir: PathBuf,
    /// 0 = unlimited.
    budget_bytes: u64,
    fingerprint: u64,
    index: HashMap<u64, IndexEntry>,
    clock: u64,
}

impl SpillStore {
    /// Open (creating if needed) a spill directory. Existing `.kvp` files
    /// are indexed by their filename key without reading their payloads —
    /// full validation happens lazily on `load` (or eagerly via
    /// [`validate_all`](Self::validate_all)). Orphaned `.tmp` files from a
    /// crashed spill are removed.
    pub fn open(dir: &Path, budget_bytes: u64, fingerprint: u64) -> Result<Self> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating kv spill dir {}", dir.display()))?;
        let mut names: Vec<(u64, PathBuf, u64)> = Vec::new();
        for entry in fs::read_dir(dir)? {
            let entry = entry?;
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) == Some("tmp") {
                let _ = fs::remove_file(&path);
                continue;
            }
            if path.extension().and_then(|e| e.to_str()) != Some(EXT) {
                continue;
            }
            let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else { continue };
            let Ok(key) = u64::from_str_radix(stem, 16) else { continue };
            let bytes = entry.metadata()?.len();
            names.push((key, path, bytes));
        }
        // deterministic recency seed: filename order (the budget valve
        // only needs *an* order, and this one is reproducible)
        names.sort_by_key(|(k, _, _)| *k);
        let mut index = HashMap::new();
        let mut clock = 0u64;
        for (key, path, bytes) in names {
            clock += 1;
            index.insert(key, IndexEntry { path, bytes, last_used: clock });
        }
        Ok(Self { dir: dir.to_path_buf(), budget_bytes, fingerprint, index, clock })
    }

    /// Number of indexed spill files.
    pub fn files(&self) -> usize {
        self.index.len()
    }

    /// Total indexed bytes on disk.
    pub fn total_bytes(&self) -> u64 {
        self.index.values().map(|e| e.bytes).sum()
    }

    fn path_for(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.{EXT}"))
    }

    /// Does the index hold a chain for exactly this token prefix? (A
    /// positive probe is a hint, not a promise — `load` still verifies.)
    pub fn probe(&self, tokens: &[i32]) -> bool {
        self.index.contains_key(&fnv1a64_tokens(tokens))
    }

    /// Serialize one page's per-rank K/V bytes under its full token
    /// prefix. Returns the bytes written (0 when the store declined:
    /// duplicate key, or a payload larger than the whole budget).
    pub fn store(&mut self, tokens: &[i32], per_rank: &[Vec<f32>]) -> Result<u64> {
        if tokens.is_empty() || per_rank.is_empty() {
            bail!("spill store: empty chain or payload");
        }
        let rank_len = per_rank[0].len();
        if per_rank.iter().any(|r| r.len() != rank_len) {
            bail!("spill store: ragged per-rank payloads");
        }
        let key = fnv1a64_tokens(tokens);
        if self.index.contains_key(&key) {
            return Ok(0); // already spilled (dedup across repeated evictions)
        }
        let mut buf: Vec<u8> = Vec::with_capacity(
            4 + 4 + 8 + 4 + 4 * tokens.len() + 4 + 8 + 4 * per_rank.len() * rank_len + 4,
        );
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.fingerprint.to_le_bytes());
        buf.extend_from_slice(&(tokens.len() as u32).to_le_bytes());
        for t in tokens {
            buf.extend_from_slice(&t.to_le_bytes());
        }
        buf.extend_from_slice(&(per_rank.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(rank_len as u64).to_le_bytes());
        for rank in per_rank {
            for v in rank {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());

        let new_bytes = buf.len() as u64;
        if self.budget_bytes > 0 {
            if new_bytes > self.budget_bytes {
                return Ok(0); // one chain bigger than the whole tier: skip
            }
            self.evict_until_fits(new_bytes);
        }

        let path = self.path_for(key);
        let tmp = path.with_extension("tmp");
        fs::write(&tmp, &buf)
            .with_context(|| format!("writing spill file {}", tmp.display()))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("publishing spill file {}", path.display()))?;
        self.clock += 1;
        self.index
            .insert(key, IndexEntry { path, bytes: new_bytes, last_used: self.clock });
        Ok(new_bytes)
    }

    /// Evict least-recently-used files until `incoming` more bytes fit
    /// under the budget.
    fn evict_until_fits(&mut self, incoming: u64) {
        while self.total_bytes() + incoming > self.budget_bytes {
            let Some((&victim, _)) =
                self.index.iter().min_by_key(|(k, e)| (e.last_used, **k))
            else {
                return;
            };
            self.drop_entry(victim);
        }
    }

    fn drop_entry(&mut self, key: u64) {
        if let Some(entry) = self.index.remove(&key) {
            let _ = fs::remove_file(&entry.path);
        }
    }

    /// Load and fully verify the chain stored under this token prefix.
    /// `Ok(None)` means miss — including any validation failure (bad
    /// magic/version, foreign fingerprint, token mismatch, short file,
    /// CRC mismatch), in which case the offending file is deleted so it
    /// is never probed again. Only an I/O error on a healthy-looking
    /// index is an `Err`.
    pub fn load(&mut self, tokens: &[i32]) -> Result<Option<Vec<Vec<f32>>>> {
        let key = fnv1a64_tokens(tokens);
        let Some(entry) = self.index.get(&key) else { return Ok(None) };
        let path = entry.path.clone();
        let buf = match fs::read(&path) {
            Ok(buf) => buf,
            Err(_) => {
                // file vanished under us (external cleanup): drop the entry
                self.index.remove(&key);
                return Ok(None);
            }
        };
        match self.decode(tokens, &buf) {
            Some(per_rank) => {
                self.clock += 1;
                if let Some(e) = self.index.get_mut(&key) {
                    e.last_used = self.clock;
                }
                Ok(Some(per_rank))
            }
            None => {
                self.drop_entry(key);
                Ok(None)
            }
        }
    }

    /// Strict decode of one spill file against an expected token prefix.
    /// Returns `None` on any structural or integrity failure.
    fn decode(&self, tokens: &[i32], buf: &[u8]) -> Option<Vec<Vec<f32>>> {
        // header (fixed part) + trailing crc must fit
        if buf.len() < 4 + 4 + 8 + 4 + 4 {
            return None;
        }
        let (body, crc_bytes) = buf.split_at(buf.len() - 4);
        let stored_crc = u32::from_le_bytes(crc_bytes.try_into().ok()?);
        if crc32(body) != stored_crc {
            return None;
        }
        let mut off = 0usize;
        let take = |off: &mut usize, n: usize| -> Option<&[u8]> {
            let s = body.get(*off..*off + n)?;
            *off += n;
            Some(s)
        };
        if take(&mut off, 4)? != MAGIC {
            return None;
        }
        if u32::from_le_bytes(take(&mut off, 4)?.try_into().ok()?) != VERSION {
            return None;
        }
        if u64::from_le_bytes(take(&mut off, 8)?.try_into().ok()?) != self.fingerprint {
            return None;
        }
        let n_tokens = u32::from_le_bytes(take(&mut off, 4)?.try_into().ok()?) as usize;
        if n_tokens != tokens.len() {
            return None;
        }
        for expect in tokens {
            let got = i32::from_le_bytes(take(&mut off, 4)?.try_into().ok()?);
            if got != *expect {
                return None;
            }
        }
        let n_ranks = u32::from_le_bytes(take(&mut off, 4)?.try_into().ok()?) as usize;
        let rank_len = u64::from_le_bytes(take(&mut off, 8)?.try_into().ok()?) as usize;
        if n_ranks == 0 || body.len() - off != 4 * n_ranks * rank_len {
            return None;
        }
        let mut per_rank = Vec::with_capacity(n_ranks);
        for _ in 0..n_ranks {
            let mut rank = Vec::with_capacity(rank_len);
            for _ in 0..rank_len {
                rank.push(f32::from_le_bytes(take(&mut off, 4)?.try_into().ok()?));
            }
            per_rank.push(rank);
        }
        Some(per_rank)
    }

    /// Eagerly verify every indexed file (the `restore` subcommand's
    /// offline pass): re-reads each file, checks magic/version/
    /// fingerprint/CRC and that the stored tokens hash to the filename
    /// key. Returns `(kept, dropped)`; invalid files are deleted.
    pub fn validate_all(&mut self) -> Result<(usize, usize)> {
        let keys: Vec<u64> = self.index.keys().copied().collect();
        let mut kept = 0usize;
        let mut dropped = 0usize;
        for key in keys {
            let path = self.index[&key].path.clone();
            let ok = match fs::read(&path) {
                Ok(buf) => self.decode_any(&buf).is_some_and(|t| fnv1a64_tokens(&t) == key),
                Err(_) => false,
            };
            if ok {
                kept += 1;
            } else {
                self.drop_entry(key);
                dropped += 1;
            }
        }
        Ok((kept, dropped))
    }

    /// Like `decode` but without an expected token prefix: returns the
    /// stored tokens when the file is structurally sound and
    /// checksum/fingerprint-valid.
    fn decode_any(&self, buf: &[u8]) -> Option<Vec<i32>> {
        if buf.len() < 4 + 4 + 8 + 4 + 4 {
            return None;
        }
        let (body, crc_bytes) = buf.split_at(buf.len() - 4);
        if crc32(body) != u32::from_le_bytes(crc_bytes.try_into().ok()?) {
            return None;
        }
        let mut off = 0usize;
        let take = |off: &mut usize, n: usize| -> Option<&[u8]> {
            let s = body.get(*off..*off + n)?;
            *off += n;
            Some(s)
        };
        if take(&mut off, 4)? != MAGIC {
            return None;
        }
        if u32::from_le_bytes(take(&mut off, 4)?.try_into().ok()?) != VERSION {
            return None;
        }
        if u64::from_le_bytes(take(&mut off, 8)?.try_into().ok()?) != self.fingerprint {
            return None;
        }
        let n_tokens = u32::from_le_bytes(take(&mut off, 4)?.try_into().ok()?) as usize;
        let mut tokens = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            tokens.push(i32::from_le_bytes(take(&mut off, 4)?.try_into().ok()?));
        }
        let n_ranks = u32::from_le_bytes(take(&mut off, 4)?.try_into().ok()?) as usize;
        let rank_len = u64::from_le_bytes(take(&mut off, 8)?.try_into().ok()?) as usize;
        if n_ranks == 0 || body.len() - off != 4 * n_ranks * rank_len {
            return None;
        }
        Some(tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn scratch_dir(tag: &str) -> PathBuf {
        static N: AtomicUsize = AtomicUsize::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "lkvs_spill_{}_{}_{tag}_{n}",
            std::process::id(),
            std::thread::current().name().unwrap_or("t").replace("::", "_"),
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn payload(seed: f32) -> Vec<Vec<f32>> {
        (0..2)
            .map(|r| (0..64).map(|i| seed + r as f32 * 100.0 + i as f32 * 0.25).collect())
            .collect()
    }

    #[test]
    fn round_trip_is_bitwise_identical() {
        let dir = scratch_dir("rt");
        let mut s = SpillStore::open(&dir, 0, 0xF00D).unwrap();
        let tokens: Vec<i32> = (1..=16).collect();
        let data = payload(3.5);
        let wrote = s.store(&tokens, &data).unwrap();
        assert!(wrote > 0);
        assert!(s.probe(&tokens));
        let back = s.load(&tokens).unwrap().expect("stored chain must load");
        assert_eq!(back.len(), data.len());
        for (a, b) in back.iter().zip(&data) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "payload must survive bitwise");
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_rebuilds_the_index_from_disk() {
        let dir = scratch_dir("reopen");
        let tokens: Vec<i32> = vec![7; 8];
        {
            let mut s = SpillStore::open(&dir, 0, 42).unwrap();
            s.store(&tokens, &payload(1.0)).unwrap();
        }
        let mut s = SpillStore::open(&dir, 0, 42).unwrap();
        assert_eq!(s.files(), 1);
        assert!(s.probe(&tokens));
        assert!(s.load(&tokens).unwrap().is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_file_is_dropped_never_served() {
        let dir = scratch_dir("corrupt");
        let mut s = SpillStore::open(&dir, 0, 9).unwrap();
        let tokens: Vec<i32> = (0..8).collect();
        s.store(&tokens, &payload(2.0)).unwrap();
        // flip one payload byte on disk
        let path = dir.join(format!("{:016x}.{EXT}", fnv1a64_tokens(&tokens)));
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        fs::write(&path, &bytes).unwrap();
        assert!(s.load(&tokens).unwrap().is_none(), "corrupt chain must read as a miss");
        assert!(!path.exists(), "corrupt file must be deleted");
        assert!(!s.probe(&tokens), "index entry must be gone");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn foreign_fingerprint_is_rejected() {
        let dir = scratch_dir("fp");
        let tokens: Vec<i32> = (0..8).collect();
        {
            let mut a = SpillStore::open(&dir, 0, 1).unwrap();
            a.store(&tokens, &payload(0.5)).unwrap();
        }
        // same dir, different engine geometry
        let mut b = SpillStore::open(&dir, 0, 2).unwrap();
        assert!(b.probe(&tokens), "index is fingerprint-blind until load");
        assert!(b.load(&tokens).unwrap().is_none(), "foreign fingerprint must miss");
        assert!(!b.probe(&tokens), "rejected file must leave the index");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_evicts_least_recently_used_files() {
        let dir = scratch_dir("budget");
        // each file: header 20 + 4*8 tokens + 12 + payload 2*64*4 + crc 4
        // = 580 bytes; budget fits two.
        let mut s = SpillStore::open(&dir, 1300, 5).unwrap();
        let t1: Vec<i32> = (0..8).collect();
        let t2: Vec<i32> = (100..108).collect();
        let t3: Vec<i32> = (200..208).collect();
        assert!(s.store(&t1, &payload(1.0)).unwrap() > 0);
        assert!(s.store(&t2, &payload(2.0)).unwrap() > 0);
        // touch t1 so t2 becomes the LRU victim
        assert!(s.load(&t1).unwrap().is_some());
        assert!(s.store(&t3, &payload(3.0)).unwrap() > 0);
        assert_eq!(s.files(), 2);
        assert!(s.probe(&t1), "recently-loaded chain survives");
        assert!(!s.probe(&t2), "LRU chain is evicted for the newcomer");
        assert!(s.probe(&t3));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn validate_all_prunes_only_broken_files() {
        let dir = scratch_dir("validate");
        let mut s = SpillStore::open(&dir, 0, 77).unwrap();
        let good: Vec<i32> = (0..8).collect();
        let bad: Vec<i32> = (50..58).collect();
        s.store(&good, &payload(1.0)).unwrap();
        s.store(&bad, &payload(2.0)).unwrap();
        let bad_path = dir.join(format!("{:016x}.{EXT}", fnv1a64_tokens(&bad)));
        let mut bytes = fs::read(&bad_path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01; // break the CRC itself
        fs::write(&bad_path, &bytes).unwrap();
        let (kept, dropped) = s.validate_all().unwrap();
        assert_eq!((kept, dropped), (1, 1));
        assert!(s.probe(&good));
        assert!(!s.probe(&bad));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn oversized_payload_is_declined_not_stored() {
        let dir = scratch_dir("oversize");
        let mut s = SpillStore::open(&dir, 64, 3).unwrap();
        let tokens: Vec<i32> = (0..8).collect();
        assert_eq!(s.store(&tokens, &payload(1.0)).unwrap(), 0);
        assert_eq!(s.files(), 0);
        let _ = fs::remove_dir_all(&dir);
    }
}
