//! Real-engine execution tracer: records module executions (stream 0) and
//! AllReduce occupancy (stream 1) with wall-clock timestamps, dumpable as
//! chrome://tracing JSON — the measured counterpart of the paper's Figure 6
//! PyTorch-profiler traces (NCCL blocking vs overlapped).

use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct EngineTraceEvent {
    pub name: String,
    /// 0 = compute (PJRT executions), 1 = interconnect (modeled AllReduce).
    pub stream: usize,
    pub start_us: f64,
    pub dur_us: f64,
}

/// Wall-clock tracer for one engine run.
#[derive(Debug)]
pub struct EngineTracer {
    origin: Instant,
    pub events: Vec<EngineTraceEvent>,
}

impl EngineTracer {
    pub fn new() -> EngineTracer {
        EngineTracer { origin: Instant::now(), events: Vec::new() }
    }

    pub fn record(&mut self, name: &str, stream: usize, start: Instant, end: Instant) {
        self.events.push(EngineTraceEvent {
            name: name.to_string(),
            stream,
            start_us: (start - self.origin).as_secs_f64() * 1e6,
            dur_us: (end - start).as_secs_f64() * 1e6,
        });
    }

    pub fn to_chrome_json(&self) -> Json {
        Json::Arr(
            self.events
                .iter()
                .map(|e| {
                    Json::obj()
                        .set("name", e.name.as_str())
                        .set("ph", "X")
                        .set("ts", e.start_us)
                        .set("dur", e.dur_us)
                        .set("pid", 0usize)
                        .set("tid", e.stream)
                })
                .collect(),
        )
    }

    /// Total busy time per stream — (compute_us, comm_us).
    pub fn stream_busy(&self) -> (f64, f64) {
        let mut busy = (0.0, 0.0);
        for e in &self.events {
            if e.stream == 0 {
                busy.0 += e.dur_us;
            } else {
                busy.1 += e.dur_us;
            }
        }
        busy
    }
}

impl Default for EngineTracer {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_serializes() {
        let mut t = EngineTracer::new();
        let a = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = Instant::now();
        t.record("attn0", 0, a, b);
        t.record("ar0", 1, a, b);
        assert_eq!(t.events.len(), 2);
        let (c, m) = t.stream_busy();
        assert!(c >= 1500.0 && m >= 1500.0);
        assert!(t.to_chrome_json().to_string().contains("attn0"));
    }
}
