//! Shared-prefix KV reuse: a token-keyed radix tree over **full,
//! immutable, ref-counted KV pages**.
//!
//! The tree is page-granular: every node owns exactly one physical page and
//! is keyed by the `page_size` token ids whose K/V rows that page holds, so
//! a root-to-node path spells out a page-aligned token prefix and the page
//! chain that backs it. When a request finishes (or is cancelled after
//! writing at least one full page), the full pages of its *prompt* are
//! published into the tree instead of freed; a later request whose prompt
//! starts with the same tokens retains the chain from the
//! [`BlockAllocator`] and prefills only its uncached suffix — the cached
//! prefill compute *and* its AllReduce traffic become a table lookup.
//!
//! Reuse is **bitwise exact**, not approximate: a page's K/V rows at
//! positions `p` are a deterministic function of tokens `0..=p` alone
//! (every kernel is batch-row-local and visits keys in logical order — the
//! chunked-prefill determinism contract from the paged-KV work), and the
//! tree's key *is* those tokens. Cached pages are read strictly through
//! page tables inside the kernels and never written: a hit's first
//! prefilled position is always page-aligned past the chain (or lands in a
//! private copy-on-write duplicate when the whole prompt is cached), so no
//! forward pass ever scatters into a shared page.
//!
//! Eviction is LRU over **zero-reference leaves**: a node may be removed
//! only when no request references its page (`BlockAllocator::req_refs ==
//! 0`) and it has no children. Because a request that matched a chain
//! references every page on that root path, interior nodes above a live
//! reference are themselves referenced — leaf-only eviction can never
//! orphan a path a live request is reading, and repeated eviction drains
//! any fully idle subtree deepest-first.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::kv::BlockAllocator;

/// One cached page: its physical id, the `page_size` token ids it holds
/// K/V for (its key under the parent), its children, and its LRU stamp.
struct Node {
    page: u32,
    key: Vec<i32>,
    parent: Option<usize>,
    children: HashMap<Vec<i32>, usize>,
    last_used: u64,
}

/// Token-keyed radix tree mapping page-aligned prompt prefixes to chains of
/// cached KV pages. Owns no pages itself — reference counts live in the
/// [`BlockAllocator`], which every structural mutation goes through.
pub struct PrefixTree {
    page_size: usize,
    /// Node arena; `None` slots are free (reused by later inserts).
    nodes: Vec<Option<Node>>,
    free_slots: Vec<usize>,
    /// Children of the (page-less) root.
    root: HashMap<Vec<i32>, usize>,
    /// LRU clock: bumped once per lookup/insert, stamped onto touched nodes.
    clock: u64,
    cached_pages: usize,
}

impl PrefixTree {
    pub fn new(page_size: usize) -> PrefixTree {
        assert!(page_size > 0, "page_size must be positive");
        PrefixTree {
            page_size,
            nodes: Vec::new(),
            free_slots: Vec::new(),
            root: HashMap::new(),
            clock: 0,
            cached_pages: 0,
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Pages currently referenced by the tree.
    pub fn cached_pages(&self) -> usize {
        self.cached_pages
    }

    /// Longest page-aligned cached prefix of `prompt`: the chain of page
    /// ids whose keys match `prompt`'s leading full pages. Touches the
    /// matched path's LRU stamps. The chain never extends past the
    /// prompt's last *full* page — a node matches only if all `page_size`
    /// of its tokens are present.
    pub fn match_prefix(&mut self, prompt: &[i32]) -> Vec<u32> {
        self.clock += 1;
        let mut chain = Vec::new();
        let mut children = &self.root;
        let mut touched = Vec::new();
        for key in prompt.chunks_exact(self.page_size) {
            let Some(&idx) = children.get(key) else { break };
            let node = self.nodes[idx].as_ref().expect("child index points at a live node");
            chain.push(node.page);
            touched.push(idx);
            children = &node.children;
        }
        for idx in touched {
            self.nodes[idx].as_mut().expect("touched above").last_used = self.clock;
        }
        chain
    }

    /// Publish a finished request's full prompt pages: walk `tokens` one
    /// page at a time, reusing existing nodes (their pages stay canonical —
    /// a duplicate chain is *not* inserted, the duplicate's pages simply
    /// get freed with their owner) and creating nodes for the uncached
    /// tail, taking a tree reference on each newly published page. The
    /// caller must still own those pages (`admit`-ed, not yet freed).
    /// Returns how many pages were newly published.
    pub fn insert(
        &mut self,
        tokens: &[i32],
        pages: &[u32],
        alloc: &mut BlockAllocator,
    ) -> Result<usize> {
        if tokens.len() < pages.len() * self.page_size {
            bail!(
                "insert: {} tokens cannot key {} full pages of {}",
                tokens.len(),
                pages.len(),
                self.page_size
            );
        }
        self.clock += 1;
        let mut parent: Option<usize> = None;
        let mut added = 0;
        for (key, &page) in tokens.chunks_exact(self.page_size).zip(pages) {
            let existing = match parent {
                None => self.root.get(key).copied(),
                Some(p) => {
                    self.nodes[p].as_ref().expect("live parent").children.get(key).copied()
                }
            };
            let idx = match existing {
                Some(idx) => {
                    self.nodes[idx].as_mut().expect("live child").last_used = self.clock;
                    idx
                }
                None => {
                    alloc.tree_retain(page)?;
                    let node = Node {
                        page,
                        key: key.to_vec(),
                        parent,
                        children: HashMap::new(),
                        last_used: self.clock,
                    };
                    let idx = match self.free_slots.pop() {
                        Some(slot) => {
                            self.nodes[slot] = Some(node);
                            slot
                        }
                        None => {
                            self.nodes.push(Some(node));
                            self.nodes.len() - 1
                        }
                    };
                    match parent {
                        None => self.root.insert(key.to_vec(), idx),
                        Some(p) => self.nodes[p]
                            .as_mut()
                            .expect("live parent")
                            .children
                            .insert(key.to_vec(), idx),
                    };
                    self.cached_pages += 1;
                    added += 1;
                    idx
                }
            };
            parent = Some(idx);
        }
        Ok(added)
    }

    /// Evict up to `want` pages in LRU order, restricted to leaves whose
    /// page no request references (evicting a leaf may expose its parent
    /// for the next round, so an idle chain drains deepest-first). Returns
    /// the evicted page ids — each is back on the allocator's free list.
    /// Fewer than `want` means nothing else is evictable right now.
    ///
    /// Victim selection is a linear arena scan per evicted page — O(nodes)
    /// each, and it only runs when the free list cannot cover a
    /// reservation. At pool sizes where that scan shows up in profiles,
    /// the upgrade is an ordered index over zero-ref leaves maintained on
    /// retain/release/insert; the scan is kept here because it cannot
    /// disagree with the refcounts it reads.
    pub fn evict(&mut self, want: usize, alloc: &mut BlockAllocator) -> Result<Vec<u32>> {
        let mut evicted = Vec::new();
        while evicted.len() < want {
            // oldest zero-ref leaf; index tie-break keeps runs deterministic
            let victim = self
                .nodes
                .iter()
                .enumerate()
                .filter_map(|(i, slot)| slot.as_ref().map(|n| (i, n)))
                .filter(|(_, n)| n.children.is_empty() && alloc.req_refs(n.page) == 0)
                .min_by_key(|(i, n)| (n.last_used, *i))
                .map(|(i, _)| i);
            let Some(idx) = victim else { break };
            let node = self.nodes[idx].take().expect("victim is live");
            let removed = match node.parent {
                None => self.root.remove(&node.key),
                Some(p) => self.nodes[p]
                    .as_mut()
                    .expect("parent outlives child")
                    .children
                    .remove(&node.key),
            };
            debug_assert_eq!(removed, Some(idx));
            self.free_slots.push(idx);
            self.cached_pages -= 1;
            alloc.tree_release(node.page)?;
            evicted.push(node.page);
        }
        Ok(evicted)
    }

    /// Evict everything evictable (drained server / tests). Each `evict`
    /// round rescans, so parents exposed by an evicted child drain in the
    /// same call. Returns pages freed.
    pub fn flush(&mut self, alloc: &mut BlockAllocator) -> Result<usize> {
        Ok(self.evict(usize::MAX, alloc)?.len())
    }

    /// Every page the tree currently references (audits).
    pub fn pages(&self) -> Vec<u32> {
        self.nodes.iter().flatten().map(|n| n.page).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pool + donor helper: admit `owner` over `tokens`, return its pages.
    fn prefill(alloc: &mut BlockAllocator, owner: u64, tokens: &[i32]) -> Vec<u32> {
        alloc.admit(owner, tokens.len(), tokens.len()).unwrap();
        alloc.table(owner).unwrap().pages.clone()
    }

    #[test]
    fn match_returns_longest_page_aligned_prefix() {
        let mut alloc = BlockAllocator::new(16, 4, 1);
        let mut tree = PrefixTree::new(4);
        let prompt: Vec<i32> = (0..12).collect();
        let pages = prefill(&mut alloc, 1, &prompt);
        assert_eq!(tree.insert(&prompt, &pages, &mut alloc).unwrap(), 3);
        alloc.free(1);
        alloc.check().unwrap();

        assert_eq!(tree.match_prefix(&prompt), pages);
        // partial page never matches: 10 tokens -> 2 full pages
        assert_eq!(tree.match_prefix(&prompt[..10]), pages[..2]);
        assert_eq!(tree.match_prefix(&prompt[..3]), Vec::<u32>::new());
        // divergence mid-chain stops the walk at the last matching page
        let mut fork = prompt.clone();
        fork[6] = 99;
        assert_eq!(tree.match_prefix(&fork), pages[..1]);
        // longer prompts still match the full cached chain
        let longer: Vec<i32> = (0..20).collect();
        assert_eq!(tree.match_prefix(&longer), pages);
    }

    #[test]
    fn insert_dedups_against_existing_chains() {
        let mut alloc = BlockAllocator::new(16, 4, 1);
        let mut tree = PrefixTree::new(4);
        let prompt: Vec<i32> = (0..8).collect();
        let pages = prefill(&mut alloc, 1, &prompt);
        tree.insert(&prompt, &pages, &mut alloc).unwrap();
        alloc.free(1);
        // an identical chain from a second donor publishes nothing new; the
        // duplicate pages stay owned by the donor and are freed with it
        let mut longer: Vec<i32> = (0..12).collect();
        let pages2 = prefill(&mut alloc, 2, &longer);
        assert_eq!(tree.insert(&longer, &pages2, &mut alloc).unwrap(), 1);
        assert_eq!(tree.cached_pages(), 3);
        assert_eq!(tree.match_prefix(&longer), vec![pages[0], pages[1], pages2[2]]);
        alloc.free(2);
        alloc.check().unwrap();
        // a diverging suffix forks the tree instead of replacing the chain
        longer[4] = 77;
        let pages3 = prefill(&mut alloc, 3, &longer);
        assert_eq!(tree.insert(&longer, &pages3, &mut alloc).unwrap(), 2);
        alloc.free(3);
        alloc.check().unwrap();
        assert_eq!(tree.match_prefix(&longer), vec![pages[0], pages3[1], pages3[2]]);
        // too few tokens for the page count is a caller bug
        assert!(tree.insert(&longer[..7], &pages3[..2], &mut alloc).is_err());
    }

    #[test]
    fn lru_eviction_takes_idle_leaves_deepest_first() {
        let mut alloc = BlockAllocator::new(16, 4, 1);
        let mut tree = PrefixTree::new(4);
        let a: Vec<i32> = (0..12).collect(); // chain of 3
        let mut b: Vec<i32> = (0..8).collect(); // forks at page 2
        b[5] = 50;
        let pa = prefill(&mut alloc, 1, &a);
        let pb = prefill(&mut alloc, 2, &b);
        tree.insert(&a, &pa, &mut alloc).unwrap();
        tree.insert(&b, &pb, &mut alloc).unwrap();
        alloc.free(1);
        alloc.free(2);
        // b's fork page (inserted later, but b's leaf...) — touch a's chain
        // so b's leaf is the LRU victim
        tree.match_prefix(&a);
        assert_eq!(tree.evict(1, &mut alloc).unwrap(), vec![pb[1]]);
        alloc.check().unwrap();
        // next round: a's deepest page is now the oldest leaf
        assert_eq!(tree.evict(1, &mut alloc).unwrap(), vec![pa[2]]);
        // interior pages only leave after their children
        assert_eq!(tree.evict(9, &mut alloc).unwrap(), vec![pa[1], pa[0]]);
        assert_eq!(tree.cached_pages(), 0);
        alloc.check().unwrap();
        assert_eq!(alloc.free_pages(), 16, "eviction round-trips to a full free list");
    }

    #[test]
    fn eviction_skips_pages_referenced_by_requests() {
        let mut alloc = BlockAllocator::new(16, 4, 1);
        let mut tree = PrefixTree::new(4);
        let prompt: Vec<i32> = (0..8).collect();
        let pages = prefill(&mut alloc, 1, &prompt);
        tree.insert(&prompt, &pages, &mut alloc).unwrap();
        alloc.free(1);
        // a follower pins the whole chain
        let chain = tree.match_prefix(&prompt);
        alloc.admit_shared(2, 8, 12, &chain).unwrap();
        assert!(tree.evict(9, &mut alloc).unwrap().is_empty(), "chain is pinned");
        assert_eq!(tree.flush(&mut alloc).unwrap(), 0);
        alloc.free(2);
        assert_eq!(tree.flush(&mut alloc).unwrap(), 2);
        alloc.check().unwrap();
        assert_eq!(alloc.free_pages(), 16);
    }
}
