//! Shared-prefix KV reuse: a token-keyed radix tree over **full,
//! immutable, ref-counted KV pages**.
//!
//! The tree is page-granular: every node owns exactly one physical page and
//! is keyed by the `page_size` token ids whose K/V rows that page holds, so
//! a root-to-node path spells out a page-aligned token prefix and the page
//! chain that backs it. When a request finishes (or is cancelled after
//! writing at least one full page), the full pages of its *prompt* are
//! published into the tree instead of freed; a later request whose prompt
//! starts with the same tokens retains the chain from the
//! [`BlockAllocator`] and prefills only its uncached suffix — the cached
//! prefill compute *and* its AllReduce traffic become a table lookup.
//!
//! Reuse is **bitwise exact**, not approximate: a page's K/V rows at
//! positions `p` are a deterministic function of tokens `0..=p` alone
//! (every kernel is batch-row-local and visits keys in logical order — the
//! chunked-prefill determinism contract from the paged-KV work), and the
//! tree's key *is* those tokens. Cached pages are read strictly through
//! page tables inside the kernels and never written: a hit's first
//! prefilled position is always page-aligned past the chain (or lands in a
//! private copy-on-write duplicate when the whole prompt is cached), so no
//! forward pass ever scatters into a shared page.
//!
//! Eviction is LRU over **zero-reference leaves**: a node may be removed
//! only when no request references its page (`BlockAllocator::req_refs ==
//! 0`) and it has no children. Because a request that matched a chain
//! references every page on that root path, interior nodes above a live
//! reference are themselves referenced — leaf-only eviction can never
//! orphan a path a live request is reading, and repeated eviction drains
//! any fully idle subtree deepest-first.

use std::collections::{BTreeSet, HashMap};

use anyhow::{bail, Result};

use super::kv::BlockAllocator;

/// One cached page: its physical id, the `page_size` token ids it holds
/// K/V for (its key under the parent), its children, and its LRU stamp.
struct Node {
    page: u32,
    key: Vec<i32>,
    parent: Option<usize>,
    children: HashMap<Vec<i32>, usize>,
    last_used: u64,
}

/// Token-keyed radix tree mapping page-aligned prompt prefixes to chains of
/// cached KV pages. Owns no pages itself — reference counts live in the
/// [`BlockAllocator`], which every structural mutation goes through.
pub struct PrefixTree {
    page_size: usize,
    /// Node arena; `None` slots are free (reused by later inserts).
    nodes: Vec<Option<Node>>,
    free_slots: Vec<usize>,
    /// Children of the (page-less) root.
    root: HashMap<Vec<i32>, usize>,
    /// LRU clock: bumped once per lookup/insert, stamped onto touched nodes.
    clock: u64,
    cached_pages: usize,
    /// `(last_used, idx)` over every live node — the eviction scan order.
    /// Kept in lockstep with the arena (insert/touch/evict) so victim
    /// selection is an ordered walk instead of an O(nodes) rescan per
    /// evicted page.
    order: BTreeSet<(u64, usize)>,
}

impl PrefixTree {
    pub fn new(page_size: usize) -> PrefixTree {
        assert!(page_size > 0, "page_size must be positive");
        PrefixTree {
            page_size,
            nodes: Vec::new(),
            free_slots: Vec::new(),
            root: HashMap::new(),
            clock: 0,
            cached_pages: 0,
            order: BTreeSet::new(),
        }
    }

    /// Move a node to the current clock in both the arena and the ordered
    /// index (the one place a stamp is allowed to change).
    fn touch(&mut self, idx: usize) {
        let node = self.nodes[idx].as_mut().expect("touched node is live");
        let old = node.last_used;
        node.last_used = self.clock;
        self.order.remove(&(old, idx));
        self.order.insert((self.clock, idx));
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Pages currently referenced by the tree.
    pub fn cached_pages(&self) -> usize {
        self.cached_pages
    }

    /// Longest page-aligned cached prefix of `prompt`: the chain of page
    /// ids whose keys match `prompt`'s leading full pages. Touches the
    /// matched path's LRU stamps. The chain never extends past the
    /// prompt's last *full* page — a node matches only if all `page_size`
    /// of its tokens are present.
    pub fn match_prefix(&mut self, prompt: &[i32]) -> Vec<u32> {
        self.clock += 1;
        let mut chain = Vec::new();
        let mut children = &self.root;
        let mut touched = Vec::new();
        for key in prompt.chunks_exact(self.page_size) {
            let Some(&idx) = children.get(key) else { break };
            let node = self.nodes[idx].as_ref().expect("child index points at a live node");
            chain.push(node.page);
            touched.push(idx);
            children = &node.children;
        }
        for idx in touched {
            self.touch(idx);
        }
        chain
    }

    /// Publish a finished request's full prompt pages: walk `tokens` one
    /// page at a time, reusing existing nodes (their pages stay canonical —
    /// a duplicate chain is *not* inserted, the duplicate's pages simply
    /// get freed with their owner) and creating nodes for the uncached
    /// tail, taking a tree reference on each newly published page. The
    /// caller must still own those pages (`admit`-ed, not yet freed).
    /// Returns how many pages were newly published.
    pub fn insert(
        &mut self,
        tokens: &[i32],
        pages: &[u32],
        alloc: &mut BlockAllocator,
    ) -> Result<usize> {
        if tokens.len() < pages.len() * self.page_size {
            bail!(
                "insert: {} tokens cannot key {} full pages of {}",
                tokens.len(),
                pages.len(),
                self.page_size
            );
        }
        self.clock += 1;
        let mut parent: Option<usize> = None;
        let mut added = 0;
        for (key, &page) in tokens.chunks_exact(self.page_size).zip(pages) {
            let existing = match parent {
                None => self.root.get(key).copied(),
                Some(p) => {
                    self.nodes[p].as_ref().expect("live parent").children.get(key).copied()
                }
            };
            let idx = match existing {
                Some(idx) => {
                    self.touch(idx);
                    idx
                }
                None => {
                    alloc.tree_retain(page)?;
                    let node = Node {
                        page,
                        key: key.to_vec(),
                        parent,
                        children: HashMap::new(),
                        last_used: self.clock,
                    };
                    let idx = match self.free_slots.pop() {
                        Some(slot) => {
                            self.nodes[slot] = Some(node);
                            slot
                        }
                        None => {
                            self.nodes.push(Some(node));
                            self.nodes.len() - 1
                        }
                    };
                    match parent {
                        None => self.root.insert(key.to_vec(), idx),
                        Some(p) => self.nodes[p]
                            .as_mut()
                            .expect("live parent")
                            .children
                            .insert(key.to_vec(), idx),
                    };
                    self.cached_pages += 1;
                    self.order.insert((self.clock, idx));
                    added += 1;
                    idx
                }
            };
            parent = Some(idx);
        }
        Ok(added)
    }

    /// Evict up to `want` pages in LRU order, restricted to leaves whose
    /// page the allocator calls evictable — no request references and no
    /// admission-window pin (evicting a leaf may expose its parent for the
    /// next round, so an idle chain drains deepest-first). Returns the
    /// evicted page ids — each is back on the allocator's free list.
    /// Fewer than `want` means nothing else is evictable right now.
    ///
    /// Victim selection walks the `(last_used, idx)` ordered index from a
    /// cursor instead of rescanning the arena per evicted page — O(k log n)
    /// for k evictions rather than O(k·n). The cursor never skips a valid
    /// victim: entries behind it were inspected and rejected, and the only
    /// rejection an eviction can undo is "has children" on the victim's own
    /// parent — whose `(last_used, idx)` key the cursor rolls back to
    /// (parents are stamped whenever a descendant is touched, so a parent's
    /// stamp is never older than its children's; only the equal-stamp
    /// smaller-index parent can sort before its child). The victim order is
    /// therefore identical to a full min-scan per round, which the seeded
    /// parity test below pins.
    pub fn evict(&mut self, want: usize, alloc: &mut BlockAllocator) -> Result<Vec<u32>> {
        Ok(self.evict_with_keys(want, alloc)?.into_iter().map(|(page, _)| page).collect())
    }

    /// [`evict`](Self::evict), additionally reporting each victim's full
    /// root-path token prefix — what the disk spill tier keys its file by.
    /// The tokens are collected *before* the node is unlinked, so the pair
    /// is exactly (page id, the page-aligned prompt prefix whose K/V rows
    /// the page holds).
    pub fn evict_with_keys(
        &mut self,
        want: usize,
        alloc: &mut BlockAllocator,
    ) -> Result<Vec<(u32, Vec<i32>)>> {
        let mut evicted = Vec::new();
        let mut cursor: (u64, usize) = (0, 0);
        while evicted.len() < want {
            let mut victim = None;
            for &(stamp, idx) in self.order.range(cursor..) {
                let node = self.nodes[idx].as_ref().expect("ordered index tracks live nodes");
                if node.children.is_empty() && alloc.evictable(node.page) {
                    victim = Some((stamp, idx));
                    break;
                }
            }
            let Some((stamp, idx)) = victim else { break };
            cursor = (stamp, idx + 1);
            let tokens = self.path_tokens(idx);
            self.order.remove(&(stamp, idx));
            let node = self.nodes[idx].take().expect("victim is live");
            let removed = match node.parent {
                None => self.root.remove(&node.key),
                Some(p) => self.nodes[p]
                    .as_mut()
                    .expect("parent outlives child")
                    .children
                    .remove(&node.key),
            };
            debug_assert_eq!(removed, Some(idx));
            self.free_slots.push(idx);
            self.cached_pages -= 1;
            alloc.tree_release(node.page)?;
            evicted.push((node.page, tokens));
            if let Some(p) = node.parent {
                let parent = self.nodes[p].as_ref().expect("parent outlives child");
                if parent.children.is_empty() {
                    // the eviction exposed its parent as a leaf; its key can
                    // sort before the cursor (equal stamp, smaller index),
                    // so rewind far enough to reconsider it
                    cursor = cursor.min((parent.last_used, p));
                }
            }
        }
        Ok(evicted)
    }

    /// The page-aligned token prefix ending at node `idx` (root-path keys
    /// concatenated in order).
    fn path_tokens(&self, idx: usize) -> Vec<i32> {
        let mut rev: Vec<usize> = Vec::new();
        let mut at = Some(idx);
        while let Some(i) = at {
            rev.push(i);
            at = self.nodes[i].as_ref().expect("path nodes are live").parent;
        }
        let mut tokens = Vec::with_capacity(rev.len() * self.page_size);
        for &i in rev.iter().rev() {
            tokens.extend_from_slice(&self.nodes[i].as_ref().expect("live").key);
        }
        tokens
    }

    /// Evict everything evictable (drained server / tests). Each `evict`
    /// round rescans, so parents exposed by an evicted child drain in the
    /// same call. Returns pages freed.
    pub fn flush(&mut self, alloc: &mut BlockAllocator) -> Result<usize> {
        Ok(self.evict(usize::MAX, alloc)?.len())
    }

    /// Every page the tree currently references (audits).
    pub fn pages(&self) -> Vec<u32> {
        self.nodes.iter().flatten().map(|n| n.page).collect()
    }

    /// Every cached chain as `(full token prefix, terminal page)` — one
    /// entry per live node, so a root-to-leaf path of depth d yields d
    /// page-granular entries. This is the engine `snapshot` walk: spilling
    /// each entry persists the whole tree to the disk tier.
    pub fn chains(&self) -> Vec<(Vec<i32>, u32)> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, slot)| slot.is_some())
            .map(|(idx, slot)| {
                (self.path_tokens(idx), slot.as_ref().expect("filtered live").page)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pool + donor helper: admit `owner` over `tokens`, return its pages.
    fn prefill(alloc: &mut BlockAllocator, owner: u64, tokens: &[i32]) -> Vec<u32> {
        alloc.admit(owner, tokens.len(), tokens.len()).unwrap();
        alloc.table(owner).unwrap().pages.clone()
    }

    #[test]
    fn match_returns_longest_page_aligned_prefix() {
        let mut alloc = BlockAllocator::new(16, 4, 1);
        let mut tree = PrefixTree::new(4);
        let prompt: Vec<i32> = (0..12).collect();
        let pages = prefill(&mut alloc, 1, &prompt);
        assert_eq!(tree.insert(&prompt, &pages, &mut alloc).unwrap(), 3);
        alloc.free(1);
        alloc.check().unwrap();

        assert_eq!(tree.match_prefix(&prompt), pages);
        // partial page never matches: 10 tokens -> 2 full pages
        assert_eq!(tree.match_prefix(&prompt[..10]), pages[..2]);
        assert_eq!(tree.match_prefix(&prompt[..3]), Vec::<u32>::new());
        // divergence mid-chain stops the walk at the last matching page
        let mut fork = prompt.clone();
        fork[6] = 99;
        assert_eq!(tree.match_prefix(&fork), pages[..1]);
        // longer prompts still match the full cached chain
        let longer: Vec<i32> = (0..20).collect();
        assert_eq!(tree.match_prefix(&longer), pages);
    }

    #[test]
    fn insert_dedups_against_existing_chains() {
        let mut alloc = BlockAllocator::new(16, 4, 1);
        let mut tree = PrefixTree::new(4);
        let prompt: Vec<i32> = (0..8).collect();
        let pages = prefill(&mut alloc, 1, &prompt);
        tree.insert(&prompt, &pages, &mut alloc).unwrap();
        alloc.free(1);
        // an identical chain from a second donor publishes nothing new; the
        // duplicate pages stay owned by the donor and are freed with it
        let mut longer: Vec<i32> = (0..12).collect();
        let pages2 = prefill(&mut alloc, 2, &longer);
        assert_eq!(tree.insert(&longer, &pages2, &mut alloc).unwrap(), 1);
        assert_eq!(tree.cached_pages(), 3);
        assert_eq!(tree.match_prefix(&longer), vec![pages[0], pages[1], pages2[2]]);
        alloc.free(2);
        alloc.check().unwrap();
        // a diverging suffix forks the tree instead of replacing the chain
        longer[4] = 77;
        let pages3 = prefill(&mut alloc, 3, &longer);
        assert_eq!(tree.insert(&longer, &pages3, &mut alloc).unwrap(), 2);
        alloc.free(3);
        alloc.check().unwrap();
        assert_eq!(tree.match_prefix(&longer), vec![pages[0], pages3[1], pages3[2]]);
        // too few tokens for the page count is a caller bug
        assert!(tree.insert(&longer[..7], &pages3[..2], &mut alloc).is_err());
    }

    #[test]
    fn lru_eviction_takes_idle_leaves_deepest_first() {
        let mut alloc = BlockAllocator::new(16, 4, 1);
        let mut tree = PrefixTree::new(4);
        let a: Vec<i32> = (0..12).collect(); // chain of 3
        let mut b: Vec<i32> = (0..8).collect(); // forks at page 2
        b[5] = 50;
        let pa = prefill(&mut alloc, 1, &a);
        let pb = prefill(&mut alloc, 2, &b);
        tree.insert(&a, &pa, &mut alloc).unwrap();
        tree.insert(&b, &pb, &mut alloc).unwrap();
        alloc.free(1);
        alloc.free(2);
        // b's fork page (inserted later, but b's leaf...) — touch a's chain
        // so b's leaf is the LRU victim
        tree.match_prefix(&a);
        assert_eq!(tree.evict(1, &mut alloc).unwrap(), vec![pb[1]]);
        alloc.check().unwrap();
        // next round: a's deepest page is now the oldest leaf
        assert_eq!(tree.evict(1, &mut alloc).unwrap(), vec![pa[2]]);
        // interior pages only leave after their children
        assert_eq!(tree.evict(9, &mut alloc).unwrap(), vec![pa[1], pa[0]]);
        assert_eq!(tree.cached_pages(), 0);
        alloc.check().unwrap();
        assert_eq!(alloc.free_pages(), 16, "eviction round-trips to a full free list");
    }

    #[test]
    fn eviction_skips_pages_referenced_by_requests() {
        let mut alloc = BlockAllocator::new(16, 4, 1);
        let mut tree = PrefixTree::new(4);
        let prompt: Vec<i32> = (0..8).collect();
        let pages = prefill(&mut alloc, 1, &prompt);
        tree.insert(&prompt, &pages, &mut alloc).unwrap();
        alloc.free(1);
        // a follower pins the whole chain
        let chain = tree.match_prefix(&prompt);
        alloc.admit_shared(2, 8, 12, &chain).unwrap();
        assert!(tree.evict(9, &mut alloc).unwrap().is_empty(), "chain is pinned");
        assert_eq!(tree.flush(&mut alloc).unwrap(), 0);
        alloc.free(2);
        assert_eq!(tree.flush(&mut alloc).unwrap(), 2);
        alloc.check().unwrap();
        assert_eq!(alloc.free_pages(), 16);
    }

    /// The old victim rule, verbatim: full arena min-scan over zero-ref
    /// leaves with the `(last_used, idx)` tie-break. The ordered-walk
    /// eviction must never disagree with it.
    fn naive_victim(tree: &PrefixTree, alloc: &BlockAllocator) -> Option<u32> {
        tree.nodes
            .iter()
            .enumerate()
            .filter_map(|(i, slot)| slot.as_ref().map(|n| (i, n)))
            .filter(|(_, n)| n.children.is_empty() && alloc.req_refs(n.page) == 0)
            .min_by_key(|(i, n)| (n.last_used, *i))
            .map(|(_, n)| n.page)
    }

    /// Seeded forest with forks, duplicate prefixes and scrambled recency
    /// — the parity fixture for the ordered eviction walk.
    fn seeded_forest() -> (PrefixTree, BlockAllocator) {
        let mut alloc = BlockAllocator::new(64, 4, 1);
        let mut tree = PrefixTree::new(4);
        let a: Vec<i32> = (0..16).collect(); // 4-page chain
        let mut b = a[..12].to_vec(); // forks off a at page 3
        b[9] = 90;
        let c: Vec<i32> = (100..112).collect(); // disjoint 3-page chain
        let d: Vec<i32> = (0..8).collect(); // pure duplicate of a's head
        for (owner, t) in [(1u64, &a), (2, &b), (3, &c), (4, &d)] {
            let pages = prefill(&mut alloc, *owner, t);
            tree.insert(t, &pages, &mut alloc).unwrap();
            alloc.free(*owner);
        }
        // scramble LRU stamps: equal-stamp ties and interleaved recency
        tree.match_prefix(&c);
        tree.match_prefix(&a[..8]);
        tree.match_prefix(&b);
        alloc.check().unwrap();
        (tree, alloc)
    }

    #[test]
    fn ordered_eviction_matches_the_naive_scan_victim_order() {
        // one-at-a-time: every evict(1) must take exactly the full-scan pick
        let (mut t1, mut a1) = seeded_forest();
        let mut order1 = Vec::new();
        loop {
            let expect = naive_victim(&t1, &a1);
            let got = t1.evict(1, &mut a1).unwrap();
            match expect {
                Some(page) => assert_eq!(got, vec![page], "victim #{}", order1.len()),
                None => {
                    assert!(got.is_empty());
                    break;
                }
            }
            order1.push(got[0]);
            a1.check().unwrap();
        }
        assert_eq!(t1.cached_pages(), 0);
        assert!(!order1.is_empty());
        // bulk drain under a single cursor produces the identical sequence
        // (this is where the cursor-rollback-to-exposed-parent rule earns
        // its keep: a's interior pages share stamps with smaller indices)
        let (mut t2, mut a2) = seeded_forest();
        let order2 = t2.evict(usize::MAX, &mut a2).unwrap();
        assert_eq!(order2, order1, "single-cursor drain must match per-round rescans");
        a2.check().unwrap();
    }

    #[test]
    fn eviction_respects_admission_pins() {
        let mut alloc = BlockAllocator::new(16, 4, 1);
        let mut tree = PrefixTree::new(4);
        let prompt: Vec<i32> = (0..8).collect();
        let pages = prefill(&mut alloc, 1, &prompt);
        tree.insert(&prompt, &pages, &mut alloc).unwrap();
        alloc.free(1);
        // a pinned leaf blocks itself and (leaf-only rule) its ancestors
        alloc.pin(pages[1]).unwrap();
        assert_eq!(tree.flush(&mut alloc).unwrap(), 0, "pinned chain must survive");
        alloc.unpin(pages[1]).unwrap();
        assert_eq!(tree.flush(&mut alloc).unwrap(), 2);
        alloc.check().unwrap();
    }

    #[test]
    fn evict_with_keys_reports_full_root_path_prefixes() {
        let mut alloc = BlockAllocator::new(16, 4, 1);
        let mut tree = PrefixTree::new(4);
        let a: Vec<i32> = (0..12).collect();
        let mut b = a[..8].to_vec();
        b[5] = 50;
        let pa = prefill(&mut alloc, 1, &a);
        let pb = prefill(&mut alloc, 2, &b);
        tree.insert(&a, &pa, &mut alloc).unwrap();
        tree.insert(&b, &pb, &mut alloc).unwrap();
        alloc.free(1);
        alloc.free(2);
        // chains() walks every node with its full prefix
        let mut chains = tree.chains();
        chains.sort();
        let mut want = vec![
            (a[..4].to_vec(), pa[0]),
            (a[..8].to_vec(), pa[1]),
            (a[..12].to_vec(), pa[2]),
            (b[..8].to_vec(), pb[1]),
        ];
        want.sort();
        assert_eq!(chains, want);
        // each eviction reports the page together with the prefix that
        // keys it on disk
        let evicted = tree.evict_with_keys(usize::MAX, &mut alloc).unwrap();
        let mut got: Vec<(Vec<i32>, u32)> =
            evicted.into_iter().map(|(page, toks)| (toks, page)).collect();
        got.sort();
        assert_eq!(got, want);
        alloc.check().unwrap();
    }
}
