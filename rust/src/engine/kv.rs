//! KV-cache storage and accounting: the legacy fixed-slot slabs, the paged
//! pool + block allocator behind continuous batching, and the byte-accurate
//! budget arithmetic the batcher admits against.
//!
//! Two layouts coexist ([`KvLayout`]):
//!
//! * **Slab** — one `[B, KVl, M, D]` tensor pair per (rank, layer); every
//!   slot owns a full `max_seq` region. Simple, and the bitwise oracle the
//!   paged path is tested against.
//! * **Paged** — one `[P, KVl, page_size, D]` pool pair per (rank, layer);
//!   requests own page *lists* handed out by a [`BlockAllocator`], so KV
//!   memory scales with tokens actually written, not with `max_seq`.
//!
//! The allocator uses **reservation-based admission**: a request is admitted
//! only if its worst-case page count (prompt + `max_new_tokens`, clamped to
//! `max_seq`) fits in the unreserved capacity. Physical pages are then
//! allocated lazily as tokens are written. Because physical use never
//! exceeds reservations and reservations never exceed capacity, an admitted
//! request can always grow to its reserved length — no deadlock, no
//! preemption, and every accepted request finishes (the paged stress
//! harness asserts exactly this).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::model::{HostTensor, LlamaConfig};

/// Which KV storage layout an engine is built with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLayout {
    /// Fixed `max_seq`-sized region per batch slot (the legacy layout).
    Slab,
    /// Block-granular pool: `pages` pages of `page_size` tokens each,
    /// shared by all slots through per-request page tables.
    Paged { page_size: usize, pages: usize },
}

impl KvLayout {
    pub fn is_paged(&self) -> bool {
        matches!(self, KvLayout::Paged { .. })
    }

    /// The one pool-sizing rule shared by every builder (CLI, examples):
    /// `budget_bytes / page_bytes` pages, but never fewer than one
    /// `max_seq`-long request (so the server can always make progress —
    /// the paged mirror of the fixed-slot clamp to >= 1 slot); a zero
    /// budget sizes the pool to `batch` full-length sequences, the same
    /// worst-case capacity the slabs reserve.
    pub fn paged_from_budget(
        cfg: &LlamaConfig,
        tp: usize,
        page_size: usize,
        budget_bytes: usize,
        batch: usize,
    ) -> KvLayout {
        let page_bytes = PagedKvCache::page_bytes_all_ranks(cfg, tp, page_size);
        let per_seq = cfg.max_seq.div_ceil(page_size);
        let pages = if budget_bytes == 0 {
            batch * per_seq
        } else {
            (budget_bytes / page_bytes.max(1)).max(per_seq)
        };
        KvLayout::Paged { page_size, pages }
    }
}

/// Per-forward paged routing data, broadcast to every rank: the padded
/// page-table matrix for the batch plus the chunk start position (chunked
/// prefill). Rows of `tables` are `-1`-padded; decode rows for inactive
/// slots are all `-1` and their `lens` entry is `-1` (the module skips
/// them entirely — no pool read or write).
#[derive(Debug, Clone)]
pub struct PagedFwd {
    /// `[B, max_pages]` page ids, row-major, `-1` padded.
    pub tables: Vec<i32>,
    /// Pages per row in `tables`.
    pub max_pages: usize,
    /// First global position of this chunk (prefill only; decode ignores).
    pub start: i32,
}

// ---------------------------------------------------------------------------
// fixed-slot slabs (legacy layout, and the paged path's bitwise oracle)
// ---------------------------------------------------------------------------

/// Host-resident fixed-slot KV cache for one rank: `layers x {k, v}` slabs.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub k: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub batch: usize,
    pub kv_heads_l: usize,
    pub max_seq: usize,
    pub head_dim: usize,
}

impl KvCache {
    pub fn new(
        layers: usize,
        batch: usize,
        kv_heads_l: usize,
        max_seq: usize,
        head_dim: usize,
    ) -> KvCache {
        let shape = vec![batch, kv_heads_l, max_seq, head_dim];
        KvCache {
            k: (0..layers).map(|_| HostTensor::zeros(shape.clone())).collect(),
            v: (0..layers).map(|_| HostTensor::zeros(shape.clone())).collect(),
            batch,
            kv_heads_l,
            max_seq,
            head_dim,
        }
    }

    /// Bytes per slot (both K and V, all layers) — the KV budget unit the
    /// batcher admits against.
    pub fn bytes_per_slot(&self) -> usize {
        Self::slot_bytes(self.k.len(), self.kv_heads_l, self.max_seq, self.head_dim)
    }

    /// Same unit computed from a config, summed over all `tp` ranks —
    /// lets the engine answer KV-budget questions without touching the
    /// caches (which live on worker threads under the threaded runtime).
    pub fn bytes_per_slot_all_ranks(cfg: &LlamaConfig, tp: usize) -> usize {
        tp * Self::slot_bytes(cfg.layers, cfg.kv_heads / tp, cfg.max_seq, cfg.head_dim)
    }

    /// Single source of truth for the per-rank slot footprint (f32 K + V).
    fn slot_bytes(layers: usize, kv_heads_l: usize, max_seq: usize, head_dim: usize) -> usize {
        2 * layers * kv_heads_l * max_seq * head_dim * 4
    }

    fn slot_stride(&self) -> usize {
        self.kv_heads_l * self.max_seq * self.head_dim
    }

    /// Overwrite slot `b` of layer `layer` from a single-slot cache tensor
    /// (shape [1, KVl, M, D]) — used when a b=1 prefill lands in a multi-slot
    /// decode batch (continuous batching).
    pub fn write_slot(
        &mut self,
        layer: usize,
        b: usize,
        k1: &HostTensor,
        v1: &HostTensor,
    ) -> Result<()> {
        let stride = self.slot_stride();
        if k1.data.len() != stride || v1.data.len() != stride {
            bail!(
                "slot tensor has {} elems, want {stride} (shape {:?})",
                k1.data.len(),
                k1.shape
            );
        }
        if b >= self.batch {
            bail!("slot {b} out of range (batch {})", self.batch);
        }
        self.k[layer].data[b * stride..(b + 1) * stride].copy_from_slice(&k1.data);
        self.v[layer].data[b * stride..(b + 1) * stride].copy_from_slice(&v1.data);
        Ok(())
    }

    /// Extract slot `b` of layer `layer` as a [1, KVl, M, D] pair.
    pub fn read_slot(&self, layer: usize, b: usize) -> (HostTensor, HostTensor) {
        let stride = self.slot_stride();
        let shape = vec![1, self.kv_heads_l, self.max_seq, self.head_dim];
        let k = self.k[layer].data[b * stride..(b + 1) * stride].to_vec();
        let v = self.v[layer].data[b * stride..(b + 1) * stride].to_vec();
        (HostTensor::new(shape.clone(), k), HostTensor::new(shape, v))
    }

    /// Zero a slot's *written prefix* (request eviction). `written` is the
    /// engine's tracked length for the slot; positions beyond it may still
    /// hold stale data (bucket-padded prefill and idle-slot decodes write
    /// past the tracked length), but that data is unreachable: attention
    /// masks every read to the tracked length, and decode writes a
    /// position before the mask ever covers it. Zeroing the whole
    /// `max_seq` slab — what this method used to do — therefore bought
    /// nothing except an `O(max_seq - written)` memset per (layer, head).
    pub fn clear_slot(&mut self, b: usize, written: usize) {
        let (m, d) = (self.max_seq, self.head_dim);
        let upto = written.min(m);
        if upto == 0 {
            return;
        }
        for layer in 0..self.k.len() {
            for kh in 0..self.kv_heads_l {
                let base = (b * self.kv_heads_l + kh) * m * d;
                self.k[layer].data[base..base + upto * d].fill(0.0);
                self.v[layer].data[base..base + upto * d].fill(0.0);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// paged pool storage (one per rank)
// ---------------------------------------------------------------------------

/// Host-resident paged KV pool for one rank: `layers x {k, v}` tensors of
/// shape `[pages, KVl, page_size, D]`. Which request owns which page is the
/// [`BlockAllocator`]'s business (it lives with the batcher); the pool only
/// stores and scatters rows.
#[derive(Debug, Clone)]
pub struct PagedKvCache {
    pub k: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub pages: usize,
    pub kv_heads_l: usize,
    pub page_size: usize,
    pub head_dim: usize,
}

impl PagedKvCache {
    pub fn new(
        layers: usize,
        pages: usize,
        kv_heads_l: usize,
        page_size: usize,
        head_dim: usize,
    ) -> PagedKvCache {
        let shape = vec![pages, kv_heads_l, page_size, head_dim];
        PagedKvCache {
            k: (0..layers).map(|_| HostTensor::zeros(shape.clone())).collect(),
            v: (0..layers).map(|_| HostTensor::zeros(shape.clone())).collect(),
            pages,
            kv_heads_l,
            page_size,
            head_dim,
        }
    }

    /// Bytes one page occupies across all `tp` ranks (K + V, all layers) —
    /// the paged counterpart of [`KvCache::bytes_per_slot_all_ranks`] and
    /// the unit `--kv-budget-mb` is accounted in.
    pub fn page_bytes_all_ranks(cfg: &LlamaConfig, tp: usize, page_size: usize) -> usize {
        tp * 2 * cfg.layers * (cfg.kv_heads / tp) * page_size * cfg.head_dim * 4
    }

    /// Move one layer's pool tensors out (zero-copy upload into a module
    /// call); the caller puts them back with [`PagedKvCache::put_layer`].
    pub fn take_layer(&mut self, layer: usize) -> (HostTensor, HostTensor) {
        let empty = || HostTensor::new(vec![0], Vec::new());
        (
            std::mem::replace(&mut self.k[layer], empty()),
            std::mem::replace(&mut self.v[layer], empty()),
        )
    }

    pub fn put_layer(&mut self, layer: usize, k: HostTensor, v: HostTensor) {
        self.k[layer] = k;
        self.v[layer] = v;
    }

    /// Scatter freshly written K/V rows into the pool. `rows` is
    /// `[n, KVl, D]` flattened; `dst[i]` is the (page, in-page offset) each
    /// row lands at.
    pub fn scatter_rows(
        &mut self,
        layer: usize,
        dst: &[(u32, usize)],
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> Result<()> {
        let (kvl, p, d) = (self.kv_heads_l, self.page_size, self.head_dim);
        if k_rows.len() != dst.len() * kvl * d || v_rows.len() != k_rows.len() {
            bail!("scatter_rows: {} rows for {} destinations", k_rows.len() / (kvl * d), dst.len());
        }
        for (i, &(page, off)) in dst.iter().enumerate() {
            let page = page as usize;
            if page >= self.pages || off >= p {
                bail!("scatter_rows: page {page} offset {off} out of range");
            }
            for kh in 0..kvl {
                let src = (i * kvl + kh) * d;
                let at = ((page * kvl + kh) * p + off) * d;
                self.k[layer].data[at..at + d].copy_from_slice(&k_rows[src..src + d]);
                self.v[layer].data[at..at + d].copy_from_slice(&v_rows[src..src + d]);
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// block allocator (free-list + per-request page tables + budget accounting)
// ---------------------------------------------------------------------------

/// One request's view of the pool.
#[derive(Debug, Clone)]
pub struct PageTable {
    /// Physical pages in logical order: token position `t` lives in
    /// `pages[t / page_size]` at in-page offset `t % page_size`.
    pub pages: Vec<u32>,
    /// Tokens with allocated backing (`pages.len() == ceil(len/page_size)`).
    pub len: usize,
    /// Worst-case pages this request may grow to (admission commitment).
    pub reserved_pages: usize,
}

/// Free-list page allocator with per-request page tables and byte-accurate
/// budget accounting. Admission reserves worst-case capacity; physical
/// pages are handed out lazily as tokens are written and returned in full
/// the instant a request finishes or is cancelled.
#[derive(Debug)]
pub struct BlockAllocator {
    page_size: usize,
    /// Bytes one page occupies across all ranks (K + V, all layers).
    page_bytes: usize,
    total_pages: usize,
    /// LIFO free list of physical page ids.
    free: Vec<u32>,
    tables: HashMap<u64, PageTable>,
    reserved_total: usize,
    high_water: usize,
}

impl BlockAllocator {
    pub fn new(total_pages: usize, page_size: usize, page_bytes: usize) -> BlockAllocator {
        assert!(page_size > 0, "page_size must be positive");
        BlockAllocator {
            page_size,
            page_bytes,
            total_pages,
            // LIFO and descending so page 0 is handed out first.
            free: (0..total_pages as u32).rev().collect(),
            tables: HashMap::new(),
            reserved_total: 0,
            high_water: 0,
        }
    }

    /// Pages needed to back `tokens` token positions.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_size)
    }

    /// Admission rule: would a request with this worst-case token count fit
    /// in the unreserved capacity right now?
    pub fn can_admit(&self, reserve_tokens: usize) -> bool {
        self.reserved_total + self.pages_for(reserve_tokens) <= self.total_pages
    }

    /// Admit `owner`: reserve `reserve_tokens` worth of pages and allocate
    /// backing for the `prompt_tokens` that are about to be written.
    pub fn admit(&mut self, owner: u64, prompt_tokens: usize, reserve_tokens: usize) -> Result<()> {
        if self.tables.contains_key(&owner) {
            bail!("owner {owner} already has a page table");
        }
        if prompt_tokens > reserve_tokens {
            bail!("prompt {prompt_tokens} exceeds reservation {reserve_tokens}");
        }
        if !self.can_admit(reserve_tokens) {
            bail!(
                "cannot admit {owner}: {} pages reserved of {}, want {} more",
                self.reserved_total,
                self.total_pages,
                self.pages_for(reserve_tokens)
            );
        }
        let reserved_pages = self.pages_for(reserve_tokens);
        self.reserved_total += reserved_pages;
        self.tables.insert(owner, PageTable { pages: Vec::new(), len: 0, reserved_pages });
        self.ensure(owner, prompt_tokens)
    }

    /// Grow `owner`'s backing to cover `new_len` tokens. Guaranteed to
    /// succeed within the reservation (the free list cannot be empty while
    /// any owner is below its reserved page count).
    pub fn ensure(&mut self, owner: u64, new_len: usize) -> Result<()> {
        let need = self.pages_for(new_len);
        let table = self
            .tables
            .get_mut(&owner)
            .ok_or_else(|| anyhow::anyhow!("owner {owner} has no page table"))?;
        if need > table.reserved_pages {
            bail!(
                "owner {owner}: {new_len} tokens need {need} pages, reserved {}",
                table.reserved_pages
            );
        }
        while table.pages.len() < need {
            let page = self.free.pop().ok_or_else(|| {
                anyhow::anyhow!("free list empty inside a reservation — allocator corrupt")
            })?;
            table.pages.push(page);
        }
        table.len = table.len.max(new_len);
        let in_use = self.total_pages - self.free.len();
        self.high_water = self.high_water.max(in_use);
        Ok(())
    }

    /// Release everything `owner` holds (finish / cancel): physical pages go
    /// straight back to the free list, the reservation is dropped. Returns
    /// the number of pages freed; unknown owners free nothing.
    pub fn free(&mut self, owner: u64) -> usize {
        let Some(table) = self.tables.remove(&owner) else { return 0 };
        self.reserved_total -= table.reserved_pages;
        let n = table.pages.len();
        self.free.extend(table.pages);
        n
    }

    pub fn table(&self, owner: u64) -> Option<&PageTable> {
        self.tables.get(&owner)
    }

    /// Encode `owner`'s page list into one `-1`-padded row of the
    /// per-forward page-table matrix — the single definition of the wire
    /// format the paged attention modules consume (shared by the batcher's
    /// decode path and `generate`).
    pub fn fill_table_row(&self, owner: u64, row: &mut [i32]) -> Result<()> {
        let table = self
            .tables
            .get(&owner)
            .ok_or_else(|| anyhow::anyhow!("owner {owner} has no page table"))?;
        if table.pages.len() > row.len() {
            bail!("owner {owner}: {} pages do not fit a {}-wide row", table.pages.len(), row.len());
        }
        for (i, dst) in row.iter_mut().enumerate() {
            *dst = table.pages.get(i).map_or(-1, |&p| p as i32);
        }
        Ok(())
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn page_bytes(&self) -> usize {
        self.page_bytes
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn pages_in_use(&self) -> usize {
        self.total_pages - self.free.len()
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn reserved_pages(&self) -> usize {
        self.reserved_total
    }

    /// Most pages ever simultaneously allocated (the `kv_pages_high_water`
    /// metric).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn bytes_in_use(&self) -> usize {
        self.pages_in_use() * self.page_bytes
    }

    /// Full structural audit, run by the stress harness after every step:
    /// conservation (free + owned == total), no page double-owned or both
    /// owned and free, per-owner backing exactly matches its length, and
    /// reservations within capacity.
    pub fn check(&self) -> Result<()> {
        let mut seen: Vec<u32> = self.free.clone();
        let mut owned = 0usize;
        let mut reserved = 0usize;
        for (owner, t) in &self.tables {
            if t.pages.len() != self.pages_for(t.len) {
                bail!(
                    "owner {owner}: {} pages backing {} tokens (want {})",
                    t.pages.len(),
                    t.len,
                    self.pages_for(t.len)
                );
            }
            if t.pages.len() > t.reserved_pages {
                bail!(
                    "owner {owner}: holds {} pages, reserved {}",
                    t.pages.len(),
                    t.reserved_pages
                );
            }
            owned += t.pages.len();
            reserved += t.reserved_pages;
            seen.extend(&t.pages);
        }
        if self.free.len() + owned != self.total_pages {
            bail!(
                "page leak: {} free + {} owned != {} total",
                self.free.len(),
                owned,
                self.total_pages
            );
        }
        seen.sort_unstable();
        for w in seen.windows(2) {
            if w[0] == w[1] {
                bail!("page {} is double-owned (or owned and free)", w[0]);
            }
        }
        if let Some(&max) = seen.last() {
            if max as usize >= self.total_pages {
                bail!("page id {max} out of range ({} pages)", self.total_pages);
            }
        }
        if reserved != self.reserved_total || reserved > self.total_pages {
            bail!(
                "reservation accounting: {} summed vs {} tracked of {} total",
                reserved,
                self.reserved_total,
                self.total_pages
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_roundtrip() {
        let mut kv = KvCache::new(2, 3, 2, 4, 2);
        let stride = 2 * 4 * 2;
        let k1 = HostTensor::new(vec![1, 2, 4, 2], (0..stride).map(|x| x as f32).collect());
        let v1 = HostTensor::new(vec![1, 2, 4, 2], (0..stride).map(|x| -(x as f32)).collect());
        kv.write_slot(1, 2, &k1, &v1).unwrap();
        let (k, v) = kv.read_slot(1, 2);
        assert_eq!(k.data, k1.data);
        assert_eq!(v.data, v1.data);
        // other slots untouched
        let (k0, _) = kv.read_slot(1, 0);
        assert!(k0.data.iter().all(|&x| x == 0.0));
        kv.clear_slot(2, 4);
        let (k, _) = kv.read_slot(1, 2);
        assert!(k.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn clear_slot_zeroes_exactly_the_written_prefix() {
        let (layers, kvl, m, d) = (2, 2, 8, 2);
        let mut kv = KvCache::new(layers, 2, kvl, m, d);
        let stride = kvl * m * d;
        let ones = HostTensor::new(vec![1, kvl, m, d], vec![1.0; stride]);
        kv.write_slot(0, 1, &ones, &ones).unwrap();
        kv.write_slot(1, 1, &ones, &ones).unwrap();
        // only 3 positions were really written: clearing with written=3
        // must zero positions 0..3 of every (layer, head) and not touch the
        // rest of the slab (which a reused slot never reads — its masked
        // attention covers only its own written prefix)
        kv.clear_slot(1, 3);
        for layer in 0..layers {
            let (k, v) = kv.read_slot(layer, 1);
            for kh in 0..kvl {
                for j in 0..m {
                    let at = (kh * m + j) * d;
                    let want = if j < 3 { 0.0 } else { 1.0 };
                    assert_eq!(k.data[at], want, "layer {layer} head {kh} pos {j}");
                    assert_eq!(v.data[at], want, "layer {layer} head {kh} pos {j}");
                }
            }
        }
        // written beyond max_seq clamps instead of panicking
        kv.clear_slot(1, 99);
        let (k, _) = kv.read_slot(0, 1);
        assert!(k.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut kv = KvCache::new(1, 2, 2, 4, 2);
        let bad = HostTensor::zeros(vec![1, 2, 2, 2]);
        assert!(kv.write_slot(0, 0, &bad, &bad).is_err());
        let good = HostTensor::zeros(vec![1, 2, 4, 2]);
        assert!(kv.write_slot(0, 5, &good, &good).is_err());
    }

    #[test]
    fn bytes_per_slot() {
        let kv = KvCache::new(2, 1, 2, 8, 4);
        assert_eq!(kv.bytes_per_slot(), 2 * 2 * 2 * 8 * 4 * 4);
    }

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            name: "t".into(),
            vocab: 32,
            hidden: 16,
            layers: 3,
            heads: 4,
            kv_heads: 4,
            head_dim: 4,
            ffn: 32,
            max_seq: 8,
            rope_theta: 1e4,
            norm_eps: 1e-5,
            params: 0,
        }
    }

    #[test]
    fn bytes_per_slot_all_ranks_matches_instances() {
        let cfg = tiny_cfg();
        for tp in [1usize, 2, 4] {
            let per_rank =
                KvCache::new(cfg.layers, 2, cfg.kv_heads / tp, cfg.max_seq, cfg.head_dim);
            assert_eq!(
                KvCache::bytes_per_slot_all_ranks(&cfg, tp),
                tp * per_rank.bytes_per_slot()
            );
        }
    }

    #[test]
    fn paged_from_budget_sizing() {
        let cfg = tiny_cfg(); // max_seq 8 -> 2 pages per sequence at page 4
        let page_bytes = PagedKvCache::page_bytes_all_ranks(&cfg, 2, 4);
        let paged = |pages| KvLayout::Paged { page_size: 4, pages };
        // zero budget: batch x worst case (slab-equivalent capacity)
        assert_eq!(KvLayout::paged_from_budget(&cfg, 2, 4, 0, 3), paged(6));
        // budget-driven
        assert_eq!(KvLayout::paged_from_budget(&cfg, 2, 4, 5 * page_bytes, 3), paged(5));
        // clamped to at least one full-length request
        assert_eq!(KvLayout::paged_from_budget(&cfg, 2, 4, 1, 3), paged(2));
    }

    #[test]
    fn page_bytes_sum_to_slab_bytes() {
        // ceil(max_seq / page_size) pages cover exactly one slab when the
        // page size divides max_seq — the budget units agree
        let cfg = tiny_cfg();
        for tp in [1usize, 2] {
            let page = PagedKvCache::page_bytes_all_ranks(&cfg, tp, 4);
            let slab = KvCache::bytes_per_slot_all_ranks(&cfg, tp);
            assert_eq!(page * (cfg.max_seq / 4), slab);
        }
    }

    #[test]
    fn paged_scatter_lands_rows() {
        let (kvl, p, d) = (2, 4, 2);
        let mut pool = PagedKvCache::new(2, 3, kvl, p, d);
        let rows: Vec<f32> = (0..2 * kvl * d).map(|x| x as f32 + 1.0).collect();
        let vrows: Vec<f32> = rows.iter().map(|x| -x).collect();
        pool.scatter_rows(1, &[(2, 1), (0, 3)], &rows, &vrows).unwrap();
        // row 0 -> page 2 offset 1; row 1 -> page 0 offset 3
        for kh in 0..kvl {
            let at = ((2 * kvl + kh) * p + 1) * d;
            assert_eq!(pool.k[1].data[at..at + d], rows[kh * d..(kh + 1) * d]);
            let at = (kh * p + 3) * d;
            assert_eq!(pool.v[1].data[at..at + d], vrows[(kvl + kh) * d..(kvl + kh + 1) * d]);
        }
        // layer 0 untouched
        assert!(pool.k[0].data.iter().all(|&x| x == 0.0));
        // out-of-range destinations are errors, not UB
        assert!(pool.scatter_rows(0, &[(9, 0)], &rows[..kvl * d], &vrows[..kvl * d]).is_err());
        assert!(pool.scatter_rows(0, &[(0, 9)], &rows[..kvl * d], &vrows[..kvl * d]).is_err());
    }

    #[test]
    fn take_put_layer_roundtrip() {
        let mut pool = PagedKvCache::new(2, 2, 1, 2, 2);
        pool.k[1].data[3] = 7.0;
        let (k, v) = pool.take_layer(1);
        assert_eq!(k.data[3], 7.0);
        assert!(pool.k[1].data.is_empty());
        pool.put_layer(1, k, v);
        assert_eq!(pool.k[1].data[3], 7.0);
    }

    #[test]
    fn allocator_admit_ensure_free_lifecycle() {
        let mut a = BlockAllocator::new(8, 4, 100);
        assert!(a.can_admit(32));
        assert!(!a.can_admit(33));
        // prompt 5 tokens (2 pages), worst case 10 tokens (3 pages)
        a.admit(1, 5, 10).unwrap();
        a.check().unwrap();
        assert_eq!(a.pages_in_use(), 2);
        assert_eq!(a.reserved_pages(), 3);
        assert_eq!(a.table(1).unwrap().pages, vec![0, 1]);
        // growing within the current page allocates nothing
        a.ensure(1, 8).unwrap();
        assert_eq!(a.pages_in_use(), 2);
        // crossing the boundary takes the third page; beyond the
        // reservation is an error
        a.ensure(1, 9).unwrap();
        assert_eq!(a.pages_in_use(), 3);
        assert!(a.ensure(1, 13).is_err());
        a.check().unwrap();
        assert_eq!(a.bytes_in_use(), 300);
        assert_eq!(a.high_water(), 3);
        assert_eq!(a.free(1), 3);
        a.check().unwrap();
        assert_eq!((a.pages_in_use(), a.reserved_pages(), a.free_pages()), (0, 0, 8));
        assert_eq!(a.high_water(), 3, "high water survives the free");
        assert_eq!(a.free(1), 0, "double free is a no-op");
    }

    #[test]
    fn allocator_admission_is_reservation_gated() {
        let mut a = BlockAllocator::new(4, 2, 1);
        a.admit(1, 1, 6).unwrap(); // reserves 3 pages, holds 1
        assert_eq!(a.pages_in_use(), 1);
        // 1 page of unreserved capacity left: a 2-page request must wait
        // even though 3 physical pages are free (they are promised to 1)
        assert!(a.can_admit(2));
        assert!(!a.can_admit(3));
        assert!(a.admit(2, 1, 4).is_err());
        a.admit(2, 1, 2).unwrap();
        // both requests can always grow to their full reservation
        a.ensure(1, 6).unwrap();
        a.ensure(2, 2).unwrap();
        a.check().unwrap();
        assert_eq!(a.free_pages(), 0);
    }

    #[test]
    fn allocator_rejects_double_admit_and_unknown_owner() {
        let mut a = BlockAllocator::new(4, 2, 1);
        a.admit(7, 2, 4).unwrap();
        assert!(a.admit(7, 2, 4).is_err());
        assert!(a.ensure(8, 2).is_err());
        assert!(a.admit(9, 5, 4).is_err(), "prompt beyond reservation");
    }

    #[test]
    fn page_table_maps_positions() {
        let mut a = BlockAllocator::new(8, 4, 1);
        a.admit(1, 9, 12).unwrap();
        let t = a.table(1).unwrap();
        assert_eq!(t.pages.len(), 3);
        assert_eq!(t.len, 9);
        // token position 6 -> pages[1], offset 2
        assert_eq!(t.pages[6 / 4], t.pages[1]);
        assert_eq!(6 % 4, 2);
        // the per-forward row encoding: pages in order, -1 padded
        let mut row = [9i32; 5];
        a.fill_table_row(1, &mut row).unwrap();
        assert_eq!(row, [0, 1, 2, -1, -1]);
        let mut tight = [9i32; 2];
        assert!(a.fill_table_row(1, &mut tight).is_err(), "row narrower than the table");
        assert!(a.fill_table_row(7, &mut row).is_err(), "unknown owner");
    }
}
