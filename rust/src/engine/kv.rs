//! KV-cache slabs: one [B, KVl, M, D] tensor pair per (rank, layer), plus
//! per-slot length bookkeeping for continuous batching.

use anyhow::{bail, Result};

use crate::model::{HostTensor, LlamaConfig};

/// Host-resident KV cache for one rank: `layers x {k, v}` slabs.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub k: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub batch: usize,
    pub kv_heads_l: usize,
    pub max_seq: usize,
    pub head_dim: usize,
}

impl KvCache {
    pub fn new(
        layers: usize,
        batch: usize,
        kv_heads_l: usize,
        max_seq: usize,
        head_dim: usize,
    ) -> KvCache {
        let shape = vec![batch, kv_heads_l, max_seq, head_dim];
        KvCache {
            k: (0..layers).map(|_| HostTensor::zeros(shape.clone())).collect(),
            v: (0..layers).map(|_| HostTensor::zeros(shape.clone())).collect(),
            batch,
            kv_heads_l,
            max_seq,
            head_dim,
        }
    }

    /// Bytes per slot (both K and V, all layers) — the KV budget unit the
    /// batcher admits against.
    pub fn bytes_per_slot(&self) -> usize {
        Self::slot_bytes(self.k.len(), self.kv_heads_l, self.max_seq, self.head_dim)
    }

    /// Same unit computed from a config, summed over all `tp` ranks —
    /// lets the engine answer KV-budget questions without touching the
    /// caches (which live on worker threads under the threaded runtime).
    pub fn bytes_per_slot_all_ranks(cfg: &LlamaConfig, tp: usize) -> usize {
        tp * Self::slot_bytes(cfg.layers, cfg.kv_heads / tp, cfg.max_seq, cfg.head_dim)
    }

    /// Single source of truth for the per-rank slot footprint (f32 K + V).
    fn slot_bytes(layers: usize, kv_heads_l: usize, max_seq: usize, head_dim: usize) -> usize {
        2 * layers * kv_heads_l * max_seq * head_dim * 4
    }

    fn slot_stride(&self) -> usize {
        self.kv_heads_l * self.max_seq * self.head_dim
    }

    /// Overwrite slot `b` of layer `layer` from a single-slot cache tensor
    /// (shape [1, KVl, M, D]) — used when a b=1 prefill lands in a multi-slot
    /// decode batch (continuous batching).
    pub fn write_slot(
        &mut self,
        layer: usize,
        b: usize,
        k1: &HostTensor,
        v1: &HostTensor,
    ) -> Result<()> {
        let stride = self.slot_stride();
        if k1.data.len() != stride || v1.data.len() != stride {
            bail!(
                "slot tensor has {} elems, want {stride} (shape {:?})",
                k1.data.len(),
                k1.shape
            );
        }
        if b >= self.batch {
            bail!("slot {b} out of range (batch {})", self.batch);
        }
        self.k[layer].data[b * stride..(b + 1) * stride].copy_from_slice(&k1.data);
        self.v[layer].data[b * stride..(b + 1) * stride].copy_from_slice(&v1.data);
        Ok(())
    }

    /// Extract slot `b` of layer `layer` as a [1, KVl, M, D] pair.
    pub fn read_slot(&self, layer: usize, b: usize) -> (HostTensor, HostTensor) {
        let stride = self.slot_stride();
        let shape = vec![1, self.kv_heads_l, self.max_seq, self.head_dim];
        let k = self.k[layer].data[b * stride..(b + 1) * stride].to_vec();
        let v = self.v[layer].data[b * stride..(b + 1) * stride].to_vec();
        (HostTensor::new(shape.clone(), k), HostTensor::new(shape, v))
    }

    /// Zero a slot (request eviction).
    pub fn clear_slot(&mut self, b: usize) {
        let stride = self.slot_stride();
        for layer in 0..self.k.len() {
            self.k[layer].data[b * stride..(b + 1) * stride].fill(0.0);
            self.v[layer].data[b * stride..(b + 1) * stride].fill(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_roundtrip() {
        let mut kv = KvCache::new(2, 3, 2, 4, 2);
        let stride = 2 * 4 * 2;
        let k1 = HostTensor::new(vec![1, 2, 4, 2], (0..stride).map(|x| x as f32).collect());
        let v1 = HostTensor::new(vec![1, 2, 4, 2], (0..stride).map(|x| -(x as f32)).collect());
        kv.write_slot(1, 2, &k1, &v1).unwrap();
        let (k, v) = kv.read_slot(1, 2);
        assert_eq!(k.data, k1.data);
        assert_eq!(v.data, v1.data);
        // other slots untouched
        let (k0, _) = kv.read_slot(1, 0);
        assert!(k0.data.iter().all(|&x| x == 0.0));
        kv.clear_slot(2);
        let (k, _) = kv.read_slot(1, 2);
        assert!(k.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut kv = KvCache::new(1, 2, 2, 4, 2);
        let bad = HostTensor::zeros(vec![1, 2, 2, 2]);
        assert!(kv.write_slot(0, 0, &bad, &bad).is_err());
        let good = HostTensor::zeros(vec![1, 2, 4, 2]);
        assert!(kv.write_slot(0, 5, &good, &good).is_err());
    }

    #[test]
    fn bytes_per_slot() {
        let kv = KvCache::new(2, 1, 2, 8, 4);
        assert_eq!(kv.bytes_per_slot(), 2 * 2 * 2 * 8 * 4 * 4);
    }

    #[test]
    fn bytes_per_slot_all_ranks_matches_instances() {
        let cfg = LlamaConfig {
            name: "t".into(),
            vocab: 32,
            hidden: 16,
            layers: 3,
            heads: 4,
            kv_heads: 4,
            head_dim: 4,
            ffn: 32,
            max_seq: 8,
            rope_theta: 1e4,
            norm_eps: 1e-5,
            params: 0,
        };
        for tp in [1usize, 2, 4] {
            let per_rank =
                KvCache::new(cfg.layers, 2, cfg.kv_heads / tp, cfg.max_seq, cfg.head_dim);
            assert_eq!(
                KvCache::bytes_per_slot_all_ranks(&cfg, tp),
                tp * per_rank.bytes_per_slot()
            );
        }
    }
}
