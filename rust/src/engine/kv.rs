//! KV-cache storage and accounting: the legacy fixed-slot slabs, the paged
//! pool + block allocator behind continuous batching, and the byte-accurate
//! budget arithmetic the batcher admits against.
//!
//! Two layouts coexist ([`KvLayout`]):
//!
//! * **Slab** — one `[B, KVl, M, D]` tensor pair per (rank, layer); every
//!   slot owns a full `max_seq` region. Simple, and the bitwise oracle the
//!   paged path is tested against.
//! * **Paged** — one `[P, KVl, page_size, D]` pool pair per (rank, layer);
//!   requests own page *lists* handed out by a [`BlockAllocator`], so KV
//!   memory scales with tokens actually written, not with `max_seq`.
//!
//! The allocator uses **reservation-based admission**: a request is admitted
//! only if its worst-case page count (prompt + `max_new_tokens`, clamped to
//! `max_seq`) fits in the unreserved capacity. Physical pages are then
//! allocated lazily as tokens are written. Because physical use never
//! exceeds reservations and reservations never exceed capacity, an admitted
//! request can always grow to its reserved length — no deadlock, no
//! preemption, and every accepted request finishes (the paged stress
//! harness asserts exactly this).
//!
//! Pages are **reference counted** so the prefix cache
//! ([`super::prefix::PrefixTree`]) can share full, immutable prompt pages
//! between requests: `rc_req` counts the request tables holding a page,
//! `tree_ref` marks the prefix tree's reference. A page returns to the free
//! list only when both drop to zero. A request admitted against a cached
//! chain reserves only its *uncached suffix*; the admission invariant
//! becomes `reserved_total + shared_active <= total_pages` (shared pages
//! pinned by live requests count once, however many requests read them),
//! which keeps the no-deadlock guarantee: every outstanding private-page
//! commitment is backed by a page that is free or evictable (cached with no
//! request references). The tree evicts in LRU order when the free list
//! alone cannot feed a commitment.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::model::{HostTensor, LlamaConfig};

/// Which KV storage layout an engine is built with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLayout {
    /// Fixed `max_seq`-sized region per batch slot (the legacy layout).
    Slab,
    /// Block-granular pool: `pages` pages of `page_size` tokens each,
    /// shared by all slots through per-request page tables.
    Paged { page_size: usize, pages: usize },
}

impl KvLayout {
    pub fn is_paged(&self) -> bool {
        matches!(self, KvLayout::Paged { .. })
    }

    /// The one pool-sizing rule shared by every builder (CLI, examples):
    /// `budget_bytes / page_bytes` pages, but never fewer than one
    /// `max_seq`-long request (so the server can always make progress —
    /// the paged mirror of the fixed-slot clamp to >= 1 slot); a zero
    /// budget sizes the pool to `batch` full-length sequences, the same
    /// worst-case capacity the slabs reserve.
    pub fn paged_from_budget(
        cfg: &LlamaConfig,
        tp: usize,
        page_size: usize,
        budget_bytes: usize,
        batch: usize,
    ) -> KvLayout {
        let page_bytes = PagedKvCache::page_bytes_all_ranks(cfg, tp, page_size);
        let per_seq = cfg.max_seq.div_ceil(page_size);
        let pages = if budget_bytes == 0 {
            batch * per_seq
        } else {
            (budget_bytes / page_bytes.max(1)).max(per_seq)
        };
        KvLayout::Paged { page_size, pages }
    }
}

/// Per-forward paged routing data, broadcast to every rank: the padded
/// page-table matrix for the batch plus the chunk start position (chunked
/// prefill). Rows of `tables` are `-1`-padded; decode rows for inactive
/// slots are all `-1` and their `lens` entry is `-1` (the module skips
/// them entirely — no pool read or write).
#[derive(Debug, Clone)]
pub struct PagedFwd {
    /// `[B, max_pages]` page ids, row-major, `-1` padded.
    pub tables: Vec<i32>,
    /// Pages per row in `tables`.
    pub max_pages: usize,
    /// First global position of this chunk (prefill only; decode ignores).
    pub start: i32,
}

// ---------------------------------------------------------------------------
// fixed-slot slabs (legacy layout, and the paged path's bitwise oracle)
// ---------------------------------------------------------------------------

/// Host-resident fixed-slot KV cache for one rank: `layers x {k, v}` slabs.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub k: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub batch: usize,
    pub kv_heads_l: usize,
    pub max_seq: usize,
    pub head_dim: usize,
}

impl KvCache {
    pub fn new(
        layers: usize,
        batch: usize,
        kv_heads_l: usize,
        max_seq: usize,
        head_dim: usize,
    ) -> KvCache {
        let shape = vec![batch, kv_heads_l, max_seq, head_dim];
        KvCache {
            k: (0..layers).map(|_| HostTensor::zeros(shape.clone())).collect(),
            v: (0..layers).map(|_| HostTensor::zeros(shape.clone())).collect(),
            batch,
            kv_heads_l,
            max_seq,
            head_dim,
        }
    }

    /// Bytes per slot (both K and V, all layers) — the KV budget unit the
    /// batcher admits against.
    pub fn bytes_per_slot(&self) -> usize {
        Self::slot_bytes(self.k.len(), self.kv_heads_l, self.max_seq, self.head_dim)
    }

    /// Same unit computed from a config, summed over all `tp` ranks —
    /// lets the engine answer KV-budget questions without touching the
    /// caches (which live on worker threads under the threaded runtime).
    pub fn bytes_per_slot_all_ranks(cfg: &LlamaConfig, tp: usize) -> usize {
        tp * Self::slot_bytes(cfg.layers, cfg.kv_heads / tp, cfg.max_seq, cfg.head_dim)
    }

    /// Single source of truth for the per-rank slot footprint (f32 K + V).
    fn slot_bytes(layers: usize, kv_heads_l: usize, max_seq: usize, head_dim: usize) -> usize {
        2 * layers * kv_heads_l * max_seq * head_dim * 4
    }

    fn slot_stride(&self) -> usize {
        self.kv_heads_l * self.max_seq * self.head_dim
    }

    /// Overwrite slot `b` of layer `layer` from a single-slot cache tensor
    /// (shape [1, KVl, M, D]) — used when a b=1 prefill lands in a multi-slot
    /// decode batch (continuous batching).
    pub fn write_slot(
        &mut self,
        layer: usize,
        b: usize,
        k1: &HostTensor,
        v1: &HostTensor,
    ) -> Result<()> {
        let stride = self.slot_stride();
        if k1.data.len() != stride || v1.data.len() != stride {
            bail!(
                "slot tensor has {} elems, want {stride} (shape {:?})",
                k1.data.len(),
                k1.shape
            );
        }
        if b >= self.batch {
            bail!("slot {b} out of range (batch {})", self.batch);
        }
        self.k[layer].data[b * stride..(b + 1) * stride].copy_from_slice(&k1.data);
        self.v[layer].data[b * stride..(b + 1) * stride].copy_from_slice(&v1.data);
        Ok(())
    }

    /// Extract slot `b` of layer `layer` as a [1, KVl, M, D] pair.
    pub fn read_slot(&self, layer: usize, b: usize) -> (HostTensor, HostTensor) {
        self.read_span(layer, b, 1)
    }

    /// Extract the contiguous slot range `[start, start+count)` of layer
    /// `layer` as a [count, KVl, M, D] pair — the batch axis leads the slab
    /// layout, so a row chunk is one contiguous slice (split-batch overlap).
    pub fn read_span(&self, layer: usize, start: usize, count: usize) -> (HostTensor, HostTensor) {
        let stride = self.slot_stride();
        let shape = vec![count, self.kv_heads_l, self.max_seq, self.head_dim];
        let k = self.k[layer].data[start * stride..(start + count) * stride].to_vec();
        let v = self.v[layer].data[start * stride..(start + count) * stride].to_vec();
        (HostTensor::new(shape.clone(), k), HostTensor::new(shape, v))
    }

    /// Overwrite the slot range `[start, start+count)` of layer `layer`
    /// from a [count, KVl, M, D] pair — the write half of [`read_span`].
    ///
    /// [`read_span`]: KvCache::read_span
    pub fn write_span(
        &mut self,
        layer: usize,
        start: usize,
        count: usize,
        kc: &HostTensor,
        vc: &HostTensor,
    ) -> Result<()> {
        let stride = self.slot_stride();
        if kc.data.len() != count * stride || vc.data.len() != count * stride {
            bail!(
                "span tensor has {} elems, want {} (shape {:?})",
                kc.data.len(),
                count * stride,
                kc.shape
            );
        }
        if start + count > self.batch {
            bail!("span {start}+{count} out of range (batch {})", self.batch);
        }
        self.k[layer].data[start * stride..(start + count) * stride].copy_from_slice(&kc.data);
        self.v[layer].data[start * stride..(start + count) * stride].copy_from_slice(&vc.data);
        Ok(())
    }

    /// Zero a slot's *written prefix* (request eviction). `written` is the
    /// engine's tracked length for the slot; positions beyond it may still
    /// hold stale data (bucket-padded prefill and idle-slot decodes write
    /// past the tracked length), but that data is unreachable: attention
    /// masks every read to the tracked length, and decode writes a
    /// position before the mask ever covers it. Zeroing the whole
    /// `max_seq` slab — what this method used to do — therefore bought
    /// nothing except an `O(max_seq - written)` memset per (layer, head).
    pub fn clear_slot(&mut self, b: usize, written: usize) {
        let (m, d) = (self.max_seq, self.head_dim);
        let upto = written.min(m);
        if upto == 0 {
            return;
        }
        for layer in 0..self.k.len() {
            for kh in 0..self.kv_heads_l {
                let base = (b * self.kv_heads_l + kh) * m * d;
                self.k[layer].data[base..base + upto * d].fill(0.0);
                self.v[layer].data[base..base + upto * d].fill(0.0);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// paged pool storage (one per rank)
// ---------------------------------------------------------------------------

/// Host-resident paged KV pool for one rank: `layers x {k, v}` tensors of
/// shape `[pages, KVl, page_size, D]`. Which request owns which page is the
/// [`BlockAllocator`]'s business (it lives with the batcher); the pool only
/// stores and scatters rows.
#[derive(Debug, Clone)]
pub struct PagedKvCache {
    pub k: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub pages: usize,
    pub kv_heads_l: usize,
    pub page_size: usize,
    pub head_dim: usize,
}

impl PagedKvCache {
    pub fn new(
        layers: usize,
        pages: usize,
        kv_heads_l: usize,
        page_size: usize,
        head_dim: usize,
    ) -> PagedKvCache {
        let shape = vec![pages, kv_heads_l, page_size, head_dim];
        PagedKvCache {
            k: (0..layers).map(|_| HostTensor::zeros(shape.clone())).collect(),
            v: (0..layers).map(|_| HostTensor::zeros(shape.clone())).collect(),
            pages,
            kv_heads_l,
            page_size,
            head_dim,
        }
    }

    /// Bytes one page occupies across all `tp` ranks (K + V, all layers) —
    /// the paged counterpart of [`KvCache::bytes_per_slot_all_ranks`] and
    /// the unit `--kv-budget-mb` is accounted in.
    pub fn page_bytes_all_ranks(cfg: &LlamaConfig, tp: usize, page_size: usize) -> usize {
        tp * 2 * cfg.layers * (cfg.kv_heads / tp) * page_size * cfg.head_dim * 4
    }

    /// Move one layer's pool tensors out (zero-copy upload into a module
    /// call); the caller puts them back with [`PagedKvCache::put_layer`].
    pub fn take_layer(&mut self, layer: usize) -> (HostTensor, HostTensor) {
        let empty = || HostTensor::new(vec![0], Vec::new());
        (
            std::mem::replace(&mut self.k[layer], empty()),
            std::mem::replace(&mut self.v[layer], empty()),
        )
    }

    pub fn put_layer(&mut self, layer: usize, k: HostTensor, v: HostTensor) {
        self.k[layer] = k;
        self.v[layer] = v;
    }

    /// Copy every row of page `src` into page `dst` across all layers (the
    /// copy-on-write step behind full-prompt prefix-cache hits: the shared
    /// trailing page is duplicated into a private page before the hit's
    /// final token is re-prefilled over it). Bitwise copy — reuse stays
    /// exact.
    pub fn copy_page(&mut self, src: u32, dst: u32) -> Result<()> {
        let (src, dst) = (src as usize, dst as usize);
        if src >= self.pages || dst >= self.pages {
            bail!("copy_page: {src} -> {dst} out of range ({} pages)", self.pages);
        }
        if src == dst {
            bail!("copy_page: source and destination are both page {src}");
        }
        let stride = self.kv_heads_l * self.page_size * self.head_dim;
        for layer in 0..self.k.len() {
            for t in [&mut self.k[layer], &mut self.v[layer]] {
                t.data.copy_within(src * stride..(src + 1) * stride, dst * stride);
            }
        }
        Ok(())
    }

    /// Elements one page holds in one pool tensor (per layer, K or V).
    fn page_stride(&self) -> usize {
        self.kv_heads_l * self.page_size * self.head_dim
    }

    /// Read one page's full contents — every layer, K plane then V plane,
    /// layer-major — as a flat f32 vector. This is the serialization order
    /// the disk spill tier ([`super::SpillStore`]) stores verbatim, so
    /// `write_page(read_page(p))` is bitwise-exact by construction.
    pub fn read_page(&self, page: u32) -> Result<Vec<f32>> {
        let p = page as usize;
        if p >= self.pages {
            bail!("read_page: page {p} out of range ({} pages)", self.pages);
        }
        let stride = self.page_stride();
        let mut out = Vec::with_capacity(2 * self.k.len() * stride);
        for layer in 0..self.k.len() {
            out.extend_from_slice(&self.k[layer].data[p * stride..(p + 1) * stride]);
            out.extend_from_slice(&self.v[layer].data[p * stride..(p + 1) * stride]);
        }
        Ok(out)
    }

    /// Overwrite one page from a flat f32 vector in [`read_page`]'s layout
    /// (the disk tier's restore path).
    ///
    /// [`read_page`]: PagedKvCache::read_page
    pub fn write_page(&mut self, page: u32, data: &[f32]) -> Result<()> {
        let p = page as usize;
        if p >= self.pages {
            bail!("write_page: page {p} out of range ({} pages)", self.pages);
        }
        let stride = self.page_stride();
        if data.len() != 2 * self.k.len() * stride {
            bail!(
                "write_page: {} elems for a {}-elem page",
                data.len(),
                2 * self.k.len() * stride
            );
        }
        for layer in 0..self.k.len() {
            let base = 2 * layer * stride;
            self.k[layer].data[p * stride..(p + 1) * stride]
                .copy_from_slice(&data[base..base + stride]);
            self.v[layer].data[p * stride..(p + 1) * stride]
                .copy_from_slice(&data[base + stride..base + 2 * stride]);
        }
        Ok(())
    }

    /// Scatter freshly written K/V rows into the pool. `rows` is
    /// `[n, KVl, D]` flattened; `dst[i]` is the (page, in-page offset) each
    /// row lands at.
    pub fn scatter_rows(
        &mut self,
        layer: usize,
        dst: &[(u32, usize)],
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> Result<()> {
        let (kvl, p, d) = (self.kv_heads_l, self.page_size, self.head_dim);
        if k_rows.len() != dst.len() * kvl * d || v_rows.len() != k_rows.len() {
            bail!("scatter_rows: {} rows for {} destinations", k_rows.len() / (kvl * d), dst.len());
        }
        for (i, &(page, off)) in dst.iter().enumerate() {
            let page = page as usize;
            if page >= self.pages || off >= p {
                bail!("scatter_rows: page {page} offset {off} out of range");
            }
            for kh in 0..kvl {
                let src = (i * kvl + kh) * d;
                let at = ((page * kvl + kh) * p + off) * d;
                self.k[layer].data[at..at + d].copy_from_slice(&k_rows[src..src + d]);
                self.v[layer].data[at..at + d].copy_from_slice(&v_rows[src..src + d]);
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// block allocator (free-list + per-request page tables + budget accounting)
// ---------------------------------------------------------------------------

/// One request's view of the pool.
#[derive(Debug, Clone)]
pub struct PageTable {
    /// Physical pages in logical order: token position `t` lives in
    /// `pages[t / page_size]` at in-page offset `t % page_size`.
    pub pages: Vec<u32>,
    /// Tokens with allocated backing (`pages.len() == ceil(len/page_size)`).
    pub len: usize,
    /// Worst-case pages this request may grow to (admission commitment,
    /// shared prefix included).
    pub reserved_pages: usize,
    /// Leading pages retained from the prefix tree at admission: shared,
    /// immutable, never allocated from (or returned to) this owner's
    /// private reservation.
    pub shared_pages: usize,
}

/// Free-list page allocator with per-request page tables, per-page
/// reference counts and byte-accurate budget accounting. Admission reserves
/// worst-case capacity for the *uncached* part of a request; physical pages
/// are handed out lazily as tokens are written and returned the instant the
/// last reference (request table or prefix tree) drops.
#[derive(Debug)]
pub struct BlockAllocator {
    page_size: usize,
    /// Bytes one page occupies across all ranks (K + V, all layers).
    page_bytes: usize,
    total_pages: usize,
    /// LIFO free list of physical page ids (all with zero references).
    free: Vec<u32>,
    tables: HashMap<u64, PageTable>,
    /// Sum over live owners of their *private* commitments
    /// (`reserved_pages - shared_pages`): the pages they may still pull
    /// from the free list.
    reserved_total: usize,
    high_water: usize,
    /// Per-page count of request tables referencing the page.
    rc_req: Vec<u32>,
    /// Per-page: does the prefix tree hold a reference? (At most one node
    /// per page — the tree never aliases.)
    tree_ref: Vec<bool>,
    /// Pages referenced by the tree AND >= 1 request (pinned: counted once
    /// against capacity no matter how many requests read them).
    shared_active: usize,
    /// Pages referenced only by the tree (the evictable cache).
    cached_idle: usize,
    /// Per-page eviction pin count: the batcher pins a matched chain (and
    /// COW source) between `match_prefix` and `tree_retain`/`copy_page` so
    /// a same-step shortfall eviction for a *different* admission cannot
    /// free it mid-admit (the match→retain TOCTOU). Pins only ever sit on
    /// tree-referenced pages and only block `tree_release`.
    pins: Vec<u32>,
    /// Per-page "backing allocated but bytes not landed yet": the disk
    /// tier's async-restore state. A pending page is owned by exactly one
    /// request (rc_req > 0, never tree-referenced) whose slot sits in the
    /// load phase until every pending bit clears.
    pending: Vec<bool>,
}

impl BlockAllocator {
    pub fn new(total_pages: usize, page_size: usize, page_bytes: usize) -> BlockAllocator {
        assert!(page_size > 0, "page_size must be positive");
        BlockAllocator {
            page_size,
            page_bytes,
            total_pages,
            // LIFO and descending so page 0 is handed out first.
            free: (0..total_pages as u32).rev().collect(),
            tables: HashMap::new(),
            reserved_total: 0,
            high_water: 0,
            rc_req: vec![0; total_pages],
            tree_ref: vec![false; total_pages],
            shared_active: 0,
            cached_idle: 0,
            pins: vec![0; total_pages],
            pending: vec![false; total_pages],
        }
    }

    /// Pages needed to back `tokens` token positions.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_size)
    }

    /// Admission rule (no cached prefix): would a request with this
    /// worst-case token count fit right now?
    pub fn can_admit(&self, reserve_tokens: usize) -> bool {
        self.can_admit_chain(reserve_tokens, &[])
    }

    /// Admission rule against a cached prefix chain: the request commits to
    /// `pages_for(reserve_tokens) - chain.len()` *private* pages, and any
    /// chain page not yet pinned by another request newly joins the
    /// shared-active set. The invariant `reserved_total + shared_active <=
    /// total_pages` guarantees every private commitment can be fed from
    /// free or evictable (zero-request-ref cached) pages — the no-deadlock
    /// rule, now shared-prefix aware. Note evicting the cache can never
    /// unblock this check (eviction frees idle pages, which already count
    /// as available); eviction only feeds *physical* page allocation.
    pub fn can_admit_chain(&self, reserve_tokens: usize, chain: &[u32]) -> bool {
        let reserved = self.pages_for(reserve_tokens);
        if chain.len() > reserved {
            return false;
        }
        let newly_active = chain.iter().filter(|&&p| self.rc_req[p as usize] == 0).count();
        self.reserved_total + (reserved - chain.len()) + self.shared_active + newly_active
            <= self.total_pages
    }

    /// Admit `owner`: reserve `reserve_tokens` worth of pages and allocate
    /// backing for the `prompt_tokens` that are about to be written.
    pub fn admit(&mut self, owner: u64, prompt_tokens: usize, reserve_tokens: usize) -> Result<()> {
        self.admit_shared(owner, prompt_tokens, reserve_tokens, &[])
    }

    /// Admit `owner` on top of a cached prefix: `chain` pages (retained
    /// from the prefix tree, every one tree-referenced and covering the
    /// prompt's leading `chain.len() * page_size` tokens) become the head
    /// of the owner's table without touching its private reservation;
    /// backing for the uncached remainder of the prompt is allocated from
    /// the free list. The caller must evict enough cached-idle pages first
    /// if the free list is short ([`BlockAllocator::free_shortfall`]).
    pub fn admit_shared(
        &mut self,
        owner: u64,
        prompt_tokens: usize,
        reserve_tokens: usize,
        chain: &[u32],
    ) -> Result<()> {
        if self.tables.contains_key(&owner) {
            bail!("owner {owner} already has a page table");
        }
        if prompt_tokens > reserve_tokens {
            bail!("prompt {prompt_tokens} exceeds reservation {reserve_tokens}");
        }
        if chain.len() * self.page_size > prompt_tokens {
            bail!(
                "cached chain of {} pages overruns the {prompt_tokens}-token prompt",
                chain.len()
            );
        }
        for &p in chain {
            if p as usize >= self.total_pages || !self.tree_ref[p as usize] {
                bail!("chain page {p} is not a cached page");
            }
        }
        if !self.can_admit_chain(reserve_tokens, chain) {
            bail!(
                "cannot admit {owner}: {} private pages reserved + {} shared-active of {}, \
                 want {} more",
                self.reserved_total,
                self.shared_active,
                self.total_pages,
                self.pages_for(reserve_tokens) - chain.len()
            );
        }
        for &p in chain {
            self.rc_req[p as usize] += 1;
            if self.rc_req[p as usize] == 1 {
                self.cached_idle -= 1;
                self.shared_active += 1;
            }
        }
        let reserved_pages = self.pages_for(reserve_tokens);
        self.reserved_total += reserved_pages - chain.len();
        self.tables.insert(
            owner,
            PageTable {
                pages: chain.to_vec(),
                len: chain.len() * self.page_size,
                reserved_pages,
                shared_pages: chain.len(),
            },
        );
        self.ensure(owner, prompt_tokens)
    }

    /// Grow `owner`'s backing to cover `new_len` tokens. Guaranteed to
    /// succeed within the reservation, provided the caller has first
    /// evicted any cached-idle pages the free list is short of
    /// ([`BlockAllocator::free_shortfall`]) — the invariant guarantees
    /// free + evictable always covers outstanding commitments.
    pub fn ensure(&mut self, owner: u64, new_len: usize) -> Result<()> {
        let need = self.pages_for(new_len);
        let table = self
            .tables
            .get_mut(&owner)
            .ok_or_else(|| anyhow::anyhow!("owner {owner} has no page table"))?;
        if need > table.reserved_pages {
            bail!(
                "owner {owner}: {new_len} tokens need {need} pages, reserved {}",
                table.reserved_pages
            );
        }
        while table.pages.len() < need {
            let page = self.free.pop().ok_or_else(|| {
                anyhow::anyhow!(
                    "free list empty inside a reservation — evict the prefix cache before \
                     growing (allocator corrupt if nothing is evictable)"
                )
            })?;
            self.rc_req[page as usize] = 1;
            table.pages.push(page);
        }
        table.len = table.len.max(new_len);
        let in_use = self.total_pages - self.free.len();
        self.high_water = self.high_water.max(in_use);
        Ok(())
    }

    /// Free pages `owner` would need to pull from the free list to back
    /// `new_len` tokens, beyond what the free list currently holds — the
    /// number of cached-idle pages the caller must evict before calling
    /// [`BlockAllocator::ensure`]. Zero when the free list already
    /// suffices. (Admission computes its own shortfall: the chain head
    /// never touches the free list.)
    pub fn free_shortfall(&self, owner: u64, new_len: usize) -> usize {
        let backed = self.tables.get(&owner).map_or(0, |t| t.pages.len());
        let grow = self.pages_for(new_len).saturating_sub(backed);
        grow.saturating_sub(self.free.len())
    }

    /// Release everything `owner` holds (finish / cancel): the owner's
    /// reference on each page is dropped; pages with no remaining
    /// references return to the free list, pages the prefix tree still
    /// references become cached-idle (evictable) instead of being freed —
    /// **never** zeroed or reused while referenced. Returns the number of
    /// pages actually freed; unknown owners free nothing.
    pub fn free(&mut self, owner: u64) -> usize {
        let Some(table) = self.tables.remove(&owner) else { return 0 };
        self.reserved_total -= table.reserved_pages - table.shared_pages;
        let mut n = 0;
        for page in table.pages {
            let p = page as usize;
            self.rc_req[p] -= 1;
            if self.rc_req[p] == 0 {
                // an aborted disk restore must not leave a stale pending
                // bit on a recycled page
                self.pending[p] = false;
                if self.tree_ref[p] {
                    self.shared_active -= 1;
                    self.cached_idle += 1;
                } else {
                    self.free.push(page);
                    n += 1;
                }
            }
        }
        n
    }

    /// Request-table references currently held on `page`.
    pub fn req_refs(&self, page: u32) -> u32 {
        self.rc_req[page as usize]
    }

    /// Is `page` referenced by the prefix tree?
    pub fn is_cached(&self, page: u32) -> bool {
        self.tree_ref[page as usize]
    }

    /// Pin a cached page against eviction for the match→retain window.
    /// Only tree-referenced pages can be pinned (a private page is already
    /// unevictable); pins nest.
    pub fn pin(&mut self, page: u32) -> Result<()> {
        let p = page as usize;
        if p >= self.total_pages || !self.tree_ref[p] {
            bail!("pin: page {page} is not a cached page");
        }
        self.pins[p] += 1;
        Ok(())
    }

    /// Drop one eviction pin from `page`.
    pub fn unpin(&mut self, page: u32) -> Result<()> {
        let p = page as usize;
        if p >= self.total_pages || self.pins[p] == 0 {
            bail!("unpin: page {page} is not pinned");
        }
        self.pins[p] -= 1;
        Ok(())
    }

    /// Eviction pins currently held on `page`.
    pub fn pin_count(&self, page: u32) -> u32 {
        self.pins[page as usize]
    }

    /// May the prefix tree evict `page` right now? (No request reference
    /// and no admission-window pin.)
    pub fn evictable(&self, page: u32) -> bool {
        self.rc_req[page as usize] == 0 && self.pins[page as usize] == 0
    }

    /// Flag `page` as awaiting its bytes from the disk tier. The page must
    /// be privately owned (rc_req > 0, not tree-referenced): the loading
    /// request already holds its backing, only the contents are in flight.
    pub fn mark_pending(&mut self, page: u32) -> Result<()> {
        let p = page as usize;
        if p >= self.total_pages {
            bail!("mark_pending: page {page} out of range");
        }
        if self.rc_req[p] == 0 {
            bail!("mark_pending: page {page} has no owner");
        }
        if self.tree_ref[p] {
            bail!("mark_pending: page {page} is a cached page (its bytes already exist)");
        }
        self.pending[p] = true;
        Ok(())
    }

    /// Clear the pending flag (the bytes landed, or the load was
    /// abandoned for a cold prefill over the same page).
    pub fn clear_pending(&mut self, page: u32) {
        self.pending[page as usize] = false;
    }

    /// Is `page` still waiting for its disk bytes?
    pub fn is_pending(&self, page: u32) -> bool {
        self.pending[page as usize]
    }

    /// Pages currently awaiting disk bytes (stats / audits).
    pub fn pending_pages(&self) -> usize {
        self.pending.iter().filter(|&&b| b).count()
    }

    /// Take the prefix tree's reference on `page` (publish). The page must
    /// currently be owned by the publishing request — a free page cannot be
    /// published — and not already cached (the tree never aliases a page).
    pub fn tree_retain(&mut self, page: u32) -> Result<()> {
        let p = page as usize;
        if p >= self.total_pages {
            bail!("tree_retain: page {page} out of range");
        }
        if self.tree_ref[p] {
            bail!("tree_retain: page {page} is already cached");
        }
        if self.rc_req[p] == 0 {
            bail!("tree_retain: page {page} has no owner to publish from");
        }
        self.tree_ref[p] = true;
        self.shared_active += 1;
        Ok(())
    }

    /// Drop the prefix tree's reference on `page` (eviction). Only legal on
    /// cached pages no request references — eviction must never touch a
    /// page with a positive request refcount. The page returns to the free
    /// list.
    pub fn tree_release(&mut self, page: u32) -> Result<()> {
        let p = page as usize;
        if p >= self.total_pages || !self.tree_ref[p] {
            bail!("tree_release: page {page} is not cached");
        }
        if self.rc_req[p] > 0 {
            bail!("tree_release: page {page} still has {} request refs", self.rc_req[p]);
        }
        if self.pins[p] > 0 {
            bail!("tree_release: page {page} is pinned by an in-flight admission");
        }
        self.tree_ref[p] = false;
        self.cached_idle -= 1;
        self.free.push(page);
        Ok(())
    }

    /// Pages currently referenced by the prefix tree (pinned + idle).
    pub fn cached_pages(&self) -> usize {
        self.shared_active + self.cached_idle
    }

    /// Cached pages no live request references — what eviction can reclaim.
    pub fn evictable_pages(&self) -> usize {
        self.cached_idle
    }

    pub fn table(&self, owner: u64) -> Option<&PageTable> {
        self.tables.get(&owner)
    }

    /// Encode `owner`'s page list into one `-1`-padded row of the
    /// per-forward page-table matrix — the single definition of the wire
    /// format the paged attention modules consume (shared by the batcher's
    /// decode path and `generate`).
    pub fn fill_table_row(&self, owner: u64, row: &mut [i32]) -> Result<()> {
        let table = self
            .tables
            .get(&owner)
            .ok_or_else(|| anyhow::anyhow!("owner {owner} has no page table"))?;
        if table.pages.len() > row.len() {
            bail!("owner {owner}: {} pages do not fit a {}-wide row", table.pages.len(), row.len());
        }
        for (i, dst) in row.iter_mut().enumerate() {
            *dst = table.pages.get(i).map_or(-1, |&p| p as i32);
        }
        Ok(())
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn page_bytes(&self) -> usize {
        self.page_bytes
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn pages_in_use(&self) -> usize {
        self.total_pages - self.free.len()
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn reserved_pages(&self) -> usize {
        self.reserved_total
    }

    /// Most pages ever simultaneously allocated (the `kv_pages_high_water`
    /// metric).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn bytes_in_use(&self) -> usize {
        self.pages_in_use() * self.page_bytes
    }

    /// Full structural audit, run by the stress harness after every step:
    /// reference counts exactly match the tables, conservation (every page
    /// is free xor referenced), a page is never both free and referenced,
    /// shared pages are tree-backed, per-owner backing exactly matches its
    /// length, reservations within capacity, and the shared-prefix
    /// admission invariant (`reserved_total + shared_active <= total`) that
    /// carries the no-deadlock guarantee.
    pub fn check(&self) -> Result<()> {
        let mut rc: Vec<u32> = vec![0; self.total_pages];
        let mut reserved = 0usize;
        for (owner, t) in &self.tables {
            if t.pages.len() != self.pages_for(t.len) {
                bail!(
                    "owner {owner}: {} pages backing {} tokens (want {})",
                    t.pages.len(),
                    t.len,
                    self.pages_for(t.len)
                );
            }
            if t.pages.len() > t.reserved_pages {
                bail!(
                    "owner {owner}: holds {} pages, reserved {}",
                    t.pages.len(),
                    t.reserved_pages
                );
            }
            if t.shared_pages > t.reserved_pages {
                bail!(
                    "owner {owner}: {} shared pages exceed its {}-page reservation",
                    t.shared_pages,
                    t.reserved_pages
                );
            }
            reserved += t.reserved_pages - t.shared_pages;
            let mut in_table = std::collections::HashSet::new();
            for (i, &p) in t.pages.iter().enumerate() {
                if p as usize >= self.total_pages {
                    bail!("owner {owner}: page id {p} out of range ({} pages)", self.total_pages);
                }
                if !in_table.insert(p) {
                    bail!("owner {owner}: page {p} appears twice in one table");
                }
                if i < t.shared_pages && !self.tree_ref[p as usize] {
                    bail!(
                        "owner {owner}: shared page {p} lost its prefix-tree reference \
                         while still in use"
                    );
                }
                rc[p as usize] += 1;
            }
        }
        if rc != self.rc_req {
            bail!("request refcounts diverge from the tables");
        }
        let mut free_seen = vec![false; self.total_pages];
        for &p in &self.free {
            let p = p as usize;
            if p >= self.total_pages {
                bail!("free page id {p} out of range");
            }
            if free_seen[p] {
                bail!("page {p} is on the free list twice");
            }
            free_seen[p] = true;
            if rc[p] > 0 || self.tree_ref[p] {
                bail!(
                    "page {p} is free but still referenced (rc {}, tree {})",
                    rc[p],
                    self.tree_ref[p]
                );
            }
        }
        let (mut active, mut idle) = (0usize, 0usize);
        for p in 0..self.total_pages {
            match (rc[p] > 0, self.tree_ref[p]) {
                (true, true) => active += 1,
                (false, true) => idle += 1,
                (false, false) if !free_seen[p] => {
                    bail!("page {p} leaked: no reference and not on the free list")
                }
                _ => {}
            }
        }
        if active != self.shared_active || idle != self.cached_idle {
            bail!(
                "shared-page accounting: {active} active / {idle} idle counted vs \
                 {} / {} tracked",
                self.shared_active,
                self.cached_idle
            );
        }
        if reserved != self.reserved_total || reserved > self.total_pages {
            bail!(
                "reservation accounting: {} summed vs {} tracked of {} total",
                reserved,
                self.reserved_total,
                self.total_pages
            );
        }
        if self.reserved_total + self.shared_active > self.total_pages {
            bail!(
                "no-deadlock invariant broken: {} reserved + {} shared-active > {} total",
                self.reserved_total,
                self.shared_active,
                self.total_pages
            );
        }
        for p in 0..self.total_pages {
            if self.pins[p] > 0 && !self.tree_ref[p] {
                bail!("page {p} is pinned ({} pins) but not tree-referenced", self.pins[p]);
            }
            if self.pending[p] {
                if rc[p] == 0 {
                    bail!("page {p} is pending a disk load with no owner");
                }
                if self.tree_ref[p] {
                    bail!("page {p} is pending a disk load but already cached");
                }
                if free_seen[p] {
                    bail!("page {p} is pending a disk load while on the free list");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_roundtrip() {
        let mut kv = KvCache::new(2, 3, 2, 4, 2);
        let stride = 2 * 4 * 2;
        let k1 = HostTensor::new(vec![1, 2, 4, 2], (0..stride).map(|x| x as f32).collect());
        let v1 = HostTensor::new(vec![1, 2, 4, 2], (0..stride).map(|x| -(x as f32)).collect());
        kv.write_slot(1, 2, &k1, &v1).unwrap();
        let (k, v) = kv.read_slot(1, 2);
        assert_eq!(k.data, k1.data);
        assert_eq!(v.data, v1.data);
        // other slots untouched
        let (k0, _) = kv.read_slot(1, 0);
        assert!(k0.data.iter().all(|&x| x == 0.0));
        kv.clear_slot(2, 4);
        let (k, _) = kv.read_slot(1, 2);
        assert!(k.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn clear_slot_zeroes_exactly_the_written_prefix() {
        let (layers, kvl, m, d) = (2, 2, 8, 2);
        let mut kv = KvCache::new(layers, 2, kvl, m, d);
        let stride = kvl * m * d;
        let ones = HostTensor::new(vec![1, kvl, m, d], vec![1.0; stride]);
        kv.write_slot(0, 1, &ones, &ones).unwrap();
        kv.write_slot(1, 1, &ones, &ones).unwrap();
        // only 3 positions were really written: clearing with written=3
        // must zero positions 0..3 of every (layer, head) and not touch the
        // rest of the slab (which a reused slot never reads — its masked
        // attention covers only its own written prefix)
        kv.clear_slot(1, 3);
        for layer in 0..layers {
            let (k, v) = kv.read_slot(layer, 1);
            for kh in 0..kvl {
                for j in 0..m {
                    let at = (kh * m + j) * d;
                    let want = if j < 3 { 0.0 } else { 1.0 };
                    assert_eq!(k.data[at], want, "layer {layer} head {kh} pos {j}");
                    assert_eq!(v.data[at], want, "layer {layer} head {kh} pos {j}");
                }
            }
        }
        // written beyond max_seq clamps instead of panicking
        kv.clear_slot(1, 99);
        let (k, _) = kv.read_slot(0, 1);
        assert!(k.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut kv = KvCache::new(1, 2, 2, 4, 2);
        let bad = HostTensor::zeros(vec![1, 2, 2, 2]);
        assert!(kv.write_slot(0, 0, &bad, &bad).is_err());
        let good = HostTensor::zeros(vec![1, 2, 4, 2]);
        assert!(kv.write_slot(0, 5, &good, &good).is_err());
    }

    #[test]
    fn bytes_per_slot() {
        let kv = KvCache::new(2, 1, 2, 8, 4);
        assert_eq!(kv.bytes_per_slot(), 2 * 2 * 2 * 8 * 4 * 4);
    }

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            name: "t".into(),
            vocab: 32,
            hidden: 16,
            layers: 3,
            heads: 4,
            kv_heads: 4,
            head_dim: 4,
            ffn: 32,
            max_seq: 8,
            rope_theta: 1e4,
            norm_eps: 1e-5,
            params: 0,
        }
    }

    #[test]
    fn bytes_per_slot_all_ranks_matches_instances() {
        let cfg = tiny_cfg();
        for tp in [1usize, 2, 4] {
            let per_rank =
                KvCache::new(cfg.layers, 2, cfg.kv_heads / tp, cfg.max_seq, cfg.head_dim);
            assert_eq!(
                KvCache::bytes_per_slot_all_ranks(&cfg, tp),
                tp * per_rank.bytes_per_slot()
            );
        }
    }

    #[test]
    fn paged_from_budget_sizing() {
        let cfg = tiny_cfg(); // max_seq 8 -> 2 pages per sequence at page 4
        let page_bytes = PagedKvCache::page_bytes_all_ranks(&cfg, 2, 4);
        let paged = |pages| KvLayout::Paged { page_size: 4, pages };
        // zero budget: batch x worst case (slab-equivalent capacity)
        assert_eq!(KvLayout::paged_from_budget(&cfg, 2, 4, 0, 3), paged(6));
        // budget-driven
        assert_eq!(KvLayout::paged_from_budget(&cfg, 2, 4, 5 * page_bytes, 3), paged(5));
        // clamped to at least one full-length request
        assert_eq!(KvLayout::paged_from_budget(&cfg, 2, 4, 1, 3), paged(2));
    }

    #[test]
    fn page_bytes_sum_to_slab_bytes() {
        // ceil(max_seq / page_size) pages cover exactly one slab when the
        // page size divides max_seq — the budget units agree
        let cfg = tiny_cfg();
        for tp in [1usize, 2] {
            let page = PagedKvCache::page_bytes_all_ranks(&cfg, tp, 4);
            let slab = KvCache::bytes_per_slot_all_ranks(&cfg, tp);
            assert_eq!(page * (cfg.max_seq / 4), slab);
        }
    }

    #[test]
    fn paged_scatter_lands_rows() {
        let (kvl, p, d) = (2, 4, 2);
        let mut pool = PagedKvCache::new(2, 3, kvl, p, d);
        let rows: Vec<f32> = (0..2 * kvl * d).map(|x| x as f32 + 1.0).collect();
        let vrows: Vec<f32> = rows.iter().map(|x| -x).collect();
        pool.scatter_rows(1, &[(2, 1), (0, 3)], &rows, &vrows).unwrap();
        // row 0 -> page 2 offset 1; row 1 -> page 0 offset 3
        for kh in 0..kvl {
            let at = ((2 * kvl + kh) * p + 1) * d;
            assert_eq!(pool.k[1].data[at..at + d], rows[kh * d..(kh + 1) * d]);
            let at = (kh * p + 3) * d;
            assert_eq!(pool.v[1].data[at..at + d], vrows[(kvl + kh) * d..(kvl + kh + 1) * d]);
        }
        // layer 0 untouched
        assert!(pool.k[0].data.iter().all(|&x| x == 0.0));
        // out-of-range destinations are errors, not UB
        assert!(pool.scatter_rows(0, &[(9, 0)], &rows[..kvl * d], &vrows[..kvl * d]).is_err());
        assert!(pool.scatter_rows(0, &[(0, 9)], &rows[..kvl * d], &vrows[..kvl * d]).is_err());
    }

    #[test]
    fn take_put_layer_roundtrip() {
        let mut pool = PagedKvCache::new(2, 2, 1, 2, 2);
        pool.k[1].data[3] = 7.0;
        let (k, v) = pool.take_layer(1);
        assert_eq!(k.data[3], 7.0);
        assert!(pool.k[1].data.is_empty());
        pool.put_layer(1, k, v);
        assert_eq!(pool.k[1].data[3], 7.0);
    }

    #[test]
    fn allocator_admit_ensure_free_lifecycle() {
        let mut a = BlockAllocator::new(8, 4, 100);
        assert!(a.can_admit(32));
        assert!(!a.can_admit(33));
        // prompt 5 tokens (2 pages), worst case 10 tokens (3 pages)
        a.admit(1, 5, 10).unwrap();
        a.check().unwrap();
        assert_eq!(a.pages_in_use(), 2);
        assert_eq!(a.reserved_pages(), 3);
        assert_eq!(a.table(1).unwrap().pages, vec![0, 1]);
        // growing within the current page allocates nothing
        a.ensure(1, 8).unwrap();
        assert_eq!(a.pages_in_use(), 2);
        // crossing the boundary takes the third page; beyond the
        // reservation is an error
        a.ensure(1, 9).unwrap();
        assert_eq!(a.pages_in_use(), 3);
        assert!(a.ensure(1, 13).is_err());
        a.check().unwrap();
        assert_eq!(a.bytes_in_use(), 300);
        assert_eq!(a.high_water(), 3);
        assert_eq!(a.free(1), 3);
        a.check().unwrap();
        assert_eq!((a.pages_in_use(), a.reserved_pages(), a.free_pages()), (0, 0, 8));
        assert_eq!(a.high_water(), 3, "high water survives the free");
        assert_eq!(a.free(1), 0, "double free is a no-op");
    }

    #[test]
    fn allocator_admission_is_reservation_gated() {
        let mut a = BlockAllocator::new(4, 2, 1);
        a.admit(1, 1, 6).unwrap(); // reserves 3 pages, holds 1
        assert_eq!(a.pages_in_use(), 1);
        // 1 page of unreserved capacity left: a 2-page request must wait
        // even though 3 physical pages are free (they are promised to 1)
        assert!(a.can_admit(2));
        assert!(!a.can_admit(3));
        assert!(a.admit(2, 1, 4).is_err());
        a.admit(2, 1, 2).unwrap();
        // both requests can always grow to their full reservation
        a.ensure(1, 6).unwrap();
        a.ensure(2, 2).unwrap();
        a.check().unwrap();
        assert_eq!(a.free_pages(), 0);
    }

    #[test]
    fn allocator_rejects_double_admit_and_unknown_owner() {
        let mut a = BlockAllocator::new(4, 2, 1);
        a.admit(7, 2, 4).unwrap();
        assert!(a.admit(7, 2, 4).is_err());
        assert!(a.ensure(8, 2).is_err());
        assert!(a.admit(9, 5, 4).is_err(), "prompt beyond reservation");
    }

    #[test]
    fn page_table_maps_positions() {
        let mut a = BlockAllocator::new(8, 4, 1);
        a.admit(1, 9, 12).unwrap();
        let t = a.table(1).unwrap();
        assert_eq!(t.pages.len(), 3);
        assert_eq!(t.len, 9);
        // token position 6 -> pages[1], offset 2
        assert_eq!(t.pages[6 / 4], t.pages[1]);
        assert_eq!(6 % 4, 2);
        // the per-forward row encoding: pages in order, -1 padded
        let mut row = [9i32; 5];
        a.fill_table_row(1, &mut row).unwrap();
        assert_eq!(row, [0, 1, 2, -1, -1]);
        let mut tight = [9i32; 2];
        assert!(a.fill_table_row(1, &mut tight).is_err(), "row narrower than the table");
        assert!(a.fill_table_row(7, &mut row).is_err(), "unknown owner");
    }

    #[test]
    fn copy_page_duplicates_all_rows() {
        let (kvl, p, d) = (2, 4, 2);
        let mut pool = PagedKvCache::new(2, 3, kvl, p, d);
        for (i, x) in pool.k[1].data.iter_mut().enumerate() {
            *x = i as f32;
        }
        let src_block: Vec<f32> = pool.k[1].data[kvl * p * d..2 * kvl * p * d].to_vec();
        pool.copy_page(1, 2).unwrap();
        assert_eq!(pool.k[1].data[2 * kvl * p * d..3 * kvl * p * d], src_block[..]);
        // source untouched, other layers' dst rows follow their own source
        assert_eq!(pool.k[1].data[kvl * p * d..2 * kvl * p * d], src_block[..]);
        assert!(pool.v[0].data.iter().all(|&x| x == 0.0));
        assert!(pool.copy_page(0, 9).is_err());
        assert!(pool.copy_page(2, 2).is_err(), "self-copy is a caller bug");
    }

    #[test]
    fn refcounted_publish_share_evict_lifecycle() {
        let mut a = BlockAllocator::new(8, 4, 1);
        // donor: 8-token prompt (2 full pages), finishes after publishing
        a.admit(1, 8, 8).unwrap();
        let chain = a.table(1).unwrap().pages.clone();
        assert_eq!(chain, vec![0, 1]);
        a.tree_retain(0).unwrap();
        a.tree_retain(1).unwrap();
        assert!(a.tree_retain(1).is_err(), "double publish must be rejected");
        a.check().unwrap();
        assert_eq!(a.free(1), 0, "published pages survive the donor");
        a.check().unwrap();
        assert_eq!((a.cached_pages(), a.evictable_pages(), a.pages_in_use()), (2, 2, 2));
        // a follower reuses the chain: only its suffix is reserved
        a.admit_shared(2, 10, 12, &chain).unwrap();
        a.check().unwrap();
        assert_eq!(a.reserved_pages(), 1, "3-page worst case minus 2 cached");
        assert_eq!(a.evictable_pages(), 0, "chain is pinned while request 2 lives");
        assert_eq!(a.req_refs(0), 1);
        assert_eq!(a.table(2).unwrap().pages[..2], chain[..]);
        assert!(a.tree_release(0).is_err(), "eviction must never touch a referenced page");
        // a second follower shares the same pages at zero extra cost
        a.admit_shared(3, 8, 8, &chain).unwrap();
        assert_eq!(a.req_refs(0), 2);
        assert_eq!(a.cached_pages(), 2);
        a.check().unwrap();
        assert_eq!(a.free(2), 1, "only the private suffix page is freed");
        a.free(3);
        a.check().unwrap();
        // both gone: the chain is evictable again, and eviction round-trips
        // the pool to a full free list
        assert_eq!(a.evictable_pages(), 2);
        a.tree_release(1).unwrap();
        a.tree_release(0).unwrap();
        a.check().unwrap();
        assert_eq!((a.pages_in_use(), a.free_pages()), (0, 8));
    }

    #[test]
    fn chain_admission_counts_shared_pages_once() {
        let mut a = BlockAllocator::new(6, 4, 1);
        a.admit(1, 8, 8).unwrap();
        let chain = a.table(1).unwrap().pages.clone();
        a.tree_retain(chain[0]).unwrap();
        a.tree_retain(chain[1]).unwrap();
        a.free(1);
        // three followers, each worst-case 3 pages: cold admission would
        // need 9 pages; sharing the 2-page chain needs 2 + 3x1
        for owner in [2u64, 3, 4] {
            assert!(a.can_admit_chain(12, &chain), "owner {owner} should fit");
            a.admit_shared(owner, 9, 12, &chain).unwrap();
            a.check().unwrap();
        }
        assert_eq!(a.reserved_pages(), 3);
        assert_eq!(a.pages_in_use(), 5);
        // a cold 2-page request no longer fits (3 reserved + 2 shared + 2 > 6)
        assert!(!a.can_admit(8));
        // rejected chains: unknown / uncached pages, over-long chains
        assert!(a.admit_shared(5, 4, 4, &[5]).is_err(), "page 5 is not cached");
        assert!(a.admit_shared(5, 4, 4, &chain).is_err(), "chain overruns the prompt");
    }

    #[test]
    fn free_shortfall_reports_eviction_need() {
        let mut a = BlockAllocator::new(4, 4, 1);
        a.admit(1, 4, 16).unwrap();
        a.tree_retain(a.table(1).unwrap().pages[0]).unwrap();
        a.free(1);
        // 3 free pages, 1 cached-idle: a 16-token ensure for a fresh owner
        // needs 4 pages -> shortfall 1 (the cached page must be evicted)
        a.admit(2, 1, 16).unwrap();
        assert_eq!(a.free_shortfall(2, 12), 0);
        assert_eq!(a.free_shortfall(2, 16), 1);
        assert_eq!(a.free_shortfall(9, 4), 0, "unknown owners have no table yet");
    }

    #[test]
    fn page_read_write_roundtrip_is_bitwise() {
        let (layers, kvl, p, d) = (2usize, 2usize, 4usize, 2usize);
        let mut pool = PagedKvCache::new(layers, 3, kvl, p, d);
        for (i, x) in pool.k[0].data.iter_mut().enumerate() {
            *x = i as f32 + 0.5;
        }
        for (i, x) in pool.v[1].data.iter_mut().enumerate() {
            *x = -(i as f32) - 0.25;
        }
        let blob = pool.read_page(1).unwrap();
        assert_eq!(blob.len(), 2 * layers * kvl * p * d);
        // restoring into a different page of a fresh pool reproduces the
        // bytes exactly (the spill tier's whole contract)
        let mut fresh = PagedKvCache::new(layers, 3, kvl, p, d);
        fresh.write_page(2, &blob).unwrap();
        let back = fresh.read_page(2).unwrap();
        for (a, b) in back.iter().zip(&blob) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // pages outside the restored one stay zero
        assert!(fresh.read_page(0).unwrap().iter().all(|&x| x == 0.0));
        assert!(pool.read_page(9).is_err());
        assert!(fresh.write_page(9, &blob).is_err());
        assert!(fresh.write_page(0, &blob[1..]).is_err(), "short payload must be rejected");
    }

    #[test]
    fn pins_block_tree_release_until_dropped() {
        let mut a = BlockAllocator::new(4, 4, 1);
        a.admit(1, 8, 8).unwrap();
        let chain = a.table(1).unwrap().pages.clone();
        a.tree_retain(chain[0]).unwrap();
        a.tree_retain(chain[1]).unwrap();
        a.free(1);
        assert!(a.pin(3).is_err(), "only cached pages can be pinned");
        a.pin(chain[0]).unwrap();
        a.pin(chain[0]).unwrap(); // pins nest
        a.check().unwrap();
        assert!(!a.evictable(chain[0]));
        assert!(a.evictable(chain[1]));
        assert!(a.tree_release(chain[0]).is_err(), "pinned page must survive eviction");
        a.unpin(chain[0]).unwrap();
        assert!(a.tree_release(chain[0]).is_err(), "still one pin outstanding");
        a.unpin(chain[0]).unwrap();
        assert!(a.unpin(chain[0]).is_err(), "unbalanced unpin is a caller bug");
        a.tree_release(chain[0]).unwrap();
        a.check().unwrap();
    }

    #[test]
    fn pending_pages_are_owned_and_cleared_on_free() {
        let mut a = BlockAllocator::new(4, 4, 1);
        assert!(a.mark_pending(0).is_err(), "a free page cannot be pending");
        a.admit(1, 8, 8).unwrap();
        let pages = a.table(1).unwrap().pages.clone();
        a.mark_pending(pages[1]).unwrap();
        assert!(a.is_pending(pages[1]));
        assert_eq!(a.pending_pages(), 1);
        a.check().unwrap();
        // publishing a pending page is impossible by construction (the
        // loading slot publishes only after the bytes land) but a cached
        // page must reject mark_pending outright
        a.tree_retain(pages[0]).unwrap();
        assert!(a.mark_pending(pages[0]).is_err());
        // an aborted load: freeing the owner clears the flag with the page
        a.free(1);
        assert!(!a.is_pending(pages[1]), "free must clear pending");
        a.check().unwrap();
    }
}
