//! Per-rank state: sharded weight values (uploaded to the backend once) +
//! KV cache, and the module invocations for one rank. Backend-agnostic: the
//! same code drives the native executor and the PJRT executables.

use anyhow::{anyhow, bail, Result};

use super::kv::{KvCache, KvLayout, PagedFwd, PagedKvCache};
use crate::model::{HostTensor, LlamaConfig, RankWeights, WeightStore};
use crate::runtime::{Exec, Value};

/// Per-layer weight values in module argument order.
struct LayerVals {
    attn: Vec<Value>, // norm, wq, wk, wv, wo
    mlp: Vec<Value>,  // norm, wg, wu, wd
}

/// Inference phase (selects the module variant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// Which KV slab slots a slab-layout forward reads and writes. Paged
/// forwards ignore this and route through their [`PagedFwd`] page tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rows {
    /// The whole batch (full-slab take/replace fast path).
    All,
    /// One slot (b=1 continuous-batching prefill against that slot's
    /// cache region).
    Slot(usize),
    /// A contiguous slot range `[start, start+count)` — one sub-chunk of a
    /// split-batch overlap forward ([`super::OverlapMode`]).
    Span(usize, usize),
}

/// This rank's KV storage, matching the engine's [`KvLayout`].
pub enum RankKv {
    /// Fixed per-slot slabs (legacy layout; the paged path's oracle).
    Slab(KvCache),
    /// Shared page pool; ownership is tracked by the batcher's
    /// [`super::kv::BlockAllocator`] and arrives per-forward as a
    /// [`PagedFwd`] page-table view.
    Paged(PagedKvCache),
}

/// One simulated TP rank: weights + caches + module runners.
pub struct RankState {
    pub rank: usize,
    pub tp: usize,
    pub kv: RankKv,
    layers: Vec<LayerVals>,
    /// The replicated embedding table — uploaded only when this state will
    /// actually run the embed module (sequential rank 0; the threaded
    /// runtime's workers never do, its coordinator uses [`Embedder`]).
    emb: Option<Value>,
    final_norm: Value,
    lm: Value,
}

impl RankState {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        exec: &Exec,
        cfg: &LlamaConfig,
        weights: &WeightStore,
        rank: usize,
        tp: usize,
        batch: usize,
        need_embed: bool,
        layout: KvLayout,
    ) -> Result<RankState> {
        let mut layers = Vec::with_capacity(cfg.layers);
        for i in 0..cfg.layers {
            let rw: RankWeights = weights.rank_layer(i, rank, tp)?;
            layers.push(LayerVals {
                attn: vec![
                    exec.upload_owned(rw.attn_norm)?,
                    exec.upload_owned(rw.wq)?,
                    exec.upload_owned(rw.wk)?,
                    exec.upload_owned(rw.wv)?,
                    exec.upload_owned(rw.wo)?,
                ],
                mlp: vec![
                    exec.upload_owned(rw.mlp_norm)?,
                    exec.upload_owned(rw.wg)?,
                    exec.upload_owned(rw.wu)?,
                    exec.upload_owned(rw.wd)?,
                ],
            });
        }
        let kvl = cfg.kv_heads / tp;
        let kv = match layout {
            KvLayout::Slab => {
                RankKv::Slab(KvCache::new(cfg.layers, batch, kvl, cfg.max_seq, cfg.head_dim))
            }
            KvLayout::Paged { page_size, pages } => {
                RankKv::Paged(PagedKvCache::new(cfg.layers, pages, kvl, page_size, cfg.head_dim))
            }
        };
        Ok(RankState {
            rank,
            tp,
            kv,
            layers,
            emb: if need_embed { Some(exec.upload(weights.get("emb")?)?) } else { None },
            final_norm: exec.upload(weights.get("final_norm")?)?,
            lm: exec.upload_owned(weights.rank_lm(rank, tp)?)?,
        })
    }

    /// Run the embedding module (replicated; only rank 0 holds the table).
    pub fn embed(&self, exec: &Exec, tokens: &[i32], b: usize, s: usize) -> Result<HostTensor> {
        let emb = self.emb.as_ref().ok_or_else(|| {
            anyhow!("rank {} was built without the embedding table (coordinator embeds)", self.rank)
        })?;
        run_embed(exec, emb, tokens, b, s)
    }

    /// Attention module (prefill or decode) for one layer. Updates this
    /// rank's KV storage in place; `rows` selects which slab slots the call
    /// touches ([`Rows::Slot`] = b=1 continuous-batching prefill,
    /// [`Rows::Span`] = one split-batch overlap chunk), and `paged=Some(..)`
    /// routes reads/writes through the page tables instead of the slot
    /// slabs.
    #[allow(clippy::too_many_arguments)]
    pub fn attn(
        &mut self,
        exec: &Exec,
        layer: usize,
        x: &HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
    ) -> Result<HostTensor> {
        self.block(exec, layer, x, phase, lens, rows, paged, BlockKind::Attn)
    }

    /// Fused attention+MLP module (Parallel architecture).
    #[allow(clippy::too_many_arguments)]
    pub fn fused(
        &mut self,
        exec: &Exec,
        layer: usize,
        x: &HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
    ) -> Result<HostTensor> {
        self.block(exec, layer, x, phase, lens, rows, paged, BlockKind::Fused)
    }

    /// Release a batch slot: slab layouts zero the slot's written prefix
    /// (`written` = the engine's tracked length); paged layouts MUST keep
    /// pool bytes as-is. That no-op is load-bearing, not an optimization:
    /// pages of the released request may still be referenced by the prefix
    /// tree (or by concurrent requests sharing them), and a later cache hit
    /// *reads them without writing first* — zeroing any page here would
    /// silently corrupt every future hit on it. Unreferenced pages are
    /// reclaimed by the batcher's allocator and fully overwritten by their
    /// next owner before any masked read covers them.
    pub fn release_slot(&mut self, slot: usize, written: usize) {
        match &mut self.kv {
            RankKv::Slab(kv) => kv.clear_slot(slot, written),
            RankKv::Paged(_) => {}
        }
    }

    /// Copy-on-write duplicate of one pool page (paged layouts only) — see
    /// [`super::tpengine::TpEngine::copy_page`].
    pub fn copy_page(&mut self, src: u32, dst: u32) -> Result<()> {
        match &mut self.kv {
            RankKv::Slab(_) => bail!("copy_page on a slab-layout rank"),
            RankKv::Paged(pool) => pool.copy_page(src, dst),
        }
    }

    /// Serialize one pool page (paged layouts only) — the disk spill tier's
    /// download path; see [`super::tpengine::TpEngine::read_page`].
    pub fn read_page(&self, page: u32) -> Result<Vec<f32>> {
        match &self.kv {
            RankKv::Slab(_) => bail!("read_page on a slab-layout rank"),
            RankKv::Paged(pool) => pool.read_page(page),
        }
    }

    /// Restore one pool page from its serialized form (paged layouts only)
    /// — the disk spill tier's upload path; see
    /// [`super::tpengine::TpEngine::write_page`].
    pub fn write_page(&mut self, page: u32, data: &[f32]) -> Result<()> {
        match &mut self.kv {
            RankKv::Slab(_) => bail!("write_page on a slab-layout rank"),
            RankKv::Paged(pool) => pool.write_page(page, data),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn block(
        &mut self,
        exec: &Exec,
        layer: usize,
        x: &HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        paged: Option<&PagedFwd>,
        kind: BlockKind,
    ) -> Result<HostTensor> {
        let paged_kv = matches!(self.kv, RankKv::Paged(_));
        match (paged_kv, paged) {
            (false, None) => self.block_slab(exec, layer, x, phase, lens, rows, kind),
            (true, Some(p)) => self.block_paged(exec, layer, x, phase, lens, p, kind),
            (false, Some(_)) => bail!("paged forward issued to a slab-layout rank"),
            (true, None) => bail!("slab forward issued to a paged-layout rank (no page tables)"),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn block_slab(
        &mut self,
        exec: &Exec,
        layer: usize,
        x: &HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        rows: Rows,
        kind: BlockKind,
    ) -> Result<HostTensor> {
        let RankKv::Slab(kv) = &mut self.kv else { unreachable!("checked by block()") };
        let (b, s) = (x.shape[0], x.shape[1]);
        match rows {
            Rows::Slot(_) if b != 1 => bail!("slot forward wants b=1, got b={b}"),
            Rows::Span(_, count) if b != count => {
                bail!("span forward: {count}-slot span for b={b}")
            }
            _ => {}
        }
        // §Perf: full-batch calls *take* the cache tensors (they are
        // replaced by the module outputs below) instead of cloning ~2x the
        // KV slab per attention call on the host side. Slot/span calls still
        // copy (subrange). NB the backend may still copy internally: xla
        // converts to literals, and the native executor clones the slabs to
        // produce its functional kc'/vc' outputs — an in-place native cache
        // path would need a consuming `run` variant (future work).
        let empty = || HostTensor::new(vec![0], Vec::new());
        let (kc, vc) = match rows {
            Rows::Slot(slot_b) => kv.read_slot(layer, slot_b),
            Rows::Span(start, count) => kv.read_span(layer, start, count),
            Rows::All => (
                std::mem::replace(&mut kv.k[layer], empty()),
                std::mem::replace(&mut kv.v[layer], empty()),
            ),
        };
        let x_v = exec.upload(x)?;
        let kc_v = exec.upload_owned(kc)?;
        let vc_v = exec.upload_owned(vc)?;
        let lens_v = match (phase, lens) {
            (Phase::Decode, Some(l)) => Some(exec.upload_i32(l, &[b])?),
            (Phase::Decode, None) => bail!("decode needs lens"),
            _ => None,
        };
        let mut args: Vec<&Value> = vec![&x_v];
        let lw = &self.layers[layer];
        match kind {
            BlockKind::Attn => args.extend(lw.attn.iter()),
            BlockKind::Fused => {
                // PaLM fusion: shared pre-norm (attn_norm), then both branches.
                args.extend(lw.attn.iter());
                args.extend(lw.mlp.iter().skip(1)); // wg, wu, wd
            }
        }
        args.push(&kc_v);
        args.push(&vc_v);
        let prefix = match kind {
            BlockKind::Attn => "attn",
            BlockKind::Fused => "fused",
        };
        let name = match phase {
            Phase::Prefill => format!("{prefix}_prefill__tp{}__b{b}__s{s}", self.tp),
            Phase::Decode => {
                args.push(lens_v.as_ref().unwrap());
                format!("{prefix}_decode__tp{}__b{b}", self.tp)
            }
        };
        let mut outs = exec.run(&name, &args)?;
        if outs.len() != 3 {
            bail!("{name}: expected 3 outputs, got {}", outs.len());
        }
        let v_new = outs.pop().unwrap().into_f32()?;
        let k_new = outs.pop().unwrap().into_f32()?;
        let partial = outs.pop().unwrap().into_f32()?;
        match rows {
            Rows::Slot(slot_b) => kv.write_slot(layer, slot_b, &k_new, &v_new)?,
            Rows::Span(start, count) => kv.write_span(layer, start, count, &k_new, &v_new)?,
            Rows::All => {
                kv.k[layer] = k_new;
                kv.v[layer] = v_new;
            }
        }
        Ok(partial)
    }

    /// The paged counterpart of [`RankState::block_slab`]: pool tensors go
    /// in (zero-copy on the native backend), only the freshly written K/V
    /// rows come out and are scattered into the pool at the positions the
    /// page table dictates. Reads happen *inside* the module, routed
    /// through the same table.
    #[allow(clippy::too_many_arguments)]
    fn block_paged(
        &mut self,
        exec: &Exec,
        layer: usize,
        x: &HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        paged: &PagedFwd,
        kind: BlockKind,
    ) -> Result<HostTensor> {
        let RankKv::Paged(pool) = &mut self.kv else { unreachable!("checked by block()") };
        let (b, s) = (x.shape[0], x.shape[1]);
        if paged.tables.len() != b * paged.max_pages {
            bail!(
                "paged forward: {} table entries for [{b}, {}]",
                paged.tables.len(),
                paged.max_pages
            );
        }
        // per-row write positions (and rope/positions argument)
        let pos: Vec<i32> = match phase {
            Phase::Prefill => vec![paged.start; b],
            Phase::Decode => match lens {
                Some(l) if l.len() == b => l.to_vec(),
                Some(l) => bail!("paged decode: {} lens for batch {b}", l.len()),
                None => bail!("decode needs lens"),
            },
        };
        let (kp, vp) = pool.take_layer(layer);
        let x_v = exec.upload(x)?;
        let kp_v = exec.upload_owned(kp)?;
        let vp_v = exec.upload_owned(vp)?;
        let table_v = exec.upload_i32(&paged.tables, &[b, paged.max_pages])?;
        let pos_v = exec.upload_i32(&pos, &[b])?;
        let mut args: Vec<&Value> = vec![&x_v];
        let lw = &self.layers[layer];
        match kind {
            BlockKind::Attn => args.extend(lw.attn.iter()),
            BlockKind::Fused => {
                args.extend(lw.attn.iter());
                args.extend(lw.mlp.iter().skip(1)); // wg, wu, wd
            }
        }
        args.push(&kp_v);
        args.push(&vp_v);
        args.push(&table_v);
        args.push(&pos_v);
        let prefix = match kind {
            BlockKind::Attn => "attn",
            BlockKind::Fused => "fused",
        };
        let name = match phase {
            Phase::Prefill => format!("{prefix}_prefill_paged__tp{}__b{b}__s{s}", self.tp),
            Phase::Decode => format!("{prefix}_decode_paged__tp{}__b{b}", self.tp),
        };
        let mut outs = exec.run(&name, &args)?;
        if outs.len() != 3 {
            bail!("{name}: expected 3 outputs, got {}", outs.len());
        }
        let v_rows = outs.pop().unwrap().into_f32()?;
        let k_rows = outs.pop().unwrap().into_f32()?;
        let partial = outs.pop().unwrap().into_f32()?;

        // reclaim the pool (zero-copy round-trip on the native backend) and
        // scatter the fresh rows. Inactive decode rows (lens < 0) own no
        // pages and are skipped.
        let kp = kp_v.into_f32()?;
        let vp = vp_v.into_f32()?;
        pool.put_layer(layer, kp, vp);
        let page_size = pool.page_size;
        let (kvl, d) = (pool.kv_heads_l, pool.head_dim);
        let row_stride = kvl * d;
        let mut dst = Vec::with_capacity(b * s);
        let mut sel_k = Vec::with_capacity(b * s * row_stride);
        let mut sel_v = Vec::with_capacity(b * s * row_stride);
        for bi in 0..b {
            if phase == Phase::Decode && pos[bi] < 0 {
                continue;
            }
            for si in 0..s {
                let at = pos[bi] as usize + if phase == Phase::Prefill { si } else { 0 };
                // bound within the ROW so an overflow cannot scatter into
                // the next request's pages
                let pi = at / page_size;
                if pi >= paged.max_pages {
                    bail!("{name}: row {bi} write position {at} beyond its page table");
                }
                let page = paged.tables[bi * paged.max_pages + pi];
                if page < 0 {
                    bail!("{name}: row {bi} writes position {at} without a page");
                }
                dst.push((page as u32, at % page_size));
                let src = (bi * s + si) * row_stride;
                sel_k.extend_from_slice(&k_rows.data[src..src + row_stride]);
                sel_v.extend_from_slice(&v_rows.data[src..src + row_stride]);
            }
        }
        pool.scatter_rows(layer, &dst, &sel_k, &sel_v)?;
        Ok(partial)
    }

    /// MLP module for one layer (no cache interaction).
    pub fn mlp(&self, exec: &Exec, layer: usize, x: &HostTensor) -> Result<HostTensor> {
        let (b, s) = (x.shape[0], x.shape[1]);
        let name = format!("mlp__tp{}__b{b}__s{s}", self.tp);
        let x_v = exec.upload(x)?;
        let mut args: Vec<&Value> = vec![&x_v];
        args.extend(self.layers[layer].mlp.iter());
        let outs = exec.run(&name, &args)?;
        first_f32(outs, &name)
    }

    /// Final norm + this rank's LM-head vocab shard: x [B,H] -> [B, V/tp].
    pub fn lm_head(&self, exec: &Exec, x: &HostTensor) -> Result<HostTensor> {
        let b = x.shape[0];
        let name = format!("lm_head__tp{}__b{b}", self.tp);
        let x_v = exec.upload(x)?;
        let outs = exec.run(&name, &[&x_v, &self.final_norm, &self.lm])?;
        first_f32(outs, &name)
    }

    /// Slice each row's `last[b]` position out of the final residual
    /// [B, S, H] and run this rank's LM-head shard: returns [B, V/tp].
    /// Shared by the sequential head and the threaded rank workers.
    pub fn lm_head_rows(&self, exec: &Exec, x: &HostTensor, last: &[usize]) -> Result<HostTensor> {
        if x.shape.len() != 3 {
            bail!("lm_head_rows wants [B,S,H], got {:?}", x.shape);
        }
        let (s, h) = (x.shape[1], x.shape[2]);
        let b = last.len();
        let mut rows = Vec::with_capacity(b * h);
        for (bi, &pos) in last.iter().enumerate() {
            if pos >= s {
                bail!("last position {pos} out of range (S={s})");
            }
            let base = (bi * s + pos) * h;
            rows.extend_from_slice(&x.data[base..base + h]);
        }
        self.lm_head(exec, &HostTensor::new(vec![b, h], rows))
    }
}

/// Coordinator-side embedding runner for the threaded runtime: the
/// replicated embedding table only, without any per-layer weight uploads
/// (those live thread-locally inside the rank workers).
pub struct Embedder {
    emb: Value,
}

impl Embedder {
    pub fn new(exec: &Exec, weights: &WeightStore) -> Result<Embedder> {
        Ok(Embedder { emb: exec.upload(weights.get("emb")?)? })
    }

    pub fn embed(&self, exec: &Exec, tokens: &[i32], b: usize, s: usize) -> Result<HostTensor> {
        run_embed(exec, &self.emb, tokens, b, s)
    }
}

fn run_embed(exec: &Exec, emb: &Value, tokens: &[i32], b: usize, s: usize) -> Result<HostTensor> {
    if tokens.len() != b * s {
        bail!("embed: {} tokens for [{b},{s}]", tokens.len());
    }
    let name = format!("embed__b{b}__s{s}");
    let toks = exec.upload_i32(tokens, &[b, s])?;
    let outs = exec.run(&name, &[&toks, emb])?;
    first_f32(outs, &name)
}

fn first_f32(outs: Vec<Value>, name: &str) -> Result<HostTensor> {
    outs.into_iter()
        .next()
        .ok_or_else(|| anyhow!("{name}: module returned no outputs"))?
        .into_f32()
}

#[derive(Clone, Copy)]
enum BlockKind {
    Attn,
    Fused,
}
