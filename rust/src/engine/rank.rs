//! Per-rank state: sharded weight literals (converted once) + KV cache, and
//! the module invocations for one rank.

use anyhow::{anyhow, bail, Result};
use xla::Literal;

use super::kv::KvCache;
use crate::model::{HostTensor, LlamaConfig, RankWeights, WeightStore};
use crate::runtime::{literal_i32, tensor_from_literal, ExecCache};

/// Per-layer weight literals in module argument order.
struct LayerLits {
    attn: Vec<Literal>, // norm, wq, wk, wv, wo
    mlp: Vec<Literal>,  // norm, wg, wu, wd
}

/// Inference phase (selects the exported module variant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// One simulated TP rank: weights + caches + module runners.
pub struct RankState {
    pub rank: usize,
    pub tp: usize,
    pub kv: KvCache,
    layers: Vec<LayerLits>,
    /// The replicated embedding table — only rank 0 ever runs the embed
    /// module (the threaded runtime's workers never do), so only rank 0
    /// pays for the literal conversion.
    emb: Option<Literal>,
    final_norm: Literal,
    lm: Literal,
}

impl RankState {
    pub fn new(
        cfg: &LlamaConfig,
        weights: &WeightStore,
        rank: usize,
        tp: usize,
        batch: usize,
    ) -> Result<RankState> {
        let mut layers = Vec::with_capacity(cfg.layers);
        for i in 0..cfg.layers {
            let rw: RankWeights = weights.rank_layer(i, rank, tp)?;
            layers.push(LayerLits {
                attn: vec![
                    rw.attn_norm.to_literal()?,
                    rw.wq.to_literal()?,
                    rw.wk.to_literal()?,
                    rw.wv.to_literal()?,
                    rw.wo.to_literal()?,
                ],
                mlp: vec![
                    rw.mlp_norm.to_literal()?,
                    rw.wg.to_literal()?,
                    rw.wu.to_literal()?,
                    rw.wd.to_literal()?,
                ],
            });
        }
        Ok(RankState {
            rank,
            tp,
            kv: KvCache::new(cfg.layers, batch, cfg.kv_heads / tp, cfg.max_seq, cfg.head_dim),
            layers,
            emb: if rank == 0 { Some(weights.get("emb")?.to_literal()?) } else { None },
            final_norm: weights.get("final_norm")?.to_literal()?,
            lm: weights.rank_lm(rank, tp)?.to_literal()?,
        })
    }

    /// Run the embedding module (replicated; only rank 0 holds the table).
    pub fn embed(&self, exec: &ExecCache, tokens: &[i32], b: usize, s: usize) -> Result<HostTensor> {
        let emb = self
            .emb
            .as_ref()
            .ok_or_else(|| anyhow!("embedding table lives on rank 0, not rank {}", self.rank))?;
        run_embed(exec, emb, tokens, b, s)
    }

    /// Attention module (prefill or decode) for one layer. Updates this
    /// rank's KV cache in place; single-slot prefill (`slot=Some(b)`) runs
    /// the b=1 module against that slot's cache region (continuous
    /// batching).
    pub fn attn(
        &mut self,
        exec: &ExecCache,
        layer: usize,
        x: &HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        slot: Option<usize>,
    ) -> Result<HostTensor> {
        self.block(exec, layer, x, phase, lens, slot, BlockKind::Attn)
    }

    /// Fused attention+MLP module (Parallel architecture).
    pub fn fused(
        &mut self,
        exec: &ExecCache,
        layer: usize,
        x: &HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        slot: Option<usize>,
    ) -> Result<HostTensor> {
        self.block(exec, layer, x, phase, lens, slot, BlockKind::Fused)
    }

    fn block(
        &mut self,
        exec: &ExecCache,
        layer: usize,
        x: &HostTensor,
        phase: Phase,
        lens: Option<&[i32]>,
        slot: Option<usize>,
        kind: BlockKind,
    ) -> Result<HostTensor> {
        let (b, s) = (x.shape[0], x.shape[1]);
        // §Perf: full-batch calls *take* the cache tensors (they are
        // replaced by the module outputs below) instead of cloning ~2x the
        // KV slab per attention call. Slot calls still copy (subrange).
        let empty = || HostTensor::new(vec![0], Vec::new());
        let (kc, vc) = match slot {
            Some(slot_b) => self.kv.read_slot(layer, slot_b),
            None => (
                std::mem::replace(&mut self.kv.k[layer], empty()),
                std::mem::replace(&mut self.kv.v[layer], empty()),
            ),
        };
        let x_lit = x.to_literal()?;
        let kc_lit = kc.to_literal()?;
        let vc_lit = vc.to_literal()?;
        let lens_lit = match (phase, lens) {
            (Phase::Decode, Some(l)) => Some(literal_i32(l, &[b])?),
            (Phase::Decode, None) => bail!("decode needs lens"),
            _ => None,
        };
        let mut args: Vec<&Literal> = vec![&x_lit];
        let lw = &self.layers[layer];
        match kind {
            BlockKind::Attn => args.extend(lw.attn.iter()),
            BlockKind::Fused => {
                // PaLM fusion: shared pre-norm (attn_norm), then both branches.
                args.extend(lw.attn.iter());
                args.extend(lw.mlp.iter().skip(1)); // wg, wu, wd
            }
        }
        args.push(&kc_lit);
        args.push(&vc_lit);
        let prefix = match kind {
            BlockKind::Attn => "attn",
            BlockKind::Fused => "fused",
        };
        let name = match phase {
            Phase::Prefill => format!("{prefix}_prefill__tp{}__b{b}__s{s}", self.tp),
            Phase::Decode => {
                args.push(lens_lit.as_ref().unwrap());
                format!("{prefix}_decode__tp{}__b{b}", self.tp)
            }
        };
        let outs = exec.run(&name, &args)?;
        let partial = tensor_from_literal(&outs[0])?;
        let k_new = tensor_from_literal(&outs[1])?;
        let v_new = tensor_from_literal(&outs[2])?;
        match slot {
            Some(slot_b) => self.kv.write_slot(layer, slot_b, &k_new, &v_new)?,
            None => {
                self.kv.k[layer] = k_new;
                self.kv.v[layer] = v_new;
            }
        }
        Ok(partial)
    }

    /// MLP module for one layer (no cache interaction).
    pub fn mlp(&self, exec: &ExecCache, layer: usize, x: &HostTensor) -> Result<HostTensor> {
        let (b, s) = (x.shape[0], x.shape[1]);
        let name = format!("mlp__tp{}__b{b}__s{s}", self.tp);
        let x_lit = x.to_literal()?;
        let mut args: Vec<&Literal> = vec![&x_lit];
        args.extend(self.layers[layer].mlp.iter());
        let outs = exec.run(&name, &args)?;
        tensor_from_literal(&outs[0])
    }

    /// Final norm + this rank's LM-head vocab shard: x [B,H] -> [B, V/tp].
    pub fn lm_head(&self, exec: &ExecCache, x: &HostTensor) -> Result<HostTensor> {
        let b = x.shape[0];
        let name = format!("lm_head__tp{}__b{b}", self.tp);
        let x_lit = x.to_literal()?;
        let outs = exec.run(&name, &[&x_lit, &self.final_norm, &self.lm])?;
        tensor_from_literal(&outs[0])
    }

    /// Slice each row's `last[b]` position out of the final residual
    /// [B, S, H] and run this rank's LM-head shard: returns [B, V/tp].
    /// Shared by the sequential head and the threaded rank workers.
    pub fn lm_head_rows(&self, exec: &ExecCache, x: &HostTensor, last: &[usize]) -> Result<HostTensor> {
        if x.shape.len() != 3 {
            bail!("lm_head_rows wants [B,S,H], got {:?}", x.shape);
        }
        let (s, h) = (x.shape[1], x.shape[2]);
        let b = last.len();
        let mut rows = Vec::with_capacity(b * h);
        for (bi, &pos) in last.iter().enumerate() {
            if pos >= s {
                bail!("last position {pos} out of range (S={s})");
            }
            let base = (bi * s + pos) * h;
            rows.extend_from_slice(&x.data[base..base + h]);
        }
        self.lm_head(exec, &HostTensor::new(vec![b, h], rows))
    }
}

/// Coordinator-side embedding runner for the threaded runtime: the
/// replicated embedding table only, without any per-layer weight literals
/// (those live thread-locally inside the rank workers).
pub struct Embedder {
    emb: Literal,
}

impl Embedder {
    pub fn new(weights: &WeightStore) -> Result<Embedder> {
        Ok(Embedder { emb: weights.get("emb")?.to_literal()? })
    }

    pub fn embed(&self, exec: &ExecCache, tokens: &[i32], b: usize, s: usize) -> Result<HostTensor> {
        run_embed(exec, &self.emb, tokens, b, s)
    }
}

fn run_embed(exec: &ExecCache, emb: &Literal, tokens: &[i32], b: usize, s: usize) -> Result<HostTensor> {
    if tokens.len() != b * s {
        bail!("embed: {} tokens for [{b},{s}]", tokens.len());
    }
    let name = format!("embed__b{b}__s{s}");
    let toks = literal_i32(tokens, &[b, s])?;
    let outs = exec.run(&name, &[&toks, emb])?;
    tensor_from_literal(&outs[0])
}

#[derive(Clone, Copy)]
enum BlockKind {
    Attn,
    Fused,
}
