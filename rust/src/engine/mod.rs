//! The multi-rank TP execution engine (the paper's system, L3).
//!
//! N simulated ranks each execute their *real* weight-sharded modules on the
//! configured backend (pure-Rust native by default, PJRT HLO executables
//! with `--features xla`); the engine owns the residual stream, performs the
//! AllReduces (real sums + modeled link time), and schedules module
//! execution per architecture — Standard blocks on every reduce, Ladder
//! launches the next module first (paper Algorithm 1), Parallel fuses
//! attention+MLP into one reduce, Desync-nx drops reduces and lets per-rank
//! residual streams diverge, Upperbound deletes communication.
//!
//! Ranks execute on one of two runtimes ([`RuntimeKind`]): the default
//! threaded runtime runs each rank on its own worker thread (true multi-core
//! overlap, rendezvous collectives), the sequential runtime is the
//! single-threaded bitwise-identical reference oracle.

pub mod generate;
pub mod kv;
pub mod overlap;
pub mod prefix;
pub mod rank;
pub mod spill;
pub mod threaded;
pub mod tpengine;
pub mod trace;

pub use generate::{GenerateReport, Sampler};
pub use kv::{BlockAllocator, KvCache, KvLayout, PageTable, PagedFwd, PagedKvCache};
pub use overlap::OverlapMode;
pub use prefix::PrefixTree;
pub use rank::{Embedder, RankKv, RankState, Rows};
pub use spill::SpillStore;
pub use threaded::ThreadedRuntime;
pub use tpengine::{RuntimeKind, TpEngine};
pub use trace::EngineTracer;

/// Accumulate a reduced delta into the residual stream. Shared by both rank
/// runtimes on purpose: the bitwise determinism contract
/// (`runtime_determinism`) requires sequential and threaded schedules to
/// accumulate identically, so there must be exactly one definition.
pub(crate) fn add_assign(x: &mut crate::model::HostTensor, delta: &crate::model::HostTensor) {
    debug_assert_eq!(x.shape, delta.shape);
    for (a, b) in x.data.iter_mut().zip(&delta.data) {
        *a += b;
    }
}

/// Which block a Desync-nx step runs (shared by both runtimes' schedulers).
#[derive(Clone, Copy)]
pub(crate) enum BlockSel {
    Attn,
    Mlp,
}
