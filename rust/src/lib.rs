//! # ladder-infer
//!
//! A tensor-parallel LLM inference framework reproducing **Ladder-Residual:
//! Parallelism-Aware Architecture for Accelerating Large Model Inference with
//! Communication Overlapping** (ICML 2025).
//!
//! Three-layer architecture:
//!
//! * **L1/L2 (build-time Python, optional)** — Pallas kernels + a Llama-style
//!   JAX model exported per-TP-rank, split at every AllReduce edge,
//!   AOT-lowered to HLO text in `artifacts/`.
//! * **L3 (this crate)** — the coordinator: a multi-rank TP engine whose
//!   per-architecture schedulers (Standard / Ladder / Parallel / Desync-nx /
//!   comm-free upper bound) own the residual stream, the collectives and the
//!   overlap; a serving stack (router, continuous batcher, KV manager); a
//!   roofline + interconnect performance model that regenerates every table
//!   and figure in the paper; and a training driver for the quality-parity
//!   experiments.
//!
//! Module execution is pluggable ([`runtime::Backend`]): the default
//! **native** backend runs the per-rank forward in pure Rust — no artifacts,
//! no toolchain beyond rustc — while `--features xla` compiles the exported
//! HLO modules on the PJRT CPU client. Python never runs on the request
//! path on either backend.

pub mod comm;
pub mod engine;
pub mod model;
pub mod perfmodel;
pub mod runtime;
pub mod server;
pub mod tokenizer;
pub mod trainer;
pub mod util;

/// Crate-wide result type (anyhow is the only error dependency available in
/// the offline vendor set; it matches the xla crate's error style).
pub type Result<T> = anyhow::Result<T>;
