//! Hand-rolled substrates.
//!
//! The build environment is fully offline and the vendored crate set contains
//! neither serde, clap, rand, criterion nor proptest — so this module
//! implements the small slices of each that the framework needs: a JSON
//! parser/writer ([`json`]), a CLI argument parser ([`args`]), seeded RNGs
//! ([`rng`]), summary statistics ([`stats`]), a timing/benchmark harness
//! ([`bench`]) and a property-testing helper ([`proptest`]).

pub mod args;
pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
