//! Seeded RNG (rand is unavailable offline): xorshift64* plus the small set
//! of distributions the framework needs (uniform, normal, categorical,
//! exponential, shuffles). Deterministic across platforms.

/// xorshift64* generator — fast, seedable, good enough for workload
/// generation and property tests (not for cryptography).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // Avoid the all-zero fixed point; mix the seed a bit.
        let s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0x2545F4914F6CDD1D);
        Rng { state: if s == 0 { 0xDEADBEEF } else { s } }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (mean 1/rate) — request arrivals.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard normals (f32) — weight init, synthetic activations.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0], "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
