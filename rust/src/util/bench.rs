//! Benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs our `harness = false` bench binaries, which use this
//! module for warmup + timed iterations + a uniform report format. Each
//! bench binary regenerates one paper table/figure.

use std::time::Instant;

use super::stats::Summary;

/// Timed measurement of a closure: warmup iterations, then `iters` samples.
pub fn time_it<F: FnMut()>(label: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.add(t0.elapsed().as_secs_f64());
    }
    println!(
        "  {label:40} mean {:>10.3}ms  p50 {:>10.3}ms  ±{:>8.3}ms  (n={})",
        s.mean() * 1e3,
        s.p50() * 1e3,
        s.ci95() * 1e3,
        s.count()
    );
    s
}

/// Pretty table printer shared by bench binaries and `paper_tables`:
/// fixed-width columns derived from the widest cell.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("| {:width$} ", c, width = widths[i]));
            }
            s.push('|');
            s
        };
        println!("{}", line(&self.header));
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        println!("{}", "-".repeat(total));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    /// Markdown rendering (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s.push_str(&format!("| {} |\n", self.header.join(" | ")));
        s.push_str(&format!(
            "|{}|\n",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            s.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        s
    }
}

/// Format a speedup multiple the way the paper does (e.g. "1.56x").
pub fn speedup(baseline: f64, improved: f64) -> String {
    format!("{:.2}x", baseline / improved)
}

/// Format a percent improvement the way the paper's Table 2/6 do.
pub fn pct_improvement(baseline: f64, improved: f64) -> String {
    format!("{:.2}", (baseline - improved) / baseline * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_counts() {
        let s = time_it("noop", 1, 5, || {});
        assert_eq!(s.count(), 5);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn formatting() {
        assert_eq!(speedup(2.0, 1.0), "2.00x");
        assert_eq!(pct_improvement(2.0, 1.0), "50.00");
    }
}
