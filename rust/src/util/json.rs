//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Covers the full JSON grammar the framework touches: the artifact
//! manifests, the server API, benchmark reports and chrome-trace dumps.
//! Numbers are stored as f64 (manifest shapes fit exactly).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use a BTreeMap for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- constructors ------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Convenience: `[1,2,3]` -> `vec![1usize,2,3]`.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    let _ = write!(out, "{:width$}", "", width = indent + 2);
                    x.write_pretty(out, indent + 2);
                }
                out.push('\n');
                let _ = write!(out, "{:width$}]", "", width = indent);
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    let _ = write!(out, "{:width$}", "", width = indent + 2);
                    write_escaped(out, k);
                    out.push_str(": ");
                    x.write_pretty(out, indent + 2);
                }
                out.push('\n');
                let _ = write!(out, "{:width$}}}", "", width = indent);
            }
            _ => self.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no NaN/Infinity literal; emitting one would corrupt the
        // whole document (empty-percentile metrics are the usual source)
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {} (got {:?})",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {} (got {:?})", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {} (got {:?})", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{} at byte {}", e as char, self.pos),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multibyte UTF-8.
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len() && (self.bytes[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..end])?);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let text = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": 2.5}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64().unwrap(), 2.5);
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo é");
    }

    #[test]
    fn builder_api() {
        let v = Json::obj().set("x", 3usize).set("y", "z");
        assert_eq!(v.to_string(), r#"{"x":3,"y":"z"}"#);
    }

    #[test]
    fn non_finite_serializes_as_null() {
        let v = Json::obj().set("nan", f64::NAN).set("inf", f64::INFINITY);
        assert_eq!(v.to_string(), r#"{"inf":null,"nan":null}"#);
        assert_eq!(parse(&v.to_string()).unwrap().get("nan").unwrap(), &Json::Null);
    }

    #[test]
    fn pretty_parses_back() {
        let v = parse(r#"{"a":[1,2,{"b":3}],"c":"d"}"#).unwrap();
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }
}
