//! Property-testing helper (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` over `cases` generated inputs
//! and, on failure, performs a bounded greedy shrink via the generator's
//! `shrink` hook before panicking with the minimal counterexample found.

use std::fmt::Debug;

use super::rng::Rng;

/// A generator of random test inputs with an optional shrinker.
pub trait Gen {
    type Value: Clone + Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller versions of a failing input (default: none).
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over generated cases; panic with a (shrunken)
/// counterexample on failure. Deterministic via the fixed seed.
pub fn check<G: Gen>(name: &str, cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Rng::new(0x1adde2);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if !prop(&value) {
            let minimal = shrink_loop(gen, value, &prop);
            panic!("property {name:?} failed on case {case}:\n{minimal:#?}");
        }
    }
}

fn shrink_loop<G: Gen>(
    gen: &G,
    mut failing: G::Value,
    prop: &impl Fn(&G::Value) -> bool,
) -> G::Value {
    // Greedy descent, bounded to avoid pathological generators.
    for _ in 0..64 {
        let mut advanced = false;
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

/// Generator for usize in [lo, hi], shrinking toward lo.
pub struct UsizeGen {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeGen {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range(self.lo, self.hi)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Generator for f32 vectors of length in [min_len, max_len], values in
/// [-scale, scale]; shrinks by halving the length and zeroing entries.
pub struct VecF32Gen {
    pub min_len: usize,
    pub max_len: usize,
    pub scale: f32,
}

impl Gen for VecF32Gen {
    type Value = Vec<f32>;
    fn generate(&self, rng: &mut Rng) -> Vec<f32> {
        let n = rng.range(self.min_len, self.max_len);
        (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * self.scale).collect()
    }
    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..self.min_len.max(v.len() / 2)].to_vec());
        }
        if v.iter().any(|x| *x != 0.0) {
            out.push(vec![0.0; v.len()]);
        }
        out
    }
}

/// Generator for unicode strings mixing 1/2/3/4-byte characters (ASCII,
/// Latin supplement, CJK, emoji) — the byte-level tokenizer's worst case,
/// where every multi-byte character is split across several tokens. Shrinks
/// by halving at a character boundary and by collapsing to ASCII.
pub struct UnicodeGen {
    pub max_chars: usize,
}

impl Gen for UnicodeGen {
    type Value = String;
    fn generate(&self, rng: &mut Rng) -> String {
        let n = rng.range(0, self.max_chars);
        (0..n)
            .map(|_| {
                let cp = match rng.below(4) {
                    0 => rng.range(0x20, 0x7E),        // 1 byte
                    1 => rng.range(0xA1, 0x7FF),       // 2 bytes
                    2 => 0x4E00 + rng.below(0x2000),   // 3 bytes (CJK)
                    _ => 0x1F300 + rng.below(0x200),   // 4 bytes (emoji)
                };
                char::from_u32(cp as u32).expect("ranges avoid surrogates")
            })
            .collect()
    }
    fn shrink(&self, v: &String) -> Vec<String> {
        if v.is_empty() {
            return Vec::new();
        }
        let half = v.chars().count() / 2;
        vec![v.chars().take(half).collect(), v.chars().map(|_| 'a').collect(), String::new()]
    }
}

/// Pair two generators.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(
            "add-commutes",
            100,
            &PairGen(UsizeGen { lo: 0, hi: 100 }, UsizeGen { lo: 0, hi: 100 }),
            |(a, b)| a + b == b + a,
        );
    }

    #[test]
    #[should_panic(expected = "lt-10")]
    fn failing_property_shrinks() {
        check("lt-10", 200, &UsizeGen { lo: 0, hi: 100 }, |v| *v < 10);
    }

    #[test]
    fn unicode_gen_covers_multibyte_chars() {
        let g = UnicodeGen { max_chars: 30 };
        let mut rng = Rng::new(9);
        let mut multibyte = false;
        for _ in 0..50 {
            let s = g.generate(&mut rng);
            assert!(s.chars().count() <= 30);
            multibyte |= s.len() > s.chars().count();
        }
        assert!(multibyte, "generator never produced a multi-byte char");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = VecF32Gen { min_len: 2, max_len: 8, scale: 1.0 };
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let v = g.generate(&mut rng);
            assert!(v.len() >= 2 && v.len() <= 8);
            assert!(v.iter().all(|x| x.abs() <= 1.0));
        }
    }
}
