//! Summary statistics + latency recorder used by benches and the server
//! metrics (criterion/hdrhistogram are unavailable offline).

/// Streaming summary over f64 samples with percentile support.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Summary {
        Summary::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile by linear interpolation, q in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q / 100.0 * (v.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// 95% confidence interval half-width for the mean (normal approx).
    pub fn ci95(&self) -> f64 {
        if self.samples.len() < 2 {
            return f64::NAN;
        }
        1.96 * self.std() / (self.samples.len() as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary(xs: &[f64]) -> Summary {
        let mut s = Summary::new();
        for &x in xs {
            s.add(x);
        }
        s
    }

    #[test]
    fn basic_moments() {
        let s = summary(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let s = summary(&(1..=100).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(s.p50(), 50.5);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.1);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn empty_is_nan() {
        assert!(Summary::new().mean().is_nan());
        assert!(Summary::new().p50().is_nan());
    }
}
