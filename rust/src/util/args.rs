//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed accessors, defaults, and a generated `--help` text.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Declarative argument specification + parsed values.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    multis: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    positional: Vec<String>,
}

#[derive(Debug, Clone)]
struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
    is_multi: bool,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Args {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare `--name <value>` with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Args {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(str::to_string),
            is_flag: false,
            is_multi: false,
        });
        self
    }

    /// Declare a repeatable `--name <value>` option; every occurrence is
    /// collected in order and read back with [`Args::get_multi`].
    pub fn multi(mut self, name: &str, help: &str) -> Args {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
            is_multi: true,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Args {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
            is_multi: false,
        });
        self
    }

    /// Parse an iterator of raw arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(mut self, argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                println!("{}", self.help_text());
                std::process::exit(0);
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| anyhow!("unknown option --{key} (try --help)"))?
                    .clone();
                if spec.is_flag {
                    if inline_val.is_some() {
                        bail!("flag --{key} takes no value");
                    }
                    self.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow!("option --{key} needs a value"))?,
                    };
                    if spec.is_multi {
                        self.multis.entry(key).or_default().push(val);
                    } else {
                        self.values.insert(key, val);
                    }
                }
            } else {
                self.positional.push(arg);
            }
        }
        Ok(self)
    }

    pub fn parse_env(self) -> Result<Args> {
        self.parse(std::env::args().skip(1))
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.program, self.about);
        for spec in &self.specs {
            let head = if spec.is_flag {
                format!("  --{}", spec.name)
            } else if spec.is_multi {
                format!("  --{} <v>..", spec.name)
            } else {
                format!("  --{} <v>", spec.name)
            };
            let default = spec
                .default
                .as_deref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:28}{}{default}\n", spec.help));
        }
        s
    }

    // -- typed accessors -----------------------------------------------------

    pub fn get(&self, name: &str) -> Result<String> {
        if let Some(v) = self.values.get(name) {
            return Ok(v.clone());
        }
        if let Some(spec) = self.specs.iter().find(|s| s.name == name) {
            if let Some(d) = &spec.default {
                return Ok(d.clone());
            }
        }
        bail!("missing required option --{name}")
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self.get(name)?;
        v.parse().map_err(|e| anyhow!("--{name}={v}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self.get(name)?;
        v.parse().map_err(|e| anyhow!("--{name}={v}: {e}"))
    }

    /// Comma-separated list accessor: `--sizes 1,2,4`.
    pub fn get_usize_list(&self, name: &str) -> Result<Vec<usize>> {
        self.get(name)?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().map_err(|e| anyhow!("--{name}: {e}")))
            .collect()
    }

    /// All values given for a repeatable option, in command-line order
    /// (empty when the option never appeared).
    pub fn get_multi(&self, name: &str) -> Vec<String> {
        self.multis.get(name).cloned().unwrap_or_default()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_options_and_flags() {
        let a = Args::new("t", "")
            .opt("model", Some("tiny"), "")
            .opt("tp", None, "")
            .flag("verbose", "")
            .parse(argv("--tp 4 --verbose run"))
            .unwrap();
        assert_eq!(a.get("model").unwrap(), "tiny");
        assert_eq!(a.get_usize("tp").unwrap(), 4);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn inline_equals() {
        let a = Args::new("t", "")
            .opt("lr", None, "")
            .parse(argv("--lr=0.5"))
            .unwrap();
        assert_eq!(a.get_f64("lr").unwrap(), 0.5);
    }

    #[test]
    fn unknown_option_is_error() {
        assert!(Args::new("t", "").parse(argv("--nope")).is_err());
    }

    #[test]
    fn missing_required_is_error() {
        let a = Args::new("t", "").opt("x", None, "").parse(argv("")).unwrap();
        assert!(a.get("x").is_err());
    }

    #[test]
    fn repeatable_option_collects_in_order() {
        let a = Args::new("t", "")
            .multi("replica", "")
            .parse(argv("--replica arch=ladder --replica=arch=standard,tp=2"))
            .unwrap();
        assert_eq!(
            a.get_multi("replica"),
            vec!["arch=ladder".to_string(), "arch=standard,tp=2".to_string()]
        );
        assert!(a.get_multi("absent").is_empty());
    }

    #[test]
    fn list_accessor() {
        let a = Args::new("t", "")
            .opt("sizes", Some("1,2,4"), "")
            .parse(argv(""))
            .unwrap();
        assert_eq!(a.get_usize_list("sizes").unwrap(), vec![1, 2, 4]);
    }
}
