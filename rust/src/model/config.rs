//! Model configuration: the exported artifact configs (parsed from the
//! manifest) and the paper's model-size table (used by the performance
//! model to regenerate Tables 1-2/6 and Figures 2-4).

use anyhow::{bail, Result};

use crate::util::json::Json;

/// The architecture variants benchmarked in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    /// Standard transformer: blocking AllReduce after attention and MLP.
    Standard,
    /// Ladder Residual (the paper's contribution): module i+1 consumes the
    /// stale residual, AllReduces overlap with the next module's compute.
    Ladder,
    /// PaLM-style parallel attention+MLP: one AllReduce per layer.
    Parallel,
    /// Desync Residual-nx (paper §5): keep every n-th AllReduce, the rest
    /// are dropped and the residual streams desynchronize between syncs.
    Desync(usize),
    /// All communication deleted — wrong numerics, speed upper bound.
    Upperbound,
    /// Hybrid: lower half standard, upper half ladder (paper §4.2).
    Hybrid,
}

impl Arch {
    pub fn parse(s: &str) -> Result<Arch> {
        Ok(match s {
            "standard" => Arch::Standard,
            "ladder" => Arch::Ladder,
            "parallel" => Arch::Parallel,
            "desync2" => Arch::Desync(2),
            "desync4" => Arch::Desync(4),
            "upperbound" => Arch::Upperbound,
            "hybrid" => Arch::Hybrid,
            _ => bail!("unknown architecture {s:?}"),
        })
    }

    pub fn name(&self) -> String {
        match self {
            Arch::Standard => "standard".into(),
            Arch::Ladder => "ladder".into(),
            Arch::Parallel => "parallel".into(),
            Arch::Desync(n) => format!("desync{n}"),
            Arch::Upperbound => "upperbound".into(),
            Arch::Hybrid => "hybrid".into(),
        }
    }

    /// All variants, in the order the paper's tables list them.
    pub fn all() -> Vec<Arch> {
        vec![
            Arch::Standard,
            Arch::Parallel,
            Arch::Ladder,
            Arch::Desync(2),
            Arch::Desync(4),
            Arch::Hybrid,
            Arch::Upperbound,
        ]
    }
}

/// Llama-style model configuration (full, unsharded sizes). Mirrors the
/// python-side `ModelConfig`; parsed from the artifact manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct LlamaConfig {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
    pub params: usize,
}

impl LlamaConfig {
    pub fn from_json(j: &Json) -> Result<LlamaConfig> {
        Ok(LlamaConfig {
            name: j.get("name")?.as_str()?.to_string(),
            vocab: j.get("vocab")?.as_usize()?,
            hidden: j.get("hidden")?.as_usize()?,
            layers: j.get("layers")?.as_usize()?,
            heads: j.get("heads")?.as_usize()?,
            kv_heads: j.get("kv_heads")?.as_usize()?,
            head_dim: j.get("head_dim")?.as_usize()?,
            ffn: j.get("ffn")?.as_usize()?,
            max_seq: j.get("max_seq")?.as_usize()?,
            rope_theta: j.get("rope_theta")?.as_f64()?,
            norm_eps: j.get("norm_eps")?.as_f64()?,
            params: j.get("params")?.as_usize()?,
        })
    }

    /// The built-in config registry, mirroring `python/compile/model.py`'s
    /// `CONFIGS` table. This is what lets the native backend boot with no
    /// `artifacts/` directory: config identity no longer requires a manifest.
    pub fn builtin(name: &str) -> Result<LlamaConfig> {
        let (vocab, hidden, layers, heads, kv_heads, head_dim, ffn, max_seq) = match name {
            "tiny" => (256, 64, 4, 4, 2, 16, 192, 128),
            "small" => (2048, 256, 8, 8, 4, 32, 768, 320),
            "parity" => (512, 128, 6, 4, 4, 32, 384, 128),
            _ => bail!(
                "unknown built-in config {name:?} (tiny|small|parity) and no \
                 artifacts/{name}/manifest.json — run `make artifacts` for exported configs"
            ),
        };
        let mut cfg = LlamaConfig {
            name: name.to_string(),
            vocab,
            hidden,
            layers,
            heads,
            kv_heads,
            head_dim,
            ffn,
            max_seq,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
            params: 0,
        };
        cfg.params = cfg.param_count();
        Ok(cfg)
    }

    /// Total parameter count (embedding + blocks + head), matching the
    /// python `ModelConfig.params()` formula.
    pub fn param_count(&self) -> usize {
        let (h, f) = (self.hidden, self.ffn);
        let per_layer =
            h * (self.q_dim() + 2 * self.kv_dim()) + self.q_dim() * h + 3 * h * f + 2 * h;
        self.vocab * h * 2 + self.layers * per_layer + h
    }

    pub fn q_dim(&self) -> usize {
        self.heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim
    }
}

/// A row of the paper's model-size table (Table 1: 1B .. 405B). Dimensions
/// follow the public Llama-family configs the paper benchmarks.
#[derive(Debug, Clone, Copy)]
pub struct PaperModel {
    pub name: &'static str,
    pub params_b: f64,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub ffn: usize,
    pub vocab: usize,
}

/// Shorthand for the table below: (name, B params, hidden, layers, heads,
/// kv_heads, ffn, vocab).
const fn pm(
    name: &'static str,
    params_b: f64,
    hidden: usize,
    layers: usize,
    heads: usize,
    kv_heads: usize,
    ffn: usize,
    vocab: usize,
) -> PaperModel {
    PaperModel { name, params_b, hidden, layers, heads, kv_heads, ffn, vocab }
}

/// The size sweep of paper Table 1. 1B/3B use the paper's trained configs
/// (Llama-3.2-like), 8B..405B are the Llama-3.1 family, 176B is
/// Bloom/Falcon-class, 34B is CodeLlama-class.
pub const PAPER_MODELS: &[PaperModel] = &[
    pm("1B", 1.2, 2048, 16, 32, 8, 8192, 128256),
    pm("3B", 3.2, 3072, 28, 24, 8, 8192, 128256),
    pm("8B", 8.0, 4096, 32, 32, 8, 14336, 128256),
    pm("34B", 34.0, 8192, 48, 64, 8, 22016, 32000),
    pm("70B", 70.0, 8192, 80, 64, 8, 28672, 128256),
    pm("176B", 176.0, 14336, 70, 112, 8, 57344, 250880),
    pm("405B", 405.0, 16384, 126, 128, 8, 53248, 128256),
];

impl PaperModel {
    pub fn by_name(name: &str) -> Result<&'static PaperModel> {
        PAPER_MODELS
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown paper model {name:?}"))
    }

    pub fn q_dim(&self) -> usize {
        // head_dim is hidden/heads across the family
        self.hidden
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arch_roundtrip() {
        for arch in Arch::all() {
            assert_eq!(Arch::parse(&arch.name()).unwrap(), arch);
        }
        assert!(Arch::parse("nope").is_err());
    }

    #[test]
    fn paper_models_sane() {
        for m in PAPER_MODELS {
            assert_eq!(m.hidden % m.heads, 0, "{}", m.name);
            assert!(m.heads % m.kv_heads == 0, "{}", m.name);
        }
        assert_eq!(PaperModel::by_name("70B").unwrap().layers, 80);
    }

    #[test]
    fn builtin_configs_mirror_python_registry() {
        for name in ["tiny", "small", "parity"] {
            let c = LlamaConfig::builtin(name).unwrap();
            assert_eq!(c.name, name);
            assert_eq!(c.params, c.param_count());
            assert_eq!(c.heads % c.kv_heads, 0, "{name}");
        }
        let tiny = LlamaConfig::builtin("tiny").unwrap();
        assert_eq!((tiny.hidden, tiny.layers, tiny.ffn), (64, 4, 192));
        assert!(LlamaConfig::builtin("llama-405b").is_err());
    }

    #[test]
    fn config_from_json() {
        let j = crate::util::json::parse(
            r#"{"name":"t","vocab":256,"hidden":64,"layers":4,"heads":4,
                "kv_heads":2,"head_dim":16,"ffn":192,"max_seq":128,
                "rope_theta":10000.0,"norm_eps":1e-5,"params":1000,"kernels":"pallas"}"#,
        )
        .unwrap();
        let c = LlamaConfig::from_json(&j).unwrap();
        assert_eq!(c.q_dim(), 64);
        assert_eq!(c.kv_dim(), 32);
    }
}
