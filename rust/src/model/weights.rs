//! Host weight storage: load a flat trained vector (the python packing
//! layout), or generate seeded random weights; slice into per-rank TP shards.
//!
//! Sharding rules (Megatron column/row parallel):
//! * `wq`, `wk`, `wv`, `wg`, `wu`, `lm` — column split (output dim)
//! * `wo`, `wd` — row split (input dim)
//! * norms, `emb` — replicated

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// A host-resident f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Column slice of a [R, C] matrix: columns [t*C/tp, (t+1)*C/tp).
    pub fn shard_cols(&self, t: usize, tp: usize) -> HostTensor {
        assert_eq!(self.shape.len(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        assert_eq!(c % tp, 0, "cols {c} % tp {tp}");
        let cl = c / tp;
        let mut data = Vec::with_capacity(r * cl);
        for row in 0..r {
            let base = row * c + t * cl;
            data.extend_from_slice(&self.data[base..base + cl]);
        }
        HostTensor::new(vec![r, cl], data)
    }

    /// Row slice of a [R, C] matrix: rows [t*R/tp, (t+1)*R/tp).
    pub fn shard_rows(&self, t: usize, tp: usize) -> HostTensor {
        assert_eq!(self.shape.len(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        assert_eq!(r % tp, 0, "rows {r} % tp {tp}");
        let rl = r / tp;
        let data = self.data[t * rl * c..(t + 1) * rl * c].to_vec();
        HostTensor::new(vec![rl, c], data)
    }
}

/// Full-model weights on the host, keyed by the python packing names
/// (`emb`, `layers.<i>.<tensor>`, `final_norm`, `lm`).
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub tensors: BTreeMap<String, HostTensor>,
    pub layers: usize,
}

/// Per-rank sharded weights for one layer, in the argument order the
/// exported attention / MLP / fused modules expect.
#[derive(Debug, Clone)]
pub struct RankWeights {
    pub attn_norm: HostTensor,
    pub wq: HostTensor,
    pub wk: HostTensor,
    pub wv: HostTensor,
    pub wo: HostTensor,
    pub mlp_norm: HostTensor,
    pub wg: HostTensor,
    pub wu: HostTensor,
    pub wd: HostTensor,
}

impl WeightStore {
    /// Load from a flat f32 file using the manifest's packing table.
    pub fn from_flat_file(
        path: &std::path::Path,
        packing: &Json,
        layers: usize,
    ) -> Result<WeightStore> {
        let bytes = std::fs::read(path).map_err(|e| anyhow!("read {path:?}: {e}"))?;
        if bytes.len() % 4 != 0 {
            bail!("{path:?}: not a f32 file ({} bytes)", bytes.len());
        }
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Self::from_flat(&flat, packing, layers)
    }

    /// Slice a flat vector into named tensors per the packing table.
    pub fn from_flat(flat: &[f32], packing: &Json, layers: usize) -> Result<WeightStore> {
        let total = packing.get("total")?.as_usize()?;
        if flat.len() != total {
            bail!("flat weight vector has {} elements, packing wants {total}", flat.len());
        }
        let mut tensors = BTreeMap::new();
        for entry in packing.get("tensors")?.as_arr()? {
            let name = entry.get("name")?.as_str()?.to_string();
            let shape = entry.get("shape")?.usize_vec()?;
            let offset = entry.get("offset")?.as_usize()?;
            let n: usize = shape.iter().product();
            tensors.insert(name, HostTensor::new(shape, flat[offset..offset + n].to_vec()));
        }
        Ok(WeightStore { tensors, layers })
    }

    /// Seeded random init with Llama-like scaling (for benches where only
    /// shapes matter). Matches the packing layout of `config`.
    pub fn random(cfg: &super::LlamaConfig, seed: u64) -> WeightStore {
        let mut rng = Rng::new(seed);
        let (h, f, v) = (cfg.hidden, cfg.ffn, cfg.vocab);
        let (qd, kvd) = (cfg.q_dim(), cfg.kv_dim());
        let std = (h as f32).powf(-0.5);
        let mut tensors = BTreeMap::new();
        tensors.insert("emb".into(), HostTensor::new(vec![v, h], rng.normal_vec(v * h, 1.0)));
        for i in 0..cfg.layers {
            let p = |name: &str| format!("layers.{i}.{name}");
            tensors.insert(p("attn_norm"), HostTensor::new(vec![h], vec![1.0; h]));
            tensors.insert(p("wq"), HostTensor::new(vec![h, qd], rng.normal_vec(h * qd, std)));
            tensors.insert(p("wk"), HostTensor::new(vec![h, kvd], rng.normal_vec(h * kvd, std)));
            tensors.insert(p("wv"), HostTensor::new(vec![h, kvd], rng.normal_vec(h * kvd, std)));
            let wo = HostTensor::new(vec![qd, h], rng.normal_vec(qd * h, std * 0.3));
            tensors.insert(p("wo"), wo);
            tensors.insert(p("mlp_norm"), HostTensor::new(vec![h], vec![1.0; h]));
            tensors.insert(p("wg"), HostTensor::new(vec![h, f], rng.normal_vec(h * f, std)));
            tensors.insert(p("wu"), HostTensor::new(vec![h, f], rng.normal_vec(h * f, std)));
            let wd_std = (f as f32).powf(-0.5) * 0.3;
            tensors.insert(p("wd"), HostTensor::new(vec![f, h], rng.normal_vec(f * h, wd_std)));
        }
        tensors.insert("final_norm".into(), HostTensor::new(vec![h], vec![1.0; h]));
        tensors.insert("lm".into(), HostTensor::new(vec![h, v], rng.normal_vec(h * v, std)));
        WeightStore { tensors, layers: cfg.layers }
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("missing tensor {name:?}"))
    }

    /// Shard layer `i`'s tensors for rank `t` of `tp`.
    pub fn rank_layer(&self, i: usize, t: usize, tp: usize) -> Result<RankWeights> {
        let g = |name: &str| self.get(&format!("layers.{i}.{name}"));
        Ok(RankWeights {
            attn_norm: g("attn_norm")?.clone(),
            wq: g("wq")?.shard_cols(t, tp),
            wk: g("wk")?.shard_cols(t, tp),
            wv: g("wv")?.shard_cols(t, tp),
            wo: g("wo")?.shard_rows(t, tp),
            mlp_norm: g("mlp_norm")?.clone(),
            wg: g("wg")?.shard_cols(t, tp),
            wu: g("wu")?.shard_cols(t, tp),
            wd: g("wd")?.shard_rows(t, tp),
        })
    }

    /// Rank `t`'s LM head column shard.
    pub fn rank_lm(&self, t: usize, tp: usize) -> Result<HostTensor> {
        Ok(self.get("lm")?.shard_cols(t, tp))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_cols_reassembles() {
        let t = HostTensor::new(vec![2, 4], vec![0., 1., 2., 3., 10., 11., 12., 13.]);
        let a = t.shard_cols(0, 2);
        let b = t.shard_cols(1, 2);
        assert_eq!(a.data, vec![0., 1., 10., 11.]);
        assert_eq!(b.data, vec![2., 3., 12., 13.]);
    }

    #[test]
    fn shard_rows_reassembles() {
        let t = HostTensor::new(vec![4, 2], (0..8).map(|x| x as f32).collect());
        let a = t.shard_rows(0, 2);
        let b = t.shard_rows(1, 2);
        assert_eq!(a.data, vec![0., 1., 2., 3.]);
        assert_eq!(b.data, vec![4., 5., 6., 7.]);
        assert_eq!(a.shape, vec![2, 2]);
    }

    #[test]
    fn from_flat_respects_offsets() {
        let packing = crate::util::json::parse(
            r#"{"total": 6, "tensors": [
                {"name": "a", "shape": [2], "offset": 0},
                {"name": "b", "shape": [2, 2], "offset": 2}]}"#,
        )
        .unwrap();
        let ws = WeightStore::from_flat(&[1., 2., 3., 4., 5., 6.], &packing, 0).unwrap();
        assert_eq!(ws.get("a").unwrap().data, vec![1., 2.]);
        assert_eq!(ws.get("b").unwrap().shape, vec![2, 2]);
        assert!(WeightStore::from_flat(&[1.0], &packing, 0).is_err());
    }

    #[test]
    fn random_weights_cover_all_layers() {
        let cfg = crate::model::LlamaConfig {
            name: "t".into(), vocab: 32, hidden: 16, layers: 2, heads: 2,
            kv_heads: 2, head_dim: 8, ffn: 32, max_seq: 16,
            rope_theta: 1e4, norm_eps: 1e-5, params: 0,
        };
        let ws = WeightStore::random(&cfg, 7);
        assert!(ws.get("layers.1.wd").is_ok());
        let rw = ws.rank_layer(0, 1, 2).unwrap();
        assert_eq!(rw.wq.shape, vec![16, 8]);
        assert_eq!(rw.wd.shape, vec![16, 16]);
    }
}
