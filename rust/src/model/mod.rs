//! Model configuration, the paper's size table, and weight handling
//! (loading flat trained vectors, seeded random init, TP sharding).

pub mod config;
pub mod weights;

pub use config::{Arch, LlamaConfig, PaperModel, PAPER_MODELS};
pub use weights::{HostTensor, RankWeights, WeightStore};
