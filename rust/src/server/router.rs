//! Fault-tolerant multi-replica serving tier.
//!
//! A [`Router`] fronts N independent engine replicas — each its own
//! [`Batcher`], page pool and prefix tree, built by a caller-supplied
//! factory on the replica's own thread (engine handles are not `Send`).
//! Streaming requests are routed by **prefix affinity**: the page-aligned
//! head of the prompt is FNV-hashed to a stable replica index, so requests
//! sharing a prompt template land on the replica that already holds the
//! template's pages in its prefix cache. When the affinity target is
//! backed up past `spill_threshold` outstanding dispatches, the request
//! spills to the least-loaded live replica instead — affinity is a
//! preference, never a queueing obligation.
//!
//! Fault tolerance is end to end:
//!
//! * Every request has a **routing record** (replica, attempt count,
//!   whether any token reached the client). A per-dispatch forwarder
//!   thread relays replica events to the client and reports how the
//!   stream ended.
//! * A replica that dies (engine error, injected crash) drops its event
//!   sinks without a terminal event; each forwarder observes the closed
//!   channel and reports the loss. Requests that had **not yet streamed a
//!   token** are transparently resubmitted — the clone carries the same
//!   prompt, sampler and RNG seed, so the replayed stream is bitwise
//!   identical (duplicate `Admitted` frames are suppressed). Requests
//!   that had already streamed fail with a terminal `Error` event,
//!   `retryable: true`, because a replay would duplicate tokens the
//!   client already holds.
//! * **Graceful drain** closes a replica's admission, bounces its queued
//!   requests (resubmitted elsewhere), finishes its in-flight slots, then
//!   retires the thread. **Crash-restart** respawns a dead replica from
//!   the factory (prefix cache cold) — automatic under
//!   `auto_restart`, or explicit via [`Router::restart`].
//! * Dispatch is bounded: every failed placement — replica loss, an
//!   empty fleet, a raced replica death — funnels through one
//!   [`Control::schedule_retry`] ledger, so per-request attempts are
//!   capped at `max_retries`, redispatches back off linearly on
//!   `retry_backoff` (attempt k waits k × base), and a request that
//!   cannot be placed within `dispatch_timeout` fails with a retryable
//!   `Error` event instead of queueing forever.
//!
//! The fleet is **heterogeneous**: each slot carries its own
//! [`ReplicaSlotConfig`] — factory plus a JSON description of the config
//! it realizes — so a ladder replica can serve next to a standard one
//! under identical live traffic (the paper's fleet-level A/B). Routing
//! weights each replica by **backpressure**, not just the router-side
//! outstanding count: replica threads report their queue depth and
//! admission-blocked flag, and a blocked replica always looks
//! past-threshold to the spill rule. A **rolling upgrade**
//! ([`Router::upgrade`]) swaps every slot's config in drain→respawn
//! waves, one replica at a time, serving throughout.
//!
//! The control loop owns all routing state on one thread; replicas,
//! forwarders and clients talk to it through one mpsc channel, so there
//! are no locks to poison and no ordering hazards between a crash
//! notification and the retries it triggers.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::api::ApiJob;
use super::batcher::Batcher;
use super::request::{FinishReason, GenerationEvent, Request, RequestResult};
use crate::util::json::Json;

/// Builds one replica's batcher on the replica's own thread. The factory
/// is the respawn recipe too: a crash-restarted replica is bitwise a
/// fresh one (same weights, cold prefix cache).
pub type ReplicaFactory = Arc<dyn Fn() -> Result<Batcher> + Send + Sync>;

/// One slot's replica recipe: the factory that builds (and respawns) it,
/// plus a JSON description of the configuration the factory realizes —
/// surfaced verbatim as the replica's `config` in the fleet stats
/// snapshot so operators and the A/B harness can tell slots apart.
#[derive(Clone)]
pub struct ReplicaSlotConfig {
    pub factory: ReplicaFactory,
    pub desc: Json,
}

impl ReplicaSlotConfig {
    /// A slot with no advertised description (`config: null` in stats).
    pub fn new(factory: ReplicaFactory) -> ReplicaSlotConfig {
        ReplicaSlotConfig { factory, desc: Json::Null }
    }

    pub fn with_desc(factory: ReplicaFactory, desc: Json) -> ReplicaSlotConfig {
        ReplicaSlotConfig { factory, desc }
    }
}

/// Builds the per-slot configs a `{"upgrade":...}` wire frame asks for.
/// The CLI supplies one that resolves `--replica`-style spec overlays
/// against its base engine flags; fleet servers booted without a builder
/// reject the frame.
pub type UpgradeBuilder<'a> = &'a dyn Fn(&Json) -> Result<Vec<ReplicaSlotConfig>>;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Hash the page-aligned prompt head to a stable replica (cache
    /// affinity), spilling on load imbalance.
    Affinity,
    /// Cycle over live replicas, ignoring prompt content (the baseline
    /// the fleet harness compares affinity against).
    RoundRobin,
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub replicas: usize,
    pub policy: RoutingPolicy,
    /// Leading prompt tokens hashed for affinity. Set this to the replica
    /// KV page size so the key is exactly the first page — the unit the
    /// prefix cache shares. 0 hashes the whole prompt.
    pub affinity_tokens: usize,
    /// Outstanding dispatches at the affinity target beyond which a
    /// request spills to the least-loaded live replica.
    pub spill_threshold: usize,
    /// Resubmission attempts after the first dispatch (0 = never retry).
    pub max_retries: usize,
    /// Base redispatch backoff; attempt k waits k × this.
    pub retry_backoff: Duration,
    /// A request that cannot be placed on any replica within this window
    /// fails with a retryable `Error` event.
    pub dispatch_timeout: Duration,
    /// Respawn crashed replicas automatically (drained replicas always
    /// stay down until `restart`).
    pub auto_restart: bool,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            replicas: 2,
            policy: RoutingPolicy::Affinity,
            affinity_tokens: 16,
            spill_threshold: 8,
            max_retries: 3,
            retry_backoff: Duration::from_millis(10),
            dispatch_timeout: Duration::from_secs(30),
            auto_restart: true,
        }
    }
}

/// What the router sends a replica thread.
enum ReplicaJob {
    Submit { request: Request, sink: Sender<GenerationEvent> },
    Cancel { id: u64 },
    /// Graceful drain: close admission, bounce the queue, finish
    /// in-flight slots, retire.
    Drain,
    /// Fault injection: drop the batcher mid-flight. Sinks close without
    /// a terminal event, exactly like a process death.
    Crash,
    Stats { respond: Sender<Json> },
}

/// Everything the control loop reacts to, from clients, forwarders and
/// replica threads alike.
enum RouterMsg {
    Submit { request: Request, sink: Sender<GenerationEvent> },
    Cancel { id: u64 },
    /// Forwarder: the replica connection closed without a terminal event.
    Lost { id: u64, streamed: bool, admitted: bool },
    /// Forwarder: the replica bounced the request pre-token with a
    /// retryable error (drain, late rejection) — resubmit elsewhere.
    Bounced { id: u64, reason: String },
    /// Forwarder: a terminal event reached the client (or the client went
    /// away) — the record is settled.
    Settled { id: u64 },
    /// A replica thread exited. `built` is false when the factory itself
    /// failed (respawning would crash-loop).
    Retired { replica: usize, epoch: u64, crashed: bool, built: bool, reason: String },
    Drain { replica: usize },
    Kill { replica: usize },
    Restart { replica: usize },
    /// Replica thread: batcher-side load report (queue depth plus the
    /// admission-blocked flag) feeding backpressure-weighted routing.
    Load { replica: usize, epoch: u64, pending: usize, blocked: bool },
    /// Begin a rolling upgrade: one drain→respawn-with-new-config wave
    /// per replica, lowest index first, serving throughout.
    Upgrade { slots: Vec<ReplicaSlotConfig>, respond: Sender<Json> },
    Stats { respond: Sender<Json> },
    Shutdown,
}

/// Handle to the router control loop. Cloneable operations all funnel
/// through the control channel; dropping the router drains the fleet.
pub struct Router {
    ctl: Sender<RouterMsg>,
    thread: Option<JoinHandle<()>>,
    completed: Arc<AtomicUsize>,
}

impl Router {
    /// A homogeneous fleet: every slot runs the same factory.
    pub fn new(factory: ReplicaFactory, config: RouterConfig) -> Result<Router> {
        let slots = (0..config.replicas)
            .map(|_| ReplicaSlotConfig::new(factory.clone()))
            .collect();
        Router::new_fleet(slots, config)
    }

    /// A heterogeneous fleet: slot i runs `slots[i]`'s factory and
    /// advertises its description. `config.replicas` must match.
    pub fn new_fleet(slots: Vec<ReplicaSlotConfig>, config: RouterConfig) -> Result<Router> {
        anyhow::ensure!(!slots.is_empty(), "router needs at least one replica");
        anyhow::ensure!(
            slots.len() == config.replicas,
            "fleet has {} replica configs but the router config says {}",
            slots.len(),
            config.replicas
        );
        let (ctl_tx, ctl_rx) = channel();
        let completed = Arc::new(AtomicUsize::new(0));
        let mut control = Control::new(slots, config, ctl_tx.clone(), completed.clone());
        let thread = std::thread::spawn(move || control.run(ctl_rx));
        Ok(Router { ctl: ctl_tx, thread: Some(thread), completed })
    }

    /// Route a streaming request. Its events arrive on `sink`; exactly
    /// one terminal event (`Finished` or `Error`) ends the stream.
    pub fn submit(&self, request: Request, sink: Sender<GenerationEvent>) {
        let _ = self.ctl.send(RouterMsg::Submit { request, sink });
    }

    pub fn cancel(&self, id: u64) {
        let _ = self.ctl.send(RouterMsg::Cancel { id });
    }

    /// Gracefully drain one replica: stop admitting, bounce its queue
    /// (bounced requests are resubmitted to other replicas), finish its
    /// in-flight requests, retire the thread. The replica stays down
    /// until [`Router::restart`].
    pub fn drain(&self, replica: usize) {
        let _ = self.ctl.send(RouterMsg::Drain { replica });
    }

    /// Fault injection: kill one replica mid-flight (its in-flight
    /// requests are retried or failed per the routing records).
    pub fn kill(&self, replica: usize) {
        let _ = self.ctl.send(RouterMsg::Kill { replica });
    }

    /// Respawn a down (crashed or drained) replica from the factory.
    /// Its prefix cache starts cold.
    pub fn restart(&self, replica: usize) {
        let _ = self.ctl.send(RouterMsg::Restart { replica });
    }

    /// Rolling upgrade: install one new [`ReplicaSlotConfig`] per slot in
    /// drain→respawn waves, one replica at a time, so the fleet keeps
    /// serving throughout. Returns the control loop's acknowledgement
    /// (`{"upgrade":"started","waves":N}` or an `error` object — e.g. an
    /// upgrade already in progress, or a config-count mismatch); progress
    /// is observable via [`Router::stats`]'s top-level `upgrade` field. A
    /// replica that is already down at its wave adopts the new config
    /// without a forced respawn — it boots with it on its next restart.
    pub fn upgrade(&self, slots: Vec<ReplicaSlotConfig>) -> Result<Json> {
        let (tx, rx) = channel();
        self.ctl
            .send(RouterMsg::Upgrade { slots, respond: tx })
            .map_err(|_| anyhow::anyhow!("router control loop gone"))?;
        rx.recv_timeout(Duration::from_secs(30))
            .map_err(|_| anyhow::anyhow!("router upgrade acknowledgement timeout"))
    }

    /// Terminal events delivered to clients so far (completions, errors,
    /// duplicate rejections alike).
    pub fn completed(&self) -> usize {
        self.completed.load(Ordering::SeqCst)
    }

    /// Fleet snapshot: per-replica liveness/load/engine metrics plus the
    /// router's own counters (see docs/API.md).
    pub fn stats(&self) -> Result<Json> {
        let (tx, rx) = channel();
        self.ctl
            .send(RouterMsg::Stats { respond: tx })
            .map_err(|_| anyhow::anyhow!("router control loop gone"))?;
        rx.recv_timeout(Duration::from_secs(30))
            .map_err(|_| anyhow::anyhow!("router stats timeout"))
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.ctl.send(RouterMsg::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Bridge the TCP listener's job channel onto a router (the fleet-mode
/// `serve_forever`). Runs until `max_requests` terminal events (0 =
/// forever) or the listener goes away. `upgrade` turns `{"upgrade":...}`
/// wire frames into per-slot configs; without one the frame is rejected
/// with an `error` reply (the fleet still serves).
pub fn route_forever(
    router: &Router,
    jobs: Receiver<ApiJob>,
    max_requests: usize,
    upgrade: Option<UpgradeBuilder>,
) -> Result<()> {
    loop {
        match jobs.recv_timeout(Duration::from_millis(50)) {
            Ok(ApiJob::Submit { request, respond }) => router.submit(request, respond),
            Ok(ApiJob::Cancel { id }) => router.cancel(id),
            Ok(ApiJob::Stats { respond }) => {
                let _ = respond.send(router.stats()?);
            }
            Ok(ApiJob::Snapshot { respond }) => {
                // replica batchers live on their own threads; a fleet-wide
                // cache snapshot is not wired up — single-process `serve`
                // owns its batcher and handles this frame
                let _ = respond.send(Json::obj().set(
                    "error",
                    "snapshot requires a single-replica server (the serve subcommand)",
                ));
            }
            Ok(ApiJob::Upgrade { spec, respond }) => {
                let reply = match upgrade {
                    None => Json::obj()
                        .set("error", "this fleet does not accept wire upgrades"),
                    Some(build) => match build(&spec) {
                        Ok(slots) => router.upgrade(slots)?,
                        Err(e) => Json::obj().set("error", format!("bad upgrade spec: {e}")),
                    },
                };
                let _ = respond.send(reply);
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => return Ok(()),
        }
        if max_requests > 0 && router.completed() >= max_requests {
            return Ok(());
        }
    }
}

/// Per-request routing record: everything needed to resubmit the request
/// or decide that resubmission is no longer safe.
struct RouteRecord {
    /// Clone kept for resubmission: same prompt, sampler and RNG seed, so
    /// a pre-first-token replay is bitwise identical.
    request: Request,
    client: Sender<GenerationEvent>,
    /// Replica of the current (or last) dispatch.
    replica: usize,
    /// Dispatch attempts completed so far.
    attempts: usize,
    /// A `Token` event reached the client — transparent retry is no
    /// longer safe.
    streamed: bool,
    /// An `Admitted` event reached the client — a retried dispatch must
    /// suppress its replayed admission.
    admitted: bool,
    first_dispatch: Instant,
    /// Pending redispatch (backoff timer), when no dispatch is in flight.
    retry_at: Option<Instant>,
    /// Why the last attempt ended (for the retries-exhausted error).
    last_loss: String,
}

/// One replica slot as the control loop sees it. The slot owns its own
/// recipe (`factory`/`desc`): a respawn — automatic, explicit, or an
/// upgrade wave — always boots whatever config the slot currently holds.
struct Slot {
    jobs: Option<Sender<ReplicaJob>>,
    thread: Option<JoinHandle<()>>,
    /// Incarnation counter: a `Retired` from a previous epoch is stale.
    epoch: u64,
    up: bool,
    draining: bool,
    /// Dispatches routed here that have not settled (router-side load
    /// signal for spillover).
    outstanding: usize,
    /// This slot's build recipe and its advertised description.
    factory: ReplicaFactory,
    desc: Json,
    /// Last queue depth the replica thread reported (lags `outstanding`
    /// slightly; the weight takes the max of the two).
    reported_pending: usize,
    /// The replica reported its queue head blocked on KV pages — the
    /// admission-backpressure signal.
    reported_blocked: bool,
}

/// A rolling upgrade in progress: one wave per replica, lowest index
/// first. `pending[i]` holds replica i's new config until its wave runs.
struct UpgradeState {
    pending: Vec<Option<ReplicaSlotConfig>>,
    /// Replica currently draining for its wave (None between waves).
    draining: Option<usize>,
    upgraded: usize,
}

struct Control {
    cfg: RouterConfig,
    ctl: Sender<RouterMsg>,
    slots: Vec<Slot>,
    records: HashMap<u64, RouteRecord>,
    completed: Arc<AtomicUsize>,
    upgrade: Option<UpgradeState>,
    rr_next: usize,
    routed: usize,
    spilled: usize,
    retries: usize,
    drains: usize,
    restarts: usize,
    lost_streams: usize,
    failed: usize,
}

impl Control {
    fn new(
        slot_cfgs: Vec<ReplicaSlotConfig>,
        cfg: RouterConfig,
        ctl: Sender<RouterMsg>,
        completed: Arc<AtomicUsize>,
    ) -> Control {
        let slots = slot_cfgs
            .into_iter()
            .enumerate()
            .map(|(i, sc)| spawn_replica(sc, i, 0, ctl.clone()))
            .collect();
        Control {
            cfg,
            ctl,
            slots,
            records: HashMap::new(),
            completed,
            upgrade: None,
            rr_next: 0,
            routed: 0,
            spilled: 0,
            retries: 0,
            drains: 0,
            restarts: 0,
            lost_streams: 0,
            failed: 0,
        }
    }

    fn run(&mut self, rx: Receiver<RouterMsg>) {
        loop {
            match rx.recv_timeout(self.next_wake()) {
                Ok(RouterMsg::Shutdown) => return self.teardown(),
                Ok(msg) => self.handle(msg),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return self.teardown(),
            }
            // drain whatever else queued up before sleeping again
            loop {
                match rx.try_recv() {
                    Ok(RouterMsg::Shutdown) => return self.teardown(),
                    Ok(msg) => self.handle(msg),
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            }
            self.fire_due_retries();
        }
    }

    /// Sleep until the earliest pending redispatch, capped so liveness
    /// checks still run.
    fn next_wake(&self) -> Duration {
        let now = Instant::now();
        self.records
            .values()
            .filter_map(|r| r.retry_at)
            .map(|t| t.saturating_duration_since(now))
            .min()
            .unwrap_or(Duration::from_millis(50))
            .clamp(Duration::from_millis(1), Duration::from_millis(50))
    }

    fn fire_due_retries(&mut self) {
        let now = Instant::now();
        let due: Vec<u64> = self
            .records
            .iter()
            .filter(|(_, r)| r.retry_at.is_some_and(|t| t <= now))
            .map(|(&id, _)| id)
            .collect();
        for id in due {
            self.dispatch(id);
        }
    }

    /// Close all job channels (replicas finish in-flight work and exit)
    /// and join every replica thread.
    fn teardown(&mut self) {
        for s in &mut self.slots {
            s.jobs = None;
        }
        for s in &mut self.slots {
            if let Some(t) = s.thread.take() {
                let _ = t.join();
            }
        }
    }

    fn handle(&mut self, msg: RouterMsg) {
        match msg {
            RouterMsg::Submit { request, sink } => {
                if self.records.contains_key(&request.id) {
                    // same live-uniqueness contract as the batcher: the
                    // duplicate fails on its own sink, the original's
                    // stream is untouched
                    let _ = sink.send(GenerationEvent::Error {
                        id: request.id,
                        retryable: false,
                        reason: "duplicate request id".to_string(),
                    });
                    self.completed.fetch_add(1, Ordering::SeqCst);
                    return;
                }
                let id = request.id;
                self.routed += 1;
                self.records.insert(
                    id,
                    RouteRecord {
                        request,
                        client: sink,
                        replica: 0,
                        attempts: 0,
                        streamed: false,
                        admitted: false,
                        first_dispatch: Instant::now(),
                        retry_at: None,
                        last_loss: String::new(),
                    },
                );
                self.dispatch(id);
            }
            RouterMsg::Cancel { id } => self.cancel(id),
            RouterMsg::Lost { id, streamed, admitted } => {
                self.lost_streams += 1;
                self.reroute(id, streamed, admitted, "replica died mid-request");
            }
            RouterMsg::Bounced { id, reason } => {
                self.reroute(id, false, false, &reason);
            }
            RouterMsg::Settled { id } => {
                if let Some(rec) = self.records.remove(&id) {
                    self.settle_load(rec.replica);
                    self.completed.fetch_add(1, Ordering::SeqCst);
                }
            }
            RouterMsg::Retired { replica, epoch, crashed, built, reason: _ } => {
                if self.slots[replica].epoch != epoch {
                    return; // a newer incarnation already lives here
                }
                self.slots[replica].up = false;
                self.slots[replica].jobs = None;
                self.slots[replica].reported_pending = 0;
                self.slots[replica].reported_blocked = false;
                if let Some(t) = self.slots[replica].thread.take() {
                    let _ = t.join();
                }
                // an upgrade wave completes on its target's retirement —
                // drained or crashed mid-drain alike: the new config was
                // installed when the wave started, so respawn it hot
                // (upgrades respawn even with auto_restart off)
                if self.upgrade.as_ref().is_some_and(|u| u.draining == Some(replica)) {
                    self.slots[replica].draining = false;
                    self.respawn(replica);
                    if let Some(u) = self.upgrade.as_mut() {
                        u.draining = None;
                        u.upgraded += 1;
                    }
                    self.advance_upgrade();
                    return;
                }
                if crashed && built && self.cfg.auto_restart && !self.slots[replica].draining {
                    self.respawn(replica);
                }
            }
            RouterMsg::Drain { replica } => {
                if replica >= self.slots.len()
                    || !self.slots[replica].up
                    || self.slots[replica].draining
                {
                    return;
                }
                self.slots[replica].draining = true;
                self.drains += 1;
                if let Some(jobs) = &self.slots[replica].jobs {
                    let _ = jobs.send(ReplicaJob::Drain);
                }
            }
            RouterMsg::Kill { replica } => {
                if replica >= self.slots.len() {
                    return;
                }
                if let Some(jobs) = &self.slots[replica].jobs {
                    let _ = jobs.send(ReplicaJob::Crash);
                }
                // stop routing to it now; `Retired` confirms the death
                self.slots[replica].up = false;
            }
            RouterMsg::Restart { replica } => {
                if replica < self.slots.len() && !self.slots[replica].up {
                    self.slots[replica].draining = false;
                    self.respawn(replica);
                }
            }
            RouterMsg::Load { replica, epoch, pending, blocked } => {
                let s = &mut self.slots[replica];
                if s.epoch == epoch {
                    s.reported_pending = pending;
                    s.reported_blocked = blocked;
                }
            }
            RouterMsg::Upgrade { slots, respond } => {
                let reply = self.start_upgrade(slots);
                let _ = respond.send(reply);
            }
            RouterMsg::Stats { respond } => {
                let stats = self.stats_json();
                let _ = respond.send(stats);
            }
            RouterMsg::Shutdown => unreachable!("handled by run()"),
        }
    }

    /// An attempt ended without a terminal event reaching the client:
    /// resubmit if that is still safe, otherwise fail the stream.
    fn reroute(&mut self, id: u64, streamed: bool, admitted: bool, why: &str) {
        let Some(mut rec) = self.records.remove(&id) else { return };
        self.settle_load(rec.replica);
        rec.streamed |= streamed;
        rec.admitted |= admitted;
        rec.last_loss = why.to_string();
        if rec.streamed {
            // tokens already reached the client: a replay would duplicate
            // them — surface the loss instead (retryable: the request
            // itself is fine)
            self.fail(rec, &format!("stream lost: {why}"));
            return;
        }
        self.schedule_retry(id, rec);
    }

    /// One failed placement attempt — replica loss, an empty fleet, a
    /// raced replica death — counted against the ledger, then either a
    /// linear-backoff redispatch is scheduled or the request fails. All
    /// redispatch sites funnel through here so "attempt k waits k ×
    /// `retry_backoff`", the `max_retries` cap and the dispatch deadline
    /// hold on every path (a flat backoff that skipped the ledger would
    /// poll a fully-down fleet forever).
    fn schedule_retry(&mut self, id: u64, mut rec: RouteRecord) {
        rec.attempts += 1;
        if rec.attempts > self.cfg.max_retries {
            let msg = format!(
                "retries exhausted after {} attempts: {}",
                rec.attempts, rec.last_loss
            );
            self.fail(rec, &msg);
            return;
        }
        let elapsed = rec.first_dispatch.elapsed();
        let Some(wait) =
            plan_retry(rec.attempts, self.cfg.retry_backoff, elapsed, self.cfg.dispatch_timeout)
        else {
            self.fail(rec, "dispatch timeout: no replica accepted the request");
            return;
        };
        self.retries += 1;
        rec.retry_at = Some(Instant::now() + wait);
        self.records.insert(id, rec);
    }

    fn settle_load(&mut self, replica: usize) {
        let s = &mut self.slots[replica];
        s.outstanding = s.outstanding.saturating_sub(1);
    }

    /// Place a record on a live replica (or schedule another try, or give
    /// up). The record is out of the map while we work on it — no aliasing
    /// with slot state.
    fn dispatch(&mut self, id: u64) {
        let Some(mut rec) = self.records.remove(&id) else { return };
        rec.retry_at = None;
        if rec.first_dispatch.elapsed() >= self.cfg.dispatch_timeout {
            self.fail(rec, "dispatch timeout: no replica accepted the request");
            return;
        }
        let key_len = match self.cfg.affinity_tokens {
            0 => rec.request.prompt.len(),
            n => rec.request.prompt.len().min(n),
        };
        let eligible: Vec<bool> = self
            .slots
            .iter()
            .map(|s| s.up && !s.draining && s.jobs.is_some())
            .collect();
        let weights: Vec<usize> = self
            .slots
            .iter()
            .map(|s| slot_weight(s, self.cfg.spill_threshold))
            .collect();
        let (target, spilled) = choose_replica(
            &rec.request.prompt[..key_len],
            &eligible,
            &weights,
            self.cfg.policy,
            &mut self.rr_next,
            self.cfg.spill_threshold,
        );
        let Some(target) = target else {
            // nothing live right now (mid-restart?): a failed placement
            // like any other — counted, linearly backed off, deadlined
            rec.last_loss = "no live replica".to_string();
            self.schedule_retry(id, rec);
            return;
        };
        let (rtx, rrx) = channel();
        let sent = self.slots[target].jobs.as_ref().is_some_and(|jobs| {
            jobs.send(ReplicaJob::Submit { request: rec.request.clone(), sink: rtx })
                .is_ok()
        });
        if !sent {
            // raced the replica's death: mark it down and retry through
            // the same ledger (the forwarder was never spawned, so no
            // Lost will race this)
            self.slots[target].up = false;
            self.slots[target].jobs = None;
            rec.last_loss = format!("replica {target} died before accepting the dispatch");
            self.schedule_retry(id, rec);
            return;
        }
        if spilled {
            self.spilled += 1;
        }
        rec.replica = target;
        self.slots[target].outstanding += 1;
        let suppress_admitted = rec.admitted;
        let client = rec.client.clone();
        let ctl = self.ctl.clone();
        std::thread::spawn(move || forward(id, suppress_admitted, rrx, client, ctl));
        self.records.insert(id, rec);
    }

    /// Terminal failure: structured retryable error to the client.
    fn fail(&mut self, rec: RouteRecord, reason: &str) {
        self.failed += 1;
        let _ = rec.client.send(GenerationEvent::Error {
            id: rec.request.id,
            retryable: true,
            reason: reason.to_string(),
        });
        self.completed.fetch_add(1, Ordering::SeqCst);
    }

    fn cancel(&mut self, id: u64) {
        let in_flight = match self.records.get(&id) {
            None => return,
            Some(rec) => (rec.retry_at.is_none(), rec.replica),
        };
        if in_flight.0 {
            // an attempt is in flight: the replica's cancel produces the
            // terminal Finished{Cancelled} through the normal event path
            if let Some(jobs) = &self.slots[in_flight.1].jobs {
                let _ = jobs.send(ReplicaJob::Cancel { id });
            }
            return;
        }
        // between attempts: no replica holds it — settle it ourselves
        let Some(rec) = self.records.remove(&id) else { return };
        let waited = rec.request.arrived.elapsed().as_secs_f64();
        let result = RequestResult {
            id,
            tokens: Vec::new(),
            finish_reason: FinishReason::Cancelled,
            queued_secs: waited,
            ttft_secs: 0.0,
            itl_p50_secs: 0.0,
            e2e_secs: waited,
        };
        let _ = rec.client.send(GenerationEvent::Finished { result });
        self.completed.fetch_add(1, Ordering::SeqCst);
    }

    fn respawn(&mut self, replica: usize) {
        let epoch = self.slots[replica].epoch + 1;
        let recipe = ReplicaSlotConfig {
            factory: self.slots[replica].factory.clone(),
            desc: self.slots[replica].desc.clone(),
        };
        self.slots[replica] = spawn_replica(recipe, replica, epoch, self.ctl.clone());
        self.restarts += 1;
    }

    /// Validate and begin a rolling upgrade; the reply goes back to the
    /// caller of [`Router::upgrade`] (or onto the wire).
    fn start_upgrade(&mut self, slots: Vec<ReplicaSlotConfig>) -> Json {
        if self.upgrade.is_some() {
            return Json::obj().set("error", "an upgrade is already in progress");
        }
        if slots.len() != self.slots.len() {
            return Json::obj().set(
                "error",
                format!(
                    "upgrade needs {} replica configs, got {}",
                    self.slots.len(),
                    slots.len()
                ),
            );
        }
        let waves = slots.len();
        self.upgrade = Some(UpgradeState {
            pending: slots.into_iter().map(Some).collect(),
            draining: None,
            upgraded: 0,
        });
        self.advance_upgrade();
        Json::obj().set("upgrade", "started").set("waves", waves)
    }

    /// Drive the rolling upgrade forward: when no wave is in flight,
    /// start the next one. A wave installs the slot's new config, drains
    /// the replica, and completes on its `Retired` (which respawns it
    /// with the new config). A replica that is already down just adopts
    /// the config — the operator took it down on purpose, so it boots
    /// upgraded on its next restart instead of being forced back up.
    fn advance_upgrade(&mut self) {
        loop {
            let next = match &self.upgrade {
                None => return,
                Some(u) if u.draining.is_some() => return, // wave in flight
                Some(u) => u.pending.iter().position(|p| p.is_some()),
            };
            let Some(next) = next else {
                self.upgrade = None; // all waves done
                return;
            };
            let Some(cfg) = self.upgrade.as_mut().and_then(|u| u.pending[next].take()) else {
                return; // unreachable: position() just said Some
            };
            self.slots[next].factory = cfg.factory;
            self.slots[next].desc = cfg.desc;
            if !self.slots[next].up {
                if let Some(u) = self.upgrade.as_mut() {
                    u.upgraded += 1;
                }
                continue;
            }
            if let Some(u) = self.upgrade.as_mut() {
                u.draining = Some(next);
            }
            self.slots[next].draining = true;
            self.drains += 1;
            if let Some(jobs) = &self.slots[next].jobs {
                let _ = jobs.send(ReplicaJob::Drain);
            }
            return;
        }
    }

    fn stats_json(&mut self) -> Json {
        let mut replicas = Vec::new();
        let mut prefill_tokens = 0usize;
        for (i, slot) in self.slots.iter().enumerate() {
            let engine = slot.jobs.as_ref().and_then(|jobs| {
                let (stx, srx) = channel();
                jobs.send(ReplicaJob::Stats { respond: stx }).ok()?;
                srx.recv_timeout(Duration::from_secs(5)).ok()
            });
            if let Some(rep) = &engine {
                if let Some(n) = rep.opt("prefill_tokens").and_then(|v| v.as_usize().ok()) {
                    prefill_tokens += n;
                }
            }
            replicas.push(
                Json::obj()
                    .set("replica", i)
                    .set("up", slot.up)
                    .set("draining", slot.draining)
                    .set("outstanding", slot.outstanding)
                    .set("pending", slot.reported_pending)
                    .set("blocked", slot.reported_blocked)
                    .set("config", slot.desc.clone())
                    .set("engine", engine.unwrap_or(Json::Null)),
            );
        }
        let upgrade = match &self.upgrade {
            None => Json::Null,
            Some(u) => Json::obj()
                .set("waves", u.pending.len())
                .set("upgraded", u.upgraded)
                .set("draining", u.draining.map_or(Json::Null, Json::from)),
        };
        Json::obj()
            .set("replicas", Json::Arr(replicas))
            .set("upgrade", upgrade)
            .set("routed", self.routed)
            .set("spilled", self.spilled)
            .set("retries", self.retries)
            .set("drains", self.drains)
            .set("restarts", self.restarts)
            .set("lost_streams", self.lost_streams)
            .set("failed", self.failed)
            .set("completed", self.completed.load(Ordering::SeqCst))
            .set("in_flight", self.records.len())
            .set("prefill_tokens", prefill_tokens)
    }
}

/// Start one replica incarnation: its thread builds the batcher from the
/// slot's factory and serves until drained, crashed or detached.
fn spawn_replica(
    recipe: ReplicaSlotConfig,
    idx: usize,
    epoch: u64,
    ctl: Sender<RouterMsg>,
) -> Slot {
    let (jtx, jrx) = channel();
    let f = recipe.factory.clone();
    let thread = std::thread::spawn(move || replica_main(idx, epoch, f, jrx, ctl));
    Slot {
        jobs: Some(jtx),
        thread: Some(thread),
        epoch,
        up: true,
        draining: false,
        outstanding: 0,
        factory: recipe.factory,
        desc: recipe.desc,
        reported_pending: 0,
        reported_blocked: false,
    }
}

/// Backpressure weight of one replica for routing: the router-side
/// outstanding count or the replica's own reported queue depth, whichever
/// is larger (the replica's number lags, the router's leads), plus a
/// penalty that pushes the weight past `spill_threshold` whenever the
/// replica reported blocked admission — a replica out of KV pages always
/// looks backed-up to the spill rule, even with few dispatches in flight.
fn slot_weight(slot: &Slot, spill_threshold: usize) -> usize {
    let depth = slot.outstanding.max(slot.reported_pending);
    if slot.reported_blocked {
        depth.saturating_add(spill_threshold.saturating_add(1))
    } else {
        depth
    }
}

/// Linear-backoff planning, pure for unit tests: given the attempt count
/// *including* the failure being recorded, the base backoff, the time
/// since the first dispatch and the dispatch deadline, returns how long
/// to wait before the next dispatch — clamped so the retry fires at the
/// deadline rather than one backoff past it — or `None` when the
/// deadline has already passed.
fn plan_retry(
    attempt: usize,
    base: Duration,
    elapsed: Duration,
    timeout: Duration,
) -> Option<Duration> {
    if elapsed >= timeout {
        return None;
    }
    let backoff = base.saturating_mul(attempt.min(u32::MAX as usize) as u32);
    Some(backoff.min(timeout - elapsed))
}

/// What applying one replica job asks the serve loop to do next.
enum Applied {
    Carry,
    Crash,
    /// Internal batcher-state corruption: retire this replica like an
    /// engine failure (in-flight sinks drop; the router retries).
    Fail(String),
}

fn apply_replica_job(batcher: &mut Batcher, job: ReplicaJob, started: Instant) -> Applied {
    match job {
        ReplicaJob::Submit { request, sink } => {
            batcher.submit_streaming(request, sink);
            Applied::Carry
        }
        ReplicaJob::Cancel { id } => match batcher.cancel(id) {
            Ok(_) => Applied::Carry,
            Err(e) => Applied::Fail(format!("cancel failed: {e}")),
        },
        ReplicaJob::Drain => {
            // bounce events route to the queued requests' sinks; the
            // forwarders turn them into resubmissions
            batcher.drain();
            Applied::Carry
        }
        ReplicaJob::Crash => Applied::Crash,
        ReplicaJob::Stats { respond } => {
            let report = batcher
                .stats_report(started.elapsed().as_secs_f64())
                .set("pending", batcher.pending())
                .set("draining", batcher.is_draining());
            let _ = respond.send(report);
            Applied::Carry
        }
    }
}

/// One replica incarnation's serve loop. Exits by: drain completing
/// (clean retire), engine error or injected crash (sinks drop with no
/// terminal event — the router's forwarders see the loss), or the router
/// going away (detach: finish in-flight work, then stop).
fn replica_main(
    idx: usize,
    epoch: u64,
    factory: ReplicaFactory,
    jobs: Receiver<ReplicaJob>,
    ctl: Sender<RouterMsg>,
) {
    let started = Instant::now();
    let retire = |crashed: bool, built: bool, reason: String| {
        let _ = ctl.send(RouterMsg::Retired { replica: idx, epoch, crashed, built, reason });
    };
    let mut batcher = match factory() {
        Ok(b) => b,
        Err(e) => return retire(true, false, format!("replica build failed: {e}")),
    };
    let mut detached = false;
    let mut last_load: Option<(usize, bool)> = None;
    loop {
        while !detached {
            match jobs.try_recv() {
                Ok(job) => match apply_replica_job(&mut batcher, job, started) {
                    Applied::Carry => {}
                    Applied::Crash => return retire(true, true, "killed".to_string()),
                    Applied::Fail(e) => return retire(true, true, e),
                },
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => detached = true,
            }
        }
        // backpressure report, sent only when it changes: the router
        // folds queue depth + the admission-blocked flag into its
        // routing weights
        let load = (batcher.pending(), batcher.admission_stalled());
        if last_load != Some(load) {
            last_load = Some(load);
            let _ = ctl.send(RouterMsg::Load {
                replica: idx,
                epoch,
                pending: load.0,
                blocked: load.1,
            });
        }
        if batcher.drained() || (detached && batcher.pending() == 0) {
            return retire(false, true, String::new());
        }
        if batcher.pending() == 0 {
            match jobs.recv_timeout(Duration::from_millis(2)) {
                Ok(job) => match apply_replica_job(&mut batcher, job, started) {
                    Applied::Carry => {}
                    Applied::Crash => return retire(true, true, "killed".to_string()),
                    Applied::Fail(e) => return retire(true, true, e),
                },
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => detached = true,
            }
            continue;
        }
        if let Err(e) = batcher.step() {
            // engine failure = replica death: dropping the batcher drops
            // every in-flight sink without a terminal event
            return retire(true, true, e.to_string());
        }
    }
}

/// Relay one dispatch attempt's events from the replica to the client and
/// report how the stream ended. Owns the per-attempt stream state
/// (`streamed`/`admitted`) so the control loop never races it.
fn forward(
    id: u64,
    suppress_admitted: bool,
    rrx: Receiver<GenerationEvent>,
    client: Sender<GenerationEvent>,
    ctl: Sender<RouterMsg>,
) {
    let mut streamed = false;
    let mut admitted = suppress_admitted;
    loop {
        match rrx.recv() {
            Ok(GenerationEvent::Admitted { .. }) if admitted => {
                // replayed admission of a retried request: the client
                // already saw exactly one Admitted
            }
            Ok(ev @ GenerationEvent::Admitted { .. }) => {
                admitted = true;
                if client.send(ev).is_err() {
                    let _ = ctl.send(RouterMsg::Settled { id });
                    return; // dropping rrx cancels replica-side
                }
            }
            Ok(ev @ GenerationEvent::Token { .. }) => {
                streamed = true;
                if client.send(ev).is_err() {
                    let _ = ctl.send(RouterMsg::Settled { id });
                    return;
                }
            }
            Ok(GenerationEvent::Error { retryable: true, reason, .. }) if !streamed => {
                // bounced before any token: the router decides whether to
                // resubmit — the client never sees this attempt fail
                let _ = ctl.send(RouterMsg::Bounced { id, reason });
                return;
            }
            Ok(ev) => {
                // Finished, or an error that must surface (not retryable,
                // or the stream already carried tokens)
                let _ = client.send(ev);
                let _ = ctl.send(RouterMsg::Settled { id });
                return;
            }
            Err(_) => {
                // replica died mid-request: no terminal event arrived
                let _ = ctl.send(RouterMsg::Lost { id, streamed, admitted });
                return;
            }
        }
    }
}

/// FNV-1a over the token ids' little-endian bytes: cheap, stable across
/// runs, and page-content-exact — the same first page always maps to the
/// same replica.
fn fnv1a(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Pure routing decision (unit-tested without threads). `weights` is the
/// per-replica backpressure weight (see [`slot_weight`]). Returns the
/// chosen replica (None when nothing is eligible) and whether the choice
/// spilled away from its affinity target. Spill semantics are strict:
/// the request moves only when the target is backed up strictly **past**
/// `spill_threshold` — exactly-at-threshold stays home.
fn choose_replica(
    key: &[i32],
    eligible: &[bool],
    weights: &[usize],
    policy: RoutingPolicy,
    rr_next: &mut usize,
    spill_threshold: usize,
) -> (Option<usize>, bool) {
    let live: Vec<usize> = eligible
        .iter()
        .enumerate()
        .filter(|(_, &e)| e)
        .map(|(i, _)| i)
        .collect();
    if live.is_empty() {
        return (None, false);
    }
    match policy {
        RoutingPolicy::RoundRobin => {
            let t = live[*rr_next % live.len()];
            *rr_next += 1;
            (Some(t), false)
        }
        RoutingPolicy::Affinity => {
            // hash against the full slot count, then walk to the next
            // live slot: affinity assignments are stable under unrelated
            // replica churn, and a down target degrades to its neighbor
            // instead of reshuffling the whole fleet
            let n = eligible.len();
            let mut t = (fnv1a(key) % n as u64) as usize;
            while !eligible[t] {
                t = (t + 1) % n;
            }
            let least = live.iter().copied().min_by_key(|&i| (weights[i], i));
            let Some(least) = least else {
                return (Some(t), false); // live was non-empty; defensive
            };
            if weights[t] > spill_threshold && weights[least] < weights[t] {
                return (Some(least), true);
            }
            (Some(t), false)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable_and_discriminates() {
        let a = fnv1a(&[1, 2, 3]);
        assert_eq!(a, fnv1a(&[1, 2, 3]));
        assert_ne!(a, fnv1a(&[1, 2, 4]));
        assert_ne!(a, fnv1a(&[3, 2, 1]));
    }

    #[test]
    fn affinity_is_stable_and_walks_past_dead_replicas() {
        let key = [5, 6, 7, 8];
        let mut rr = 0;
        let (t1, s1) =
            choose_replica(&key, &[true; 4], &[0; 4], RoutingPolicy::Affinity, &mut rr, 8);
        let (t2, _) =
            choose_replica(&key, &[true; 4], &[3, 3, 3, 3], RoutingPolicy::Affinity, &mut rr, 8);
        assert_eq!(t1, t2, "load below the spill threshold must not move affinity");
        assert!(!s1);
        // kill the affinity target: the choice walks to the next live slot
        let target = t1.unwrap();
        let mut eligible = [true; 4];
        eligible[target] = false;
        let (t3, _) =
            choose_replica(&key, &eligible, &[0; 4], RoutingPolicy::Affinity, &mut rr, 8);
        assert_eq!(t3, Some((target + 1) % 4));
    }

    #[test]
    fn affinity_spills_to_least_loaded_when_backed_up() {
        let key = [5, 6, 7, 8];
        let mut rr = 0;
        let (target, _) =
            choose_replica(&key, &[true; 3], &[0; 3], RoutingPolicy::Affinity, &mut rr, 2);
        let target = target.unwrap();
        let mut load = [0usize; 3];
        load[target] = 5; // past the threshold of 2
        let (t, spilled) =
            choose_replica(&key, &[true; 3], &load, RoutingPolicy::Affinity, &mut rr, 2);
        assert!(spilled);
        let t = t.unwrap();
        assert_ne!(t, target);
        assert_eq!(load[t], 0);
        // evenly backed up: nobody is strictly less loaded — stay home
        let (t, spilled) =
            choose_replica(&key, &[true; 3], &[5; 3], RoutingPolicy::Affinity, &mut rr, 2);
        assert_eq!(t, Some(target));
        assert!(!spilled);
    }

    #[test]
    fn round_robin_cycles_over_live_replicas_only() {
        let mut rr = 0;
        let eligible = [true, false, true, true];
        let picks: Vec<usize> = (0..6)
            .map(|_| {
                choose_replica(&[1], &eligible, &[0; 4], RoutingPolicy::RoundRobin, &mut rr, 8)
                    .0
                    .unwrap()
            })
            .collect();
        assert_eq!(picks, vec![0, 2, 3, 0, 2, 3]);
    }

    #[test]
    fn nothing_eligible_is_none() {
        let mut rr = 0;
        let (t, _) =
            choose_replica(&[1], &[false; 3], &[0; 3], RoutingPolicy::Affinity, &mut rr, 8);
        assert_eq!(t, None);
    }

    #[test]
    fn spill_boundary_exactly_at_threshold_stays_home() {
        // the documented contract: a request spills only when its target
        // is backed up strictly PAST spill_threshold
        let key = [5, 6, 7, 8];
        let mut rr = 0;
        let threshold = 3;
        let (target, _) = choose_replica(
            &key,
            &[true; 3],
            &[0; 3],
            RoutingPolicy::Affinity,
            &mut rr,
            threshold,
        );
        let target = target.unwrap();
        // exactly at the threshold: stay home, even with idle siblings
        let mut load = [0usize; 3];
        load[target] = threshold;
        let (t, spilled) =
            choose_replica(&key, &[true; 3], &load, RoutingPolicy::Affinity, &mut rr, threshold);
        assert_eq!(t, Some(target), "weight == threshold must not spill");
        assert!(!spilled);
        // one past the threshold: spill to the least-loaded live replica
        load[target] = threshold + 1;
        let (t, spilled) =
            choose_replica(&key, &[true; 3], &load, RoutingPolicy::Affinity, &mut rr, threshold);
        assert!(spilled, "weight == threshold + 1 must spill");
        let t = t.unwrap();
        assert_ne!(t, target);
        assert_eq!(load[t], 0);
    }

    #[test]
    fn affinity_walks_to_the_sole_live_replica() {
        let key = [9, 9, 9, 9];
        let mut rr = 0;
        for survivor in 0..4 {
            let mut eligible = [false; 4];
            eligible[survivor] = true;
            let (t, spilled) =
                choose_replica(&key, &eligible, &[0; 4], RoutingPolicy::Affinity, &mut rr, 8);
            assert_eq!(t, Some(survivor), "the walk must reach the only live replica");
            assert!(!spilled, "landing on the sole survivor is affinity, not spill");
        }
    }

    #[test]
    fn plan_retry_scales_linearly_and_honors_the_deadline() {
        let base = Duration::from_millis(10);
        let timeout = Duration::from_secs(30);
        // attempt k waits k × base — the documented contract
        for k in 1..=5 {
            assert_eq!(
                plan_retry(k, base, Duration::ZERO, timeout),
                Some(base * k as u32),
                "attempt {k}"
            );
        }
        // at or past the deadline: no more retries
        assert_eq!(plan_retry(1, base, timeout, timeout), None);
        assert_eq!(plan_retry(1, base, timeout + base, timeout), None);
        // near the deadline the wait clamps to it, so the next dispatch
        // fires exactly at the deadline instead of one backoff later
        let near = timeout - Duration::from_millis(3);
        assert_eq!(plan_retry(4, base, near, timeout), Some(Duration::from_millis(3)));
    }

    #[test]
    fn blocked_replicas_weigh_past_the_spill_threshold() {
        let dead_factory: ReplicaFactory = Arc::new(|| anyhow::bail!("unused in this test"));
        let mut slot = Slot {
            jobs: None,
            thread: None,
            epoch: 0,
            up: true,
            draining: false,
            outstanding: 2,
            factory: dead_factory,
            desc: Json::Null,
            reported_pending: 5,
            reported_blocked: false,
        };
        // unblocked: the weight is the larger of the two depth signals
        assert_eq!(slot_weight(&slot, 8), 5);
        slot.outstanding = 7;
        assert_eq!(slot_weight(&slot, 8), 7);
        // blocked admission always pushes the weight past the threshold
        slot.reported_blocked = true;
        assert!(slot_weight(&slot, 8) > 8);
        slot.outstanding = 0;
        slot.reported_pending = 0;
        assert!(slot_weight(&slot, 8) > 8, "blocked alone must exceed the threshold");
    }
}
