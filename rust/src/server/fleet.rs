//! Per-replica configuration overlays for heterogeneous fleets.
//!
//! A fleet is described as a fleet-wide **base** (the ordinary engine
//! flags: `--arch`, `--tp`, `--page-size`, ...) plus zero or more
//! per-slot **overlays**, each a comma-separated `key=value` spec:
//!
//! ```text
//! --replica arch=ladder,tp=2,page-size=8 --replica arch=standard
//! ```
//!
//! Overlay keys reuse the CLI flag names, so a spec reads exactly like
//! the flags it overrides. Only engine-shape keys are accepted — model,
//! backend and seed stay fleet-wide (every replica must tokenize and
//! sample identically, or the router's bitwise retry/upgrade oracle
//! breaks). The same grammar arrives over the wire in the
//! `{"upgrade": ...}` control frame, as either a spec string or a JSON
//! object of scalars (see `docs/API.md`).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Keys an overlay may override; everything else is fleet-wide.
pub const REPLICA_KEYS: &[&str] = &[
    "arch",
    "tp",
    "batch",
    "fabric",
    "codec",
    "runtime",
    "overlap",
    "page-size",
    "kv-budget-mb",
    "prefill-chunk",
    "prefix-cache",
    "decode-burst",
];

/// One replica's configuration overlay: the subset of engine flags this
/// slot overrides. An empty spec means "exactly the fleet-wide base".
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ReplicaSpec {
    overrides: BTreeMap<String, String>,
}

impl ReplicaSpec {
    /// Parse a `key=value,key=value` spec string. A bare `key` (no `=`)
    /// is shorthand for `key=true`, matching boolean flags like
    /// `prefix-cache`.
    pub fn parse(spec: &str) -> Result<ReplicaSpec> {
        let mut overrides = BTreeMap::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                bail!("replica spec {spec:?} has an empty segment");
            }
            let (key, value) = match part.split_once('=') {
                Some((k, v)) => (k.trim(), v.trim()),
                None => (part, "true"),
            };
            if !REPLICA_KEYS.contains(&key) {
                bail!(
                    "replica spec key {key:?} is not overridable per-slot \
                     (allowed: {})",
                    REPLICA_KEYS.join(", ")
                );
            }
            if value.is_empty() {
                bail!("replica spec key {key:?} has an empty value");
            }
            if overrides.insert(key.to_string(), value.to_string()).is_some() {
                bail!("replica spec sets {key:?} twice");
            }
        }
        Ok(ReplicaSpec { overrides })
    }

    /// Parse a wire-side spec: either a spec string (`"arch=ladder,tp=2"`)
    /// or an object of scalar overrides (`{"arch":"ladder","tp":2}`).
    pub fn from_json(v: &Json) -> Result<ReplicaSpec> {
        match v {
            Json::Str(s) => ReplicaSpec::parse(s),
            Json::Obj(map) => {
                let mut flat = Vec::new();
                for (key, val) in map {
                    let rendered = match val {
                        Json::Str(s) => s.clone(),
                        Json::Bool(b) => b.to_string(),
                        Json::Num(n) if n.fract() == 0.0 && n.is_finite() => {
                            format!("{}", *n as i64)
                        }
                        Json::Num(n) => n.to_string(),
                        other => bail!("replica spec key {key:?} has a non-scalar value {other:?}"),
                    };
                    flat.push(format!("{key}={rendered}"));
                }
                if flat.is_empty() {
                    return Ok(ReplicaSpec::default());
                }
                ReplicaSpec::parse(&flat.join(","))
            }
            other => bail!("replica spec must be a string or object, got {other:?}"),
        }
    }

    /// The overlay value for `key`, if this spec overrides it.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.overrides.get(key).map(String::as_str)
    }

    pub fn is_empty(&self) -> bool {
        self.overrides.is_empty()
    }

    /// Render the overlay as a JSON object (for stats/debug surfaces).
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        for (k, v) in &self.overrides {
            obj = obj.set(k, v.as_str());
        }
        obj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_pairs_and_bare_flags() {
        let s = ReplicaSpec::parse("arch=ladder, tp=2 ,prefix-cache").unwrap();
        assert_eq!(s.get("arch"), Some("ladder"));
        assert_eq!(s.get("tp"), Some("2"));
        assert_eq!(s.get("prefix-cache"), Some("true"));
        assert_eq!(s.get("page-size"), None);
    }

    #[test]
    fn rejects_unknown_duplicate_and_empty() {
        assert!(ReplicaSpec::parse("model=tiny").is_err());
        assert!(ReplicaSpec::parse("tp=2,tp=4").is_err());
        assert!(ReplicaSpec::parse("tp=").is_err());
        assert!(ReplicaSpec::parse("arch=ladder,,tp=2").is_err());
    }

    #[test]
    fn from_json_accepts_string_and_object() {
        let s = ReplicaSpec::from_json(&Json::Str("arch=ladder".into())).unwrap();
        assert_eq!(s.get("arch"), Some("ladder"));
        let obj = Json::obj().set("tp", 4usize).set("prefix-cache", true);
        let s = ReplicaSpec::from_json(&obj).unwrap();
        assert_eq!(s.get("tp"), Some("4"));
        assert_eq!(s.get("prefix-cache"), Some("true"));
        assert!(ReplicaSpec::from_json(&Json::Num(3.0)).is_err());
        assert!(ReplicaSpec::from_json(&Json::obj().set("model", "tiny")).is_err());
    }

    #[test]
    fn round_trips_through_json() {
        let s = ReplicaSpec::parse("arch=standard,page-size=4").unwrap();
        let back = ReplicaSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(s, back);
    }
}
