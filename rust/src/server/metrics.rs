//! Server-side aggregate metrics (throughput, latency percentiles).

use crate::util::json::Json;
use crate::util::stats::Summary;

use super::request::RequestResult;

#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub submitted: usize,
    pub completed: usize,
    pub prefills: usize,
    pub decode_steps: usize,
    pub tokens_out: usize,
    pub queued_secs: Summary,
    pub ttft_secs: Summary,
    pub e2e_secs: Summary,
}

impl ServerMetrics {
    pub fn record_completion(&mut self, r: &RequestResult) {
        self.completed += 1;
        self.ttft_secs.add(r.ttft_secs);
        self.e2e_secs.add(r.e2e_secs);
    }

    pub fn report(&self, wall_secs: f64) -> Json {
        Json::obj()
            .set("submitted", self.submitted)
            .set("completed", self.completed)
            .set("prefills", self.prefills)
            .set("decode_steps", self.decode_steps)
            .set("tokens_out", self.tokens_out)
            .set("throughput_tok_per_s", self.tokens_out as f64 / wall_secs.max(1e-9))
            .set("ttft_p50_ms", self.ttft_secs.p50() * 1e3)
            .set("ttft_p99_ms", self.ttft_secs.p99() * 1e3)
            .set("e2e_p50_ms", self.e2e_secs.p50() * 1e3)
            .set("e2e_p99_ms", self.e2e_secs.p99() * 1e3)
            .set("queue_p50_ms", self.queued_secs.p50() * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_completions() {
        let mut m = ServerMetrics::default();
        m.record_completion(&RequestResult {
            id: 1,
            tokens: vec![1, 2, 3],
            queued_secs: 0.0,
            ttft_secs: 0.1,
            e2e_secs: 0.5,
        });
        assert_eq!(m.completed, 1);
        assert!((m.e2e_secs.p50() - 0.5).abs() < 1e-9);
        let rep = m.report(2.0);
        assert!(rep.get("ttft_p50_ms").unwrap().as_f64().unwrap() > 99.0);
    }
}
