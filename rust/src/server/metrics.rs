//! Server-side aggregate metrics (throughput, latency percentiles).

use crate::util::json::Json;
use crate::util::stats::Summary;

use super::request::{FinishReason, RequestResult};

#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub submitted: usize,
    pub completed: usize,
    pub cancelled: usize,
    /// Requests that terminated with an `Error` event (rejected at
    /// admission or bounced by a drain) — they count as completed too.
    pub errors: usize,
    pub prefills: usize,
    pub decode_steps: usize,
    pub tokens_out: usize,
    /// KV pages currently allocated across all requests (paged engines;
    /// gauge, updated by the batcher every scheduler iteration).
    pub kv_pages_in_use: usize,
    /// Most KV pages ever simultaneously allocated.
    pub kv_pages_high_water: usize,
    /// Times the head of the queue could not be admitted because its
    /// worst-case page reservation did not fit the free pool.
    pub admission_blocked: usize,
    /// Prompt tokens actually prefilled (chunk sums; prefix-cache hits skip
    /// their cached prefix, so this is the compute the cache saves).
    pub prefill_tokens: usize,
    /// Prefix-cache lookups (one per paged admission with the cache on).
    pub prefix_lookups: usize,
    /// Admissions that reused at least one cached page.
    pub prefix_hits: usize,
    /// Prompt tokens served from the prefix cache instead of prefilled.
    pub prefix_hit_tokens: usize,
    /// KV pages currently referenced by the prefix tree (gauge).
    pub prefix_cached_pages: usize,
    /// Cached pages evicted (LRU, zero-reference chains only) to feed page
    /// reservations.
    pub prefix_evicted_pages: usize,
    /// Pages restored from the disk spill tier into a request's backing.
    pub prefix_disk_hits: usize,
    /// Evicted (or snapshotted) pages serialized to the disk tier.
    pub prefix_spilled_pages: usize,
    /// Bytes read back from the disk tier by successful restores.
    pub prefix_restore_bytes: usize,
    /// Spill files rejected at restore time (bad checksum, foreign
    /// geometry fingerprint, token mismatch, or vanished file) — each one
    /// fell back to cold prefill instead of serving untrusted bytes.
    pub prefix_disk_rejected: usize,
    pub queued_secs: Summary,
    pub ttft_secs: Summary,
    /// Inter-token latency samples (one per decode-phase token) — the
    /// per-token metric a TP-sharded server's users actually observe.
    pub itl_secs: Summary,
    pub e2e_secs: Summary,
}

impl ServerMetrics {
    pub fn record_completion(&mut self, r: &RequestResult) {
        self.completed += 1;
        if r.finish_reason == FinishReason::Cancelled {
            self.cancelled += 1;
        }
        if r.finish_reason == FinishReason::Error {
            self.errors += 1;
        }
        // requests torn down before their first token have no latency
        // breakdown worth folding into the percentiles
        if !r.tokens.is_empty() {
            self.ttft_secs.add(r.ttft_secs);
            self.e2e_secs.add(r.e2e_secs);
        }
    }

    pub fn report(&self, wall_secs: f64) -> Json {
        Json::obj()
            .set("submitted", self.submitted)
            .set("completed", self.completed)
            .set("cancelled", self.cancelled)
            .set("errors", self.errors)
            .set("prefills", self.prefills)
            .set("decode_steps", self.decode_steps)
            .set("tokens_out", self.tokens_out)
            .set("kv_pages_in_use", self.kv_pages_in_use)
            .set("kv_pages_high_water", self.kv_pages_high_water)
            .set("admission_blocked", self.admission_blocked)
            .set("prefill_tokens", self.prefill_tokens)
            .set("prefix_lookups", self.prefix_lookups)
            .set("prefix_hits", self.prefix_hits)
            .set("prefix_hit_tokens", self.prefix_hit_tokens)
            .set("prefix_cached_pages", self.prefix_cached_pages)
            .set("prefix_evicted_pages", self.prefix_evicted_pages)
            .set("prefix_disk_hits", self.prefix_disk_hits)
            .set("prefix_spilled_pages", self.prefix_spilled_pages)
            .set("prefix_restore_bytes", self.prefix_restore_bytes)
            .set("prefix_disk_rejected", self.prefix_disk_rejected)
            .set("throughput_tok_per_s", self.tokens_out as f64 / wall_secs.max(1e-9))
            .set("ttft_p50_ms", self.ttft_secs.p50() * 1e3)
            .set("ttft_p99_ms", self.ttft_secs.p99() * 1e3)
            .set("itl_p50_ms", self.itl_secs.p50() * 1e3)
            .set("itl_p95_ms", self.itl_secs.p95() * 1e3)
            .set("e2e_p50_ms", self.e2e_secs.p50() * 1e3)
            .set("e2e_p99_ms", self.e2e_secs.p99() * 1e3)
            .set("queue_p50_ms", self.queued_secs.p50() * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(tokens: Vec<i32>, finish_reason: FinishReason) -> RequestResult {
        RequestResult {
            id: 1,
            tokens,
            finish_reason,
            queued_secs: 0.0,
            ttft_secs: 0.1,
            itl_p50_secs: 0.02,
            e2e_secs: 0.5,
        }
    }

    #[test]
    fn records_completions() {
        let mut m = ServerMetrics::default();
        m.record_completion(&result(vec![1, 2, 3], FinishReason::Length));
        assert_eq!(m.completed, 1);
        assert_eq!(m.cancelled, 0);
        assert!((m.e2e_secs.p50() - 0.5).abs() < 1e-9);
        let rep = m.report(2.0);
        assert!(rep.get("ttft_p50_ms").unwrap().as_f64().unwrap() > 99.0);
    }

    #[test]
    fn counts_cancellations_and_itl() {
        let mut m = ServerMetrics::default();
        m.record_completion(&result(vec![1, 2], FinishReason::Cancelled));
        m.record_completion(&result(Vec::new(), FinishReason::Cancelled));
        assert_eq!((m.completed, m.cancelled), (2, 2));
        // unstarted cancel must not pollute the latency percentiles
        assert_eq!(m.ttft_secs.count(), 1);
        m.itl_secs.add(0.010);
        m.itl_secs.add(0.030);
        let rep = m.report(1.0);
        assert!((rep.get("itl_p50_ms").unwrap().as_f64().unwrap() - 20.0).abs() < 1e-9);
        assert_eq!(rep.get("cancelled").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn paged_kv_gauges_reach_the_report() {
        let mut m = ServerMetrics::default();
        m.kv_pages_in_use = 5;
        m.kv_pages_high_water = 9;
        m.admission_blocked = 2;
        let rep = m.report(1.0);
        assert_eq!(rep.get("kv_pages_in_use").unwrap().as_usize().unwrap(), 5);
        assert_eq!(rep.get("kv_pages_high_water").unwrap().as_usize().unwrap(), 9);
        assert_eq!(rep.get("admission_blocked").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn prefix_cache_counters_reach_the_report() {
        let mut m = ServerMetrics::default();
        m.prefill_tokens = 120;
        m.prefix_lookups = 10;
        m.prefix_hits = 7;
        m.prefix_hit_tokens = 300;
        m.prefix_cached_pages = 12;
        m.prefix_evicted_pages = 3;
        let rep = m.report(1.0);
        for (key, want) in [
            ("prefill_tokens", 120usize),
            ("prefix_lookups", 10),
            ("prefix_hits", 7),
            ("prefix_hit_tokens", 300),
            ("prefix_cached_pages", 12),
            ("prefix_evicted_pages", 3),
        ] {
            assert_eq!(rep.get(key).unwrap().as_usize().unwrap(), want, "{key}");
        }
    }

    #[test]
    fn disk_tier_counters_reach_the_report() {
        let mut m = ServerMetrics::default();
        m.prefix_disk_hits = 4;
        m.prefix_spilled_pages = 9;
        m.prefix_restore_bytes = 4096;
        m.prefix_disk_rejected = 1;
        let rep = m.report(1.0);
        for (key, want) in [
            ("prefix_disk_hits", 4usize),
            ("prefix_spilled_pages", 9),
            ("prefix_restore_bytes", 4096),
            ("prefix_disk_rejected", 1),
        ] {
            assert_eq!(rep.get(key).unwrap().as_usize().unwrap(), want, "{key}");
        }
    }
}
