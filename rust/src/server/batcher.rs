//! Continuous batcher: the core serving loop.
//!
//! Slot-based continuous batching over the fixed-B decode executable:
//! waiting requests are admitted into free slots via single-slot prefill
//! (`prefill_slot`), then all live slots advance together one decode step
//! per iteration. Prefill-priority policy (admit whenever a slot is free)
//! matches the paper's gpt-fast-derived serving setup; admission is gated
//! by the KV budget.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::metrics::ServerMetrics;
use super::request::{Request, RequestResult};
use crate::engine::TpEngine;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max tokens a decode step may produce before we re-check the queue.
    pub decode_burst: usize,
    /// KV memory budget in bytes (0 = slots are the only limit).
    pub kv_budget_bytes: usize,
}

impl Default for BatcherConfig {
    fn default() -> BatcherConfig {
        BatcherConfig { decode_burst: 1, kv_budget_bytes: 0 }
    }
}

/// Per-slot in-flight request state.
struct SlotState {
    request: Request,
    generated: Vec<i32>,
    next_token: i32,
    prefill_done: Instant,
    /// Queue wait measured at admission, carried into the result.
    queued_secs: f64,
}

/// The continuous batcher. Owns the engine (whose ranks run on either the
/// sequential or the threaded runtime; the batcher itself stays on one
/// scheduler thread).
pub struct Batcher {
    pub engine: TpEngine,
    pub config: BatcherConfig,
    pub metrics: ServerMetrics,
    queue: VecDeque<Request>,
    slots: Vec<Option<SlotState>>,
    rng: Rng,
}

impl Batcher {
    pub fn new(engine: TpEngine, config: BatcherConfig) -> Batcher {
        let slots = (0..engine.batch).map(|_| None).collect();
        Batcher {
            engine,
            config,
            metrics: ServerMetrics::default(),
            queue: VecDeque::new(),
            slots,
            rng: Rng::new(0xbac4),
        }
    }

    pub fn submit(&mut self, request: Request) {
        self.metrics.submitted += 1;
        self.queue.push_back(request);
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Number of requests the KV budget admits simultaneously.
    fn kv_slot_limit(&self) -> usize {
        if self.config.kv_budget_bytes == 0 {
            return self.engine.batch;
        }
        (self.config.kv_budget_bytes / self.engine.kv_bytes_per_slot().max(1))
            .clamp(1, self.engine.batch)
    }

    /// One scheduler iteration: admit + prefill waiting requests into free
    /// slots, then run `decode_burst` decode steps for live slots. Returns
    /// results completed this iteration.
    pub fn step(&mut self) -> Result<Vec<RequestResult>> {
        let mut done = Vec::new();

        // -- admission (prefill-priority, FIFO) --
        let limit = self.kv_slot_limit();
        for slot in 0..self.slots.len() {
            let live = self.slots.iter().filter(|s| s.is_some()).count();
            if live >= limit {
                break;
            }
            if self.slots[slot].is_some() {
                continue;
            }
            let Some(request) = self.queue.pop_front() else { break };
            let bucket = self.engine.pick_bucket(request.prompt.len())?;
            let mut padded = vec![0i32; bucket];
            padded[..request.prompt.len()].copy_from_slice(&request.prompt);
            let queued = request.arrived.elapsed().as_secs_f64();
            let logits = self
                .engine
                .prefill_slot(slot, &padded, bucket, request.prompt.len())?;
            let logits_t =
                crate::model::HostTensor::new(vec![1, logits.len()], logits);
            let next = request.sampler.sample(&logits_t, &mut self.rng)[0];
            self.metrics.queued_secs.add(queued);
            self.metrics.prefills += 1;
            self.slots[slot] = Some(SlotState {
                request,
                generated: vec![next],
                next_token: next,
                prefill_done: Instant::now(),
                queued_secs: queued,
            });
        }

        // -- decode burst --
        let any_live = self.slots.iter().any(|s| s.is_some());
        if any_live {
            for _ in 0..self.config.decode_burst.max(1) {
                // tokens for all slots (idle slots feed token 0, ignored)
                let tokens: Vec<i32> = self
                    .slots
                    .iter()
                    .map(|s| s.as_ref().map_or(0, |st| st.next_token))
                    .collect();
                let logits = self.engine.decode(&tokens)?;
                self.metrics.decode_steps += 1;
                let v = logits.shape[1];
                for (slot, state) in self.slots.iter_mut().enumerate() {
                    let Some(st) = state else { continue };
                    let row = crate::model::HostTensor::new(
                        vec![1, v],
                        logits.data[slot * v..(slot + 1) * v].to_vec(),
                    );
                    let tok = st.request.sampler.sample(&row, &mut self.rng)[0];
                    st.generated.push(tok);
                    st.next_token = tok;
                    self.metrics.tokens_out += 1;
                    let finished = st.generated.len() >= st.request.max_new_tokens
                        || st.request.eos == Some(tok)
                        || self.engine.lens[slot] as usize >= self.engine.cfg.max_seq - 1;
                    if finished {
                        let st = state.take().unwrap();
                        let now = Instant::now();
                        let result = RequestResult {
                            id: st.request.id,
                            tokens: st.generated,
                            queued_secs: st.queued_secs,
                            ttft_secs: (st.prefill_done - st.request.arrived).as_secs_f64(),
                            e2e_secs: (now - st.request.arrived).as_secs_f64(),
                        };
                        self.metrics.record_completion(&result);
                        self.engine.release_slot(slot);
                        done.push(result);
                    }
                }
                if self.slots.iter().all(|s| s.is_none()) {
                    break;
                }
            }
        }
        Ok(done)
    }

    /// Drive until the queue and all slots drain; returns all results.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestResult>> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.extend(self.step()?);
        }
        Ok(out)
    }
}
