//! Continuous batcher: the core serving loop.
//!
//! Two admission regimes share one scheduler:
//!
//! * **Fixed-slot** (`KvLayout::Slab`): waiting requests are admitted into
//!   free slots via single-slot prefill (`prefill_slot`) and every slot
//!   reserves a full `max_seq`-sized KV region — one long prompt dictates
//!   memory for every request. Kept as the bitwise oracle.
//! * **Paged** (`KvLayout::Paged`): admission is gated by a
//!   [`BlockAllocator`] — a request enters whenever its *worst-case* page
//!   count (prompt + `max_new_tokens`) fits the unreserved pool, so
//!   concurrency scales with what requests actually need. Prompts prefill
//!   in chunks of `prefill_chunk` tokens, interleaved with decode bursts,
//!   so a long prompt cannot stall in-flight decodes; cancellation and
//!   completion return pages to the free list immediately.
//!
//! With `prefix_cache` on (paged engines only), a [`PrefixTree`] maps
//! page-aligned prompt prefixes to cached chains of full, immutable,
//! ref-counted pages: an incoming prompt is matched before admission,
//! reserves only its *uncached suffix*, and chunk-prefills from the first
//! uncached position — a hit converts the shared prefix's prefill compute
//! and AllReduce traffic into a table lookup, bitwise-identically to a
//! cold prefill. On finish/cancel, the full pages of the prompt are
//! published back to the tree instead of freed; zero-reference chains are
//! evicted LRU when a reservation needs physical pages the free list
//! cannot supply.
//!
//! Per-request token streams are **bitwise identical** across both regimes
//! (and any admission interleaving): every kernel is batch-row-local, keys
//! are visited in logical order, and each slot samples from a private RNG
//! seeded by the request. The paged stress harness asserts this against
//! the fixed-slot oracle.
//!
//! The batcher's output is a typed **event stream**: [`Batcher::step`]
//! emits [`GenerationEvent`]s (`Admitted` → `Token`* → `Finished`, or a
//! terminal `Error` for rejected requests) and routes each request's
//! events to its per-request sink when one was registered via
//! [`Batcher::submit_streaming`]. A sink whose receiver has been dropped
//! (client timeout / disconnect) cancels the request instead of decoding
//! tokens nobody will read. [`Batcher::cancel`] aborts a request
//! mid-flight, freeing its slot and KV immediately. [`Batcher::drain`]
//! closes admission for good — queued requests bounce with a retryable
//! `Error`, in-flight ones finish — so a replica can retire without
//! losing work; the router resubmits the bounced requests elsewhere.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::metrics::ServerMetrics;
use super::request::{itl_p50, FinishReason, GenerationEvent, Request, RequestResult};
use crate::engine::{BlockAllocator, KvLayout, PrefixTree, SpillStore, TpEngine};
use crate::model::HostTensor;
use crate::tokenizer::{DecodeStream, Tokenizer};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max tokens a decode step may produce before we re-check the queue.
    pub decode_burst: usize,
    /// KV memory budget in bytes (0 = storage capacity is the only limit).
    pub kv_budget_bytes: usize,
    /// Paged engines: max prompt tokens prefetched per scheduler iteration
    /// (0 = the whole prompt in one chunk). In-flight decodes advance
    /// between chunks.
    pub prefill_chunk: usize,
    /// Paged engines: enable shared-prefix KV reuse (the radix-tree prefix
    /// cache over full prompt pages). Ignored on slab engines.
    pub prefix_cache: bool,
    /// Disk tier for the prefix cache (`--kv-spill-dir`): LRU-evicted
    /// chains are serialized here and restored on later misses. Empty =
    /// disabled. Requires `prefix_cache`.
    pub kv_spill_dir: String,
    /// Byte budget for the spill directory (`--kv-spill-budget-mb`); 0 =
    /// unlimited. The store LRU-evicts files to stay under it.
    pub kv_spill_budget_bytes: usize,
}

impl Default for BatcherConfig {
    fn default() -> BatcherConfig {
        BatcherConfig {
            decode_burst: 1,
            kv_budget_bytes: 0,
            prefill_chunk: 0,
            prefix_cache: false,
            kv_spill_dir: String::new(),
            kv_spill_budget_bytes: 0,
        }
    }
}

/// Where a live slot is in its request's lifecycle.
#[derive(Debug, Clone, PartialEq, Eq)]
enum SlotPhase {
    /// Chunked prefill in progress: this many prompt tokens are in KV.
    Prefill { consumed: usize },
    /// Disk-tier restore in progress: spilled pages planned at admission
    /// land one per scheduler iteration before prefill starts.
    Load {
        /// Remaining loads: (index into the request's page table, the full
        /// root-path token prefix keying the spill file).
        loads: VecDeque<(usize, Vec<i32>)>,
        /// Prompt tokens already durable in KV (RAM chain + restored
        /// pages): the prefill start once the plan drains, and the
        /// fall-back start if a load fails verification.
        consumed: usize,
    },
    /// Prefill finished; the slot advances one token per decode step.
    Decode,
}

/// Per-slot in-flight request state.
struct SlotState {
    request: Request,
    generated: Vec<i32>,
    next_token: i32,
    phase: SlotPhase,
    prefill_done: Instant,
    /// When the previous token was sampled (inter-token latency anchor).
    last_token_at: Instant,
    /// Queue wait measured at admission, carried into the result.
    queued_secs: f64,
    /// Inter-token gaps observed so far (seconds).
    itl: Vec<f64>,
    /// Private sampling stream, seeded from the request — never shared, so
    /// sampled output is independent of batch interleaving.
    rng: Rng,
    /// Incremental detokenizer feeding `Token::text_delta`.
    decoder: Option<DecodeStream>,
}

/// The continuous batcher. Owns the engine (whose ranks run on either the
/// sequential or the threaded runtime; the batcher itself stays on one
/// scheduler thread).
pub struct Batcher {
    pub engine: TpEngine,
    pub config: BatcherConfig,
    pub metrics: ServerMetrics,
    queue: VecDeque<Request>,
    slots: Vec<Option<SlotState>>,
    /// Page bookkeeping (paged engines only): free list, per-request page
    /// tables, reservation accounting, per-page refcounts.
    alloc: Option<BlockAllocator>,
    /// Shared-prefix radix tree (paged engines with `prefix_cache` on).
    prefix: Option<PrefixTree>,
    /// Disk tier for evicted prefix chains (`kv_spill_dir` set): victims
    /// are spilled on eviction, probed on a RAM miss at admission, and
    /// restored page-wise while the slot sits in its `Load` phase.
    spill: Option<SpillStore>,
    /// Per-request event sinks (streaming submissions only).
    sinks: HashMap<u64, Sender<GenerationEvent>>,
    /// Tokenizer for `text_delta`s; without one, deltas are empty strings.
    tokenizer: Option<Arc<Tokenizer>>,
    /// Draining: admission is closed and queued requests bounce with a
    /// retryable `Error` event; in-flight slots run to completion.
    draining: bool,
    /// The last admission pass ended with the queue head blocked for lack
    /// of KV pages — the backpressure signal the router folds into its
    /// routing weights.
    admission_stalled: bool,
}

/// Reason string on the `Error` event a draining batcher bounces queued
/// requests with (retryable — another replica can serve them).
pub const DRAIN_REASON: &str = "replica draining";

impl Batcher {
    pub fn new(engine: TpEngine, config: BatcherConfig) -> Batcher {
        let slots = (0..engine.batch).map(|_| None).collect();
        let alloc = match engine.kv_layout() {
            KvLayout::Slab => None,
            KvLayout::Paged { page_size, pages } => {
                let page_bytes = engine.kv_page_bytes();
                // budget clamps the pool, but never below one max-length
                // request (the paged mirror of the slab path's clamp(1, B))
                let total = if config.kv_budget_bytes == 0 {
                    pages
                } else {
                    (config.kv_budget_bytes / page_bytes.max(1))
                        .max(engine.kv_max_pages_per_seq())
                        .min(pages)
                };
                Some(BlockAllocator::new(total, page_size, page_bytes))
            }
        };
        let prefix = match (&alloc, config.prefix_cache) {
            (Some(a), true) => Some(PrefixTree::new(a.page_size())),
            _ => None,
        };
        // the disk tier rides on the prefix cache (it persists evicted
        // chains); a store that fails to open degrades to no tier rather
        // than refusing to serve
        let spill = match (&prefix, config.kv_spill_dir.is_empty()) {
            (Some(_), false) => match SpillStore::open(
                std::path::Path::new(&config.kv_spill_dir),
                config.kv_spill_budget_bytes as u64,
                engine.kv_fingerprint(),
            ) {
                Ok(s) => Some(s),
                Err(e) => {
                    eprintln!("kv spill tier disabled ({}): {e:#}", config.kv_spill_dir);
                    None
                }
            },
            _ => None,
        };
        Batcher {
            engine,
            config,
            metrics: ServerMetrics::default(),
            queue: VecDeque::new(),
            slots,
            alloc,
            prefix,
            spill,
            sinks: HashMap::new(),
            tokenizer: None,
            draining: false,
            admission_stalled: false,
        }
    }

    /// A batcher that also detokenizes incrementally: `Token` events carry
    /// the exact text each token appends (a trailing incomplete UTF-8
    /// sequence is held back; the terminal result's full decode renders it
    /// as U+FFFD).
    pub fn with_tokenizer(engine: TpEngine, config: BatcherConfig, tok: Tokenizer) -> Batcher {
        let mut b = Batcher::new(engine, config);
        b.tokenizer = Some(Arc::new(tok));
        b
    }

    pub fn submit(&mut self, request: Request) {
        self.metrics.submitted += 1;
        self.queue.push_back(request);
    }

    /// Submit with a per-request event sink. Every event for this request
    /// is sent to `sink` as it happens; if the receiver is dropped the
    /// request is cancelled at the next event boundary.
    ///
    /// Request ids must be unique among live requests: a submission whose
    /// id is already queued or in flight is rejected immediately on its
    /// *own* sink (terminal `Error` event, not retryable) — inserting it
    /// into the sinks map would hijack the original request's stream.
    pub fn submit_streaming(&mut self, request: Request, sink: Sender<GenerationEvent>) {
        if self.id_in_flight(request.id) {
            self.metrics.submitted += 1;
            self.record_rejection(&request, 0.0);
            let _ = sink.send(GenerationEvent::Error {
                id: request.id,
                retryable: false,
                reason: "duplicate request id".to_string(),
            });
            return;
        }
        self.sinks.insert(request.id, sink);
        self.submit(request);
    }

    /// Is `id` currently queued, occupying a slot, or bound to a sink?
    fn id_in_flight(&self, id: u64) -> bool {
        self.queue.iter().any(|r| r.id == id)
            || self.slots.iter().any(|s| s.as_ref().is_some_and(|st| st.request.id == id))
            || self.sinks.contains_key(&id)
    }

    /// Record the metrics side of a rejection (a completion with reason
    /// `Error`) for a request that never reached a slot. Shared by every
    /// rejection path so the two regimes cannot drift; the caller emits
    /// the matching terminal `Error` event.
    fn record_rejection(&mut self, request: &Request, queued: f64) {
        let result = RequestResult {
            id: request.id,
            tokens: Vec::new(),
            finish_reason: FinishReason::Error,
            queued_secs: queued,
            ttft_secs: 0.0,
            itl_p50_secs: 0.0,
            e2e_secs: request.arrived.elapsed().as_secs_f64(),
        };
        self.metrics.record_completion(&result);
    }

    /// Terminate a request that never reached a slot with a terminal
    /// `Error` event (routed to its sink, which is then released).
    fn fail_unstarted(
        &mut self,
        request: Request,
        queued: f64,
        retryable: bool,
        reason: &str,
    ) -> GenerationEvent {
        self.record_rejection(&request, queued);
        let ev = GenerationEvent::Error {
            id: request.id,
            retryable,
            reason: reason.to_string(),
        };
        self.route(&ev);
        self.sinks.remove(&request.id);
        ev
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.live()
    }

    /// Did the last admission pass leave the queue head blocked on KV
    /// pages? A router treats a stalled replica as backed up past its
    /// spill threshold regardless of how few dispatches it holds.
    pub fn admission_stalled(&self) -> bool {
        self.admission_stalled
    }

    /// Begin a graceful drain: admission closes permanently and every
    /// queued (not yet admitted) request is bounced immediately with a
    /// retryable `Error` event — another replica can serve it. Requests
    /// already in a slot (including mid-chunked-prefill and COW re-prefill
    /// slots) run to completion via further `step()` calls. Returns the
    /// bounce events; anything submitted after this bounces on the next
    /// `step()`.
    pub fn drain(&mut self) -> Vec<GenerationEvent> {
        self.draining = true;
        let mut events = Vec::new();
        self.bounce_queue(&mut events);
        events
    }

    pub fn is_draining(&self) -> bool {
        self.draining
    }

    /// A drain is complete once nothing is queued or in flight; the owner
    /// can then retire the replica.
    pub fn drained(&self) -> bool {
        self.draining && self.pending() == 0
    }

    /// Bounce every queued request with a retryable `Error` event.
    fn bounce_queue(&mut self, events: &mut Vec<GenerationEvent>) {
        while let Some(request) = self.queue.pop_front() {
            let queued = request.arrived.elapsed().as_secs_f64();
            events.push(self.fail_unstarted(request, queued, true, DRAIN_REASON));
        }
    }

    /// The `{"stats":true}` snapshot: the [`ServerMetrics`] report plus the
    /// comm-layer fields only the engine knows — the collective wire codec,
    /// its raw-vs-encoded byte ledger, the per-tier traffic split of a
    /// hierarchical `two_tier:` fabric, and the per-phase (prefill/decode)
    /// overlap fractions (docs/API.md).
    pub fn stats_report(&self, wall_secs: f64) -> crate::util::json::Json {
        let comm = self.engine.comm.stats();
        let page_size = match self.engine.kv_layout() {
            KvLayout::Slab => 0,
            KvLayout::Paged { page_size, .. } => page_size,
        };
        let report = self
            .metrics
            .report(wall_secs)
            .set("arch", self.engine.arch.name())
            .set("tp", self.engine.tp)
            .set("page_size", page_size)
            .set("runtime", self.engine.runtime.name())
            .set("overlap", self.engine.overlap.name())
            .set("codec", self.engine.codec().name())
            .set("comm_allreduces", comm.allreduce_count)
            .set("comm_bytes_moved", comm.bytes_moved)
            .set("comm_bytes_raw", comm.bytes_raw)
            .set("comm_bytes_intra", comm.bytes_intra)
            .set("comm_bytes_cross", comm.bytes_cross)
            .set("comm_hidden_fraction", comm.hidden_fraction())
            .set("comm_hidden_fraction_prefill", comm.hidden_fraction_prefill())
            .set("comm_hidden_fraction_decode", comm.hidden_fraction_decode());
        match &self.spill {
            Some(s) => report
                .set("spill_files", s.files())
                .set("spill_bytes", s.total_bytes() as usize),
            None => report,
        }
    }

    /// The paged page-table bookkeeping, when this batcher runs a paged
    /// engine (tests and the stress harness audit its invariants).
    pub fn allocator(&self) -> Option<&BlockAllocator> {
        self.alloc.as_ref()
    }

    /// The shared-prefix radix tree, when enabled (tests audit it against
    /// the allocator's tree-reference counts).
    pub fn prefix_tree(&self) -> Option<&PrefixTree> {
        self.prefix.as_ref()
    }

    /// The disk spill tier, when configured (tests inspect its ledger).
    pub fn spill_store(&self) -> Option<&SpillStore> {
        self.spill.as_ref()
    }

    /// Spill every cached chain page to the disk tier without evicting it
    /// — the warm-restart snapshot behind the `snapshot` subcommand and
    /// the `{"snapshot":true}` API frame. Cached pages are full and
    /// immutable, so reading them mid-serve is safe; pages whose chain is
    /// already on disk are skipped by the store's duplicate check.
    /// Returns (files written, bytes written).
    pub fn snapshot_cache(&mut self) -> Result<(usize, u64)> {
        let Some(tree) = self.prefix.as_ref() else {
            return Err(anyhow!("snapshot: prefix cache is not enabled"));
        };
        if self.spill.is_none() {
            return Err(anyhow!("snapshot: no --kv-spill-dir configured"));
        }
        let chains = tree.chains();
        let mut files = 0usize;
        let mut bytes = 0u64;
        for (tokens, page) in chains {
            let per_rank = self.engine.read_page(page)?;
            let spill = self.spill.as_mut().expect("checked above");
            let wrote = spill.store(&tokens, &per_rank)?;
            if wrote > 0 {
                files += 1;
                bytes += wrote;
                self.metrics.prefix_spilled_pages += 1;
            }
        }
        Ok((files, bytes))
    }

    /// Evict every zero-reference cached chain (drained server / tests:
    /// afterwards a drained batcher's whole pool is back on the free
    /// list). Returns the pages freed.
    pub fn flush_prefix_cache(&mut self) -> Result<usize> {
        let (Some(alloc), Some(tree)) = (self.alloc.as_mut(), self.prefix.as_mut()) else {
            return Ok(0);
        };
        let n = tree.flush(alloc)?;
        self.metrics.prefix_evicted_pages += n;
        self.metrics.prefix_cached_pages = alloc.cached_pages();
        self.metrics.kv_pages_in_use = alloc.pages_in_use();
        Ok(n)
    }

    /// Evict up to `want` LRU idle chain pages, spilling each victim's
    /// bytes to the disk tier first (when one is configured). Reading the
    /// page AFTER `tree_release` is safe: a freed page is only rewritten
    /// once a later reservation hands it out and a forward pass runs, and
    /// both happen after this call returns. Disk write failures are
    /// tolerated — the tier is best-effort; eviction itself never rolls
    /// back. Returns the number of pages evicted.
    fn evict_and_spill(&mut self, want: usize) -> Result<usize> {
        let (Some(alloc), Some(tree)) = (self.alloc.as_mut(), self.prefix.as_mut()) else {
            return Ok(0);
        };
        let victims = tree.evict_with_keys(want, alloc)?;
        let n = victims.len();
        self.metrics.prefix_evicted_pages += n;
        if let Some(spill) = self.spill.as_mut() {
            for (page, tokens) in &victims {
                let per_rank = self.engine.read_page(*page)?;
                match spill.store(tokens, &per_rank) {
                    Ok(bytes) if bytes > 0 => self.metrics.prefix_spilled_pages += 1,
                    Ok(_) => {}  // duplicate chain or over-budget payload: skipped
                    Err(_) => {} // disk trouble: the tier degrades, serving continues
                }
            }
        }
        Ok(n)
    }

    fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Number of requests the KV budget admits simultaneously (fixed-slot
    /// engines; paged engines admit by pages instead).
    fn kv_slot_limit(&self) -> usize {
        if self.alloc.is_some() || self.config.kv_budget_bytes == 0 {
            return self.engine.batch;
        }
        (self.config.kv_budget_bytes / self.engine.kv_bytes_per_slot().max(1))
            .clamp(1, self.engine.batch)
    }

    /// Worst-case KV tokens a request may write (admission reservation).
    fn reserve_tokens(&self, request: &Request) -> usize {
        (request.prompt.len() + request.max_new_tokens).min(self.engine.cfg.max_seq)
    }

    /// Send an event to its request's sink, if registered. Returns false
    /// when the receiver is gone — the caller must cancel the request.
    fn route(&mut self, ev: &GenerationEvent) -> bool {
        let id = ev.id();
        if let Some(sink) = self.sinks.get(&id) {
            if sink.send(ev.clone()).is_err() {
                self.sinks.remove(&id);
                return false;
            }
        }
        true
    }

    /// Abort an in-flight or queued request. The slot and its KV (slab
    /// region or pages) are freed immediately; the terminal `Finished`
    /// event (reason `Cancelled`, partial tokens) is routed to the sink and
    /// returned. `Ok(None)` if the id is unknown (already finished, or
    /// never submitted); `Err` only on internal-state corruption (a live
    /// slot without its page table), which the caller should treat as a
    /// replica-fatal engine error.
    pub fn cancel(&mut self, id: u64) -> Result<Option<GenerationEvent>> {
        if let Some(pos) = self.queue.iter().position(|r| r.id == id) {
            let Some(request) = self.queue.remove(pos) else {
                return Ok(None); // raced: position came from this queue
            };
            let queued = request.arrived.elapsed().as_secs_f64();
            return Ok(Some(self.finish_unstarted(request, queued, FinishReason::Cancelled)));
        }
        let Some(slot) = self
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|st| st.request.id == id))
        else {
            return Ok(None);
        };
        Ok(Some(self.finish_slot(slot, FinishReason::Cancelled)?))
    }

    /// One scheduler iteration: admit waiting requests (into free slots,
    /// and — paged — into free pages), advance one prefill chunk per
    /// admitted-but-unprefilled slot, then run `decode_burst` decode steps
    /// for slots past their prefill. Returns every event this iteration
    /// produced (sinks receive them too).
    pub fn step(&mut self) -> Result<Vec<GenerationEvent>> {
        let mut events = Vec::new();
        self.admit(&mut events)?;
        self.advance_loads()?;
        self.advance_prefills(&mut events)?;
        self.decode_burst(&mut events)?;
        if let Some(alloc) = &self.alloc {
            self.metrics.kv_pages_in_use = alloc.pages_in_use();
            self.metrics.kv_pages_high_water = alloc.high_water();
            self.metrics.prefix_cached_pages = alloc.cached_pages();
        }
        Ok(events)
    }

    /// Admission (prefill-priority, FIFO). Fixed-slot engines prefill the
    /// whole prompt inline, exactly as before; paged engines only claim the
    /// slot + reservation here and leave the prompt to `advance_prefills`.
    fn admit(&mut self, events: &mut Vec<GenerationEvent>) -> Result<()> {
        // recomputed every pass: the stall flag reflects the *current*
        // admission state, not a historical one
        self.admission_stalled = false;
        if self.draining {
            // drained admission never reopens: late submissions bounce
            // with the same retryable error the drain itself issued
            self.bounce_queue(events);
            return Ok(());
        }
        let limit = self.kv_slot_limit();
        for slot in 0..self.slots.len() {
            if self.slots[slot].is_some() {
                continue;
            }
            if self.live() >= limit {
                break;
            }
            // pop until a request that is servable and still has a client
            let admitted = loop {
                let Some(request) = self.queue.pop_front() else { break None };
                let queued = request.arrived.elapsed().as_secs_f64();
                // an id colliding with an in-flight slot is rejected FIRST,
                // and inline: every later rejection path routes through the
                // sinks map, whose entry for this id belongs to the
                // original request's stream and must not be disturbed
                let occupied = self
                    .slots
                    .iter()
                    .any(|s| s.as_ref().is_some_and(|st| st.request.id == request.id));
                if occupied {
                    self.record_rejection(&request, queued);
                    events.push(GenerationEvent::Error {
                        id: request.id,
                        retryable: false,
                        reason: "duplicate request id".to_string(),
                    });
                    continue;
                }
                if request.prompt.is_empty() {
                    events.push(self.fail_unstarted(request, queued, false, "empty prompt"));
                    continue;
                }
                let bucket = match self.engine.pick_bucket(request.prompt.len()) {
                    Ok(b) => b,
                    Err(_) => {
                        // unservable prompt: fail this request, not the loop
                        let ev = self.fail_unstarted(
                            request,
                            queued,
                            false,
                            "prompt exceeds every engine bucket",
                        );
                        events.push(ev);
                        continue;
                    }
                };
                // paged admission rule: the head of the queue enters only
                // when its worst case fits the unreserved pool — otherwise
                // admission stops (FIFO; later requests must not starve it).
                // A reservation larger than the whole pool can never fit:
                // fail that request instead of blocking the queue forever.
                // The prefix cache is consulted first: a matched chain
                // shrinks the reservation to the uncached suffix (shared
                // pages count once against capacity however many requests
                // pin them), so a hit can only make admission easier.
                let mut chain: Vec<u32> = Vec::new();
                let mut cow_src: Option<u32> = None;
                let mut start = 0usize;
                let mut disk_prefixes: Vec<Vec<i32>> = Vec::new();
                if self.alloc.is_some() {
                    let reserve = self.reserve_tokens(&request);
                    let alloc = self.alloc.as_mut().expect("checked above");
                    // a reservation larger than the whole pool can never be
                    // admitted: fail it alone, never the loop (its id is
                    // unique — checked above — so sink routing is safe)
                    if alloc.pages_for(reserve) > alloc.total_pages() {
                        let ev = self.fail_unstarted(
                            request,
                            queued,
                            false,
                            "page reservation exceeds pool capacity",
                        );
                        events.push(ev);
                        continue;
                    }
                    if let Some(tree) = &mut self.prefix {
                        chain = tree.match_prefix(&request.prompt);
                        start = chain.len() * tree.page_size();
                        if start == request.prompt.len() && !chain.is_empty() {
                            // whole prompt cached: the final token must be
                            // re-prefilled for its logits, and its KV write
                            // must not land in a shared page — drop the
                            // trailing page from the chain and duplicate it
                            // copy-on-write into the request's own page
                            cow_src = chain.pop();
                            start = request.prompt.len() - 1;
                        }
                    }
                    // pin the matched chain (and the COW source) for the
                    // rest of this admission: the shortfall eviction between
                    // here and `admit_shared` must not be able to free — and
                    // now spill — pages this request is about to share. LRU
                    // stamps made that unlikely; pins make it impossible.
                    for &p in &chain {
                        alloc.pin(p)?;
                    }
                    if let Some(src) = cow_src {
                        alloc.pin(src)?;
                    }
                    // on a RAM miss past the chain, probe the disk tier for
                    // contiguous follow-on pages; capped one token short of
                    // the prompt so at least one position always prefills
                    // (disk hits therefore never need the COW path)
                    if cow_src.is_none() {
                        if let Some(spill) = &self.spill {
                            let ps = alloc.page_size();
                            let plen = request.prompt.len();
                            let mut m = chain.len() + 1;
                            while m * ps < plen && spill.probe(&request.prompt[..m * ps]) {
                                disk_prefixes.push(request.prompt[..m * ps].to_vec());
                                m += 1;
                            }
                        }
                    }
                    if !alloc.can_admit_chain(reserve, &chain) {
                        for &p in &chain {
                            alloc.unpin(p)?;
                        }
                        if let Some(src) = cow_src {
                            alloc.unpin(src)?;
                        }
                        self.metrics.admission_blocked += 1;
                        self.admission_stalled = true;
                        self.queue.push_front(request);
                        return Ok(());
                    }
                }
                let ev = GenerationEvent::Admitted { id: request.id, queued_secs: queued };
                if !self.route(&ev) {
                    // client vanished while queued: skip the prefill
                    // entirely (dropping the admission pins first)
                    if let Some(alloc) = self.alloc.as_mut() {
                        for &p in &chain {
                            alloc.unpin(p)?;
                        }
                        if let Some(src) = cow_src {
                            alloc.unpin(src)?;
                        }
                    }
                    let ev = self.finish_unstarted(request, queued, FinishReason::Cancelled);
                    events.push(ev);
                    continue;
                }
                events.push(ev);
                break Some((request, queued, bucket, chain, cow_src, start, disk_prefixes));
            };
            let Some((request, queued, bucket, chain, mut cow_src, mut start, disk_prefixes)) =
                admitted
            else {
                break;
            };
            let reserve = self.reserve_tokens(&request);
            let now = Instant::now();
            let rng = Rng::new(request.rng_seed());
            let mut st = SlotState {
                decoder: self.tokenizer.as_ref().map(|t| DecodeStream::new(t.clone())),
                request,
                generated: Vec::new(),
                next_token: 0,
                phase: SlotPhase::Prefill { consumed: 0 },
                prefill_done: now,
                last_token_at: now,
                queued_secs: queued,
                itl: Vec::new(),
                rng,
            };
            if self.alloc.is_some() {
                // reservation guarantees the request can always grow to
                // prompt + max_new tokens — no deadlock, no preemption;
                // the uncached prompt suffix runs chunk-wise in
                // advance_prefills, starting at the first uncached position
                let plen = st.request.prompt.len();
                let mut cow_pinned = cow_src.is_some();
                // physical room for the suffix backing: the admission rule
                // counted evictable cached pages as available, so evict LRU
                // idle chains (spilling each victim to the disk tier) to
                // make the free list whole. The matched chain is pinned, so
                // eviction can never consume a page this request is about
                // to share — a guarantee the old LRU-stamp argument only
                // approximated.
                let short = {
                    let alloc = self.alloc.as_ref().expect("checked above");
                    let grow = alloc.pages_for(plen).saturating_sub(chain.len());
                    grow.saturating_sub(alloc.free_pages())
                };
                if short > 0 {
                    let evicted = self.evict_and_spill(short)?;
                    if evicted < short && cow_pinned {
                        // the only pinned evictable candidate is the COW
                        // source: release it and let eviction take it — the
                        // fall-back below re-prefills that page cold
                        let src = cow_src.expect("cow_pinned implies cow_src");
                        self.alloc.as_mut().expect("checked above").unpin(src)?;
                        cow_pinned = false;
                        self.evict_and_spill(short - evicted)?;
                    }
                }
                let alloc = self.alloc.as_mut().expect("checked above");
                // The popped COW source may have been sacrificed just above
                // when it was the last evictable leaf — fall back to
                // re-prefilling that whole trailing page cold instead of
                // copying a page that is gone (or about to be reallocated
                // as the copy's own destination).
                if cow_src.is_some_and(|src| !alloc.is_cached(src)) {
                    cow_src = None;
                    start = chain.len() * alloc.page_size();
                }
                alloc.admit_shared(st.request.id, plen, reserve, &chain)?;
                // the request's own references now hold the chain: the
                // admission-window pins retire
                for &p in &chain {
                    alloc.unpin(p)?;
                }
                if let Some(src) = cow_src {
                    // trailing-page copy-on-write: the final prompt token's
                    // KV row is re-prefilled into a private bitwise copy of
                    // the shared page
                    let dst = alloc
                        .table(st.request.id)
                        .ok_or_else(|| anyhow!("admitted request lost its page table"))?
                        .pages[chain.len()];
                    self.engine.copy_page(src, dst)?;
                    if cow_pinned {
                        self.alloc.as_mut().expect("checked above").unpin(src)?;
                    }
                }
                if self.prefix.is_some() {
                    // counted at admission — not per blocked retry — so
                    // prefix_hits / prefix_lookups is a true hit rate
                    self.metrics.prefix_lookups += 1;
                    if start > 0 {
                        self.metrics.prefix_hits += 1;
                        self.metrics.prefix_hit_tokens += start;
                    }
                }
                if disk_prefixes.is_empty() {
                    st.phase = SlotPhase::Prefill { consumed: start };
                } else {
                    // disk-tier hit: the backing pages for the spilled
                    // prefix are already reserved (private, this request's
                    // own) — mark them pending so the allocator can audit
                    // that un-restored bytes are never treated as cached,
                    // and restore them page-wise in `advance_loads`
                    let alloc = self.alloc.as_mut().expect("checked above");
                    let table = alloc
                        .table(st.request.id)
                        .ok_or_else(|| anyhow!("admitted request lost its page table"))?
                        .pages
                        .clone();
                    let mut loads = VecDeque::new();
                    for (i, prefix) in disk_prefixes.into_iter().enumerate() {
                        let idx = chain.len() + i;
                        alloc.mark_pending(table[idx])?;
                        loads.push_back((idx, prefix));
                    }
                    st.phase = SlotPhase::Load { loads, consumed: start };
                }
                self.slots[slot] = Some(st);
                continue;
            }
            // slab path: one-shot padded prefill into the slot
            let plen = st.request.prompt.len();
            let mut padded = vec![0i32; bucket];
            padded[..plen].copy_from_slice(&st.request.prompt);
            self.metrics.prefill_tokens += plen;
            let logits = self.engine.prefill_slot(slot, &padded, bucket, plen)?;
            self.slots[slot] = Some(st);
            self.complete_prefill(slot, logits, events)?;
        }
        Ok(())
    }

    /// Shared prefill-completion tail (slab one-shot and paged final
    /// chunk): sample the first token from the prefill logits, move the
    /// slot to its decode phase, record metrics, emit the `Token` event.
    /// One definition keeps both admission regimes bitwise-identical.
    fn complete_prefill(
        &mut self,
        slot: usize,
        logits: Vec<f32>,
        events: &mut Vec<GenerationEvent>,
    ) -> Result<()> {
        let st = self.slots[slot]
            .as_mut()
            .ok_or_else(|| anyhow!("complete_prefill on an empty slot"))?;
        let logits_t = HostTensor::new(vec![1, logits.len()], logits);
        let first = st.request.sampler.sample(&logits_t, &mut st.rng)[0];
        self.metrics.queued_secs.add(st.queued_secs);
        self.metrics.prefills += 1;
        let now = Instant::now();
        st.phase = SlotPhase::Decode;
        st.next_token = first;
        st.prefill_done = now;
        st.last_token_at = now;
        self.push_token(slot, first, events)
    }

    /// Disk-tier restore pump: each slot in its `Load` phase lands one
    /// spilled page per scheduler iteration, so restores interleave with
    /// decode bursts exactly like chunked prefill. A page that fails
    /// verification — bad checksum, foreign fingerprint, token mismatch,
    /// or a file the spill budget evicted since the admission probe —
    /// aborts the slot's remaining loads and falls the prefill start back
    /// to the last durable position: corrupt bytes are never served, the
    /// suffix is recomputed cold.
    fn advance_loads(&mut self) -> Result<()> {
        if self.spill.is_none() {
            return Ok(());
        }
        for slot in 0..self.slots.len() {
            let (id, page_idx, prefix) = {
                let Some(st) = self.slots[slot].as_mut() else { continue };
                let SlotPhase::Load { loads, consumed } = &mut st.phase else { continue };
                match loads.pop_front() {
                    Some((idx, prefix)) => (st.request.id, idx, prefix),
                    None => {
                        // defensive: an empty plan degenerates to prefill
                        let consumed = *consumed;
                        st.phase = SlotPhase::Prefill { consumed };
                        continue;
                    }
                }
            };
            let alloc = self
                .alloc
                .as_mut()
                .ok_or_else(|| anyhow!("disk restore without an allocator"))?;
            let ps = alloc.page_size();
            let pages = alloc
                .table(id)
                .ok_or_else(|| anyhow!("loading slot lost its page table"))?
                .pages
                .clone();
            let page = pages[page_idx];
            let spill = self
                .spill
                .as_mut()
                .ok_or_else(|| anyhow!("disk restore without a spill store"))?;
            // an I/O error here is indistinguishable from a miss: either
            // way the bytes cannot be trusted, so both fall back cold
            let restored = spill.load(&prefix).unwrap_or(None);
            match restored {
                Some(per_rank) => {
                    let bytes: usize = per_rank.iter().map(|r| r.len() * 4).sum();
                    self.engine.write_page(page, &per_rank)?;
                    let alloc = self.alloc.as_mut().expect("checked above");
                    alloc.clear_pending(page);
                    self.metrics.prefix_disk_hits += 1;
                    self.metrics.prefix_hit_tokens += ps;
                    self.metrics.prefix_restore_bytes += bytes;
                    let st = self.slots[slot].as_mut().expect("checked above");
                    let SlotPhase::Load { loads, consumed } = &mut st.phase else {
                        return Err(anyhow!("loading slot changed phase mid-restore"));
                    };
                    *consumed += ps;
                    if loads.is_empty() {
                        let consumed = *consumed;
                        st.phase = SlotPhase::Prefill { consumed };
                    }
                }
                None => {
                    self.metrics.prefix_disk_rejected += 1;
                    let st = self.slots[slot].as_mut().expect("checked above");
                    let SlotPhase::Load { loads, consumed } = &mut st.phase else {
                        return Err(anyhow!("loading slot changed phase mid-restore"));
                    };
                    let consumed = *consumed;
                    let aborted: Vec<usize> = loads.drain(..).map(|(idx, _)| idx).collect();
                    st.phase = SlotPhase::Prefill { consumed };
                    let alloc = self.alloc.as_mut().expect("checked above");
                    alloc.clear_pending(page);
                    for idx in aborted {
                        alloc.clear_pending(pages[idx]);
                    }
                }
            }
        }
        Ok(())
    }

    /// Paged chunked prefill: every slot still consuming its prompt runs
    /// exactly one chunk per scheduler iteration, so decodes interleave
    /// with long prompts. The final chunk's logits sample the first token.
    ///
    /// Known limitation: a client that disconnects mid-prefill is only
    /// detected at the first token send (`std::sync::mpsc::Sender` has no
    /// disconnect probe short of sending, and fabricating an extra event
    /// would corrupt the stream contract), so up to one prompt's worth of
    /// chunks can run for a dead client before the slot is reclaimed.
    fn advance_prefills(&mut self, events: &mut Vec<GenerationEvent>) -> Result<()> {
        if self.alloc.is_none() {
            return Ok(());
        }
        for slot in 0..self.slots.len() {
            let Some(st) = self.slots[slot].as_ref() else { continue };
            let SlotPhase::Prefill { consumed } = st.phase else { continue };
            let id = st.request.id;
            let total = st.request.prompt.len();
            let chunk = match self.config.prefill_chunk {
                0 => total - consumed,
                c => c.min(total - consumed),
            };
            let tokens = st.request.prompt[consumed..consumed + chunk].to_vec();
            let table = self
                .alloc
                .as_ref()
                .ok_or_else(|| anyhow!("chunked prefill without an allocator"))?
                .table(id)
                .ok_or_else(|| anyhow!("admitted request lost its page table"))?
                .pages
                .clone();
            self.metrics.prefill_tokens += chunk;
            let logits = self.engine.prefill_chunk_slot(slot, &tokens, consumed, &table)?;
            if consumed + chunk < total {
                let st = self.slots[slot]
                    .as_mut()
                    .ok_or_else(|| anyhow!("prefilling slot emptied mid-chunk"))?;
                st.phase = SlotPhase::Prefill { consumed: consumed + chunk };
                continue;
            }
            self.complete_prefill(slot, logits, events)?;
        }
        Ok(())
    }

    /// Decode phase of one scheduler iteration.
    fn decode_burst(&mut self, events: &mut Vec<GenerationEvent>) -> Result<()> {
        let decoding = |slots: &[Option<SlotState>]| {
            slots
                .iter()
                .filter(|s| s.as_ref().is_some_and(|st| st.phase == SlotPhase::Decode))
                .count()
        };
        if decoding(&self.slots) == 0 {
            return Ok(());
        }
        for _ in 0..self.config.decode_burst.max(1) {
            // tokens for all slots (idle/prefilling slots feed 0, ignored)
            let active: Vec<bool> = self
                .slots
                .iter()
                .map(|s| s.as_ref().is_some_and(|st| st.phase == SlotPhase::Decode))
                .collect();
            let tokens: Vec<i32> = self
                .slots
                .iter()
                .map(|s| match s {
                    Some(st) if st.phase == SlotPhase::Decode => st.next_token,
                    _ => 0,
                })
                .collect();
            let logits = if self.alloc.is_none() {
                self.engine.decode(&tokens)?
            } else {
                // grow each active request's backing for the incoming
                // token (evicting — and spilling — idle cached chains when
                // the free list alone cannot feed the reservation), then
                // hand the engine the page-table matrix
                let max_pages = self.engine.kv_max_pages_per_seq();
                let mut tables = vec![-1i32; self.slots.len() * max_pages];
                let work: Vec<(usize, u64, usize)> = self
                    .slots
                    .iter()
                    .enumerate()
                    .filter_map(|(slot, s)| {
                        let st = s.as_ref()?;
                        if st.phase != SlotPhase::Decode {
                            return None;
                        }
                        Some((slot, st.request.id, self.engine.lens[slot] as usize + 1))
                    })
                    .collect();
                for (slot, id, new_len) in work {
                    let short = self
                        .alloc
                        .as_ref()
                        .expect("checked above")
                        .free_shortfall(id, new_len);
                    if short > 0 {
                        self.evict_and_spill(short)?;
                    }
                    let alloc = self.alloc.as_mut().expect("checked above");
                    alloc.ensure(id, new_len)?;
                    let row = &mut tables[slot * max_pages..(slot + 1) * max_pages];
                    alloc.fill_table_row(id, row)?;
                }
                self.engine.decode_paged(&tokens, &active, tables, max_pages)?
            };
            self.metrics.decode_steps += 1;
            let v = logits.shape[1];
            for slot in 0..self.slots.len() {
                let tok = {
                    let Some(st) = self.slots[slot].as_mut() else { continue };
                    if st.phase != SlotPhase::Decode {
                        continue;
                    }
                    let row = HostTensor::new(
                        vec![1, v],
                        logits.data[slot * v..(slot + 1) * v].to_vec(),
                    );
                    st.request.sampler.sample(&row, &mut st.rng)[0]
                };
                self.push_token(slot, tok, events)?;
            }
            if decoding(&self.slots) == 0 {
                break;
            }
        }
        Ok(())
    }

    /// Record one sampled token into `slot`: emit its `Token` event, then
    /// finish the slot if a terminal condition (or a dead sink) is hit.
    fn push_token(
        &mut self,
        slot: usize,
        tok: i32,
        events: &mut Vec<GenerationEvent>,
    ) -> Result<()> {
        let (id, index, text_delta, finish) = {
            let st = self.slots[slot]
                .as_mut()
                .ok_or_else(|| anyhow!("push_token on an empty slot"))?;
            let now = Instant::now();
            if !st.generated.is_empty() {
                let gap = (now - st.last_token_at).as_secs_f64();
                st.itl.push(gap);
                self.metrics.itl_secs.add(gap);
            }
            st.last_token_at = now;
            st.generated.push(tok);
            st.next_token = tok;
            let text_delta = st.decoder.as_mut().map_or(String::new(), |d| d.push(tok));
            let index = st.generated.len() - 1;
            let finish = if st.request.eos == Some(tok) {
                Some(FinishReason::Eos)
            } else if st
                .request
                .stop
                .iter()
                .any(|s| !s.is_empty() && st.generated.ends_with(s))
            {
                Some(FinishReason::Stop)
            } else if st.generated.len() >= st.request.max_new_tokens
                || self.engine.lens[slot] as usize >= self.engine.cfg.max_seq - 1
            {
                Some(FinishReason::Length)
            } else {
                None
            };
            (st.request.id, index, text_delta, finish)
        };
        self.metrics.tokens_out += 1;
        let ev = GenerationEvent::Token { id, index, token: tok, text_delta };
        let client_alive = self.route(&ev);
        events.push(ev);
        if !client_alive {
            // nobody is reading: free the slot instead of decoding on
            events.push(self.finish_slot(slot, FinishReason::Cancelled)?);
        } else if let Some(reason) = finish {
            events.push(self.finish_slot(slot, reason)?);
        }
        Ok(())
    }

    /// Terminate a live slot: publish the prompt's full pages to the
    /// prefix tree (when enabled), release its KV (unreferenced pages
    /// return to the free list immediately on paged engines), record
    /// metrics, route and return the `Finished` event.
    fn finish_slot(&mut self, slot: usize, reason: FinishReason) -> Result<GenerationEvent> {
        let st = self.slots[slot]
            .take()
            .ok_or_else(|| anyhow!("finish_slot on an empty slot"))?;
        // publish before the allocator drops this request's references so
        // the tree can retain the pages instead of letting them free.
        // Cancelled requests publish what they actually wrote — a chunked
        // prefill may have covered only part of the prompt. A slot still in
        // its Load phase publishes nothing: no forward has run (engine.lens
        // is zero) and its pending pages must never reach the tree; `free`
        // below clears their pending bits as the refcounts drop.
        let mid_load = matches!(st.phase, SlotPhase::Load { .. });
        if let (Some(alloc), Some(tree), false) =
            (self.alloc.as_mut(), self.prefix.as_mut(), mid_load)
        {
            let written = self.engine.lens[slot].max(0) as usize;
            let covered = written.min(st.request.prompt.len());
            let full = covered / tree.page_size();
            if full > 0 {
                let table = alloc
                    .table(st.request.id)
                    .ok_or_else(|| anyhow!("live paged slot lost its page table"))?;
                let pages = table.pages[..full].to_vec();
                tree.insert(&st.request.prompt[..full * tree.page_size()], &pages, alloc)?;
            }
        }
        let now = Instant::now();
        let result = RequestResult {
            id: st.request.id,
            itl_p50_secs: itl_p50(&st.itl),
            tokens: st.generated,
            finish_reason: reason,
            queued_secs: st.queued_secs,
            ttft_secs: (st.prefill_done - st.request.arrived).as_secs_f64(),
            e2e_secs: (now - st.request.arrived).as_secs_f64(),
        };
        self.metrics.record_completion(&result);
        if let Some(alloc) = &mut self.alloc {
            alloc.free(result.id);
            self.metrics.kv_pages_in_use = alloc.pages_in_use();
            self.metrics.prefix_cached_pages = alloc.cached_pages();
        }
        self.engine.release_slot(slot);
        let ev = GenerationEvent::Finished { result };
        self.route(&ev);
        self.sinks.remove(&ev.id());
        Ok(ev)
    }

    /// Terminate a request that never reached a slot with a `Finished`
    /// event (cancelled while queued; rejections use `fail_unstarted`).
    fn finish_unstarted(
        &mut self,
        request: Request,
        queued: f64,
        reason: FinishReason,
    ) -> GenerationEvent {
        let result = RequestResult {
            id: request.id,
            tokens: Vec::new(),
            finish_reason: reason,
            queued_secs: queued,
            ttft_secs: 0.0,
            itl_p50_secs: 0.0,
            e2e_secs: request.arrived.elapsed().as_secs_f64(),
        };
        self.metrics.record_completion(&result);
        let ev = GenerationEvent::Finished { result };
        self.route(&ev);
        self.sinks.remove(&ev.id());
        ev
    }

    /// Drive until the queue and all slots drain; returns all results.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestResult>> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            for ev in self.step()? {
                if let GenerationEvent::Finished { result } = ev {
                    out.push(result);
                }
            }
        }
        Ok(out)
    }
}
