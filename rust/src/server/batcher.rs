//! Continuous batcher: the core serving loop.
//!
//! Slot-based continuous batching over the fixed-B decode executable:
//! waiting requests are admitted into free slots via single-slot prefill
//! (`prefill_slot`), then all live slots advance together one decode step
//! per iteration. Prefill-priority policy (admit whenever a slot is free)
//! matches the paper's gpt-fast-derived serving setup; admission is gated
//! by the KV budget.
//!
//! The batcher's output is a typed **event stream**: [`Batcher::step`]
//! emits [`GenerationEvent`]s (`Admitted` → `Token`* → `Finished`) and
//! routes each request's events to its per-request sink when one was
//! registered via [`Batcher::submit_streaming`]. A sink whose receiver has
//! been dropped (client timeout / disconnect) cancels the request instead
//! of decoding tokens nobody will read. [`Batcher::cancel`] aborts a
//! request mid-flight, freeing its slot and KV immediately.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::metrics::ServerMetrics;
use super::request::{itl_p50, FinishReason, GenerationEvent, Request, RequestResult};
use crate::engine::TpEngine;
use crate::model::HostTensor;
use crate::tokenizer::{DecodeStream, Tokenizer};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max tokens a decode step may produce before we re-check the queue.
    pub decode_burst: usize,
    /// KV memory budget in bytes (0 = slots are the only limit).
    pub kv_budget_bytes: usize,
}

impl Default for BatcherConfig {
    fn default() -> BatcherConfig {
        BatcherConfig { decode_burst: 1, kv_budget_bytes: 0 }
    }
}

/// Per-slot in-flight request state.
struct SlotState {
    request: Request,
    generated: Vec<i32>,
    next_token: i32,
    prefill_done: Instant,
    /// When the previous token was sampled (inter-token latency anchor).
    last_token_at: Instant,
    /// Queue wait measured at admission, carried into the result.
    queued_secs: f64,
    /// Inter-token gaps observed so far (seconds).
    itl: Vec<f64>,
    /// Private sampling stream, seeded from the request — never shared, so
    /// sampled output is independent of batch interleaving.
    rng: Rng,
    /// Incremental detokenizer feeding `Token::text_delta`.
    decoder: Option<DecodeStream>,
}

/// The continuous batcher. Owns the engine (whose ranks run on either the
/// sequential or the threaded runtime; the batcher itself stays on one
/// scheduler thread).
pub struct Batcher {
    pub engine: TpEngine,
    pub config: BatcherConfig,
    pub metrics: ServerMetrics,
    queue: VecDeque<Request>,
    slots: Vec<Option<SlotState>>,
    /// Per-request event sinks (streaming submissions only).
    sinks: HashMap<u64, Sender<GenerationEvent>>,
    /// Tokenizer for `text_delta`s; without one, deltas are empty strings.
    tokenizer: Option<Arc<Tokenizer>>,
}

impl Batcher {
    pub fn new(engine: TpEngine, config: BatcherConfig) -> Batcher {
        let slots = (0..engine.batch).map(|_| None).collect();
        Batcher {
            engine,
            config,
            metrics: ServerMetrics::default(),
            queue: VecDeque::new(),
            slots,
            sinks: HashMap::new(),
            tokenizer: None,
        }
    }

    /// A batcher that also detokenizes incrementally: `Token` events carry
    /// the exact text each token appends (a trailing incomplete UTF-8
    /// sequence is held back; the terminal result's full decode renders it
    /// as U+FFFD).
    pub fn with_tokenizer(engine: TpEngine, config: BatcherConfig, tok: Tokenizer) -> Batcher {
        let mut b = Batcher::new(engine, config);
        b.tokenizer = Some(Arc::new(tok));
        b
    }

    pub fn submit(&mut self, request: Request) {
        self.metrics.submitted += 1;
        self.queue.push_back(request);
    }

    /// Submit with a per-request event sink. Every event for this request
    /// is sent to `sink` as it happens; if the receiver is dropped the
    /// request is cancelled at the next event boundary.
    pub fn submit_streaming(&mut self, request: Request, sink: Sender<GenerationEvent>) {
        self.sinks.insert(request.id, sink);
        self.submit(request);
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.live()
    }

    fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Number of requests the KV budget admits simultaneously.
    fn kv_slot_limit(&self) -> usize {
        if self.config.kv_budget_bytes == 0 {
            return self.engine.batch;
        }
        (self.config.kv_budget_bytes / self.engine.kv_bytes_per_slot().max(1))
            .clamp(1, self.engine.batch)
    }

    /// Send an event to its request's sink, if registered. Returns false
    /// when the receiver is gone — the caller must cancel the request.
    fn route(&mut self, ev: &GenerationEvent) -> bool {
        let id = ev.id();
        if let Some(sink) = self.sinks.get(&id) {
            if sink.send(ev.clone()).is_err() {
                self.sinks.remove(&id);
                return false;
            }
        }
        true
    }

    /// Abort an in-flight or queued request. The slot and its KV are freed
    /// immediately; the terminal `Finished` event (reason `Cancelled`,
    /// partial tokens) is routed to the sink and returned. `None` if the id
    /// is unknown (already finished, or never submitted).
    pub fn cancel(&mut self, id: u64) -> Option<GenerationEvent> {
        if let Some(pos) = self.queue.iter().position(|r| r.id == id) {
            let request = self.queue.remove(pos).expect("position came from iter");
            let queued = request.arrived.elapsed().as_secs_f64();
            return Some(self.finish_unstarted(request, queued, FinishReason::Cancelled));
        }
        let slot = self
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|st| st.request.id == id))?;
        Some(self.finish_slot(slot, FinishReason::Cancelled))
    }

    /// One scheduler iteration: admit + prefill waiting requests into free
    /// slots, then run `decode_burst` decode steps for live slots. Returns
    /// every event this iteration produced (sinks receive them too).
    pub fn step(&mut self) -> Result<Vec<GenerationEvent>> {
        let mut events = Vec::new();

        // -- admission (prefill-priority, FIFO) --
        let limit = self.kv_slot_limit();
        for slot in 0..self.slots.len() {
            if self.slots[slot].is_some() {
                continue;
            }
            if self.live() >= limit {
                break;
            }
            // pop until a request that is servable and still has a client
            let admitted = loop {
                let Some(request) = self.queue.pop_front() else { break None };
                let queued = request.arrived.elapsed().as_secs_f64();
                if request.prompt.is_empty() {
                    events.push(self.finish_unstarted(request, queued, FinishReason::Error));
                    continue;
                }
                let bucket = match self.engine.pick_bucket(request.prompt.len()) {
                    Ok(b) => b,
                    Err(_) => {
                        // unservable prompt: fail this request, not the loop
                        let ev = self.finish_unstarted(request, queued, FinishReason::Error);
                        events.push(ev);
                        continue;
                    }
                };
                let ev = GenerationEvent::Admitted { id: request.id, queued_secs: queued };
                if !self.route(&ev) {
                    // client vanished while queued: skip the prefill entirely
                    let ev = self.finish_unstarted(request, queued, FinishReason::Cancelled);
                    events.push(ev);
                    continue;
                }
                events.push(ev);
                break Some((request, queued, bucket));
            };
            let Some((request, queued, bucket)) = admitted else { break };
            let mut padded = vec![0i32; bucket];
            padded[..request.prompt.len()].copy_from_slice(&request.prompt);
            let logits = self
                .engine
                .prefill_slot(slot, &padded, bucket, request.prompt.len())?;
            let logits_t = HostTensor::new(vec![1, logits.len()], logits);
            let mut rng = Rng::new(request.rng_seed());
            let first = request.sampler.sample(&logits_t, &mut rng)[0];
            self.metrics.queued_secs.add(queued);
            self.metrics.prefills += 1;
            let now = Instant::now();
            self.slots[slot] = Some(SlotState {
                decoder: self.tokenizer.as_ref().map(|t| DecodeStream::new(t.clone())),
                request,
                generated: Vec::new(),
                next_token: first,
                prefill_done: now,
                last_token_at: now,
                queued_secs: queued,
                itl: Vec::new(),
                rng,
            });
            self.push_token(slot, first, &mut events);
        }

        // -- decode burst --
        if self.live() > 0 {
            for _ in 0..self.config.decode_burst.max(1) {
                // tokens for all slots (idle slots feed token 0, ignored)
                let tokens: Vec<i32> = self
                    .slots
                    .iter()
                    .map(|s| s.as_ref().map_or(0, |st| st.next_token))
                    .collect();
                let logits = self.engine.decode(&tokens)?;
                self.metrics.decode_steps += 1;
                let v = logits.shape[1];
                for slot in 0..self.slots.len() {
                    let tok = {
                        let Some(st) = self.slots[slot].as_mut() else { continue };
                        let row = HostTensor::new(
                            vec![1, v],
                            logits.data[slot * v..(slot + 1) * v].to_vec(),
                        );
                        st.request.sampler.sample(&row, &mut st.rng)[0]
                    };
                    self.push_token(slot, tok, &mut events);
                }
                if self.live() == 0 {
                    break;
                }
            }
        }
        Ok(events)
    }

    /// Record one sampled token into `slot`: emit its `Token` event, then
    /// finish the slot if a terminal condition (or a dead sink) is hit.
    fn push_token(&mut self, slot: usize, tok: i32, events: &mut Vec<GenerationEvent>) {
        let (id, index, text_delta, finish) = {
            let st = self.slots[slot].as_mut().expect("push_token on empty slot");
            let now = Instant::now();
            if !st.generated.is_empty() {
                let gap = (now - st.last_token_at).as_secs_f64();
                st.itl.push(gap);
                self.metrics.itl_secs.add(gap);
            }
            st.last_token_at = now;
            st.generated.push(tok);
            st.next_token = tok;
            let text_delta = st.decoder.as_mut().map_or(String::new(), |d| d.push(tok));
            let index = st.generated.len() - 1;
            let finish = if st.request.eos == Some(tok) {
                Some(FinishReason::Eos)
            } else if st
                .request
                .stop
                .iter()
                .any(|s| !s.is_empty() && st.generated.ends_with(s))
            {
                Some(FinishReason::Stop)
            } else if st.generated.len() >= st.request.max_new_tokens
                || self.engine.lens[slot] as usize >= self.engine.cfg.max_seq - 1
            {
                Some(FinishReason::Length)
            } else {
                None
            };
            (st.request.id, index, text_delta, finish)
        };
        self.metrics.tokens_out += 1;
        let ev = GenerationEvent::Token { id, index, token: tok, text_delta };
        let client_alive = self.route(&ev);
        events.push(ev);
        if !client_alive {
            // nobody is reading: free the slot instead of decoding on
            events.push(self.finish_slot(slot, FinishReason::Cancelled));
        } else if let Some(reason) = finish {
            events.push(self.finish_slot(slot, reason));
        }
    }

    /// Terminate a live slot: release its KV, record metrics, route and
    /// return the `Finished` event.
    fn finish_slot(&mut self, slot: usize, reason: FinishReason) -> GenerationEvent {
        let st = self.slots[slot].take().expect("finish_slot on empty slot");
        let now = Instant::now();
        let result = RequestResult {
            id: st.request.id,
            itl_p50_secs: itl_p50(&st.itl),
            tokens: st.generated,
            finish_reason: reason,
            queued_secs: st.queued_secs,
            ttft_secs: (st.prefill_done - st.request.arrived).as_secs_f64(),
            e2e_secs: (now - st.request.arrived).as_secs_f64(),
        };
        self.metrics.record_completion(&result);
        self.engine.release_slot(slot);
        let ev = GenerationEvent::Finished { result };
        self.route(&ev);
        self.sinks.remove(&ev.id());
        ev
    }

    /// Terminate a request that never reached a slot (cancelled or
    /// unservable while queued).
    fn finish_unstarted(
        &mut self,
        request: Request,
        queued: f64,
        reason: FinishReason,
    ) -> GenerationEvent {
        let result = RequestResult {
            id: request.id,
            tokens: Vec::new(),
            finish_reason: reason,
            queued_secs: queued,
            ttft_secs: 0.0,
            itl_p50_secs: 0.0,
            e2e_secs: request.arrived.elapsed().as_secs_f64(),
        };
        self.metrics.record_completion(&result);
        let ev = GenerationEvent::Finished { result };
        self.route(&ev);
        self.sinks.remove(&ev.id());
        ev
    }

    /// Drive until the queue and all slots drain; returns all results.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestResult>> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            for ev in self.step()? {
                if let GenerationEvent::Finished { result } = ev {
                    out.push(result);
                }
            }
        }
        Ok(out)
    }
}
