//! Serving coordinator: request queue, continuous batcher, metrics and a
//! line-JSON TCP API — the vLLM-router-shaped stack around the TP engine.
//!
//! The engine↔server boundary is a typed per-request **event stream**
//! ([`GenerationEvent`]): the batcher emits `Admitted` / `Token` /
//! `Finished` / terminal `Error` events into per-request sinks, the wire
//! layer renders them as line-JSON frames (protocol v2, see
//! `docs/API.md`), and cancellation propagates back through
//! [`Batcher::cancel`].
//!
//! Above a single batcher sits the fault-tolerant multi-replica tier
//! ([`Router`]): N independent engine replicas (each its own batcher,
//! page pool and prefix tree) behind prefix-affinity routing with
//! load-based spillover, transparent pre-first-token retry, graceful
//! drain and crash-restart supervision (see `docs/ARCHITECTURE.md`,
//! "Router & fault tolerance").
//!
//! Threading: PJRT handles are not `Send`, so each engine loop owns its
//! thread; the TCP acceptor and per-connection readers are separate threads
//! that communicate through `std::sync::mpsc` channels of plain data.

pub mod api;
pub mod batcher;
pub mod fleet;
pub mod metrics;
pub mod request;
pub mod router;

pub use batcher::{Batcher, BatcherConfig};
pub use fleet::ReplicaSpec;
pub use metrics::ServerMetrics;
pub use request::{FinishReason, GenerationEvent, Request, RequestResult};
pub use router::{
    ReplicaFactory, ReplicaSlotConfig, Router, RouterConfig, RoutingPolicy, UpgradeBuilder,
};
