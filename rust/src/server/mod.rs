//! Serving coordinator: request queue, continuous batcher, metrics and a
//! line-JSON TCP API — the vLLM-router-shaped stack around the TP engine.
//!
//! The engine↔server boundary is a typed per-request **event stream**
//! ([`GenerationEvent`]): the batcher emits `Admitted` / `Token` /
//! `Finished` events into per-request sinks, the wire layer renders them as
//! line-JSON frames (protocol v2, see `docs/API.md`), and cancellation
//! propagates back through [`Batcher::cancel`].
//!
//! Threading: PJRT handles are not `Send`, so the engine loop owns its
//! thread; the TCP acceptor and per-connection readers are separate threads
//! that communicate through `std::sync::mpsc` channels of plain data.

pub mod api;
pub mod batcher;
pub mod metrics;
pub mod request;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::ServerMetrics;
pub use request::{FinishReason, GenerationEvent, Request, RequestResult};
