//! Serving coordinator: request queue, continuous batcher, metrics and a
//! line-JSON TCP API — the vLLM-router-shaped stack around the TP engine.
//!
//! Threading: PJRT handles are not `Send`, so the engine loop owns its
//! thread; the TCP acceptor and per-connection readers are separate threads
//! that communicate through `std::sync::mpsc` channels of plain data.

pub mod api;
pub mod batcher;
pub mod metrics;
pub mod request;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::ServerMetrics;
pub use request::{Request, RequestResult};
