//! Line-JSON TCP API — wire protocol v2 (see `docs/API.md`).
//!
//! One JSON object per line, both directions.
//!
//! Non-streaming request (v1-compatible, the default):
//!   -> {"prompt": "text", "max_new_tokens": 32}
//!   <- {"id": 1, "text": "...", "tokens": [...], "queued_ms": ..,
//!       "ttft_ms": .., "e2e_ms": ..}
//!
//! Streaming request (`"stream": true`) produces typed event frames:
//!   <- {"event":"admitted","id":1,"queued_ms":..}
//!   <- {"event":"token","id":1,"index":0,"token":42,"text_delta":"*"}
//!   <- ...
//!   <- {"event":"done","id":1,"finish_reason":"length","text":"...",
//!       "tokens":[...],"queued_ms":..,"ttft_ms":..,"itl_ms_p50":..,
//!       "e2e_ms":..}
//!
//! A request that fails before producing a result ends with a structured
//! error frame instead of `done` — `retryable` says whether resubmitting
//! the same request can succeed (fleet conditions: yes; malformed or
//! unservable requests: no):
//!   <- {"event":"error","id":1,"retryable":true,"reason":"..."}
//! (non-streaming requests get {"id":1,"error":"...","retryable":..}).
//!
//! Sampling is per-request (`temperature`, `top_k`, `seed`), decoding stops
//! on `stop` strings or the `eos` id, and `{"cancel": <id>}` aborts an
//! in-flight request (its stream ends with `finish_reason:"cancelled"`).
//!
//! The acceptor and connection readers run on their own threads; the engine
//! loop (PJRT is not Send) stays on the caller's thread and is driven by
//! [`serve_forever`], which routes [`GenerationEvent`]s back over
//! per-request channels. A per-request forwarder thread renders events into
//! frames; writes share one per-connection mutex so frames stay
//! line-atomic.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::batcher::Batcher;
use super::request::{GenerationEvent, Request, RequestResult};
use crate::engine::Sampler;
use crate::tokenizer::Tokenizer;
use crate::util::json::{parse, Json};

/// Default for how long a request may go without producing an event before
/// the wire layer gives up on it (the dropped channel then cancels it
/// engine-side). Override per listener with `--client-io-timeout-ms`.
pub const DEFAULT_CLIENT_IO_TIMEOUT: Duration = Duration::from_secs(300);

/// What the socket side hands the engine loop.
pub enum ApiJob {
    /// A new request plus the sink its events must be routed to.
    Submit { request: Request, respond: Sender<GenerationEvent> },
    /// Abort an in-flight or queued request.
    Cancel { id: u64 },
    /// `{"stats": true}` — snapshot the server metrics
    /// (throughput/latency percentiles, `kv_pages_in_use` /
    /// `kv_pages_high_water` / `admission_blocked`, and the prefix-cache
    /// counters `prefill_tokens` / `prefix_lookups` / `prefix_hits` /
    /// `prefix_hit_tokens` / `prefix_cached_pages` /
    /// `prefix_evicted_pages`; see docs/API.md).
    Stats { respond: Sender<crate::util::json::Json> },
    /// `{"snapshot": true}` — spill every cached prefix chain to the disk
    /// tier without evicting it, so a restarted engine pointed at the same
    /// `--kv-spill-dir` serves the cache warm. Replies
    /// `{"snapshot_files":.., "snapshot_bytes":..}`, or an `error` object
    /// when no spill dir (or no prefix cache) is configured (docs/API.md).
    Snapshot { respond: Sender<crate::util::json::Json> },
    /// `{"upgrade": ...}` — fleet-mode rolling upgrade: the spec names
    /// one replica config overlay per slot (or one for all). A single
    /// `serve` process (and a fleet booted without an upgrade builder)
    /// rejects the frame with an `error` reply; the fleet keeps serving
    /// either way (docs/API.md).
    Upgrade { spec: crate::util::json::Json, respond: Sender<crate::util::json::Json> },
}

/// Spawn the TCP acceptor with the default dead-client timeout; returns
/// the job channel the engine loop drains.
pub fn spawn_listener(addr: &str, tokenizer: Tokenizer) -> Result<(Receiver<ApiJob>, u16)> {
    spawn_listener_with(addr, tokenizer, DEFAULT_CLIENT_IO_TIMEOUT)
}

/// Spawn the TCP acceptor with an explicit per-connection io timeout: a
/// request stream (or stats round-trip) that produces no event for this
/// long is terminated with a retryable error frame and its channel dropped
/// so the engine reclaims the slot.
pub fn spawn_listener_with(
    addr: &str,
    tokenizer: Tokenizer,
    io_timeout: Duration,
) -> Result<(Receiver<ApiJob>, u16)> {
    let listener = TcpListener::bind(addr)?;
    let port = listener.local_addr()?.port();
    let (tx, rx) = channel::<ApiJob>();
    let tokenizer = Arc::new(tokenizer);
    std::thread::spawn(move || {
        let mut next_id: u64 = 1;
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            let tok = tokenizer.clone();
            let base_id = next_id;
            next_id += 1_000_000;
            std::thread::spawn(move || {
                let _ = handle_conn(stream, tx, tok, base_id, io_timeout);
            });
        }
    });
    Ok((rx, port))
}

/// Serialize one reply line under the connection's write lock. Returns
/// false when the client is gone.
fn write_line(writer: &Arc<Mutex<TcpStream>>, json: &Json) -> bool {
    let mut w = match writer.lock() {
        Ok(w) => w,
        Err(_) => return false,
    };
    w.write_all(json.to_string().as_bytes()).is_ok() && w.write_all(b"\n").is_ok()
}

fn handle_conn(
    stream: TcpStream,
    tx: Sender<ApiJob>,
    tok: Arc<Tokenizer>,
    base_id: u64,
    io_timeout: Duration,
) -> Result<()> {
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let reader = BufReader::new(stream);
    let mut local_id = 0u64;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let msg = match parse(&line) {
            Ok(msg) => msg,
            Err(e) => {
                write_line(&writer, &Json::obj().set("error", format!("bad json: {e}")));
                continue;
            }
        };
        if msg.opt("stats").is_some_and(|v| v.as_bool().unwrap_or(false)) {
            let (stx, srx) = channel();
            if tx.send(ApiJob::Stats { respond: stx }).is_err() {
                write_line(&writer, &Json::obj().set("error", "engine loop gone"));
                return Ok(());
            }
            let w = writer.clone();
            std::thread::spawn(move || match srx.recv_timeout(io_timeout) {
                Ok(stats) => {
                    write_line(&w, &stats);
                }
                // a wedged engine loop must not leave the client blocked
                // on a read forever
                Err(_) => {
                    write_line(&w, &Json::obj().set("error", "stats timeout"));
                }
            });
            continue;
        }
        if msg.opt("snapshot").is_some_and(|v| v.as_bool().unwrap_or(false)) {
            let (stx, srx) = channel();
            if tx.send(ApiJob::Snapshot { respond: stx }).is_err() {
                write_line(&writer, &Json::obj().set("error", "engine loop gone"));
                return Ok(());
            }
            // replied from its own thread, like stats: a long spill must
            // not block this connection's reader
            let w = writer.clone();
            std::thread::spawn(move || match srx.recv_timeout(io_timeout) {
                Ok(reply) => {
                    write_line(&w, &reply);
                }
                Err(_) => {
                    write_line(&w, &Json::obj().set("error", "snapshot timeout"));
                }
            });
            continue;
        }
        if let Some(spec) = msg.opt("upgrade") {
            let (utx, urx) = channel();
            let job = ApiJob::Upgrade { spec: spec.clone(), respond: utx };
            if tx.send(job).is_err() {
                write_line(&writer, &Json::obj().set("error", "engine loop gone"));
                return Ok(());
            }
            // replied from its own thread, like stats: the control loop's
            // acknowledgement must not block this connection's reader
            let w = writer.clone();
            std::thread::spawn(move || match urx.recv_timeout(io_timeout) {
                Ok(reply) => {
                    write_line(&w, &reply);
                }
                Err(_) => {
                    write_line(&w, &Json::obj().set("error", "upgrade timeout"));
                }
            });
            continue;
        }
        if let Some(cancel) = msg.opt("cancel") {
            match cancel.as_usize() {
                Ok(id) => {
                    if tx.send(ApiJob::Cancel { id: id as u64 }).is_err() {
                        write_line(&writer, &Json::obj().set("error", "engine loop gone"));
                        return Ok(());
                    }
                }
                Err(e) => {
                    write_line(&writer, &Json::obj().set("error", format!("bad cancel: {e}")));
                }
            }
            continue;
        }
        local_id += 1;
        let id = base_id + local_id;
        match build_request(&msg, &tok, id) {
            Ok((request, stream_mode)) => {
                let (etx, erx) = channel();
                if tx.send(ApiJob::Submit { request, respond: etx }).is_err() {
                    write_line(&writer, &Json::obj().set("error", "engine loop gone"));
                    return Ok(());
                }
                let w = writer.clone();
                let t = tok.clone();
                std::thread::spawn(move || {
                    forward_events(erx, w, t, id, stream_mode, io_timeout)
                });
            }
            Err(e) => {
                write_line(&writer, &Json::obj().set("error", e.to_string()));
            }
        }
    }
    Ok(())
}

/// Render one request's event stream onto the shared connection writer.
/// Streaming mode emits a frame per event; non-streaming mode stays silent
/// until `Finished` and then replies with the v1 single-object shape.
fn forward_events(
    erx: Receiver<GenerationEvent>,
    writer: Arc<Mutex<TcpStream>>,
    tok: Arc<Tokenizer>,
    id: u64,
    stream_mode: bool,
    io_timeout: Duration,
) {
    loop {
        match erx.recv_timeout(io_timeout) {
            Ok(GenerationEvent::Admitted { id, queued_secs }) => {
                if stream_mode {
                    let frame = Json::obj()
                        .set("event", "admitted")
                        .set("id", id)
                        .set("queued_ms", queued_secs * 1e3);
                    if !write_line(&writer, &frame) {
                        return; // client gone: dropping erx cancels engine-side
                    }
                }
            }
            Ok(GenerationEvent::Token { id, index, token, text_delta }) => {
                if stream_mode {
                    let frame = Json::obj()
                        .set("event", "token")
                        .set("id", id)
                        .set("index", index)
                        .set("token", token)
                        .set("text_delta", text_delta);
                    if !write_line(&writer, &frame) {
                        return;
                    }
                }
            }
            Ok(GenerationEvent::Finished { result }) => {
                let frame = if stream_mode {
                    render_done(&result, &tok)
                } else {
                    render_result(&result, &tok)
                };
                write_line(&writer, &frame);
                return;
            }
            Ok(GenerationEvent::Error { id, retryable, reason }) => {
                write_line(&writer, &render_error(id, retryable, &reason, stream_mode));
                return;
            }
            Err(RecvTimeoutError::Timeout) => {
                // tell the client, then drop erx so the batcher reclaims
                // the slot instead of decoding tokens nobody reads.
                // Retryable: the request itself was fine, the fleet (or
                // this connection) was too slow.
                write_line(&writer, &render_error(id, true, "timeout", stream_mode));
                return;
            }
            Err(RecvTimeoutError::Disconnected) => return, // engine loop gone
        }
    }
}

fn build_request(j: &Json, tok: &Tokenizer, id: u64) -> Result<(Request, bool)> {
    let prompt_text = j.get("prompt")?.as_str()?;
    let prompt = tok.encode(prompt_text);
    if prompt.is_empty() {
        anyhow::bail!("empty prompt");
    }
    let max_new = j.opt("max_new_tokens").map_or(Ok(16), |v| v.as_usize())?;
    let stream = j.opt("stream").map_or(Ok(false), |v| v.as_bool())?;
    let temperature = j.opt("temperature").map_or(Ok(0.0), |v| v.as_f64())?;
    let top_k = j.opt("top_k").map_or(Ok(0), |v| v.as_usize())?;
    let seed = j.opt("seed").map_or(Ok(id), |v| v.as_usize().map(|s| s as u64))?;
    let sampler = if temperature > 0.0 {
        Sampler::TopK { k: if top_k == 0 { 50 } else { top_k }, temperature, seed }
    } else {
        Sampler::Greedy
    };
    let stop: Vec<Vec<i32>> = match j.opt("stop") {
        Some(v) => v
            .as_arr()?
            .iter()
            .map(|s| Ok(tok.encode(s.as_str()?)))
            .collect::<Result<_>>()?,
        None => Vec::new(),
    };
    let eos = match j.opt("eos") {
        Some(Json::Null) => None,
        Some(v) => Some(v.as_usize()? as i32),
        None => tok.eos_id(),
    };
    let request = Request::new(id, prompt, max_new)
        .with_sampler(sampler)
        .with_stop(stop)
        .with_eos(eos);
    Ok((request, stream))
}

/// v1-compatible single-object reply (non-streaming requests): exactly the
/// key set protocol v1 used — byte-compatible for existing clients.
fn render_result(r: &RequestResult, tok: &Tokenizer) -> Json {
    Json::obj()
        .set("id", r.id)
        .set("text", tok.decode(&r.tokens))
        .set(
            "tokens",
            Json::Arr(r.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        )
        .set("queued_ms", r.queued_secs * 1e3)
        .set("ttft_ms", r.ttft_secs * 1e3)
        .set("e2e_ms", r.e2e_secs * 1e3)
}

/// Structured request-failure reply: a typed terminal `error` frame for
/// streaming requests, the flat v1-style error object otherwise. Both
/// carry `retryable` so clients know whether resubmitting can succeed.
fn render_error(id: u64, retryable: bool, reason: &str, stream_mode: bool) -> Json {
    if stream_mode {
        Json::obj()
            .set("event", "error")
            .set("id", id)
            .set("retryable", retryable)
            .set("reason", reason)
    } else {
        Json::obj().set("id", id).set("error", reason).set("retryable", retryable)
    }
}

/// Terminal frame of a streamed request.
fn render_done(r: &RequestResult, tok: &Tokenizer) -> Json {
    Json::obj()
        .set("event", "done")
        .set("id", r.id)
        .set("finish_reason", r.finish_reason.as_str())
        .set("text", tok.decode(&r.tokens))
        .set(
            "tokens",
            Json::Arr(r.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        )
        .set("queued_ms", r.queued_secs * 1e3)
        .set("ttft_ms", r.ttft_secs * 1e3)
        .set("itl_ms_p50", r.itl_p50_secs * 1e3)
        .set("e2e_ms", r.e2e_secs * 1e3)
}

/// Feed one socket-side job into the batcher; returns how many requests
/// reached a terminal state doing so. `started` anchors the wall clock the
/// stats snapshot's throughput is computed over.
fn apply_job(batcher: &mut Batcher, job: ApiJob, started: std::time::Instant) -> Result<usize> {
    match job {
        ApiJob::Submit { request, respond } => {
            batcher.submit_streaming(request, respond);
            Ok(0)
        }
        ApiJob::Cancel { id } => Ok(usize::from(batcher.cancel(id)?.is_some())),
        ApiJob::Stats { respond } => {
            // a dropped receiver (client gone) is fine — nothing to clean up
            let _ = respond.send(batcher.stats_report(started.elapsed().as_secs_f64()));
            Ok(0)
        }
        ApiJob::Snapshot { respond } => {
            let reply = match batcher.snapshot_cache() {
                Ok((files, bytes)) => Json::obj()
                    .set("snapshot_files", files)
                    .set("snapshot_bytes", bytes as usize),
                Err(e) => Json::obj().set("error", e.to_string()),
            };
            let _ = respond.send(reply);
            Ok(0)
        }
        ApiJob::Upgrade { respond, .. } => {
            // rolling upgrades are a fleet operation — a single batcher
            // has no slot set to wave over
            let _ = respond.send(
                Json::obj().set("error", "upgrade requires fleet mode (the router subcommand)"),
            );
            Ok(0)
        }
    }
}

/// Engine-thread serve loop: an event router. Drains socket jobs into the
/// batcher, steps it, and counts terminal events; the batcher itself routes
/// every event to its request's sink as it happens. Runs until
/// `max_requests` terminal events (0 = forever).
pub fn serve_forever(
    batcher: &mut Batcher,
    jobs: Receiver<ApiJob>,
    max_requests: usize,
) -> Result<()> {
    let mut served = 0usize;
    let started = std::time::Instant::now();
    loop {
        // admit everything currently queued on the socket side
        loop {
            match jobs.try_recv() {
                Ok(job) => served += apply_job(batcher, job, started)?,
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => return Ok(()),
            }
        }
        if batcher.pending() == 0 {
            // idle: block briefly for the next job
            match jobs.recv_timeout(Duration::from_millis(50)) {
                Ok(job) => served += apply_job(batcher, job, started)?,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return Ok(()),
            }
        }
        for ev in batcher.step()? {
            if ev.is_terminal() {
                served += 1;
            }
        }
        if max_requests > 0 && served >= max_requests {
            return Ok(());
        }
    }
}
