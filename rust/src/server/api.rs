//! Line-JSON TCP API.
//!
//! Protocol: one JSON object per line.
//!
//! request:  {"id": 1, "prompt": "text", "max_new_tokens": 32}
//! response: {"id": 1, "text": "...", "tokens": [...], "queued_ms": ..,
//!            "ttft_ms": .., "e2e_ms": ..}
//!
//! The acceptor and connection readers run on their own threads; the engine
//! loop (PJRT is not Send) stays on the caller's thread and is driven by
//! [`serve_forever`]. Responses are routed back over per-request channels.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use anyhow::Result;

use super::batcher::Batcher;
use super::request::{Request, RequestResult};
use crate::tokenizer::Tokenizer;
use crate::util::json::{parse, Json};

/// A request paired with its response channel.
pub struct ApiJob {
    pub request: Request,
    pub respond: Sender<RequestResult>,
}

/// Spawn the TCP acceptor; returns the job channel the engine loop drains.
pub fn spawn_listener(addr: &str, tokenizer: Tokenizer) -> Result<(Receiver<ApiJob>, u16)> {
    let listener = TcpListener::bind(addr)?;
    let port = listener.local_addr()?.port();
    let (tx, rx) = channel::<ApiJob>();
    std::thread::spawn(move || {
        let mut next_id: u64 = 1;
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            let tok = tokenizer.clone();
            let base_id = next_id;
            next_id += 1_000_000;
            std::thread::spawn(move || {
                let _ = handle_conn(stream, tx, tok, base_id);
            });
        }
    });
    Ok((rx, port))
}

fn handle_conn(stream: TcpStream, tx: Sender<ApiJob>, tok: Tokenizer, base_id: u64) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let mut local_id = 0u64;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match parse(&line) {
            Ok(req_json) => {
                local_id += 1;
                match build_request(&req_json, &tok, base_id + local_id) {
                    Ok(request) => {
                        let (rtx, rrx) = channel();
                        let id = request.id;
                        tx.send(ApiJob { request, respond: rtx })
                            .map_err(|_| anyhow::anyhow!("engine loop gone"))?;
                        match rrx.recv_timeout(Duration::from_secs(300)) {
                            Ok(result) => render_result(&result, &tok),
                            Err(_) => Json::obj().set("id", id).set("error", "timeout"),
                        }
                    }
                    Err(e) => Json::obj().set("error", e.to_string()),
                }
            }
            Err(e) => Json::obj().set("error", format!("bad json: {e}")),
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

fn build_request(j: &Json, tok: &Tokenizer, id: u64) -> Result<Request> {
    let prompt_text = j.get("prompt")?.as_str()?;
    let prompt = tok.encode(prompt_text);
    let max_new = j.opt("max_new_tokens").map_or(Ok(16), |v| v.as_usize())?;
    Ok(Request::new(id, prompt, max_new))
}

fn render_result(r: &RequestResult, tok: &Tokenizer) -> Json {
    Json::obj()
        .set("id", r.id)
        .set("text", tok.decode(&r.tokens))
        .set(
            "tokens",
            Json::Arr(r.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        )
        .set("queued_ms", r.queued_secs * 1e3)
        .set("ttft_ms", r.ttft_secs * 1e3)
        .set("e2e_ms", r.e2e_secs * 1e3)
}

/// Engine-thread serve loop: drain jobs into the batcher, step it, route
/// completions back. Runs until `max_requests` completions (0 = forever).
pub fn serve_forever(
    batcher: &mut Batcher,
    jobs: Receiver<ApiJob>,
    max_requests: usize,
) -> Result<()> {
    let mut pending: Vec<(u64, Sender<RequestResult>)> = Vec::new();
    let mut served = 0usize;
    loop {
        // admit everything currently queued on the socket side
        loop {
            match jobs.try_recv() {
                Ok(job) => {
                    pending.push((job.request.id, job.respond));
                    batcher.submit(job.request);
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => return Ok(()),
            }
        }
        if batcher.pending() == 0 {
            // idle: block briefly for the next job
            match jobs.recv_timeout(Duration::from_millis(50)) {
                Ok(job) => {
                    pending.push((job.request.id, job.respond));
                    batcher.submit(job.request);
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return Ok(()),
            }
        }
        for result in batcher.step()? {
            if let Some(pos) = pending.iter().position(|(id, _)| *id == result.id) {
                let (_, tx) = pending.swap_remove(pos);
                let _ = tx.send(result);
                served += 1;
                if max_requests > 0 && served >= max_requests {
                    return Ok(());
                }
            }
        }
    }
}
