//! Request / response / event types for the serving stack.
//!
//! The engine↔server boundary is a typed **event stream**: every request
//! produces `Admitted` → `Token`* → (`Finished` | `Error`), routed to its
//! submitter through a per-request sink (see [`crate::server::Batcher`]).
//! A terminal [`RequestResult`] still exists for batch-style callers,
//! carried inside the `Finished` event; requests that fail before
//! producing a usable stream terminate with [`GenerationEvent::Error`]
//! instead, which tells the client whether resubmission can succeed.

use std::time::Instant;

use crate::engine::Sampler;
use crate::util::stats::Summary;

/// An inference request as admitted to the queue.
///
/// `Clone` exists for the router tier: resubmitting a clone replays the
/// identical prompt / sampler / seed, so a retry that starts before the
/// first token was ever emitted reproduces the original stream bitwise
/// (see [`Request::rng_seed`]).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Per-request sampling strategy (greedy / seeded top-k).
    pub sampler: Sampler,
    /// Stop decoding at this token id (e.g. tokenizer EOS), if any.
    pub eos: Option<i32>,
    /// Stop token-sequences: generation finishes (reason `Stop`) as soon as
    /// the generated tail matches any one of them.
    pub stop: Vec<Vec<i32>>,
    pub arrived: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            sampler: Sampler::Greedy,
            eos: None,
            stop: Vec::new(),
            arrived: Instant::now(),
        }
    }

    pub fn with_sampler(mut self, sampler: Sampler) -> Request {
        self.sampler = sampler;
        self
    }

    pub fn with_eos(mut self, eos: Option<i32>) -> Request {
        self.eos = eos;
        self
    }

    pub fn with_stop(mut self, stop: Vec<Vec<i32>>) -> Request {
        self.stop = stop;
        self
    }

    /// Seed of this request's private sampling RNG stream. Seeding from the
    /// request — never from shared batcher state — makes sampled output
    /// reproducible regardless of how requests interleave in the batch.
    pub fn rng_seed(&self) -> u64 {
        match self.sampler {
            Sampler::TopK { seed, .. } => seed,
            Sampler::Greedy => self.id,
        }
    }
}

/// Why a request stopped generating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_new_tokens` (or the engine's KV capacity).
    Length,
    /// Sampled the request's EOS token.
    Eos,
    /// Generated tail matched one of the request's stop sequences.
    Stop,
    /// Cancelled mid-flight (explicit cancel, or the client went away).
    Cancelled,
    /// The request itself was unservable (e.g. prompt exceeds every bucket).
    Error,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Eos => "eos",
            FinishReason::Stop => "stop",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Error => "error",
        }
    }
}

/// One step of a request's life, as emitted by `Batcher::step` and routed
/// to the request's sink.
#[derive(Debug, Clone)]
pub enum GenerationEvent {
    /// The request left the queue and its prefill ran.
    Admitted { id: u64, queued_secs: f64 },
    /// One generated token. `index` counts from 0 and is strictly monotone
    /// per request; `text_delta` is the incremental detokenization (empty
    /// when the batcher has no tokenizer or the token ends mid-character).
    Token { id: u64, index: usize, token: i32, text_delta: String },
    /// Terminal: carries the full result (every request gets exactly one
    /// terminal event — `Finished` or `Error`, never both).
    Finished { result: RequestResult },
    /// Terminal: the request failed before producing a usable result
    /// (rejected at admission, bounced by a draining replica, or lost to a
    /// replica crash after its stream had started). `retryable` tells the
    /// client whether resubmitting the same request can succeed: admission
    /// rejections (duplicate id, empty prompt, unservable prompt) are
    /// permanent, fleet conditions (drain, crash, dispatch timeout) are
    /// not.
    Error { id: u64, retryable: bool, reason: String },
}

impl GenerationEvent {
    pub fn id(&self) -> u64 {
        match self {
            GenerationEvent::Admitted { id, .. } => *id,
            GenerationEvent::Token { id, .. } => *id,
            GenerationEvent::Finished { result } => result.id,
            GenerationEvent::Error { id, .. } => *id,
        }
    }

    /// Is this a stream-ending event? Exactly one per request.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            GenerationEvent::Finished { .. } | GenerationEvent::Error { .. }
        )
    }
}

/// Completion record with the latency breakdown the paper reports.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub finish_reason: FinishReason,
    /// Queue wait before prefill started.
    pub queued_secs: f64,
    /// Time to first token (arrival -> first logits sampled).
    pub ttft_secs: f64,
    /// Median inter-token latency (0.0 with fewer than two tokens).
    pub itl_p50_secs: f64,
    /// Total latency (arrival -> last token).
    pub e2e_secs: f64,
}

impl RequestResult {
    /// Decode-phase throughput: tokens after the first over the decode wall
    /// clock. Requests that never reached a second token have no decode
    /// phase and report 0.0.
    pub fn decode_tok_per_sec(&self) -> f64 {
        if self.tokens.len() <= 1 {
            return 0.0;
        }
        (self.tokens.len() - 1) as f64 / (self.e2e_secs - self.ttft_secs).max(1e-12)
    }
}

/// p50 of a request's inter-token gaps (helper shared by batcher + tests).
pub(crate) fn itl_p50(itl: &[f64]) -> f64 {
    if itl.is_empty() {
        return 0.0;
    }
    let mut s = Summary::new();
    for &x in itl {
        s.add(x);
    }
    s.p50()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(tokens: Vec<i32>, ttft: f64, e2e: f64) -> RequestResult {
        RequestResult {
            id: 1,
            tokens,
            finish_reason: FinishReason::Length,
            queued_secs: 0.0,
            ttft_secs: ttft,
            itl_p50_secs: 0.0,
            e2e_secs: e2e,
        }
    }

    #[test]
    fn decode_tok_per_sec_short_outputs() {
        // 0 or 1 token: no decode phase — must not divide by ~0 wall clock
        assert_eq!(result(vec![], 0.0, 0.0).decode_tok_per_sec(), 0.0);
        assert_eq!(result(vec![7], 0.1, 0.1).decode_tok_per_sec(), 0.0);
        // 3 tokens over 1s of decode: 2 decode tokens / 1s
        let r = result(vec![7, 8, 9], 0.5, 1.5);
        assert!((r.decode_tok_per_sec() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn rng_seed_is_per_request() {
        use crate::engine::Sampler;
        let a = Request::new(1, vec![1], 4);
        let b = Request::new(2, vec![1], 4);
        assert_ne!(a.rng_seed(), b.rng_seed());
        let s = Sampler::TopK { k: 4, temperature: 1.0, seed: 99 };
        assert_eq!(a.with_sampler(s).rng_seed(), 99);
    }

    #[test]
    fn itl_p50_empty_is_zero() {
        assert_eq!(itl_p50(&[]), 0.0);
        assert!((itl_p50(&[0.1, 0.3, 0.2]) - 0.2).abs() < 1e-12);
    }
}
