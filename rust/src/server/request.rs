//! Request / response types for the serving stack.

use std::time::Instant;

use crate::engine::Sampler;

/// An inference request as admitted to the queue.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
    /// Stop decoding at this token id (e.g. tokenizer EOS), if any.
    pub eos: Option<i32>,
    pub arrived: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            sampler: Sampler::Greedy,
            eos: None,
            arrived: Instant::now(),
        }
    }
}

/// Completion record with the latency breakdown the paper reports.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Queue wait before prefill started.
    pub queued_secs: f64,
    /// Time to first token (arrival -> first logits sampled).
    pub ttft_secs: f64,
    /// Total latency (arrival -> last token).
    pub e2e_secs: f64,
}

impl RequestResult {
    pub fn decode_tok_per_sec(&self) -> f64 {
        if self.tokens.len() <= 1 {
            return 0.0;
        }
        (self.tokens.len() - 1) as f64 / (self.e2e_secs - self.ttft_secs).max(1e-12)
    }
}
