//! Training driver for the quality-parity experiments (paper Tables 3/4/5).
//!
//! The python side AOT-exports one `train_<arch>` graph (fwd + bwd + AdamW,
//! all weights in a single flat f32 vector) and one `eval_<arch>` graph per
//! architecture; this module drives them from Rust over a synthetic corpus —
//! python never runs at experiment time.

pub mod data;
pub mod parity;
pub mod train_loop;

pub use data::Corpus;
pub use train_loop::{EvalMetrics, TrainRun, Trainer};
