//! Synthetic token corpus: a seeded sparse first-order Markov source.
//!
//! Substitution for the paper's FineWeb-edu 100B-token pretraining corpus
//! (DESIGN.md §1): what the parity experiments need is a *learnable*
//! distribution shared across architectures, so relative quality is
//! meaningful. A sparse weighted bigram chain gives exactly that: the model
//! can drive held-out perplexity from `vocab` down toward the source
//! entropy (~`branching` effective successors per token), and greedy
//! next-token accuracy has a clean ceiling (the top successor's weight).

use crate::util::rng::Rng;

/// Decaying successor weights: w_i ∝ 2^-i (top candidate ~53% for b=4).
fn weight(i: usize) -> f64 {
    0.5f64.powi(i as i32)
}

/// Seeded synthetic corpus over `vocab` tokens.
pub struct Corpus {
    pub vocab: usize,
    pub branching: usize,
    /// per prev-token: candidate successors (weight ∝ 2^-index)
    table: Vec<Vec<i32>>,
    rng: Rng,
}

impl Corpus {
    /// `branching`: candidate successors per token (smaller = more
    /// structure, lower achievable perplexity).
    ///
    /// The transition *table* comes from a fixed seed, so every corpus over
    /// the same (vocab, branching) describes the same language; `seed` only
    /// drives the sampling stream (train vs held-out splits).
    pub fn new(vocab: usize, branching: usize, seed: u64) -> Corpus {
        let mut table_rng = Rng::new(0xc0de_ba5e);
        let table = (0..vocab)
            .map(|_| (0..branching).map(|_| table_rng.below(vocab) as i32).collect())
            .collect();
        Corpus { vocab, branching, table, rng: Rng::new(seed) }
    }

    /// Candidate successors of `prev`, most likely first — the ground-truth
    /// table, used by examples/tests to score generated continuations.
    pub fn successors(&self, prev: i32) -> &[i32] {
        &self.table[prev as usize % self.vocab]
    }

    /// Sample one sequence of `len` tokens.
    pub fn sequence(&mut self, len: usize) -> Vec<i32> {
        let weights: Vec<f64> = (0..self.branching).map(weight).collect();
        let mut out = Vec::with_capacity(len);
        let mut prev = self.rng.below(self.vocab) as i32;
        for _ in 0..len {
            let cands = &self.table[prev as usize];
            let next = cands[self.rng.categorical(&weights)];
            out.push(next);
            prev = next;
        }
        out
    }

    /// A [batch, seq] token matrix, row-major.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            out.extend(self.sequence(seq));
        }
        out
    }

    /// Source cross-entropy in nats (the perplexity floor a perfect model
    /// reaches): H = -sum_i p_i ln p_i over the normalized 2^-i weights.
    pub fn entropy(&self) -> f64 {
        let total: f64 = (0..self.branching).map(weight).sum();
        -(0..self.branching)
            .map(|i| {
                let p = weight(i) / total;
                p * p.ln()
            })
            .sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::new(64, 4, 9);
        let mut b = Corpus::new(64, 4, 9);
        assert_eq!(a.sequence(50), b.sequence(50));
    }

    #[test]
    fn tokens_in_range() {
        let mut c = Corpus::new(100, 3, 1);
        assert!(c.batch(4, 32).iter().all(|&t| t >= 0 && t < 100));
    }

    #[test]
    fn transitions_follow_the_table() {
        let mut c = Corpus::new(64, 4, 2);
        let seq = c.sequence(2000);
        for w in seq.windows(2) {
            assert!(c.successors(w[0]).contains(&w[1]), "{:?}", w);
        }
    }

    #[test]
    fn top_successor_dominates() {
        // greedy ceiling: the top candidate carries ~53% of the mass
        let mut c = Corpus::new(64, 4, 3);
        let seq = c.sequence(20_000);
        let mut hits = 0usize;
        for w in seq.windows(2) {
            if c.successors(w[0])[0] == w[1] {
                hits += 1;
            }
        }
        let frac = hits as f64 / (seq.len() - 1) as f64;
        assert!(frac > 0.45 && frac < 0.62, "{frac}");
    }

    #[test]
    fn entropy_matches_weights() {
        let c = Corpus::new(64, 4, 0);
        // H(8/15,4/15,2/15,1/15) ≈ 1.137 nats => ppl floor ≈ 3.12
        assert!((c.entropy() - 1.137).abs() < 0.01, "{}", c.entropy());
    }
}
