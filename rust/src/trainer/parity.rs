//! The quality-parity experiment drivers (paper Tables 3, 4, 5 analogs).
//!
//! * [`pretrain_parity`] — train every architecture from the same seeded
//!   init on the same data stream; report held-out perplexity + probe
//!   accuracy (Table 3: standard vs parallel vs ladder; Table 5: desync).
//! * [`hybrid_adaptation`] — pretrain a standard model, evaluate it
//!   *zero-shot* under the hybrid-ladder computation flow (the paper's huge
//!   drop), then retrain briefly and report the recovery (Table 4).

use anyhow::Result;

use super::data::Corpus;
use super::train_loop::{EvalMetrics, TrainRun, Trainer};
use crate::runtime::Exec;
use crate::util::bench::Table;

const TRAIN_SEED: u64 = 11;
const EVAL_SEED: u64 = 1213;
const BRANCHING: usize = 4;

/// One architecture's parity row.
#[derive(Debug, Clone)]
pub struct ParityRow {
    pub arch: String,
    pub final_train_loss: f32,
    pub eval: EvalMetrics,
}

/// Train each architecture for `steps` from the shared init; equal data.
pub fn pretrain_parity(
    exec: &Exec,
    arches: &[&str],
    steps: usize,
    peak_lr: f32,
    eval_batches: usize,
) -> Result<Vec<ParityRow>> {
    let mut out = Vec::new();
    for &arch in arches {
        let mut trainer = Trainer::new(exec)?;
        let vocab = exec.cfg().vocab;
        let mut corpus = Corpus::new(vocab, BRANCHING, TRAIN_SEED);
        let run: TrainRun =
            trainer.run(arch, steps, peak_lr, &mut corpus, EVAL_SEED, eval_batches)?;
        let tail = &run.losses[run.losses.len().saturating_sub(5)..];
        out.push(ParityRow {
            arch: arch.to_string(),
            final_train_loss: tail.iter().sum::<f32>() / tail.len() as f32,
            eval: run.final_eval,
        });
    }
    Ok(out)
}

pub fn parity_table(title: &str, rows: &[ParityRow]) -> Table {
    let mut t = Table::new(title, &["Model", "Train loss", "Held-out PPL", "Probe acc (%)"]);
    for r in rows {
        t.row(&[
            r.arch.clone(),
            format!("{:.3}", r.final_train_loss),
            format!("{:.2}", r.eval.perplexity),
            format!("{:.1}", r.eval.accuracy * 100.0),
        ]);
    }
    t
}

/// Table 4 analog: zero-shot hybrid conversion + light retraining recovery.
#[derive(Debug, Clone)]
pub struct HybridReport {
    pub base: EvalMetrics,
    pub zeroshot: EvalMetrics,
    pub retrained: EvalMetrics,
    pub base_steps: usize,
    pub adapt_steps: usize,
}

pub fn hybrid_adaptation(
    exec: &Exec,
    base_steps: usize,
    adapt_steps: usize,
    peak_lr: f32,
    eval_batches: usize,
) -> Result<HybridReport> {
    let vocab = exec.cfg().vocab;

    // 1. pretrain the standard model
    let mut trainer = Trainer::new(exec)?;
    let mut corpus = Corpus::new(vocab, BRANCHING, TRAIN_SEED);
    trainer.run("standard", base_steps, peak_lr, &mut corpus, EVAL_SEED, eval_batches)?;
    let mut eval_corpus = Corpus::new(vocab, BRANCHING, EVAL_SEED);
    let base = trainer.eval("standard", &mut eval_corpus, eval_batches)?;

    // 2. zero-shot: same weights, hybrid-ladder computation flow
    let mut eval_corpus = Corpus::new(vocab, BRANCHING, EVAL_SEED);
    let zeroshot = trainer.eval("hybrid", &mut eval_corpus, eval_batches)?;

    // 3. light retraining under the hybrid flow (fresh optimizer state,
    //    lower LR — the paper's 3B-token SFT analog)
    trainer.m.fill(0.0);
    trainer.v.fill(0.0);
    trainer.step = 0;
    let mut adapt_corpus = Corpus::new(vocab, BRANCHING, TRAIN_SEED + 1);
    let warmup_lr = peak_lr * 0.3;
    for s in 0..adapt_steps {
        let lr = if s < adapt_steps / 5 + 1 {
            warmup_lr * (s + 1) as f32 / (adapt_steps / 5 + 1) as f32
        } else {
            warmup_lr
        };
        let tokens = adapt_corpus.batch(trainer.train_batch, trainer.train_seq);
        trainer.train_step("hybrid", lr, &tokens)?;
    }
    let mut eval_corpus = Corpus::new(vocab, BRANCHING, EVAL_SEED);
    let retrained = trainer.eval("hybrid", &mut eval_corpus, eval_batches)?;

    Ok(HybridReport { base, zeroshot, retrained, base_steps, adapt_steps })
}

pub fn hybrid_table(r: &HybridReport) -> Table {
    let mut t = Table::new(
        "Table 4 analog: hybrid Ladder conversion of a pretrained standard model",
        &["Model", "Held-out PPL", "Probe acc (%)"],
    );
    let row = |name: &str, e: &EvalMetrics| {
        [name.to_string(), format!("{:.2}", e.perplexity), format!("{:.1}", e.accuracy * 100.0)]
    };
    t.row(&row("standard (pretrained)", &r.base));
    t.row(&row("hybrid-ladder zeroshot", &r.zeroshot));
    t.row(&row("hybrid-ladder retrained", &r.retrained));
    t
}
