//! The train/eval loop over the AOT-compiled flat-vector graphs.
//!
//! Training executes the exported `train_<arch>` / `eval_<arch>` graphs
//! (forward + backward + AdamW in one module), which only the xla backend
//! provides — the native backend rejects them with a pointer to
//! `--features xla`. The driver itself is backend-agnostic: it talks to
//! [`Exec`] in host values only.

use anyhow::{anyhow, Result};

use super::data::Corpus;
use crate::runtime::{Exec, Value};

/// Held-out evaluation metrics.
#[derive(Debug, Clone, Copy)]
pub struct EvalMetrics {
    pub loss: f64,
    pub perplexity: f64,
    /// Greedy next-token accuracy (the probe-task analog of the paper's
    /// benchmark accuracies).
    pub accuracy: f64,
}

/// Recorded history of one training run.
#[derive(Debug, Clone)]
pub struct TrainRun {
    pub arch: String,
    pub losses: Vec<f32>,
    pub final_eval: EvalMetrics,
}

/// Drives `train_<arch>` / `eval_<arch>` graphs for the parity config.
pub struct Trainer<'a> {
    exec: &'a Exec,
    pub train_batch: usize,
    pub train_seq: usize,
    pub eval_batch: usize,
    pub eval_seq: usize,
    /// Flat parameter vector and AdamW state.
    pub w: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: i32,
}

impl<'a> Trainer<'a> {
    /// Initialize from the artifact manifest's seeded `init_weights` vector.
    /// Needs an artifact directory even on the native backend (the init
    /// vector and training params live in the manifest).
    pub fn new(exec: &'a Exec) -> Result<Trainer<'a>> {
        let art = exec.artifacts()?;
        let man = &art.manifest;
        let tr = man.get("training")?;
        let w = art.read_f32(tr.get("init_weights")?.as_str()?)?;
        let n = art.packing()?.get("total")?.as_usize()?;
        if w.len() != n {
            return Err(anyhow!("init weights: {} elems, packing wants {n}", w.len()));
        }
        Ok(Trainer {
            exec,
            train_batch: tr.get("train_batch")?.as_usize()?,
            train_seq: tr.get("train_seq")?.as_usize()?,
            eval_batch: tr.get("eval_batch")?.as_usize()?,
            eval_seq: tr.get("eval_seq")?.as_usize()?,
            m: vec![0.0; w.len()],
            v: vec![0.0; w.len()],
            w,
            step: 0,
        })
    }

    /// Reset parameters to a fresh copy (for running several arches from
    /// the same seed point).
    pub fn reset(&mut self) -> Result<()> {
        let art = self.exec.artifacts()?;
        let tr = art.manifest.get("training")?;
        self.w = art.read_f32(tr.get("init_weights")?.as_str()?)?;
        self.m.fill(0.0);
        self.v.fill(0.0);
        self.step = 0;
        Ok(())
    }

    /// One AdamW step; returns the batch loss.
    pub fn train_step(&mut self, arch: &str, lr: f32, tokens: &[i32]) -> Result<f32> {
        let n = self.w.len();
        let args: Vec<Value> = vec![
            self.exec.upload_f32(&self.w, &[n])?,
            self.exec.upload_f32(&self.m, &[n])?,
            self.exec.upload_f32(&self.v, &[n])?,
            self.exec.upload_i32(&[self.step], &[])?,
            self.exec.upload_f32(&[lr], &[])?,
            self.exec.upload_i32(tokens, &[self.train_batch, self.train_seq])?,
        ];
        let arg_refs: Vec<&Value> = args.iter().collect();
        let outs = self.exec.run(&format!("train_{arch}"), &arg_refs)?;
        if outs.len() < 4 {
            return Err(anyhow!("train_{arch}: expected 4 outputs, got {}", outs.len()));
        }
        let loss = outs[0].to_f32_vec()?[0];
        self.w = outs[1].to_f32_vec()?;
        self.m = outs[2].to_f32_vec()?;
        self.v = outs[3].to_f32_vec()?;
        self.step += 1;
        Ok(loss)
    }

    /// Evaluate current weights on held-out batches.
    pub fn eval(&self, arch: &str, corpus: &mut Corpus, batches: usize) -> Result<EvalMetrics> {
        let n = self.w.len();
        let mut loss_sum = 0.0f64;
        let mut hits = 0i64;
        let n_pred_per_batch = self.eval_batch * (self.eval_seq - 1);
        for _ in 0..batches {
            let tokens = corpus.batch(self.eval_batch, self.eval_seq);
            let args: Vec<Value> = vec![
                self.exec.upload_f32(&self.w, &[n])?,
                self.exec.upload_i32(&tokens, &[self.eval_batch, self.eval_seq])?,
            ];
            let arg_refs: Vec<&Value> = args.iter().collect();
            let outs = self.exec.run(&format!("eval_{arch}"), &arg_refs)?;
            loss_sum += outs[0].to_f32_vec()?[0] as f64;
            hits += outs[1].to_i32_vec()?[0] as i64;
        }
        let n_pred = (batches * n_pred_per_batch) as f64;
        let loss = loss_sum / n_pred;
        Ok(EvalMetrics {
            loss,
            perplexity: loss.exp(),
            accuracy: hits as f64 / n_pred,
        })
    }

    /// Full run: cosine LR schedule with warmup, loss logged each step.
    pub fn run(
        &mut self,
        arch: &str,
        steps: usize,
        peak_lr: f32,
        corpus: &mut Corpus,
        eval_corpus_seed: u64,
        eval_batches: usize,
    ) -> Result<TrainRun> {
        let warmup = (steps / 10).max(1);
        let mut losses = Vec::with_capacity(steps);
        for s in 0..steps {
            let lr = if s < warmup {
                peak_lr * (s + 1) as f32 / warmup as f32
            } else {
                let t = (s - warmup) as f32 / (steps - warmup).max(1) as f32;
                let floor = peak_lr * 0.1;
                floor + 0.5 * (peak_lr - floor) * (1.0 + (std::f32::consts::PI * t).cos())
            };
            let tokens = corpus.batch(self.train_batch, self.train_seq);
            losses.push(self.train_step(arch, lr, &tokens)?);
        }
        // fresh seeded held-out stream: identical across architectures
        let mut eval_corpus = Corpus::new(corpus.vocab, corpus.branching, eval_corpus_seed);
        let final_eval = self.eval(arch, &mut eval_corpus, eval_batches)?;
        Ok(TrainRun { arch: arch.to_string(), losses, final_eval })
    }
}
