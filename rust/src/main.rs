//! ladder-infer CLI — the launcher.
//!
//! Subcommands:
//!   generate  one-shot batched generation on an artifact config
//!   serve     boot the line-JSON TCP serving API (continuous batching)
//!   router    fault-tolerant multi-replica serving tier (N engines behind
//!             a prefix-affinity router with drain/crash-restart)
//!   tables    regenerate the paper's tables/figures from the perf model
//!   train     run the quality-parity training experiments
//!   snapshot  ask a running serve process to spill its prefix cache to disk
//!   restore   offline audit of a --kv-spill-dir against an engine geometry
//!
//! Example:
//!   ladder-infer serve --model small --arch ladder --tp 2 --port 8771
//!   echo '{"prompt":"hello","max_new_tokens":8}' | nc -q1 localhost 8771

use std::rc::Rc;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use ladder_infer::comm::{Codec, Interconnect};
use ladder_infer::engine::{generate, KvLayout, OverlapMode, RuntimeKind, Sampler, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::perfmodel::tables;
use ladder_infer::runtime::{BackendKind, Exec};
use ladder_infer::server::{
    api, router, Batcher, BatcherConfig, ReplicaFactory, ReplicaSlotConfig, ReplicaSpec, Router,
    RouterConfig, RoutingPolicy,
};
use ladder_infer::tokenizer::Tokenizer;
use ladder_infer::trainer::parity;
use ladder_infer::util::args::Args;
use ladder_infer::util::json::Json;

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    match cmd.as_str() {
        "generate" => cmd_generate(argv),
        "serve" => cmd_serve(argv),
        "router" => cmd_router(argv),
        "tables" => cmd_tables(argv),
        "train" => cmd_train(argv),
        "snapshot" => cmd_snapshot(argv),
        "restore" => cmd_restore(argv),
        _ => {
            println!(
                "ladder-infer — Ladder-Residual TP inference framework\n\n\
                 usage: ladder-infer <generate|serve|router|tables|train|snapshot|restore> \
                 [options]\n\
                 run any subcommand with --help for its options.\n\n\
                 see also: cargo run --release --example <quickstart|serve_e2e|\
                 train_parity|adapt_hybrid|paper_tables>"
            );
            Ok(())
        }
    }
}

fn engine_args(program: &str, about: &str) -> Args {
    Args::new(program, about)
        .opt("model", Some("tiny"), "model config (tiny|small|parity, or any exported artifact)")
        .opt("arch", Some("ladder"), "standard|ladder|parallel|desync2|desync4|upperbound|hybrid")
        .opt("tp", Some("2"), "tensor-parallel degree")
        .opt("batch", Some("2"), "batch slots")
        .opt(
            "fabric",
            Some("pcie"),
            "nvlink|pcie|infiniband|local|slow|custom:<lat_us>:<gbps>|\
             two_tier:<intra>:<cross>:<gpus_per_node>",
        )
        .opt("codec", Some("fp32"), "collective wire codec: fp32|int8|int4 (quantized allreduce)")
        .opt("runtime", Some("threaded"), "rank runtime: threaded|sequential (oracle)")
        .opt(
            "overlap",
            Some("none"),
            "split-batch overlap: none|split2|split4 (chunked forwards, bitwise-exact)",
        )
        .opt(
            "backend",
            Some("native"),
            "execution backend: native|xla (xla: --features xla + make artifacts)",
        )
        .opt(
            "seed",
            Some("42"),
            "weight seed (tiny prefers shipped test weights when artifacts exist)",
        )
        .opt(
            "page-size",
            Some("0"),
            "KV page size in tokens (0 = legacy fixed-slot slabs; >0 = paged KV pool)",
        )
        .opt(
            "kv-budget-mb",
            Some("0"),
            "KV admission budget in MiB (0 = storage capacity is the only limit)",
        )
        .opt(
            "kv-spill-dir",
            Some(""),
            "disk tier for the prefix cache: evicted chains spill here and are \
             restored on later misses (empty = no tier; needs --prefix-cache)",
        )
        .opt(
            "kv-spill-budget-mb",
            Some("0"),
            "byte budget for the spill dir in MiB; oldest files are deleted to \
             stay under it (0 = unlimited)",
        )
}

/// KV layout from the shared flags: `--page-size 0` keeps the fixed-slot
/// slabs; a positive page size builds a paged pool sized from
/// `--kv-budget-mb` by [`KvLayout::paged_from_budget`].
fn kv_layout(args: &Args, cfg: &ladder_infer::model::LlamaConfig) -> anyhow::Result<KvLayout> {
    let page_size = args.get_usize("page-size")?;
    if page_size == 0 {
        return Ok(KvLayout::Slab);
    }
    Ok(KvLayout::paged_from_budget(
        cfg,
        args.get_usize("tp")?,
        page_size,
        args.get_usize("kv-budget-mb")? << 20,
        args.get_usize("batch")?,
    ))
}

fn build_engine(args: &Args) -> Result<(TpEngine, Tokenizer)> {
    let model = args.get("model")?;
    let backend = BackendKind::parse(&args.get("backend")?)?;
    let exec = Rc::new(Exec::open(&model, backend)?);
    let cfg = exec.cfg().clone();
    // deterministic weights: the tiny config uses the shipped test vector
    // when an artifact dir is present (a broken artifact dir is an error,
    // not a silent fall back to different weights); everything else — and
    // the artifact-free native path — gets a seeded random init
    let weights = match (model.as_str(), exec.artifacts_opt()) {
        ("tiny", Some(art)) => {
            let flat = art.read_f32("testvec_weights.f32")?;
            WeightStore::from_flat(&flat, art.packing()?, cfg.layers)?
        }
        _ => WeightStore::random(&cfg, args.get_usize("seed")? as u64),
    };
    let engine = TpEngine::with_overlap(
        exec,
        &weights,
        args.get_usize("tp")?,
        Arch::parse(&args.get("arch")?)?,
        args.get_usize("batch")?,
        Interconnect::parse(&args.get("fabric")?)?,
        RuntimeKind::parse(&args.get("runtime")?)?,
        kv_layout(args, &cfg)?,
        Codec::parse(&args.get("codec")?)?,
        OverlapMode::parse(&args.get("overlap")?)?,
    )?;
    let tok = Tokenizer::bytes_only(cfg.vocab);
    Ok((engine, tok))
}

fn cmd_generate(argv: Vec<String>) -> Result<()> {
    let args = engine_args("ladder-infer generate", "one-shot batched generation")
        .opt("prompt", Some("hello world"), "prompt text (repeated per slot)")
        .opt("gen", Some("16"), "tokens to generate")
        .opt("temperature", Some("0"), "sampling temperature (0 = greedy)")
        .opt("top-k", Some("40"), "top-k cutoff when sampling")
        .opt("sample-seed", Some("7"), "sampling RNG seed")
        .parse(argv)?;
    let (mut engine, tok) = build_engine(&args)?;
    let prompt = tok.encode(&args.get("prompt")?);
    let prompts = vec![prompt; engine.batch];
    let temperature = args.get_f64("temperature")?;
    let sampler = if temperature > 0.0 {
        Sampler::TopK {
            k: args.get_usize("top-k")?,
            temperature,
            seed: args.get_usize("sample-seed")? as u64,
        }
    } else {
        Sampler::Greedy
    };
    let report = generate::generate(&mut engine, &prompts, args.get_usize("gen")?, &sampler)?;
    for (i, t) in report.tokens.iter().enumerate() {
        println!("slot {i}: {:?}", tok.decode(t));
    }
    println!(
        "[{} / {}] prefill {:.1}ms, decode {:.1}ms, {:.1} tok/s, comm hidden {:.0}%",
        report.runtime,
        engine.backend_name(),
        report.prefill_time.as_secs_f64() * 1e3,
        report.decode_time.as_secs_f64() * 1e3,
        report.tokens_per_sec(),
        report.comm.hidden_fraction() * 100.0
    );
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let args = engine_args("ladder-infer serve", "line-JSON TCP serving API (protocol v2)")
        .opt("port", Some("8771"), "listen port (0 = ephemeral)")
        .opt("max-requests", Some("0"), "stop after N completions (0 = forever)")
        .opt("decode-burst", Some("1"), "decode steps per scheduler iteration")
        .opt(
            "prefill-chunk",
            Some("32"),
            "paged engines: prompt tokens prefilled per scheduler iteration (0 = whole prompt)",
        )
        .flag(
            "prefix-cache",
            "paged engines: reuse KV pages across requests sharing a prompt prefix \
             (radix-tree cache; bitwise-exact)",
        )
        .opt(
            "client-io-timeout-ms",
            Some("300000"),
            "terminate a request whose client produces/consumes no event for this long",
        )
        .parse(argv)?;
    let (engine, tok) = build_engine(&args)?;
    let backend = engine.backend_name();
    if args.has_flag("prefix-cache") && !engine.kv_layout().is_paged() {
        anyhow::bail!("--prefix-cache needs a paged KV layout (set --page-size > 0)");
    }
    if !args.get("kv-spill-dir")?.is_empty() && !args.has_flag("prefix-cache") {
        anyhow::bail!("--kv-spill-dir needs --prefix-cache (the tier persists evicted chains)");
    }
    let config = BatcherConfig {
        decode_burst: args.get_usize("decode-burst")?,
        kv_budget_bytes: args.get_usize("kv-budget-mb")? * (1 << 20),
        prefill_chunk: args.get_usize("prefill-chunk")?,
        prefix_cache: args.has_flag("prefix-cache"),
        kv_spill_dir: args.get("kv-spill-dir")?,
        kv_spill_budget_bytes: args.get_usize("kv-spill-budget-mb")? << 20,
    };
    let mut batcher = Batcher::with_tokenizer(engine, config, tok.clone());
    let addr = format!("127.0.0.1:{}", args.get_usize("port")?);
    let io_timeout = Duration::from_millis(args.get_usize("client-io-timeout-ms")? as u64);
    let (jobs, port) = api::spawn_listener_with(&addr, tok, io_timeout)?;
    println!(
        "serving {} [{}] tp={} runtime={} codec={} backend={backend} on 127.0.0.1:{port} — \
         line-JSON protocol v2 (docs/API.md): set \"stream\":true for per-token \
         frames, {{\"cancel\":id}} to abort",
        args.get("model")?,
        args.get("arch")?,
        args.get_usize("tp")?,
        args.get("runtime")?,
        args.get("codec")?
    );
    api::serve_forever(&mut batcher, jobs, args.get_usize("max-requests")?)
}

fn cmd_router(argv: Vec<String>) -> Result<()> {
    let args = engine_args(
        "ladder-infer router",
        "fault-tolerant multi-replica serving tier (prefix-affinity routing)",
    )
    .opt("port", Some("8771"), "listen port (0 = ephemeral)")
    .opt("max-requests", Some("0"), "stop after N terminal events (0 = forever)")
    .opt("decode-burst", Some("1"), "decode steps per scheduler iteration, per replica")
    .opt(
        "prefill-chunk",
        Some("32"),
        "paged engines: prompt tokens prefilled per scheduler iteration (0 = whole prompt)",
    )
    .flag(
        "prefix-cache",
        "paged engines: per-replica radix-tree prefix cache (what affinity routing feeds)",
    )
    .opt("replicas", Some("2"), "independent engine replicas behind the router")
    .multi(
        "replica",
        "per-slot config overlay (repeatable): key=value[,key=value..] over the base engine \
         flags, e.g. arch=ladder,tp=2,page-size=8 — slot i takes the i-th spec",
    )
    .opt("policy", Some("affinity"), "routing policy: affinity|round-robin")
    .opt(
        "spill-threshold",
        Some("8"),
        "outstanding requests at the affinity target before spilling to the least loaded",
    )
    .opt("max-retries", Some("3"), "resubmissions after a replica loss (pre-first-token only)")
    .opt("retry-backoff-ms", Some("10"), "base redispatch backoff (attempt k waits k times this)")
    .opt(
        "dispatch-timeout-ms",
        Some("30000"),
        "fail a request (retryable error event) undispatchable for this long",
    )
    .opt(
        "client-io-timeout-ms",
        Some("300000"),
        "terminate a request whose client produces/consumes no event for this long",
    )
    .flag("no-auto-restart", "leave crashed replicas down instead of respawning them")
    .parse(argv)?;
    // probe the model once for the wire tokenizer; each replica thread
    // opens its own exec (engine handles are not Send)
    let model = args.get("model")?;
    let backend = BackendKind::parse(&args.get("backend")?)?;
    let cfg = Exec::open(&model, backend)?.cfg().clone();
    let tok = Tokenizer::bytes_only(cfg.vocab);
    let page_size = args.get_usize("page-size")?;
    // per-slot overlays: slot i takes the i-th --replica spec; slots past
    // the spec list (and the whole fleet when none are given) run the base
    let specs: Vec<ReplicaSpec> = args
        .get_multi("replica")
        .iter()
        .map(|s| ReplicaSpec::parse(s))
        .collect::<Result<Vec<_>>>()?;
    let replicas = args.get_usize("replicas")?.max(specs.len()).max(1);
    let mut slots = Vec::with_capacity(replicas);
    for i in 0..replicas {
        let spec = specs.get(i).cloned().unwrap_or_default();
        slots.push(replica_slot(&args, &spec, &model, backend, &tok)?);
    }
    let policy = match args.get("policy")?.as_str() {
        "affinity" => RoutingPolicy::Affinity,
        "round-robin" | "rr" => RoutingPolicy::RoundRobin,
        p => anyhow::bail!("unknown policy {p:?} (affinity|round-robin)"),
    };
    let router_config = RouterConfig {
        replicas,
        policy,
        // affinity key = the first KV page of the *base* config, the unit
        // the prefix cache shares; slab engines fall back to 16 tokens
        affinity_tokens: if page_size > 0 { page_size } else { 16 },
        spill_threshold: args.get_usize("spill-threshold")?,
        max_retries: args.get_usize("max-retries")?,
        retry_backoff: Duration::from_millis(args.get_usize("retry-backoff-ms")? as u64),
        dispatch_timeout: Duration::from_millis(args.get_usize("dispatch-timeout-ms")? as u64),
        auto_restart: !args.has_flag("no-auto-restart"),
    };
    let r = Router::new_fleet(slots, router_config)?;
    let addr = format!("127.0.0.1:{}", args.get_usize("port")?);
    let io_timeout = Duration::from_millis(args.get_usize("client-io-timeout-ms")? as u64);
    let (jobs, port) = api::spawn_listener_with(&addr, tok.clone(), io_timeout)?;
    println!(
        "routing {replicas} replicas of {model} [base {}] policy={} on 127.0.0.1:{port} — \
         line-JSON protocol v2 (docs/API.md); {{\"stats\":true}} returns per-replica config, \
         {{\"upgrade\":...}} rolls the fleet onto a new one",
        args.get("arch")?,
        args.get("policy")?
    );
    // wire upgrades resolve through the same overlay grammar as --replica:
    // {"all": spec} or a bare spec applies one overlay fleet-wide,
    // {"replicas": [spec, ...]} gives each slot its own
    let build_upgrade = |upgrade_spec: &Json| -> Result<Vec<ReplicaSlotConfig>> {
        let per_slot: Vec<ReplicaSpec> = if let Some(list) = upgrade_spec.opt("replicas") {
            list.as_arr()?
                .iter()
                .map(ReplicaSpec::from_json)
                .collect::<Result<Vec<_>>>()?
        } else {
            let spec = match upgrade_spec.opt("all") {
                Some(v) => ReplicaSpec::from_json(v)?,
                None => ReplicaSpec::from_json(upgrade_spec)?,
            };
            vec![spec; replicas]
        };
        anyhow::ensure!(
            per_slot.len() == replicas,
            "upgrade lists {} replica specs but the fleet has {replicas}",
            per_slot.len()
        );
        per_slot
            .iter()
            .map(|sp| replica_slot(&args, sp, &model, backend, &tok))
            .collect()
    };
    router::route_forever(&r, jobs, args.get_usize("max-requests")?, Some(&build_upgrade))
}

/// Resolve one replica's recipe — the `--replica`-style overlay `spec`
/// over the fleet-wide base flags — into a [`ReplicaSlotConfig`]: a
/// factory the router (re)spawns the slot from, plus the stats-visible
/// config description. Model, backend and seed stay fleet-wide so every
/// replica tokenizes and samples bitwise identically.
fn replica_slot(
    args: &Args,
    spec: &ReplicaSpec,
    model: &str,
    backend: BackendKind,
    tok: &Tokenizer,
) -> Result<ReplicaSlotConfig> {
    let s = |key: &str| -> Result<String> {
        match spec.get(key) {
            Some(v) => Ok(v.to_string()),
            None => args.get(key),
        }
    };
    let n = |key: &str| -> Result<usize> {
        let v = s(key)?;
        v.parse().map_err(|e| anyhow::anyhow!("replica spec {key}={v}: {e}"))
    };
    let arch = Arch::parse(&s("arch")?)?;
    let tp = n("tp")?;
    let batch = n("batch")?;
    let fabric = s("fabric")?;
    let codec = Codec::parse(&s("codec")?)?;
    let runtime = RuntimeKind::parse(&s("runtime")?)?;
    let overlap = OverlapMode::parse(&s("overlap")?)?;
    let page_size = n("page-size")?;
    let kv_budget = n("kv-budget-mb")? << 20;
    let prefix_cache = match spec.get("prefix-cache") {
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("replica spec prefix-cache={v}: expected true|false"))?,
        None => args.has_flag("prefix-cache"),
    };
    if prefix_cache && page_size == 0 {
        anyhow::bail!("prefix-cache needs a paged KV layout (set page-size > 0)");
    }
    // a fleet may point several replicas at one spill dir: writes are
    // tmp+rename atomic, files are content-keyed and checksummed, and a
    // file deleted under a peer's index degrades to a cold-prefill miss
    let batcher_config = BatcherConfig {
        decode_burst: n("decode-burst")?,
        kv_budget_bytes: kv_budget,
        prefill_chunk: n("prefill-chunk")?,
        prefix_cache,
        kv_spill_dir: s("kv-spill-dir")?,
        kv_spill_budget_bytes: n("kv-spill-budget-mb")? << 20,
    };
    let seed = args.get_usize("seed")? as u64;
    let desc = Json::obj()
        .set("arch", arch.name())
        .set("tp", tp)
        .set("batch", batch)
        .set("fabric", fabric.as_str())
        .set("codec", codec.name())
        .set("runtime", runtime.name())
        .set("overlap", overlap.name())
        .set("page_size", page_size)
        .set("prefix_cache", prefix_cache);
    let model = model.to_string();
    let tok = tok.clone();
    let factory: ReplicaFactory = Arc::new(move || {
        let exec = Rc::new(Exec::open(&model, backend)?);
        let cfg = exec.cfg().clone();
        // same weight-selection rule as `build_engine`: every replica
        // (and every respawn) is bitwise the same model
        let weights = match (model.as_str(), exec.artifacts_opt()) {
            ("tiny", Some(art)) => {
                let flat = art.read_f32("testvec_weights.f32")?;
                WeightStore::from_flat(&flat, art.packing()?, cfg.layers)?
            }
            _ => WeightStore::random(&cfg, seed),
        };
        let layout = if page_size == 0 {
            KvLayout::Slab
        } else {
            KvLayout::paged_from_budget(&cfg, tp, page_size, kv_budget, batch)
        };
        let engine = TpEngine::with_overlap(
            exec,
            &weights,
            tp,
            arch,
            batch,
            Interconnect::parse(&fabric)?,
            runtime,
            layout,
            codec,
            overlap,
        )?;
        Ok(Batcher::with_tokenizer(engine, batcher_config.clone(), tok.clone()))
    });
    Ok(ReplicaSlotConfig::with_desc(factory, desc))
}

/// Ask a running `serve` process to spill its cached prefix chains to its
/// disk tier ({"snapshot":true} over the line-JSON socket) and print the
/// server's reply — `{"snapshot_files":..,"snapshot_bytes":..}` on
/// success, an error object when the server has no tier configured.
fn cmd_snapshot(argv: Vec<String>) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let args = Args::new(
        "ladder-infer snapshot",
        "spill a running server's prefix cache to its disk tier",
    )
    .opt("host", Some("127.0.0.1"), "serve host to contact")
    .opt("port", Some("8771"), "serve port to contact")
    .parse(argv)?;
    let addr = format!("{}:{}", args.get("host")?, args.get_usize("port")?);
    let mut stream = std::net::TcpStream::connect(&addr)?;
    stream.write_all(b"{\"snapshot\": true}\n")?;
    let mut line = String::new();
    BufReader::new(stream.try_clone()?).read_line(&mut line)?;
    anyhow::ensure!(!line.trim().is_empty(), "server closed the connection without a reply");
    println!("{}", line.trim_end());
    Ok(())
}

/// Offline spill-dir audit: open the disk tier against this engine
/// geometry's fingerprint, re-verify every chain file (checksum, header,
/// token key) and delete the broken ones — exactly what a warm restart
/// would do lazily, done eagerly with a report.
fn cmd_restore(argv: Vec<String>) -> Result<()> {
    let args = engine_args(
        "ladder-infer restore",
        "offline spill-dir audit: validate every chain file against this engine geometry",
    )
    .parse(argv)?;
    let dir = args.get("kv-spill-dir")?;
    anyhow::ensure!(!dir.is_empty(), "restore needs --kv-spill-dir");
    let (engine, _tok) = build_engine(&args)?;
    anyhow::ensure!(
        engine.kv_layout().is_paged(),
        "restore needs a paged KV layout (set --page-size > 0)"
    );
    let mut store = ladder_infer::engine::SpillStore::open(
        std::path::Path::new(&dir),
        0, // audit never budget-evicts
        engine.kv_fingerprint(),
    )?;
    let (kept, dropped) = store.validate_all()?;
    let report = Json::obj()
        .set("dir", dir)
        .set("kept", kept)
        .set("dropped", dropped)
        .set("files", store.files())
        .set("bytes", store.total_bytes() as usize);
    println!("{}", report.to_pretty());
    Ok(())
}

fn cmd_tables(argv: Vec<String>) -> Result<()> {
    let args = Args::new("ladder-infer tables", "regenerate paper tables/figures")
        .opt("only", Some(""), "comma list: table1,table2,fig2,fig3,fig4,table6,codec,overlap")
        .parse(argv)?;
    let only = args.get("only")?;
    let want = |n: &str| only.is_empty() || only.split(',').any(|s| s == n);
    if want("table1") {
        tables::table1().print();
    }
    if want("table2") {
        tables::table2().print();
    }
    if want("fig2") {
        for t in tables::fig2() {
            t.print();
        }
    }
    if want("fig3") {
        tables::fig3().print();
    }
    if want("fig4") {
        tables::fig4().print();
    }
    if want("table6") {
        tables::table6().print();
    }
    if want("codec") {
        tables::codec_compound().print();
    }
    if want("overlap") {
        tables::overlap_compound().print();
    }
    Ok(())
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let args = Args::new("ladder-infer train", "quality-parity training experiments")
        .opt("arches", Some("standard,ladder"), "comma list of architectures")
        .opt("steps", Some("100"), "training steps")
        .opt("lr", Some("0.0015"), "peak learning rate")
        .opt("backend", Some("xla"), "training graphs need the xla backend (--features xla)")
        .parse(argv)?;
    let exec = Exec::open("parity", BackendKind::parse(&args.get("backend")?)?)?;
    let arches: Vec<String> = args.get("arches")?.split(',').map(str::to_string).collect();
    let refs: Vec<&str> = arches.iter().map(String::as_str).collect();
    let rows = parity::pretrain_parity(
        &exec,
        &refs,
        args.get_usize("steps")?,
        args.get_f64("lr")? as f32,
        8,
    )?;
    parity::parity_table("pretraining parity", &rows).print();
    Ok(())
}
