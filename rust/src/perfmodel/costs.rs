//! Roofline cost model: per-module execution times for a paper-scale model
//! under TP sharding.
//!
//! time(module) = max(flops / gpu.flops, bytes / gpu.mem_bw) + launch
//!
//! Prefill is compute-bound (large GEMMs over S=1024 tokens); decode is
//! memory-bound (weights + KV cache streamed per token) — the regimes the
//! paper's Table 2 prefill/decode split reflects.

use super::hardware::{GpuSpec, ELEM_BYTES};
use crate::comm::{Codec, Interconnect};
use crate::model::PaperModel;

/// Execution times (seconds) for one layer's modules on one rank.
#[derive(Debug, Clone, Copy, Default)]
pub struct ModuleTimes {
    pub attn: f64,
    pub mlp: f64,
    /// Fused attention+MLP (Parallel architecture).
    pub fused: f64,
    /// AllReduce of one [B,S,H] message.
    pub allreduce: f64,
    /// embed + final norm + lm head (+ its AllGather), per forward.
    pub edges: f64,
}

/// Cost model for one (model, gpu, tp, fabric) setting.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub model: PaperModel,
    pub gpu: GpuSpec,
    pub tp: usize,
    pub interconnect: Interconnect,
    /// Cross-node hop (e.g. TP16 across 2 nodes via InfiniBand): the
    /// AllReduce additionally traverses this fabric with the full message.
    pub cross_node: Option<(Interconnect, usize)>,
    /// Collective wire codec: AllReduce messages are charged their encoded
    /// size (`comm/codec.rs`) instead of the raw `ELEM_BYTES` payload.
    pub codec: Codec,
}

impl CostModel {
    pub fn new(
        model: PaperModel,
        gpu: GpuSpec,
        tp: usize,
        interconnect: Interconnect,
    ) -> CostModel {
        CostModel { model, gpu, tp, interconnect, cross_node: None, codec: Codec::default() }
    }

    pub fn with_cross_node(mut self, fabric: Interconnect, nodes: usize) -> CostModel {
        self.cross_node = Some((fabric, nodes));
        self
    }

    pub fn with_codec(mut self, codec: Codec) -> CostModel {
        self.codec = codec;
        self
    }

    fn roofline(&self, flops: f64, bytes: f64) -> f64 {
        (flops / self.gpu.flops).max(bytes / self.gpu.mem_bw) + self.gpu.launch_overhead
    }

    /// AllReduce time for a [B, S, H] activation message. The wire size is
    /// the codec's encoding of a `numel`-element, `ELEM_BYTES`-wide message
    /// (fp32 passthrough charges the raw bf16 payload, exactly the
    /// pre-codec model; int8/int4 charge the quantized payload + per-block
    /// scales).
    pub fn allreduce(&self, batch: usize, seq: usize) -> f64 {
        let numel = batch * seq * self.model.hidden;
        let bytes = self.codec.wire_bytes_for(numel, ELEM_BYTES as usize);
        // a two-tier interconnect already decomposes hierarchically
        // (reduce-scatter intra -> allreduce cross -> allgather intra), so
        // it is charged whole; the legacy `cross_node` split stays for the
        // paper tables that predate `two_tier:` fabrics
        if self.interconnect.two_tier.is_some() {
            return self.interconnect.allreduce_time(bytes, self.tp);
        }
        let intra_ranks = match self.cross_node {
            Some((_, nodes)) => self.tp / nodes,
            None => self.tp,
        };
        let mut t = self.interconnect.allreduce_time(bytes, intra_ranks);
        if let Some((fabric, nodes)) = self.cross_node {
            t += fabric.allreduce_time(bytes, nodes);
        }
        t
    }

    /// Module times for the prefill phase (S = prompt length).
    pub fn prefill(&self, batch: usize, seq: usize) -> ModuleTimes {
        let m = &self.model;
        let t = self.tp as f64;
        let (b, s) = (batch as f64, seq as f64);
        let h = m.hidden as f64;
        let (qd, kvd) = (m.q_dim() as f64, m.kv_dim() as f64);
        let hd = m.head_dim() as f64;
        let heads_l = m.heads as f64 / t;
        let f = m.ffn as f64;

        // projections + attention scores/values (causal halves the matrix)
        let attn_flops = 2.0 * b * s * h * (qd + 2.0 * kvd) / t
            + 2.0 * b * s * qd / t * h
            + 2.0 * heads_l * b * s * s * hd;
        let attn_bytes = (h * (qd + 2.0 * kvd) + qd * h) / t * ELEM_BYTES;
        let mlp_flops = 6.0 * b * s * h * f / t;
        let mlp_bytes = 3.0 * h * f / t * ELEM_BYTES;

        let attn = self.roofline(attn_flops, attn_bytes);
        let mlp = self.roofline(mlp_flops, mlp_bytes);
        ModuleTimes {
            attn,
            mlp,
            // fusion saves one dispatch, not FLOPs
            fused: attn + mlp - self.gpu.launch_overhead,
            allreduce: self.allreduce(batch, seq),
            edges: self.edges(batch, seq),
        }
    }

    /// Module times for one decode step at context length `ctx`.
    pub fn decode(&self, batch: usize, ctx: usize) -> ModuleTimes {
        let m = &self.model;
        let t = self.tp as f64;
        let b = batch as f64;
        let h = m.hidden as f64;
        let (qd, kvd) = (m.q_dim() as f64, m.kv_dim() as f64);
        let hd = m.head_dim() as f64;
        let heads_l = m.heads as f64 / t;
        let kv_heads_l = m.kv_heads as f64 / t;
        let f = m.ffn as f64;
        let l = ctx as f64;

        let attn_flops =
            2.0 * b * (h * (qd + 2.0 * kvd) / t + qd / t * h) + 4.0 * b * heads_l * l * hd;
        // decode reads the weight shard + this batch's KV cache
        let attn_bytes = (h * (qd + 2.0 * kvd) + qd * h) / t * ELEM_BYTES
            + b * 2.0 * kv_heads_l * l * hd * ELEM_BYTES;
        let mlp_flops = 6.0 * b * h * f / t;
        let mlp_bytes = 3.0 * h * f / t * ELEM_BYTES;

        let attn = self.roofline(attn_flops, attn_bytes);
        let mlp = self.roofline(mlp_flops, mlp_bytes);
        ModuleTimes {
            attn,
            mlp,
            fused: attn + mlp - self.gpu.launch_overhead,
            allreduce: self.allreduce(batch, 1),
            edges: self.edges(batch, 1),
        }
    }

    /// Embedding + final norm + LM head (incl. its vocab AllGather).
    fn edges(&self, batch: usize, seq: usize) -> f64 {
        let m = &self.model;
        let t = self.tp as f64;
        let (b, _s) = (batch as f64, seq as f64);
        let h = m.hidden as f64;
        let v = m.vocab as f64;
        // lm head on last position only
        let lm_flops = 2.0 * b * h * v / t;
        let lm_bytes = h * v / t * ELEM_BYTES;
        let gather_bytes = (b * v / t) * ELEM_BYTES;
        self.roofline(lm_flops, lm_bytes)
            + self.interconnect.allgather_time(gather_bytes as usize, self.tp)
    }

    /// Fraction of a standard-architecture decode step spent in (exposed)
    /// communication — the paper's "38% of latency" style headline number.
    pub fn comm_fraction_decode(&self, batch: usize, ctx: usize) -> f64 {
        let mt = self.decode(batch, ctx);
        let layers = self.model.layers as f64;
        let comm = layers * 2.0 * mt.allreduce;
        let compute = layers * (mt.attn + mt.mlp) + mt.edges;
        comm / (comm + compute)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Fabric, Interconnect};
    use crate::model::PaperModel;
    use crate::perfmodel::hardware::H100;

    fn m70b() -> PaperModel {
        *PaperModel::by_name("70B").unwrap()
    }

    #[test]
    fn prefill_compute_bound_decode_memory_bound() {
        let cm = CostModel::new(m70b(), H100, 8, Interconnect::new(Fabric::NvLink));
        let p = cm.prefill(4, 1024);
        let d = cm.decode(4, 1024);
        // prefill per-layer compute far exceeds a decode step's
        assert!(p.attn + p.mlp > 10.0 * (d.attn + d.mlp));
        // decode attn time should be dominated by bytes, i.e. larger than
        // the pure-flops time
        let flops_only = 2.0e0 * 4.0 * (8192.0 * (8192.0 + 2.0 * 1024.0) / 8.0) / H100.flops;
        assert!(d.attn > flops_only);
    }

    #[test]
    fn comm_fraction_70b_matches_paper_ballpark() {
        // paper: ~30-38% of inference latency is AllReduce for 70B TP8 bs4
        // with NVLink enabled
        let cm = CostModel::new(m70b(), H100, 8, Interconnect::new(Fabric::NvLink));
        let frac = cm.comm_fraction_decode(4, 1024);
        assert!(frac > 0.2 && frac < 0.5, "comm fraction {frac}");
    }

    #[test]
    fn no_nvlink_increases_comm_fraction() {
        let nv = CostModel::new(m70b(), H100, 8, Interconnect::new(Fabric::NvLink));
        let pcie = CostModel::new(m70b(), H100, 8, Interconnect::new(Fabric::Pcie));
        assert!(
            pcie.comm_fraction_decode(4, 1024) > nv.comm_fraction_decode(4, 1024) + 0.1
        );
    }

    #[test]
    fn tp_scaling_reduces_compute_time() {
        let cm2 = CostModel::new(m70b(), H100, 2, Interconnect::new(Fabric::NvLink));
        let cm8 = CostModel::new(m70b(), H100, 8, Interconnect::new(Fabric::NvLink));
        assert!(cm8.decode(4, 1024).mlp < cm2.decode(4, 1024).mlp);
    }

    #[test]
    fn codec_shrinks_allreduce_time() {
        let base = CostModel::new(m70b(), H100, 8, Interconnect::new(Fabric::NvLink));
        for (b, s) in [(4usize, 1usize), (4, 1024)] {
            let fp32 = base.allreduce(b, s);
            let int8 = base.with_codec(Codec::Int8).allreduce(b, s);
            let int4 = base.with_codec(Codec::Int4).allreduce(b, s);
            assert!(int8 < fp32, "int8 {int8} !< fp32 {fp32}");
            assert!(int4 < int8, "int4 {int4} !< int8 {int8}");
        }
        // fp32 codec is exactly the pre-codec cost
        assert_eq!(base.with_codec(Codec::Fp32).allreduce(4, 1), base.allreduce(4, 1));
    }

    #[test]
    fn cross_node_adds_cost() {
        let m = *PaperModel::by_name("405B").unwrap();
        let local = CostModel::new(m, H100, 16, Interconnect::new(Fabric::NvLink));
        let cross = CostModel::new(m, H100, 16, Interconnect::new(Fabric::NvLink))
            .with_cross_node(Interconnect::new(Fabric::InfiniBand), 2);
        assert!(cross.allreduce(4, 1) > local.allreduce(4, 1));
    }

    #[test]
    fn two_tier_cost_sits_between_flat_fabrics() {
        let m = *PaperModel::by_name("405B").unwrap();
        let nv = CostModel::new(m, H100, 16, Interconnect::new(Fabric::NvLink));
        let ib = CostModel::new(m, H100, 16, Interconnect::new(Fabric::InfiniBand));
        let two = CostModel::new(
            m,
            H100,
            16,
            Interconnect::new(Fabric::NvLink).with_two_tier(Fabric::InfiniBand, 8),
        );
        for (b, s) in [(4usize, 1usize), (4, 1024)] {
            let t = two.allreduce(b, s);
            assert!(t > nv.allreduce(b, s), "hierarchical should cost more than flat NVLink");
            assert!(t < ib.allreduce(b, s), "hierarchical should beat a flat cross fabric");
        }
    }
}
