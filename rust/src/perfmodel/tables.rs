//! Generators for every table and figure in the paper's evaluation section.
//!
//! Each function returns a [`Table`] whose rows mirror the paper's layout;
//! the bench binaries and `examples/paper_tables.rs` print them and compare
//! against the published numbers in EXPERIMENTS.md.

use crate::comm::{Codec, Fabric, Interconnect};
use crate::model::{Arch, PaperModel, PAPER_MODELS};
use crate::perfmodel::costs::CostModel;
use crate::perfmodel::hardware::H100;
use crate::perfmodel::timeline::{simulate_generation, simulate_generation_overlap, GenTimes};
use crate::util::bench::Table;

const PROMPT: usize = 1024;
const GEN: usize = 512;

fn cost_model(m: &PaperModel, tp: usize, fabric: Fabric) -> CostModel {
    let cm = CostModel::new(*m, H100, tp, Interconnect::new(fabric));
    if tp > 8 {
        // >8 GPUs spans nodes (8 per node), traversed via InfiniBand
        cm.with_cross_node(Interconnect::new(Fabric::InfiniBand), tp / 8)
    } else {
        cm
    }
}

fn gen(arch: Arch, m: &PaperModel, tp: usize, fabric: Fabric, batch: usize) -> GenTimes {
    simulate_generation(arch, &cost_model(m, tp, fabric), batch, PROMPT, GEN)
}

/// Table 1: Ladder vs Standard inference speedup across model sizes,
/// batch 4, TP8 (TP16 for 405B), with and without NVLink.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1: Ladder speedup vs Standard (prompt 1024, gen 512, bs 4)",
        &["Model size", "With NVLink", "No NVLink"],
    );
    for m in PAPER_MODELS {
        let tp = if m.name == "405B" { 16 } else { 8 };
        let row = |fabric: Fabric| {
            let std = gen(Arch::Standard, m, tp, fabric, 4);
            let lad = gen(Arch::Ladder, m, tp, fabric, 4);
            format!("{:.2}x", lad.tok_per_sec() / std.tok_per_sec())
        };
        t.row(&[m.name.to_string(), row(Fabric::NvLink), row(Fabric::Pcie)]);
    }
    t
}

/// Codec compounding study (ROADMAP "compressed collectives"): end-to-end
/// 70B TP8 bs4 generation time per (fabric, arch, collective codec).
/// Ladder *hides* AllReduce latency architecturally while int8/int4
/// quantization *shrinks* it — the two effects compound, so the
/// ladder+int8 cell must undercut both ladder+fp32 and standard+int8
/// (gated by `tests/codec_divergence.rs`).
pub fn codec_compound() -> Table {
    let mut t = Table::new(
        "Codec compounding: 70B TP8 bs4 e2e seconds (prompt 1024, gen 512)",
        &["Fabric", "Arch", "fp32", "int8", "int4", "int8 speedup"],
    );
    let m = PaperModel::by_name("70B").unwrap();
    let arches =
        [Arch::Standard, Arch::Parallel, Arch::Desync(2), Arch::Ladder, Arch::Upperbound];
    for fabric in [Fabric::NvLink, Fabric::Pcie] {
        for arch in arches {
            let e2e = |codec: Codec| {
                let cm = cost_model(m, 8, fabric).with_codec(codec);
                simulate_generation(arch, &cm, 4, PROMPT, GEN).total()
            };
            let (fp32, int8, int4) = (e2e(Codec::Fp32), e2e(Codec::Int8), e2e(Codec::Int4));
            t.row(&[
                Interconnect::new(fabric).name(),
                arch.name(),
                format!("{fp32:.3}s"),
                format!("{int8:.3}s"),
                format!("{int4:.3}s"),
                format!("{:.2}x", fp32 / int8),
            ]);
        }
    }
    t
}

/// Overlap compounding (ladder vs TokenWeave-style split-batch overlap,
/// head to head): 405B TP16 bs16 on hierarchical two-tier fabrics, prefill
/// latency and mean decode-step latency per (topology, arch, overlap mode).
///
/// Splitting each forward's batch into pipelined chunks lets even the
/// Standard architecture hide AllReduce time behind sibling chunks'
/// compute — which narrows the prefill gap to Ladder substantially. Decode
/// is different: a decode step's compute is weight-streaming-bound, so
/// every chunk re-streams the full weight shard and splitting buys nothing,
/// while Ladder still hides the reduce architecturally. The real-engine
/// analogue of this table is gated by `tests/overlap_wallclock.rs`.
pub fn overlap_compound() -> Table {
    let mut t = Table::new(
        "Overlap compounding: 405B TP16 bs16 (prompt 1024, gen 512) — prefill s / decode ms per step",
        &["Topology", "Arch", "pf none", "pf split2", "pf split4", "pf gain", "dec none", "dec split4"],
    );
    let m = PaperModel::by_name("405B").unwrap();
    let arches =
        [Arch::Standard, Arch::Parallel, Arch::Desync(2), Arch::Ladder, Arch::Upperbound];
    let fabrics = [
        Interconnect::new(Fabric::NvLink).with_two_tier(Fabric::InfiniBand, 8),
        Interconnect::new(Fabric::Pcie).with_two_tier(Fabric::InfiniBand, 8),
    ];
    for ic in fabrics {
        for arch in arches {
            let run = |chunks: usize| {
                let cm = CostModel::new(*m, H100, 16, ic);
                simulate_generation_overlap(arch, &cm, 16, PROMPT, GEN, chunks)
            };
            let (none, s2, s4) = (run(1), run(2), run(4));
            t.row(&[
                ic.name(),
                arch.name(),
                format!("{:.3}s", none.prefill),
                format!("{:.3}s", s2.prefill),
                format!("{:.3}s", s4.prefill),
                format!("{:.2}x", none.prefill / s4.prefill),
                format!("{:.2}ms", none.decode_latency() * 1e3),
                format!("{:.2}ms", s4.decode_latency() * 1e3),
            ]);
        }
    }
    t
}

/// Table 2: 70B bs=1 TP8 latency-optimized breakdown — prefill / decode /
/// token-per-sec improvement (%) over Standard, per arch and fabric.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2: 70B breakdown, bs 1, TP 8 (improvement % over Standard)",
        &["Model", "Prefill Impr (%)", "Decode Impr (%)", "Tok/s Impr (%)"],
    );
    let m = PaperModel::by_name("70B").unwrap();
    for (fabric, tag) in [(Fabric::NvLink, "NVLINK"), (Fabric::Pcie, "NO-NVLINK")] {
        let std = gen(Arch::Standard, m, 8, fabric, 1);
        for (arch, name) in [
            (Arch::Upperbound, "UpperBound"),
            (Arch::Parallel, "Parallel"),
            (Arch::Ladder, "Ladder"),
        ] {
            let g = gen(arch, m, 8, fabric, 1);
            t.row(&[
                format!("{tag}-{name}-Llama-70B"),
                format!("{:.2}", (1.0 - g.prefill / std.prefill) * 100.0),
                format!("{:.2}", (1.0 - g.decode_latency() / std.decode_latency()) * 100.0),
                format!("{:.2}", (g.tok_per_sec() / std.tok_per_sec() - 1.0) * 100.0),
            ]);
        }
    }
    t
}

/// Figure 2: 70B throughput improvement over Standard for TP in {1,2,4,8} x
/// batch in {1,4,16,64}, per fabric. Returns one table per fabric.
pub fn fig2() -> Vec<Table> {
    let m = PaperModel::by_name("70B").unwrap();
    let mut out = Vec::new();
    for (fabric, tag) in [(Fabric::NvLink, "NVLink"), (Fabric::Pcie, "No NVLink")] {
        let mut t = Table::new(
            &format!("Figure 2 ({tag}): 70B throughput improvement vs Standard"),
            &["TP", "batch", "Ladder", "Parallel", "UpperBound"],
        );
        for tp in [1usize, 2, 4, 8] {
            for bs in [1usize, 4, 16, 64] {
                let std = gen(Arch::Standard, m, tp, fabric, bs);
                let f = |a: Arch| {
                    let g = gen(a, m, tp, fabric, bs);
                    format!("{:+.1}%", (g.tok_per_sec() / std.tok_per_sec() - 1.0) * 100.0)
                };
                t.row(&[
                    tp.to_string(),
                    bs.to_string(),
                    f(Arch::Ladder),
                    f(Arch::Parallel),
                    f(Arch::Upperbound),
                ]);
            }
        }
        out.push(t);
    }
    out
}

/// Figure 3: 405B TP16 across two nodes (InfiniBand between nodes),
/// throughput improvement by batch size, intra-node NVLink on/off.
pub fn fig3() -> Table {
    let m = PaperModel::by_name("405B").unwrap();
    let mut t = Table::new(
        "Figure 3: 405B cross-node TP16 throughput improvement vs Standard",
        &["Fabric (intra-node)", "batch", "Ladder", "UpperBound"],
    );
    for (fabric, tag) in [(Fabric::NvLink, "NVLink"), (Fabric::Pcie, "No NVLink")] {
        for bs in [1usize, 4, 16, 64] {
            let std = gen(Arch::Standard, m, 16, fabric, bs);
            let f = |a: Arch| {
                let g = gen(a, m, 16, fabric, bs);
                format!("{:+.1}%", (g.tok_per_sec() / std.tok_per_sec() - 1.0) * 100.0)
            };
            t.row(&[tag.to_string(), bs.to_string(), f(Arch::Ladder), f(Arch::Upperbound)]);
        }
    }
    t
}

/// Figure 4: Pareto frontier of completion latency vs throughput/GPU for
/// 70B over arch x TP x batch (NVLink).
pub fn fig4() -> Table {
    let m = PaperModel::by_name("70B").unwrap();
    let mut points: Vec<(String, f64, f64)> = Vec::new(); // (label, latency, thpt/gpu)
    for arch in [Arch::Standard, Arch::Parallel, Arch::Ladder] {
        for tp in [1usize, 2, 4, 8] {
            for bs in [1usize, 4, 16, 64] {
                let g = gen(arch, m, tp, Fabric::NvLink, bs);
                let latency = g.total();
                let thpt_per_gpu = g.tok_per_sec() / tp as f64;
                points.push((format!("{}-tp{tp}-bs{bs}", arch.name()), latency, thpt_per_gpu));
            }
        }
    }
    // pareto-optimal: no other point has both lower latency and higher thpt
    let pareto: Vec<_> = points
        .iter()
        .filter(|(_, l, th)| {
            !points
                .iter()
                .any(|(_, l2, th2)| *l2 < *l && *th2 > *th)
        })
        .collect();
    let mut t = Table::new(
        "Figure 4: 70B Pareto frontier (completion latency vs tokens/s/GPU, NVLink)",
        &["Config", "Latency (s)", "Tok/s per GPU", "Pareto"],
    );
    let mut sorted = points.clone();
    sorted.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (label, l, th) in &sorted {
        let is_pareto = pareto.iter().any(|(pl, _, _)| pl == label);
        if is_pareto {
            t.row(&[
                label.clone(),
                format!("{l:.2}"),
                format!("{th:.1}"),
                "*".to_string(),
            ]);
        }
    }
    t
}

/// Count how many Pareto-frontier points each architecture owns (the
/// paper's claim: ladder dominates the frontier).
pub fn fig4_pareto_counts() -> Vec<(String, usize)> {
    let t = fig4();
    let mut counts = vec![
        ("standard".to_string(), 0),
        ("parallel".to_string(), 0),
        ("ladder".to_string(), 0),
    ];
    for row in table_rows(&t) {
        for (name, c) in counts.iter_mut() {
            if row.starts_with(name.as_str()) {
                *c += 1;
            }
        }
    }
    counts
}

fn table_rows(t: &Table) -> Vec<String> {
    t.to_markdown()
        .lines()
        .skip(4)
        .map(|l| l.trim_start_matches("| ").to_string())
        .collect()
}

/// Table 6: 8B bs=64 TP8 breakdown incl. Desync (improvement % vs Standard).
pub fn table6() -> Table {
    let mut t = Table::new(
        "Table 6: 8B breakdown, bs 64, TP 8 (improvement % over Standard)",
        &["Model", "Prefill Impr (%)", "Decode Impr (%)", "Tok/s Impr (%)"],
    );
    let m = PaperModel::by_name("8B").unwrap();
    for (fabric, tag) in [(Fabric::NvLink, "NVLINK"), (Fabric::Pcie, "NO-NVLINK")] {
        let std = gen(Arch::Standard, m, 8, fabric, 64);
        for (arch, name) in [
            (Arch::Upperbound, "UpperBound"),
            (Arch::Ladder, "Ladder"),
            (Arch::Desync(2), "Desync-Residual-2x"),
            (Arch::Desync(4), "Desync-Residual-4x"),
        ] {
            let g = gen(arch, m, 8, fabric, 64);
            t.row(&[
                format!("{tag}-{name}-Llama-8B"),
                format!("{:.2}", (1.0 - g.prefill / std.prefill) * 100.0),
                format!("{:.2}", (1.0 - g.decode_latency() / std.decode_latency()) * 100.0),
                format!("{:.2}", (g.tok_per_sec() / std.tok_per_sec() - 1.0) * 100.0),
            ]);
        }
    }
    t
}

/// Training-step speedup estimate (paper abstract: "5-7% training speedup
/// when training an 8B model with 8k context on 64 H100s with 3D
/// parallelism"). We model the TP dimension of one fwd+bwd step: forward
/// ARs as in inference, backward costs ~2x forward compute with its own two
/// (overlappable) reduces per layer; FSDP gradient comm is excluded — the
/// paper notes it is already overlapped, which is why the net gain is much
/// smaller than at inference.
pub fn training_speedup() -> Table {
    let m = PaperModel::by_name("8B").unwrap();
    let mut t = Table::new(
        "Training-step speedup, TP dimension only (8B, seq 8k, TP8) — an upper bound: the paper's measured 5-7% e2e gain is diluted by the FSDP/PP dimensions Ladder does not change",
        &["Fabric", "Standard step (ms)", "Ladder step (ms)", "Speedup"],
    );
    for (fabric, tag) in [(Fabric::NvLink, "NVLink"), (Fabric::InfiniBand, "InfiniBand")] {
        let cm = cost_model(m, 8, fabric);
        let mt = cm.prefill(1, 8192);
        // fwd + bwd: 3x module compute, 2x the reduces (grad reduces carry
        // the same [B,S,H] message)
        let step = |arch: Arch| {
            let fwd = crate::perfmodel::timeline::simulate_forward(arch, m.layers, &mt, false);
            let bwd_mt = crate::perfmodel::costs::ModuleTimes {
                attn: 2.0 * mt.attn,
                mlp: 2.0 * mt.mlp,
                fused: 2.0 * mt.fused,
                ..mt
            };
            let bwd = crate::perfmodel::timeline::simulate_forward(arch, m.layers, &bwd_mt, false);
            fwd.total + bwd.total
        };
        let std = step(Arch::Standard);
        let lad = step(Arch::Ladder);
        t.row(&[
            tag.to_string(),
            format!("{:.1}", std * 1e3),
            format!("{:.1}", lad * 1e3),
            format!("{:.2}x", std / lad),
        ]);
    }
    t
}

/// Figure 6: chrome-trace of one decode step, Standard vs Ladder (NVLink,
/// 70B TP8) — shows NCCL ops blocking vs overlapped.
pub fn fig6_traces() -> (crate::util::json::Json, crate::util::json::Json) {
    use crate::perfmodel::timeline::{simulate_decode_step, trace_to_chrome_json};
    let m = PaperModel::by_name("70B").unwrap();
    let cm = cost_model(m, 8, Fabric::NvLink);
    let std = simulate_decode_step(Arch::Standard, &cm, 1, PROMPT, true);
    let lad = simulate_decode_step(Arch::Ladder, &cm, 1, PROMPT, true);
    (trace_to_chrome_json(&std.trace), trace_to_chrome_json(&lad.trace))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_ladder_always_speeds_up() {
        let t = table1();
        let md = t.to_markdown();
        for line in md.lines().skip(4) {
            for cell in line.split('|').filter(|c| c.contains('x')) {
                let v: f64 = cell.trim().trim_end_matches('x').parse().unwrap();
                assert!(v >= 1.0, "{line}");
                assert!(v < 2.0, "{line}");
            }
        }
    }

    #[test]
    fn table2_upperbound_dominates() {
        let m = PaperModel::by_name("70B").unwrap();
        for fabric in [Fabric::NvLink, Fabric::Pcie] {
            let std = gen(Arch::Standard, m, 8, fabric, 1);
            let lad = gen(Arch::Ladder, m, 8, fabric, 1);
            let ub = gen(Arch::Upperbound, m, 8, fabric, 1);
            assert!(ub.tok_per_sec() >= lad.tok_per_sec());
            assert!(lad.tok_per_sec() >= std.tok_per_sec());
        }
    }

    #[test]
    fn no_nvlink_gains_are_larger_at_70b() {
        // paper: 70B NVLink 1.29x vs no-NVLink 1.59x
        let m = PaperModel::by_name("70B").unwrap();
        let nv = gen(Arch::Ladder, m, 8, Fabric::NvLink, 4).tok_per_sec()
            / gen(Arch::Standard, m, 8, Fabric::NvLink, 4).tok_per_sec();
        let pcie = gen(Arch::Ladder, m, 8, Fabric::Pcie, 4).tok_per_sec()
            / gen(Arch::Standard, m, 8, Fabric::Pcie, 4).tok_per_sec();
        assert!(pcie > nv, "pcie {pcie} !> nv {nv}");
    }

    #[test]
    fn fig2_gains_grow_with_tp() {
        let m = PaperModel::by_name("70B").unwrap();
        let speedup = |tp: usize| {
            gen(Arch::Ladder, m, tp, Fabric::NvLink, 4).tok_per_sec()
                / gen(Arch::Standard, m, tp, Fabric::NvLink, 4).tok_per_sec()
        };
        assert!(speedup(8) > speedup(2));
        assert!((speedup(1) - 1.0).abs() < 1e-9); // TP1: no comm at all
    }

    #[test]
    fn fig3_cross_node_improvement_over_30pct() {
        // paper: >30% improvement across batch sizes with NVLink intra-node
        let m = PaperModel::by_name("405B").unwrap();
        for bs in [1usize, 4, 16] {
            let std = gen(Arch::Standard, m, 16, Fabric::NvLink, bs);
            let lad = gen(Arch::Ladder, m, 16, Fabric::NvLink, bs);
            let impr = lad.tok_per_sec() / std.tok_per_sec() - 1.0;
            assert!(impr > 0.15, "bs={bs}: {impr}");
        }
    }

    #[test]
    fn fig4_ladder_dominates_pareto() {
        let counts = fig4_pareto_counts();
        let get = |n: &str| counts.iter().find(|(k, _)| k == n).unwrap().1;
        assert!(get("ladder") > get("standard"));
        assert!(get("ladder") > get("parallel"));
    }

    #[test]
    fn table6_desync4_beats_ladder_without_nvlink() {
        // paper §5: without NVLink desync-4x (39%) > ladder (23%)
        let m = PaperModel::by_name("8B").unwrap();
        let std = gen(Arch::Standard, m, 8, Fabric::Pcie, 64);
        let lad = gen(Arch::Ladder, m, 8, Fabric::Pcie, 64);
        let d4 = gen(Arch::Desync(4), m, 8, Fabric::Pcie, 64);
        assert!(d4.tok_per_sec() > lad.tok_per_sec());
        assert!(lad.tok_per_sec() > std.tok_per_sec());
    }

    #[test]
    fn overlap_none_matches_serial_generation() {
        // chunks=1 is the unsplit schedule: for Standard (never more than
        // one reduce in flight) the chunked simulator must agree exactly
        let m = PaperModel::by_name("405B").unwrap();
        let ic = Interconnect::new(Fabric::NvLink).with_two_tier(Fabric::InfiniBand, 8);
        let cm = CostModel::new(*m, H100, 16, ic);
        let serial = simulate_generation(Arch::Standard, &cm, 16, PROMPT, 16);
        let chunked = simulate_generation_overlap(Arch::Standard, &cm, 16, PROMPT, 16, 1);
        assert!((serial.prefill - chunked.prefill).abs() < 1e-9);
        assert!((serial.decode_total - chunked.decode_total).abs() < 1e-9);
    }

    #[test]
    fn overlap_split4_narrows_standard_prefill_gap_but_ladder_leads() {
        // the table's headline: on the two-tier fabric, standard+split4
        // recovers a strictly positive fraction of the standard-vs-ladder
        // prefill gap, and ladder without any splitting still leads
        let m = PaperModel::by_name("405B").unwrap();
        let ic = Interconnect::new(Fabric::NvLink).with_two_tier(Fabric::InfiniBand, 8);
        let cm = CostModel::new(*m, H100, 16, ic);
        let pre = |arch: Arch, chunks: usize| {
            simulate_generation_overlap(arch, &cm, 16, PROMPT, 1, chunks).prefill
        };
        let (std_none, std_s4) = (pre(Arch::Standard, 1), pre(Arch::Standard, 4));
        let lad_none = pre(Arch::Ladder, 1);
        assert!(std_s4 < std_none, "split4 should shorten standard prefill");
        assert!(lad_none <= std_s4, "ladder+none should still lead");
        let gap_none = std_none - lad_none;
        let gap_s4 = std_s4 - lad_none;
        assert!(gap_s4 < gap_none, "gap {gap_s4} !< {gap_none}");
    }

    #[test]
    fn overlap_split_cannot_fix_decode_but_ladder_does() {
        // decode compute is weight-streaming-bound: every chunk re-streams
        // the shard, so splitting does not beat ladder's architectural
        // overlap on a single decode step
        let m = PaperModel::by_name("405B").unwrap();
        let ic = Interconnect::new(Fabric::NvLink).with_two_tier(Fabric::InfiniBand, 8);
        let cm = CostModel::new(*m, H100, 16, ic);
        let dec = |arch: Arch, chunks: usize| {
            simulate_generation_overlap(arch, &cm, 16, PROMPT, 8, chunks).decode_latency()
        };
        assert!(dec(Arch::Ladder, 1) < dec(Arch::Standard, 4));
        assert!(dec(Arch::Ladder, 1) < dec(Arch::Standard, 1));
    }

    #[test]
    fn fig6_traces_nonempty() {
        let (std, lad) = fig6_traces();
        assert!(std.to_string().len() > 100);
        assert!(lad.to_string().len() > 100);
    }
}
