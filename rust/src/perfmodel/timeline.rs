//! Discrete-event timeline simulation of one forward pass per architecture.
//!
//! Two resources per rank (symmetric ranks => simulate one): the compute
//! stream and the interconnect. The architecture fixes the dependency
//! structure:
//!
//! * Standard — every AllReduce blocks the compute stream.
//! * Ladder   — an AllReduce is waited on one module later (paper Alg. 1),
//!              so it overlaps the next module's compute.
//! * Parallel — one blocking AllReduce per layer over the fused module.
//! * Desync-n — only every n-th AllReduce is issued (blocking).
//! * Upperbound — no communication at all.

use super::costs::{CostModel, ModuleTimes};
use crate::model::Arch;

/// One simulated forward pass.
#[derive(Debug, Clone, Default)]
pub struct TimelineResult {
    /// Wall time of the forward pass (seconds).
    pub total: f64,
    /// Total modeled AllReduce time.
    pub comm_total: f64,
    /// Comm time the compute stream actually stalled on.
    pub comm_exposed: f64,
    pub trace: Vec<TraceEvent>,
}

/// Chrome-trace-style event (stream 0 = compute, 1 = interconnect).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    pub stream: usize,
    pub start: f64,
    pub dur: f64,
}

/// Simulate one forward pass of `layers` transformer layers.
pub fn simulate_forward(
    arch: Arch,
    layers: usize,
    mt: &ModuleTimes,
    with_trace: bool,
) -> TimelineResult {
    let mut sim = Sim::new(with_trace);
    match arch {
        Arch::Standard => {
            for i in 0..layers {
                sim.compute(&format!("attn{i}"), mt.attn);
                sim.allreduce_blocking(&format!("ar_attn{i}"), mt.allreduce);
                sim.compute(&format!("mlp{i}"), mt.mlp);
                sim.allreduce_blocking(&format!("ar_mlp{i}"), mt.allreduce);
            }
        }
        Arch::Ladder => {
            let mut pend_attn: Option<f64> = None;
            let mut pend_mlp: Option<f64> = None;
            for i in 0..layers {
                if let Some(done) = pend_attn.take() {
                    sim.wait(done);
                }
                sim.compute(&format!("attn{i}"), mt.attn);
                pend_attn = Some(sim.allreduce_async(&format!("ar_attn{i}"), mt.allreduce));
                if let Some(done) = pend_mlp.take() {
                    sim.wait(done);
                }
                sim.compute(&format!("mlp{i}"), mt.mlp);
                pend_mlp = Some(sim.allreduce_async(&format!("ar_mlp{i}"), mt.allreduce));
            }
            if let Some(done) = pend_attn {
                sim.wait(done);
            }
            if let Some(done) = pend_mlp {
                sim.wait(done);
            }
        }
        Arch::Hybrid => {
            let split = layers / 2;
            let mut pend_attn: Option<f64> = None;
            let mut pend_mlp: Option<f64> = None;
            for i in 0..layers {
                if i < split {
                    sim.compute(&format!("attn{i}"), mt.attn);
                    sim.allreduce_blocking(&format!("ar_attn{i}"), mt.allreduce);
                    sim.compute(&format!("mlp{i}"), mt.mlp);
                    sim.allreduce_blocking(&format!("ar_mlp{i}"), mt.allreduce);
                } else {
                    if let Some(done) = pend_attn.take() {
                        sim.wait(done);
                    }
                    sim.compute(&format!("attn{i}"), mt.attn);
                    pend_attn = Some(sim.allreduce_async(&format!("ar_attn{i}"), mt.allreduce));
                    if let Some(done) = pend_mlp.take() {
                        sim.wait(done);
                    }
                    sim.compute(&format!("mlp{i}"), mt.mlp);
                    pend_mlp = Some(sim.allreduce_async(&format!("ar_mlp{i}"), mt.allreduce));
                }
            }
            if let Some(done) = pend_attn {
                sim.wait(done);
            }
            if let Some(done) = pend_mlp {
                sim.wait(done);
            }
        }
        Arch::Parallel => {
            for i in 0..layers {
                sim.compute(&format!("fused{i}"), mt.fused);
                sim.allreduce_blocking(&format!("ar{i}"), mt.allreduce);
            }
        }
        Arch::Desync(n) => {
            let mut c = 0usize;
            for i in 0..layers {
                for (kind, dur) in [("attn", mt.attn), ("mlp", mt.mlp)] {
                    sim.compute(&format!("{kind}{i}"), dur);
                    c += 1;
                    if c % n == 0 {
                        sim.allreduce_blocking(&format!("ar_{kind}{i}"), mt.allreduce);
                    }
                }
            }
            if (2 * layers) % n != 0 {
                sim.allreduce_blocking("ar_final_resync", mt.allreduce);
            }
        }
        Arch::Upperbound => {
            for i in 0..layers {
                sim.compute(&format!("attn{i}"), mt.attn);
                sim.compute(&format!("mlp{i}"), mt.mlp);
            }
        }
    }
    sim.compute("edges", mt.edges);
    sim.finish()
}

/// Simulate one forward pass with split-batch overlap (`engine/overlap.rs`):
/// the batch rows are split into `chunks` sub-chunks pipelined round-robin
/// through the per-layer blocks, so one chunk's AllReduce overlaps the other
/// chunks' compute even under the Standard architecture (TokenWeave-style
/// systems overlap). `mt` holds *per-chunk* module times, except `edges`,
/// which runs once over the re-concatenated full batch.
///
/// Chunk collectives get independent completion deadlines (no link-queue
/// serialization): this matches the rendezvous runtime, where every round's
/// deadline is anchored at its own rendezvous instant
/// (`comm/rendezvous.rs`), the way multi-stream NCCL calls over disjoint
/// chunks pipeline on a real fabric. Waits follow the engine's chunked
/// schedule: a chunk's reduce is absorbed at that chunk's *next* block step,
/// with the other chunks' compute in between.
pub fn simulate_forward_chunked(
    arch: Arch,
    layers: usize,
    mt: &ModuleTimes,
    chunks: usize,
) -> TimelineResult {
    let mut sim = Sim::new(false);
    let c = chunks.max(1);
    match arch {
        Arch::Standard | Arch::Ladder | Arch::Hybrid => {
            // mirror engine/tpengine.rs fwd_synced_chunked: ladder_from is
            // the first layer of the deferred-wait (ladder) region
            let ladder_from = match arch {
                Arch::Standard => layers,
                Arch::Ladder => 0,
                _ => layers / 2,
            };
            let mut pend_attn: Vec<Option<f64>> = vec![None; c];
            let mut pend_mlp: Vec<Option<f64>> = vec![None; c];
            for i in 0..layers {
                for r in 0..c {
                    let h =
                        if i > ladder_from { pend_attn[r].take() } else { pend_mlp[r].take() };
                    if let Some(done) = h {
                        sim.wait(done);
                    }
                    sim.compute(&format!("attn{i}.{r}"), mt.attn);
                    pend_attn[r] = Some(sim.allreduce_concurrent(mt.allreduce));
                }
                for r in 0..c {
                    let h =
                        if i >= ladder_from { pend_mlp[r].take() } else { pend_attn[r].take() };
                    if let Some(done) = h {
                        sim.wait(done);
                    }
                    sim.compute(&format!("mlp{i}.{r}"), mt.mlp);
                    pend_mlp[r] = Some(sim.allreduce_concurrent(mt.allreduce));
                }
            }
            for r in 0..c {
                if let Some(done) = pend_attn[r].take() {
                    sim.wait(done);
                }
                if let Some(done) = pend_mlp[r].take() {
                    sim.wait(done);
                }
            }
        }
        Arch::Parallel => {
            let mut pend: Vec<Option<f64>> = vec![None; c];
            for i in 0..layers {
                for (r, p) in pend.iter_mut().enumerate() {
                    if let Some(done) = p.take() {
                        sim.wait(done);
                    }
                    sim.compute(&format!("fused{i}.{r}"), mt.fused);
                    *p = Some(sim.allreduce_concurrent(mt.allreduce));
                }
            }
            for done in pend.into_iter().flatten() {
                sim.wait(done);
            }
        }
        Arch::Desync(n) => {
            // chunked desync defers the retained reduce to the chunk's next
            // step (engine fwd_desync_chunked), unlike the unsplit path's
            // blocking reduce
            let mut pend: Vec<Option<f64>> = vec![None; c];
            let mut count = vec![0usize; c];
            let mut synced = vec![true; c];
            for i in 0..layers {
                for (kind, dur) in [("attn", mt.attn), ("mlp", mt.mlp)] {
                    for r in 0..c {
                        if let Some(done) = pend[r].take() {
                            sim.wait(done);
                        }
                        sim.compute(&format!("{kind}{i}.{r}"), dur);
                        count[r] += 1;
                        if count[r] % n == 0 {
                            pend[r] = Some(sim.allreduce_concurrent(mt.allreduce));
                            synced[r] = true;
                        } else {
                            synced[r] = false;
                        }
                    }
                }
            }
            for r in 0..c {
                if let Some(done) = pend[r].take() {
                    sim.wait(done);
                }
                if !synced[r] {
                    let done = sim.allreduce_concurrent(mt.allreduce);
                    sim.wait(done);
                }
            }
        }
        Arch::Upperbound => {
            for i in 0..layers {
                for r in 0..c {
                    sim.compute(&format!("attn{i}.{r}"), mt.attn);
                    sim.compute(&format!("mlp{i}.{r}"), mt.mlp);
                }
            }
        }
    }
    sim.compute("edges", mt.edges);
    sim.finish()
}

/// Full generation with split-batch overlap: per-forward module times are
/// taken at the chunk's row count (`batch / chunks` — use a divisible pair;
/// the engine itself handles remainders) while the LM-head edges run once on
/// the full batch, exactly as the engine concatenates chunks before the head.
pub fn simulate_generation_overlap(
    arch: Arch,
    cm: &CostModel,
    batch: usize,
    prompt: usize,
    gen: usize,
    chunks: usize,
) -> GenTimes {
    let c = chunks.clamp(1, batch);
    let mut mt = cm.prefill(batch / c, prompt);
    mt.edges = cm.prefill(batch, prompt).edges;
    let pre = simulate_forward_chunked(arch, cm.model.layers, &mt, c);
    let mut decode_total = 0.0;
    let mut exposed = pre.comm_exposed;
    let mut comm_total = pre.comm_total;
    for step in 0..gen {
        let mut mt = cm.decode(batch / c, prompt + step);
        mt.edges = cm.decode(batch, prompt + step).edges;
        let r = simulate_forward_chunked(arch, cm.model.layers, &mt, c);
        decode_total += r.total;
        exposed += r.comm_exposed;
        comm_total += r.comm_total;
    }
    GenTimes {
        prefill: pre.total,
        decode_total,
        gen_tokens: gen,
        batch,
        comm_exposed: exposed,
        comm_total,
    }
}

/// Prefill latency for one forward over the prompt.
pub fn simulate_prefill(arch: Arch, cm: &CostModel, batch: usize, prompt: usize) -> TimelineResult {
    let mt = cm.prefill(batch, prompt);
    simulate_forward(arch, cm.model.layers, &mt, false)
}

/// One decode step at a given context length.
pub fn simulate_decode_step(
    arch: Arch,
    cm: &CostModel,
    batch: usize,
    ctx: usize,
    with_trace: bool,
) -> TimelineResult {
    let mt = cm.decode(batch, ctx);
    simulate_forward(arch, cm.model.layers, &mt, with_trace)
}

/// Full generation run: prefill + `gen` decode steps with a growing context.
#[derive(Debug, Clone)]
pub struct GenTimes {
    pub prefill: f64,
    pub decode_total: f64,
    pub gen_tokens: usize,
    pub batch: usize,
    pub comm_exposed: f64,
    pub comm_total: f64,
}

impl GenTimes {
    pub fn total(&self) -> f64 {
        self.prefill + self.decode_total
    }

    /// Generated tokens per second (the paper's throughput metric).
    pub fn tok_per_sec(&self) -> f64 {
        (self.batch * self.gen_tokens) as f64 / self.total()
    }

    /// Mean per-step decode latency.
    pub fn decode_latency(&self) -> f64 {
        self.decode_total / self.gen_tokens as f64
    }
}

pub fn simulate_generation(
    arch: Arch,
    cm: &CostModel,
    batch: usize,
    prompt: usize,
    gen: usize,
) -> GenTimes {
    let pre = simulate_prefill(arch, cm, batch, prompt);
    let mut decode_total = 0.0;
    let mut exposed = pre.comm_exposed;
    let mut comm_total = pre.comm_total;
    for step in 0..gen {
        let r = simulate_decode_step(arch, cm, batch, prompt + step, false);
        decode_total += r.total;
        exposed += r.comm_exposed;
        comm_total += r.comm_total;
    }
    GenTimes {
        prefill: pre.total,
        decode_total,
        gen_tokens: gen,
        batch,
        comm_exposed: exposed,
        comm_total,
    }
}

// ---------------------------------------------------------------------------

struct Sim {
    /// compute-stream head time
    tc: f64,
    /// interconnect free time
    link_free: f64,
    comm_total: f64,
    comm_exposed: f64,
    trace: Option<Vec<TraceEvent>>,
}

impl Sim {
    fn new(with_trace: bool) -> Sim {
        Sim {
            tc: 0.0,
            link_free: 0.0,
            comm_total: 0.0,
            comm_exposed: 0.0,
            trace: if with_trace { Some(Vec::new()) } else { None },
        }
    }

    fn compute(&mut self, name: &str, dur: f64) {
        if let Some(t) = &mut self.trace {
            t.push(TraceEvent { name: name.into(), stream: 0, start: self.tc, dur });
        }
        self.tc += dur;
    }

    /// Issue an AllReduce and immediately block on it.
    fn allreduce_blocking(&mut self, name: &str, dur: f64) {
        let done = self.allreduce_async(name, dur);
        self.wait(done);
    }

    /// Issue an AllReduce on the link; returns its completion time.
    fn allreduce_async(&mut self, name: &str, dur: f64) -> f64 {
        let start = self.tc.max(self.link_free);
        let done = start + dur;
        self.link_free = done;
        self.comm_total += dur;
        if let Some(t) = &mut self.trace {
            t.push(TraceEvent { name: name.into(), stream: 1, start, dur });
        }
        done
    }

    /// Issue an AllReduce whose deadline is independent of other in-flight
    /// collectives (rendezvous-style per-round deadlines, no link queue);
    /// returns its completion time.
    fn allreduce_concurrent(&mut self, dur: f64) -> f64 {
        self.comm_total += dur;
        self.tc + dur
    }

    /// Stall the compute stream until `done`.
    fn wait(&mut self, done: f64) {
        if done > self.tc {
            self.comm_exposed += done - self.tc;
            self.tc = done;
        }
    }

    fn finish(self) -> TimelineResult {
        TimelineResult {
            total: self.tc.max(self.link_free),
            comm_total: self.comm_total,
            comm_exposed: self.comm_exposed,
            trace: self.trace.unwrap_or_default(),
        }
    }
}

/// Dump a trace as chrome://tracing JSON.
pub fn trace_to_chrome_json(events: &[TraceEvent]) -> crate::util::json::Json {
    use crate::util::json::Json;
    let arr = events
        .iter()
        .map(|e| {
            Json::obj()
                .set("name", e.name.as_str())
                .set("ph", "X")
                .set("ts", e.start * 1e6)
                .set("dur", e.dur * 1e6)
                .set("pid", 0usize)
                .set("tid", e.stream)
        })
        .collect::<Vec<_>>();
    Json::Arr(arr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;

    fn mt(attn: f64, mlp: f64, ar: f64) -> ModuleTimes {
        ModuleTimes { attn, mlp, fused: attn + mlp, allreduce: ar, edges: 0.0 }
    }

    #[test]
    fn standard_serializes_comm() {
        let r = simulate_forward(Arch::Standard, 4, &mt(1.0, 1.0, 0.5), false);
        assert!((r.total - (4.0 * (1.0 + 0.5 + 1.0 + 0.5))).abs() < 1e-9);
        assert!((r.comm_exposed - r.comm_total).abs() < 1e-9);
    }

    #[test]
    fn ladder_hides_comm_when_compute_is_longer() {
        // comm (0.5) < module (1.0): ladder hides everything except the two
        // trailing reduces of the last layer.
        let r = simulate_forward(Arch::Ladder, 4, &mt(1.0, 1.0, 0.5), false);
        let std = simulate_forward(Arch::Standard, 4, &mt(1.0, 1.0, 0.5), false);
        assert!(r.total < std.total);
        assert!(r.comm_exposed < 0.25 * r.comm_total, "{r:?}");
    }

    #[test]
    fn ladder_bounded_by_comm_when_link_is_slow() {
        // comm (4.0) >> module (1.0): the link is the bottleneck; the total
        // approaches the serialized link occupancy.
        let r = simulate_forward(Arch::Ladder, 4, &mt(1.0, 1.0, 4.0), false);
        assert!(r.total >= 8.0 * 4.0, "{}", r.total); // 8 ARs serialized
        let std = simulate_forward(Arch::Standard, 4, &mt(1.0, 1.0, 4.0), false);
        assert!(r.total < std.total); // still better than standard
    }

    #[test]
    fn parallel_halves_comm_count() {
        let r = simulate_forward(Arch::Parallel, 4, &mt(1.0, 1.0, 0.5), false);
        assert!((r.comm_total - 4.0 * 0.5).abs() < 1e-9);
    }

    #[test]
    fn desync_drops_comm() {
        let r2 = simulate_forward(Arch::Desync(2), 4, &mt(1.0, 1.0, 0.5), false);
        let r4 = simulate_forward(Arch::Desync(4), 4, &mt(1.0, 1.0, 0.5), false);
        assert!((r2.comm_total - 4.0 * 0.5).abs() < 1e-9);
        assert!((r4.comm_total - 2.0 * 0.5).abs() < 1e-9);
    }

    #[test]
    fn upperbound_has_no_comm_and_is_fastest() {
        let m = mt(1.0, 1.0, 0.5);
        let ub = simulate_forward(Arch::Upperbound, 4, &m, false);
        assert_eq!(ub.comm_total, 0.0);
        for arch in [Arch::Standard, Arch::Ladder, Arch::Parallel, Arch::Desync(2)] {
            let r = simulate_forward(arch, 4, &m, false);
            assert!(ub.total <= r.total + 1e-12, "{arch:?}");
        }
    }

    #[test]
    fn ordering_upperbound_le_ladder_le_standard() {
        for ar in [0.1, 0.5, 2.0, 10.0] {
            let m = mt(1.0, 1.3, ar);
            let ub = simulate_forward(Arch::Upperbound, 6, &m, false).total;
            let lad = simulate_forward(Arch::Ladder, 6, &m, false).total;
            let std = simulate_forward(Arch::Standard, 6, &m, false).total;
            assert!(ub <= lad + 1e-12 && lad <= std + 1e-12, "ar={ar}");
        }
    }

    #[test]
    fn chunked_single_chunk_matches_standard_serial() {
        // C=1 standard defers each wait exactly one block step with nothing
        // in between — identical arithmetic to the blocking schedule
        let m = mt(1.0, 1.3, 0.7);
        let serial = simulate_forward(Arch::Standard, 5, &m, false);
        let chunked = simulate_forward_chunked(Arch::Standard, 5, &m, 1);
        assert!((serial.total - chunked.total).abs() < 1e-12);
        assert!((serial.comm_exposed - chunked.comm_exposed).abs() < 1e-12);
    }

    #[test]
    fn chunked_standard_hides_comm_behind_sibling_chunks() {
        // per-chunk compute 1.0, AR 2.0: with 4 chunks in flight the other
        // chunks' compute fills most of each chunk's AR window
        let m = mt(1.0, 1.0, 2.0);
        let none = simulate_forward_chunked(Arch::Standard, 4, &m, 1);
        let split = simulate_forward_chunked(Arch::Standard, 4, &m, 4);
        // unsplit runs 4 rows' worth of compute per module: rescale
        let unsplit = simulate_forward(Arch::Standard, 4, &mt(4.0, 4.0, 2.0), false);
        assert!(none.total > 0.0);
        assert!(split.total < unsplit.total, "{} !< {}", split.total, unsplit.total);
        assert!(split.comm_exposed < unsplit.comm_exposed);
    }

    #[test]
    fn chunked_ladder_still_beats_chunked_standard() {
        let m = mt(1.0, 1.0, 2.0);
        for c in [1usize, 2, 4] {
            let lad = simulate_forward_chunked(Arch::Ladder, 6, &m, c);
            let std = simulate_forward_chunked(Arch::Standard, 6, &m, c);
            assert!(lad.total <= std.total + 1e-12, "chunks={c}");
        }
    }

    #[test]
    fn trace_events_emitted() {
        let r = simulate_forward(Arch::Ladder, 2, &mt(1.0, 1.0, 0.5), true);
        assert!(r.trace.iter().any(|e| e.stream == 1));
        let json = trace_to_chrome_json(&r.trace);
        assert!(json.to_string().contains("ar_attn0"));
    }
}
