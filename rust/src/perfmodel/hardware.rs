//! GPU hardware specifications for the roofline model.

/// Effective (achieved, not peak-datasheet) throughput numbers for one GPU.
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Achievable dense bf16 FLOP/s on large GEMMs.
    pub flops: f64,
    /// Achievable HBM bandwidth (bytes/s).
    pub mem_bw: f64,
    /// Fixed per-module dispatch overhead (seconds). The paper runs under
    /// CUDA graphs, so this is small.
    pub launch_overhead: f64,
}

/// H100 SXM: ~989 TF peak bf16; sustained GEMM efficiency ~0.7. HBM3 3.35
/// TB/s peak, ~0.85 achievable.
/// launch_overhead models the *intra-module* dispatch gaps: each exported
/// module covers ~5-6 GPU kernels (norm, projections, attention core, ...);
/// even under CUDA graphs the inter-kernel gaps sum to several us. This is
/// what makes small-model decode latency launch-bound — the regime where the
/// paper's 1B/3B rows show the biggest ladder gains.
pub const H100: GpuSpec = GpuSpec {
    name: "H100-SXM",
    flops: 700e12,
    mem_bw: 2.9e12,
    launch_overhead: 6e-6,
};

/// Element size the paper serves in (bf16).
pub const ELEM_BYTES: f64 = 2.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_in_plausible_range() {
        assert!(H100.flops > 4e14 && H100.flops < 1e15);
        assert!(H100.mem_bw > 2e12 && H100.mem_bw < 3.35e12);
    }
}
