//! Performance model: H100 roofline compute costs + interconnect model +
//! per-architecture discrete-event timeline simulation.
//!
//! This is the substitution for the paper's 8-16 H100 testbed (DESIGN.md §1):
//! absolute numbers are calibrated to public hardware specs, while the
//! who-wins/by-how-much *shape* of every table and figure emerges from the
//! same dependency structures the real systems have (blocking vs overlapped
//! vs dropped AllReduces).

pub mod costs;
pub mod hardware;
pub mod tables;
pub mod timeline;

pub use costs::{CostModel, ModuleTimes};
pub use hardware::{GpuSpec, H100};
pub use timeline::{simulate_decode_step, simulate_prefill, GenTimes, TimelineResult};
