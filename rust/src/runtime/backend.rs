//! The pluggable execution backend abstraction.
//!
//! Everything above this layer (schedulers, runtimes, collectives, batcher,
//! server, trainer) talks to a [`Backend`] through host-side [`Value`]s and
//! named module calls — the same module vocabulary the AOT export emits
//! (`attn_prefill__tp2__b1__s16`, `mlp__...`, `lm_head__...`, ...). Two
//! implementations exist:
//!
//! * [`NativeBackend`] (default) — executes the per-rank Llama shard forward
//!   directly over [`HostTensor`] in pure Rust ([`crate::runtime::native`]).
//!   No artifacts, no PJRT, runs on any stock machine.
//! * `XlaBackend` (`--features xla`) — compiles the exported HLO modules on
//!   the PJRT CPU client ([`crate::runtime::executable`]). Requires
//!   `artifacts/<config>/` from `make artifacts` and the real vendored
//!   xla-rs toolchain.
//!
//! An [`Exec`] bundles a backend instance with the model config, the serving
//! export parameters, and (optionally) the artifact directory; a
//! [`BackendSpec`] is the `Send` recipe worker threads use to rebuild their
//! own backend instance (PJRT handles are thread-local by construction).
//!
//! [`HostTensor`]: crate::model::HostTensor
//! [`NativeBackend`]: crate::runtime::NativeBackend

use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use super::artifact::ArtifactDir;
use super::native::NativeBackend;
use crate::model::{HostTensor, LlamaConfig};

/// A backend-resident value: weights are uploaded once at engine build,
/// activations per module call. The native backend stores plain host
/// tensors; the xla backend stores PJRT literals.
pub enum Value {
    F32(HostTensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
    #[cfg(feature = "xla")]
    Xla(xla::Literal),
}

impl Value {
    /// View as an f32 host tensor, copying out of device-side storage.
    pub fn to_f32(&self) -> Result<HostTensor> {
        match self {
            Value::F32(t) => Ok(t.clone()),
            Value::I32 { .. } => bail!("value is i32, wanted f32"),
            #[cfg(feature = "xla")]
            Value::Xla(lit) => super::literal::tensor_from_literal(lit),
        }
    }

    /// Consume into an f32 host tensor (zero-copy on the native backend).
    pub fn into_f32(self) -> Result<HostTensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32 { .. } => bail!("value is i32, wanted f32"),
            #[cfg(feature = "xla")]
            Value::Xla(lit) => super::literal::tensor_from_literal(&lit),
        }
    }

    /// The raw f32 data (flattened).
    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        match self {
            Value::F32(t) => Ok(t.data.clone()),
            Value::I32 { .. } => bail!("value is i32, wanted f32"),
            #[cfg(feature = "xla")]
            Value::Xla(lit) => Ok(lit.to_vec::<f32>()?),
        }
    }

    /// The raw i32 data (flattened).
    pub fn to_i32_vec(&self) -> Result<Vec<i32>> {
        match self {
            Value::F32(_) => bail!("value is f32, wanted i32"),
            Value::I32 { data, .. } => Ok(data.clone()),
            #[cfg(feature = "xla")]
            Value::Xla(lit) => Ok(lit.to_vec::<i32>()?),
        }
    }
}

/// One execution backend: value upload + named module execution.
///
/// Implementations are *not* required to be `Send` (the PJRT client is
/// thread-local); worker threads rebuild their own instance from a
/// [`BackendSpec`].
pub trait Backend {
    fn name(&self) -> &'static str;

    /// f32 host data -> backend value of the given shape.
    fn upload_f32(&self, data: &[f32], shape: &[usize]) -> Result<Value>;

    /// Consume an owned host tensor (native: zero-copy wrap).
    fn upload_owned(&self, t: HostTensor) -> Result<Value>;

    /// i32 host data -> backend value of the given shape.
    fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<Value>;

    /// Execute a named module; outputs in the module's declared order.
    fn run(&self, module: &str, args: &[&Value]) -> Result<Vec<Value>>;

    /// Number of module executables compiled/instantiated so far.
    fn compiled_count(&self) -> usize;
}

/// Which backend to construct (CLI `--backend` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust reference executor over host tensors (default).
    #[default]
    Native,
    /// AOT HLO artifacts on the PJRT CPU client (`--features xla`).
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        Ok(match s {
            "native" => BackendKind::Native,
            "xla" | "pjrt" => BackendKind::Xla,
            _ => bail!("unknown backend {s:?} (native|xla)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// `Send` recipe for building a backend instance on any thread.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    Native { cfg: LlamaConfig },
    Xla { dir: PathBuf },
}

impl BackendSpec {
    /// Build a fresh [`Exec`] for the current thread.
    pub fn build(&self) -> Result<Exec> {
        match self {
            BackendSpec::Native { cfg } => Ok(Exec::native(cfg.clone())),
            #[cfg(feature = "xla")]
            BackendSpec::Xla { dir } => Exec::xla(Rc::new(ArtifactDir::open(dir)?)),
            #[cfg(not(feature = "xla"))]
            BackendSpec::Xla { dir } => bail!(
                "xla backend spec ({dir:?}) in a build without the `xla` feature — \
                 rebuild with `--features xla`"
            ),
        }
    }
}

/// Serving export parameters: which (tp, batch, bucket) combinations an
/// engine may be built with. Artifact-backed backends read these from the
/// manifest and treat them as hard limits (`compiled_shapes = true` — the
/// executables only exist for those shapes); the native executor dispatches
/// on argument shapes, so its defaults are advisory (divisibility rules
/// still apply) and membership is not enforced.
#[derive(Debug, Clone)]
pub struct ServingParams {
    pub tps: Vec<usize>,
    pub batches: Vec<usize>,
    pub buckets: Vec<usize>,
    /// True when the lists are compiled-shape constraints (manifest-backed)
    /// rather than shape-agnostic defaults.
    pub compiled_shapes: bool,
}

impl ServingParams {
    /// Native-backend defaults: every TP degree that divides the sharded
    /// dims, power-of-two prefill buckets up to `max_seq`. Advisory only.
    pub fn native_default(cfg: &LlamaConfig) -> ServingParams {
        let tps = (1..=cfg.kv_heads)
            .filter(|t| {
                cfg.heads % t == 0
                    && cfg.kv_heads % t == 0
                    && cfg.ffn % t == 0
                    && cfg.vocab % t == 0
            })
            .collect();
        let batches = (1..=16).collect();
        let mut buckets = Vec::new();
        let mut b = 8;
        while b < cfg.max_seq {
            buckets.push(b);
            b *= 2;
        }
        buckets.push(cfg.max_seq);
        ServingParams { tps, batches, buckets, compiled_shapes: false }
    }
}

/// An execution context: backend + config + serving params + optional
/// artifact directory. This is what the engine, trainer and CLI hold where
/// they used to hold the xla `ExecCache`.
pub struct Exec {
    cfg: LlamaConfig,
    serving: ServingParams,
    spec: BackendSpec,
    artifacts: Option<Rc<ArtifactDir>>,
    backend: Box<dyn Backend>,
}

impl Exec {
    /// Open a named config on the requested backend.
    ///
    /// Native: uses `artifacts/<name>/` for config + serving params + weight
    /// files when present, otherwise falls back to the built-in config
    /// registry — an artifact directory is optional, never a startup
    /// hard-fail. Xla: artifacts are mandatory (they hold the HLO modules).
    pub fn open(name: &str, kind: BackendKind) -> Result<Exec> {
        // absent artifacts are fine (native path); a present-but-corrupt
        // directory is a real error, never a silent fallback
        let artifacts = ArtifactDir::open_named_opt(name)?.map(Rc::new);
        match kind {
            BackendKind::Native => {
                let cfg = match &artifacts {
                    Some(a) => a.config.clone(),
                    None => LlamaConfig::builtin(name)?,
                };
                // always the shape-agnostic defaults: inheriting manifest
                // serving lists would shrink what the native executor can
                // serve (e.g. manifest buckets cap prompts below max_seq)
                let serving = ServingParams::native_default(&cfg);
                Ok(Exec {
                    spec: BackendSpec::Native { cfg: cfg.clone() },
                    backend: Box::new(NativeBackend::new(cfg.clone())),
                    cfg,
                    serving,
                    artifacts,
                })
            }
            BackendKind::Xla => {
                // feature check first: without it, "run `make artifacts`"
                // would send the user on a round-trip that can't help
                #[cfg(not(feature = "xla"))]
                {
                    let _ = artifacts;
                    bail!(
                        "backend \"xla\" requires building with `--features xla` \
                         (and the real vendored xla-rs toolchain); the default build is native-only"
                    );
                }
                #[cfg(feature = "xla")]
                {
                    let artifacts = artifacts.ok_or_else(|| {
                        anyhow!(
                            "xla backend needs artifacts/{name}/manifest.json — run `make artifacts`"
                        )
                    })?;
                    Self::xla_from(artifacts)
                }
            }
        }
    }

    /// Shorthand: `open(name, BackendKind::Native)`.
    pub fn native_named(name: &str) -> Result<Exec> {
        Exec::open(name, BackendKind::Native)
    }

    /// A native exec straight from a config (no artifact lookup). Used by
    /// rank worker threads and by callers that already hold a config.
    pub fn native(cfg: LlamaConfig) -> Exec {
        Exec {
            spec: BackendSpec::Native { cfg: cfg.clone() },
            backend: Box::new(NativeBackend::new(cfg.clone())),
            serving: ServingParams::native_default(&cfg),
            cfg,
            artifacts: None,
        }
    }

    /// An artifact-backed PJRT exec.
    #[cfg(feature = "xla")]
    pub fn xla(artifacts: Rc<ArtifactDir>) -> Result<Exec> {
        Self::xla_from(artifacts)
    }

    #[cfg(feature = "xla")]
    fn xla_from(artifacts: Rc<ArtifactDir>) -> Result<Exec> {
        let (tps, batches, buckets) = artifacts.serving_params()?;
        Ok(Exec {
            cfg: artifacts.config.clone(),
            serving: ServingParams { tps, batches, buckets, compiled_shapes: true },
            spec: BackendSpec::Xla { dir: artifacts.dir.clone() },
            backend: Box::new(super::executable::XlaBackend::new(
                super::executable::ExecCache::new(artifacts.clone()),
            )),
            artifacts: Some(artifacts),
        })
    }

    pub fn cfg(&self) -> &LlamaConfig {
        &self.cfg
    }

    pub fn serving(&self) -> &ServingParams {
        &self.serving
    }

    pub fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The artifact directory, or a guiding error for callers that truly
    /// need one (trainer graphs, golden test vectors, shipped weights).
    pub fn artifacts(&self) -> Result<&ArtifactDir> {
        self.artifacts_opt().ok_or_else(|| {
            anyhow!(
                "no artifact directory for config {:?} — run `make artifacts` \
                 (the native serving path does not need one, but this operation does)",
                self.cfg.name
            )
        })
    }

    pub fn artifacts_opt(&self) -> Option<&ArtifactDir> {
        self.artifacts.as_deref()
    }

    // -- execution (delegates to the backend) ------------------------------

    pub fn upload(&self, t: &HostTensor) -> Result<Value> {
        self.backend.upload_f32(&t.data, &t.shape)
    }

    pub fn upload_f32(&self, data: &[f32], shape: &[usize]) -> Result<Value> {
        self.backend.upload_f32(data, shape)
    }

    pub fn upload_owned(&self, t: HostTensor) -> Result<Value> {
        self.backend.upload_owned(t)
    }

    pub fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<Value> {
        self.backend.upload_i32(data, shape)
    }

    pub fn run(&self, module: &str, args: &[&Value]) -> Result<Vec<Value>> {
        self.backend.run(module, args)
    }

    pub fn compiled_count(&self) -> usize {
        self.backend.compiled_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_open_without_artifacts() {
        // must not require artifacts/ to exist anywhere
        let exec = Exec::native_named("tiny").unwrap();
        assert_eq!(exec.backend_name(), "native");
        assert_eq!(exec.cfg().hidden, 64);
        assert!(exec.serving().tps.contains(&2));
        assert!(exec.serving().buckets.contains(&16));
        // native serving params span the whole context window regardless of
        // whether an artifact dir with narrower export lists is present
        assert!(exec.serving().buckets.contains(&exec.cfg().max_seq));
        assert!(!exec.serving().compiled_shapes);
    }

    #[test]
    fn native_spec_rebuilds_on_any_thread() {
        let exec = Exec::native_named("tiny").unwrap();
        let spec = exec.spec().clone();
        let handle = std::thread::spawn(move || {
            let worker = spec.build().unwrap();
            worker.cfg().layers
        });
        assert_eq!(handle.join().unwrap(), 4);
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("tpu").is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn xla_backend_errors_without_feature() {
        // the feature gap is reported first — "run `make artifacts`" alone
        // could not fix a native-only build
        let err = Exec::open("tiny", BackendKind::Xla).unwrap_err().to_string();
        assert!(err.contains("--features xla"), "{err}");
    }

    #[test]
    fn serving_defaults_respect_divisibility() {
        let cfg = LlamaConfig::builtin("tiny").unwrap();
        let sp = ServingParams::native_default(&cfg);
        assert_eq!(sp.tps, vec![1, 2]); // kv_heads=2 caps TP at 2
        for t in &sp.tps {
            assert_eq!(cfg.heads % t, 0);
        }
    }
}
