//! PJRT client + executable cache.
//!
//! PJRT handles in the `xla` crate are `Rc`-based (not `Send`/`Sync`), so
//! the client is **thread-local**: each engine thread owns one CPU client
//! and its own compilations. Within a thread, the N simulated TP ranks and
//! every layer share a single compilation per (module, phase, shape).
//!
//! The threaded rank runtime leans on exactly this escape hatch: every rank
//! worker thread constructs its own `ExecCache` over the shared artifact
//! directory (see [`crate::engine::ThreadedRuntime`]), so each rank compiles
//! against — and executes on — its own thread-local client, and nothing
//! XLA-shaped ever crosses a thread boundary.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifact::ArtifactDir;

thread_local! {
    static CLIENT: PjRtClient = PjRtClient::cpu().expect("create PJRT CPU client");
}

/// The thread's PJRT CPU client (clones share the underlying client).
pub fn client() -> PjRtClient {
    CLIENT.with(|c| c.clone())
}

/// Lazy compile-on-first-use cache over an artifact directory.
pub struct ExecCache {
    artifacts: ArtifactDir,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
}

impl ExecCache {
    pub fn new(artifacts: ArtifactDir) -> ExecCache {
        ExecCache { artifacts, cache: RefCell::new(HashMap::new()) }
    }

    /// Open the conventional artifact dir for `name` and wrap it.
    pub fn open(name: &str) -> Result<ExecCache> {
        Ok(ExecCache::new(ArtifactDir::open_named(name)?))
    }

    pub fn artifacts(&self) -> &ArtifactDir {
        &self.artifacts
    }

    /// Compile (or fetch) the executable for a module name.
    pub fn get(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.artifacts.module(name)?;
        let path = spec
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {:?}", spec.file))?;
        let proto = HloModuleProto::from_text_file(path)?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Rc::new(client().compile(&comp)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a module: literals in (by reference — weight literals are
    /// shared across layers/calls), decomposed output tuple out.
    ///
    /// All exported modules are lowered with `return_tuple=True`, so the
    /// result is a single tuple buffer which we bring to the host and
    /// decompose.
    pub fn run(&self, name: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        let exe = self.get(name)?;
        let result = exe.execute::<&Literal>(args)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.decompose_tuple()?)
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}
