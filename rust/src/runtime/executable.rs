//! PJRT client + executable cache.
//!
//! PJRT handles in the `xla` crate are `Rc`-based (not `Send`/`Sync`), so
//! the client is **thread-local**: each engine thread owns one CPU client
//! and its own compilations. Within a thread, the N simulated TP ranks and
//! every layer share a single compilation per (module, phase, shape).
//!
//! The threaded rank runtime leans on exactly this escape hatch: every rank
//! worker thread constructs its own `ExecCache` over the shared artifact
//! directory (see [`crate::engine::ThreadedRuntime`]), so each rank compiles
//! against — and executes on — its own thread-local client, and nothing
//! XLA-shaped ever crosses a thread boundary.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifact::ArtifactDir;
use super::backend::{Backend, Value};
use super::literal::{literal_f32, literal_i32};
use crate::model::HostTensor;

thread_local! {
    static CLIENT: PjRtClient = PjRtClient::cpu().expect("create PJRT CPU client");
}

/// The thread's PJRT CPU client (clones share the underlying client).
pub fn client() -> PjRtClient {
    CLIENT.with(|c| c.clone())
}

/// Lazy compile-on-first-use cache over an artifact directory.
pub struct ExecCache {
    artifacts: Rc<ArtifactDir>,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
}

impl ExecCache {
    pub fn new(artifacts: Rc<ArtifactDir>) -> ExecCache {
        ExecCache { artifacts, cache: RefCell::new(HashMap::new()) }
    }

    /// Open the conventional artifact dir for `name` and wrap it.
    pub fn open(name: &str) -> Result<ExecCache> {
        Ok(ExecCache::new(Rc::new(ArtifactDir::open_named(name)?)))
    }

    pub fn artifacts(&self) -> &ArtifactDir {
        &self.artifacts
    }

    /// Compile (or fetch) the executable for a module name.
    pub fn get(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.artifacts.module(name)?;
        let path = spec
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {:?}", spec.file))?;
        let proto = HloModuleProto::from_text_file(path)?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Rc::new(client().compile(&comp)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a module: literals in (by reference — weight literals are
    /// shared across layers/calls), decomposed output tuple out.
    ///
    /// All exported modules are lowered with `return_tuple=True`, so the
    /// result is a single tuple buffer which we bring to the host and
    /// decompose.
    pub fn run(&self, name: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        let exe = self.get(name)?;
        let result = exe.execute::<&Literal>(args)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.decompose_tuple()?)
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// The artifact-backed PJRT implementation of the [`Backend`] trait: values
/// are `xla::Literal`s, module execution compiles-and-caches the exported
/// HLO text through the thread-local CPU client.
pub struct XlaBackend {
    exec: ExecCache,
}

impl XlaBackend {
    pub fn new(exec: ExecCache) -> XlaBackend {
        XlaBackend { exec }
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn upload_f32(&self, data: &[f32], shape: &[usize]) -> Result<Value> {
        Ok(Value::Xla(literal_f32(data, shape)?))
    }

    fn upload_owned(&self, t: HostTensor) -> Result<Value> {
        Ok(Value::Xla(literal_f32(&t.data, &t.shape)?))
    }

    fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<Value> {
        Ok(Value::Xla(literal_i32(data, shape)?))
    }

    fn run(&self, module: &str, args: &[&Value]) -> Result<Vec<Value>> {
        // Paged-KV modules are not part of the AOT export set yet: fail
        // with the actual gap instead of a generic missing-module error
        // from the artifact manifest.
        if module.split("__").next().is_some_and(|k| k.ends_with("_paged")) {
            return Err(anyhow!(
                "module {module:?}: paged-KV attention is not in the HLO export set — \
                 run paged engines on the native backend (`--backend native`), or extend \
                 python/compile to export paged modules"
            ));
        }
        let lits: Vec<&Literal> = args
            .iter()
            .map(|v| match v {
                Value::Xla(lit) => Ok(lit),
                _ => Err(anyhow!("xla backend got a non-xla value for module {module:?}")),
            })
            .collect::<Result<_>>()?;
        Ok(self.exec.run(module, &lits)?.into_iter().map(Value::Xla).collect())
    }

    fn compiled_count(&self) -> usize {
        self.exec.compiled_count()
    }
}