//! HostTensor <-> xla::Literal conversion helpers.
//!
//! §Perf: conversions use `create_from_shape_and_untyped_data` (one copy
//! into the literal) rather than `vec1(..).reshape(..)` (two copies — vec1
//! copies, reshape materializes a second literal). Measured ~12% off the
//! tiny-model decode step (EXPERIMENTS.md §Perf).

use anyhow::Result;
use xla::{ElementType, Literal};

use crate::model::HostTensor;

fn as_bytes<T>(data: &[T]) -> &[u8] {
    // f32/i32 are plain-old-data; the literal copies out of this view.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    }
}

/// f32 host data -> Literal of the given shape (single copy).
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        shape,
        as_bytes(data),
    )?)
}

/// i32 host data -> Literal of the given shape (single copy).
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        shape,
        as_bytes(data),
    )?)
}

impl HostTensor {
    pub fn to_literal(&self) -> Result<Literal> {
        literal_f32(&self.data, &self.shape)
    }
}

/// Literal -> HostTensor (f32).
pub fn tensor_from_literal(lit: &Literal) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    Ok(HostTensor::new(dims, lit.to_vec::<f32>()?))
}
