//! The pure-Rust native backend: a reference executor for every serving
//! module the AOT layer exports, computed directly over [`HostTensor`] data.
//!
//! Semantics mirror `python/compile/model.py` + `kernels/ref.py` op for op
//! (RMSNorm, rotate-half RoPE, causal/cached GQA attention, SwiGLU, f32
//! matmul with sequential-k accumulation), so:
//!
//! * the same module is **deterministic** — the sequential and threaded rank
//!   runtimes produce bitwise-identical logits (`runtime_determinism`), and
//! * where artifacts exist, native logits match the python golden vectors
//!   within the same tolerance the PJRT path does (`engine_numerics`).
//!
//! Module names are the shared vocabulary with the AOT export
//! (`attn_prefill__tp2__b1__s16`, ...); the executor dispatches on the name
//! prefix and reads every dimension from the argument shapes, so any
//! (tp, batch, bucket) combination runs without a compiled-shape registry.
//! Training graphs (`train_*` / `eval_*`) are xla-only: they embed a full
//! backward pass + AdamW that this executor does not reimplement.
//!
//! [`HostTensor`]: crate::model::HostTensor

use std::cell::RefCell;
use std::collections::HashSet;

use anyhow::{anyhow, bail, Result};

use super::backend::{Backend, Value};
use crate::model::{HostTensor, LlamaConfig};

/// The native executor. Stateless apart from the config (KV caches flow
/// through module arguments, exactly like the exported HLO modules).
pub struct NativeBackend {
    cfg: LlamaConfig,
    /// Distinct module names executed so far — the native analog of the
    /// PJRT compilation cache, kept so `compiled_count` stays meaningful.
    seen: RefCell<HashSet<String>>,
}

impl NativeBackend {
    pub fn new(cfg: LlamaConfig) -> NativeBackend {
        NativeBackend { cfg, seen: RefCell::new(HashSet::new()) }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn upload_f32(&self, data: &[f32], shape: &[usize]) -> Result<Value> {
        if shape.iter().product::<usize>() != data.len() {
            bail!("upload_f32: {} elems for shape {shape:?}", data.len());
        }
        Ok(Value::F32(HostTensor::new(shape.to_vec(), data.to_vec())))
    }

    fn upload_owned(&self, t: HostTensor) -> Result<Value> {
        Ok(Value::F32(t))
    }

    fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<Value> {
        if shape.iter().product::<usize>() != data.len() {
            bail!("upload_i32: {} elems for shape {shape:?}", data.len());
        }
        Ok(Value::I32 { shape: shape.to_vec(), data: data.to_vec() })
    }

    fn run(&self, module: &str, args: &[&Value]) -> Result<Vec<Value>> {
        self.seen.borrow_mut().insert(module.to_string());
        let kind = module.split("__").next().unwrap_or(module);
        match kind {
            "embed" => self.embed(module, args),
            "attn_prefill" => self.attn(module, args, Phase::Prefill, false),
            "attn_decode" => self.attn(module, args, Phase::Decode, false),
            "fused_prefill" => self.attn(module, args, Phase::Prefill, true),
            "fused_decode" => self.attn(module, args, Phase::Decode, true),
            "attn_prefill_paged" => self.attn_paged(module, args, Phase::Prefill, false),
            "attn_decode_paged" => self.attn_paged(module, args, Phase::Decode, false),
            "fused_prefill_paged" => self.attn_paged(module, args, Phase::Prefill, true),
            "fused_decode_paged" => self.attn_paged(module, args, Phase::Decode, true),
            "mlp" => self.mlp(module, args),
            "lm_head" => self.lm_head(module, args),
            k if k.starts_with("train_") || k.starts_with("eval_") => bail!(
                "module {module:?}: training/eval graphs run only on the xla backend \
                 (build with `--features xla` after `make artifacts`)"
            ),
            _ => bail!("native backend: unknown module {module:?}"),
        }
    }

    fn compiled_count(&self) -> usize {
        self.seen.borrow().len()
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Phase {
    Prefill,
    Decode,
}

impl NativeBackend {
    /// `embed`: tokens [B,S] i32, emb [V,H] -> h [B,S,H].
    fn embed(&self, module: &str, args: &[&Value]) -> Result<Vec<Value>> {
        let (tokens, tshape) = i32_arg(module, args, 0)?;
        let emb = f32_arg(module, args, 1)?;
        let [v, h] = dims2(module, "emb", &emb.shape)?;
        let mut out = Vec::with_capacity(tokens.len() * h);
        for &tok in tokens {
            let t = tok as usize;
            if tok < 0 || t >= v {
                bail!("{module}: token id {tok} out of range (vocab {v})");
            }
            out.extend_from_slice(&emb.data[t * h..(t + 1) * h]);
        }
        let mut shape = tshape.to_vec();
        shape.push(h);
        Ok(vec![Value::F32(HostTensor::new(shape, out))])
    }

    /// `mlp`: x [B,S,H], norm [H], wg,wu [H,Fl], wd [Fl,H] -> partial.
    fn mlp(&self, module: &str, args: &[&Value]) -> Result<Vec<Value>> {
        if args.len() != 5 {
            bail!("{module}: want 5 args (x, norm, wg, wu, wd), got {}", args.len());
        }
        let x = f32_arg(module, args, 0)?;
        let norm = f32_arg(module, args, 1)?;
        let wg = f32_arg(module, args, 2)?;
        let wu = f32_arg(module, args, 3)?;
        let wd = f32_arg(module, args, 4)?;
        let partial = self.mlp_partial(module, x, norm, wg, wu, wd)?;
        Ok(vec![Value::F32(partial)])
    }

    fn mlp_partial(
        &self,
        module: &str,
        x: &HostTensor,
        norm: &HostTensor,
        wg: &HostTensor,
        wu: &HostTensor,
        wd: &HostTensor,
    ) -> Result<HostTensor> {
        let h = *x.shape.last().ok_or_else(|| anyhow!("{module}: scalar x"))?;
        let rows = x.data.len() / h;
        let [_, fl] = dims2(module, "wg", &wg.shape)?;
        let y = rmsnorm(&x.data, h, &norm.data, self.cfg.norm_eps as f32);
        let gate = matmul(&y, rows, h, &wg.data, fl);
        let up = matmul(&y, rows, h, &wu.data, fl);
        let mut act = vec![0.0f32; rows * fl];
        for ((a, &g), &u) in act.iter_mut().zip(&gate).zip(&up) {
            // SwiGLU: silu(g) * up  ==  g * sigmoid(g) * up
            *a = g * (1.0 / (1.0 + (-g).exp())) * u;
        }
        let out = matmul(&act, rows, fl, &wd.data, h);
        Ok(HostTensor::new(x.shape.clone(), out))
    }

    /// `attn_*` / `fused_*`: the attention block (plus the MLP branch when
    /// fused — PaLM-style shared pre-norm, partials summed).
    ///
    /// Prefill args: x, norm, wq, wk, wv, wo, [wg, wu, wd,] kc, vc
    /// Decode args:  the same, plus lens [B] i32 at the end.
    fn attn(&self, module: &str, args: &[&Value], phase: Phase, fused: bool) -> Result<Vec<Value>> {
        let base = if fused { 9 } else { 6 };
        let want = base + 2 + usize::from(phase == Phase::Decode);
        if args.len() != want {
            bail!("{module}: want {want} args, got {}", args.len());
        }
        let x = f32_arg(module, args, 0)?;
        let norm = f32_arg(module, args, 1)?;
        let wq = f32_arg(module, args, 2)?;
        let wk = f32_arg(module, args, 3)?;
        let wv = f32_arg(module, args, 4)?;
        let wo = f32_arg(module, args, 5)?;
        let kc = f32_arg(module, args, base)?;
        let vc = f32_arg(module, args, base + 1)?;

        if x.shape.len() != 3 {
            bail!("{module}: x wants [B,S,H], got {:?}", x.shape);
        }
        let (b, s, h) = (x.shape[0], x.shape[1], x.shape[2]);
        if kc.shape.len() != 4 || kc.shape != vc.shape || kc.shape[0] != b {
            bail!("{module}: cache shape {:?} vs x {:?}", kc.shape, x.shape);
        }
        let (kvl, max_seq, d) = (kc.shape[1], kc.shape[2], kc.shape[3]);
        if d != self.cfg.head_dim {
            bail!("{module}: cache head_dim {d} != config {}", self.cfg.head_dim);
        }
        if self.cfg.kv_heads % kvl != 0 {
            bail!("{module}: {kvl} local kv heads do not divide kv_heads {}", self.cfg.kv_heads);
        }
        let tp = self.cfg.kv_heads / kvl;
        let hl = self.cfg.heads / tp; // local q heads
        if wq.shape != vec![h, hl * d] || wk.shape != vec![h, kvl * d] {
            bail!(
                "{module}: weight shards wq {:?} / wk {:?} inconsistent with tp={tp}",
                wq.shape,
                wk.shape
            );
        }
        let lens: Vec<i32> = match phase {
            Phase::Prefill => {
                if s > max_seq {
                    bail!("{module}: prefill length {s} exceeds cache {max_seq}");
                }
                Vec::new()
            }
            Phase::Decode => {
                if s != 1 {
                    bail!("{module}: decode wants S=1, got {s}");
                }
                let (l, lshape) = i32_arg(module, args, want - 1)?;
                if lshape != [b] {
                    bail!("{module}: lens shape {lshape:?}, want [{b}]");
                }
                l.to_vec()
            }
        };

        // projections on the normed input (rows = B*S, layout [row, head*d])
        let rows = b * s;
        let y = rmsnorm(&x.data, h, &norm.data, self.cfg.norm_eps as f32);
        let mut q = matmul(&y, rows, h, &wq.data, hl * d);
        let mut k = matmul(&y, rows, h, &wk.data, kvl * d);
        let v = matmul(&y, rows, h, &wv.data, kvl * d);

        // rotary embedding; positions are 0..S (prefill) or lens[b] (decode)
        let theta = self.cfg.rope_theta as f32;
        let pos_of = |bi: usize, si: usize| -> f32 {
            match phase {
                Phase::Prefill => si as f32,
                Phase::Decode => lens[bi] as f32,
            }
        };
        rope(&mut q, b, s, hl, d, theta, &pos_of);
        rope(&mut k, b, s, kvl, d, theta, &pos_of);

        // cache update (jax dynamic_update_slice semantics: indices
        // clamped). Functional like the exported modules: updated copies go
        // back in the outputs — one slab memcpy per call, comparable to the
        // xla path's literal conversion; an in-place variant would need a
        // consuming `Backend::run` (future work).
        let mut kc2 = kc.data.clone();
        let mut vc2 = vc.data.clone();
        let cache_at = |bi: usize, kh: usize, j: usize| ((bi * kvl + kh) * max_seq + j) * d;
        for bi in 0..b {
            for si in 0..s {
                let j = match phase {
                    Phase::Prefill => si,
                    Phase::Decode => (lens[bi].max(0) as usize).min(max_seq - 1),
                };
                for kh in 0..kvl {
                    let src = (bi * s + si) * kvl * d + kh * d;
                    let dst = cache_at(bi, kh, j);
                    kc2[dst..dst + d].copy_from_slice(&k[src..src + d]);
                    vc2[dst..dst + d].copy_from_slice(&v[src..src + d]);
                }
            }
        }

        // attention: causal over the fresh K/V (prefill) or masked over the
        // updated cache (decode attends positions < lens+1)
        let group = hl / kvl;
        let scale = (d as f32).powf(-0.5);
        let mut attn_out = vec![0.0f32; rows * hl * d]; // [row, head*d]
        let mut probs = vec![0.0f32; max_seq.max(s)];
        for bi in 0..b {
            for head in 0..hl {
                let kh = head / group;
                for qi in 0..s {
                    let qoff = (bi * s + qi) * hl * d + head * d;
                    // valid context length + where key/value j lives
                    let ctx = match phase {
                        Phase::Prefill => qi + 1, // causal: keys 0..=qi
                        Phase::Decode => ((lens[bi].max(0) as usize) + 1).min(max_seq),
                    };
                    let (keys, vals): (&[f32], &[f32]) = match phase {
                        Phase::Prefill => (&k, &v),
                        Phase::Decode => (&kc2, &vc2),
                    };
                    let kv_off = |j: usize| match phase {
                        Phase::Prefill => (bi * s + j) * kvl * d + kh * d,
                        Phase::Decode => cache_at(bi, kh, j),
                    };
                    let qrow = &q[qoff..qoff + d];
                    let mut m = f32::NEG_INFINITY;
                    for (j, p) in probs.iter_mut().enumerate().take(ctx) {
                        let koff = kv_off(j);
                        let mut dot = 0.0f32;
                        for (a, kb) in qrow.iter().zip(&keys[koff..koff + d]) {
                            dot += a * kb;
                        }
                        *p = dot * scale;
                        m = m.max(*p);
                    }
                    let mut denom = 0.0f32;
                    for p in probs.iter_mut().take(ctx) {
                        *p = (*p - m).exp();
                        denom += *p;
                    }
                    let out = &mut attn_out[qoff..qoff + d];
                    for (j, p) in probs.iter().enumerate().take(ctx) {
                        let w = p / denom;
                        let voff = kv_off(j);
                        for (o, vv) in out.iter_mut().zip(&vals[voff..voff + d]) {
                            *o += w * vv;
                        }
                    }
                }
            }
        }

        // output projection back to the residual width
        let mut partial =
            HostTensor::new(x.shape.clone(), matmul(&attn_out, rows, hl * d, &wo.data, h));

        if fused {
            let wg = f32_arg(module, args, 6)?;
            let wu = f32_arg(module, args, 7)?;
            let wd = f32_arg(module, args, 8)?;
            // PaLM fusion: the MLP branch reuses the shared pre-norm weights
            let mlp = self.mlp_partial(module, x, norm, wg, wu, wd)?;
            for (a, m) in partial.data.iter_mut().zip(&mlp.data) {
                *a += m;
            }
        }

        Ok(vec![
            Value::F32(partial),
            Value::F32(HostTensor::new(kc.shape.clone(), kc2)),
            Value::F32(HostTensor::new(vc.shape.clone(), vc2)),
        ])
    }

    /// `attn_*_paged` / `fused_*_paged`: the attention block with its K/V
    /// reads and writes routed through **page tables** instead of per-slot
    /// slabs (plus the MLP branch when fused).
    ///
    /// Prefill args: x, norm, wq, wk, wv, wo, [wg, wu, wd,] k_pool, v_pool,
    ///               table i32 [B, maxp], start i32 [B]
    /// Decode args:  ..., k_pool, v_pool, table i32 [B, maxp], lens i32 [B]
    ///
    /// Pools are `[P, KVl, page_size, D]`; token position `t` of row `b`
    /// lives in page `table[b][t / page_size]` at offset `t % page_size`.
    /// Outputs are `(partial, k_rows, v_rows)` where the row tensors are
    /// `[B, S, KVl, D]` — only the *freshly written* entries, which the
    /// caller scatters into its pool (the module never mutates the pool, so
    /// it stays functional like every other exported module while avoiding
    /// a whole-pool copy in its outputs).
    ///
    /// Bitwise contract: for every query, keys are visited in ascending
    /// logical position (pool pages for the cached prefix, then the fresh
    /// chunk), which is exactly the slab path's accumulation order — so
    /// chunked-paged logits are bit-identical to one-shot slab logits
    /// (asserted by the unit tests below and the paged stress harness).
    ///
    /// A decode row with `lens[b] < 0` is **inactive** (idle batch slot):
    /// its attention is skipped entirely — no pool read, `partial` row
    /// zeros from the attention branch — and the caller must not scatter
    /// its `k_rows`/`v_rows`.
    fn attn_paged(
        &self,
        module: &str,
        args: &[&Value],
        phase: Phase,
        fused: bool,
    ) -> Result<Vec<Value>> {
        let base = if fused { 9 } else { 6 };
        let want = base + 4;
        if args.len() != want {
            bail!("{module}: want {want} args, got {}", args.len());
        }
        let x = f32_arg(module, args, 0)?;
        let norm = f32_arg(module, args, 1)?;
        let wq = f32_arg(module, args, 2)?;
        let wk = f32_arg(module, args, 3)?;
        let wv = f32_arg(module, args, 4)?;
        let wo = f32_arg(module, args, 5)?;
        let k_pool = f32_arg(module, args, base)?;
        let v_pool = f32_arg(module, args, base + 1)?;
        let (table, tshape) = i32_arg(module, args, base + 2)?;
        let (pos_arg, pshape) = i32_arg(module, args, base + 3)?;

        if x.shape.len() != 3 {
            bail!("{module}: x wants [B,S,H], got {:?}", x.shape);
        }
        let (b, s, h) = (x.shape[0], x.shape[1], x.shape[2]);
        if k_pool.shape.len() != 4 || k_pool.shape != v_pool.shape {
            bail!("{module}: pool shape {:?} vs {:?}", k_pool.shape, v_pool.shape);
        }
        let (pages, kvl, page_size, d) =
            (k_pool.shape[0], k_pool.shape[1], k_pool.shape[2], k_pool.shape[3]);
        if d != self.cfg.head_dim {
            bail!("{module}: pool head_dim {d} != config {}", self.cfg.head_dim);
        }
        if self.cfg.kv_heads % kvl != 0 {
            bail!("{module}: {kvl} local kv heads do not divide kv_heads {}", self.cfg.kv_heads);
        }
        let tp = self.cfg.kv_heads / kvl;
        let hl = self.cfg.heads / tp; // local q heads
        if wq.shape != vec![h, hl * d] || wk.shape != vec![h, kvl * d] {
            bail!(
                "{module}: weight shards wq {:?} / wk {:?} inconsistent with tp={tp}",
                wq.shape,
                wk.shape
            );
        }
        if tshape.len() != 2 || tshape[0] != b {
            bail!("{module}: table shape {tshape:?}, want [{b}, maxp]");
        }
        let maxp = tshape[1];
        if pshape != [b] {
            bail!("{module}: positions shape {pshape:?}, want [{b}]");
        }
        if phase == Phase::Decode && s != 1 {
            bail!("{module}: decode wants S=1, got {s}");
        }

        // projections on the normed input (rows = B*S, layout [row, head*d])
        let rows = b * s;
        let y = rmsnorm(&x.data, h, &norm.data, self.cfg.norm_eps as f32);
        let mut q = matmul(&y, rows, h, &wq.data, hl * d);
        let mut k = matmul(&y, rows, h, &wk.data, kvl * d);
        let v = matmul(&y, rows, h, &wv.data, kvl * d);

        // rotary positions: start[b] + si (chunked prefill) or lens[b]
        // (decode). Inactive decode rows (lens < 0) rotate by a garbage
        // position; their projections are never read.
        let theta = self.cfg.rope_theta as f32;
        let pos_of = |bi: usize, si: usize| -> f32 {
            match phase {
                Phase::Prefill => (pos_arg[bi] + si as i32) as f32,
                Phase::Decode => pos_arg[bi].max(0) as f32,
            }
        };
        rope(&mut q, b, s, hl, d, theta, &pos_of);
        rope(&mut k, b, s, kvl, d, theta, &pos_of);

        // one key/value slice per logical position: the cached prefix comes
        // from the pool through the page table, the fresh chunk from k/v.
        let pool_at = |bi: usize, kh: usize, j: usize| -> Result<usize> {
            // bound within the ROW: an overflow on a non-last row would
            // otherwise silently read the next request's page id
            let pi = j / page_size;
            if pi >= maxp {
                bail!("{module}: row {bi} position {j} beyond its {maxp}-page table");
            }
            let page = table[bi * maxp + pi];
            if page < 0 || page as usize >= pages {
                bail!("{module}: row {bi} position {j} maps to invalid page {page}");
            }
            Ok(((page as usize * kvl + kh) * page_size + j % page_size) * d)
        };

        let group = hl / kvl;
        let scale = (d as f32).powf(-0.5);
        let mut attn_out = vec![0.0f32; rows * hl * d]; // [row, head*d]
        let mut probs = vec![0.0f32; maxp * page_size + s];
        for bi in 0..b {
            // logical positions below `boundary` live in the pool; at or
            // above it they are rows of this call's fresh K/V
            let boundary = match phase {
                Phase::Prefill => pos_arg[bi].max(0) as usize,
                Phase::Decode => {
                    if pos_arg[bi] < 0 {
                        continue; // inactive slot: attention skipped
                    }
                    pos_arg[bi] as usize
                }
            };
            for head in 0..hl {
                let kh = head / group;
                for qi in 0..s {
                    let qoff = (bi * s + qi) * hl * d + head * d;
                    let ctx = boundary + qi + 1; // causal over logical positions
                    if ctx > probs.len() {
                        bail!(
                            "{module}: row {bi} context {ctx} exceeds the page table's \
                             {maxp} pages"
                        );
                    }
                    let qrow = &q[qoff..qoff + d];
                    let mut m = f32::NEG_INFINITY;
                    for (j, p) in probs.iter_mut().enumerate().take(ctx) {
                        let keys: &[f32] = if j < boundary { &k_pool.data } else { &k };
                        let koff = if j < boundary {
                            pool_at(bi, kh, j)?
                        } else {
                            (bi * s + (j - boundary)) * kvl * d + kh * d
                        };
                        let mut dot = 0.0f32;
                        for (a, kb) in qrow.iter().zip(&keys[koff..koff + d]) {
                            dot += a * kb;
                        }
                        *p = dot * scale;
                        m = m.max(*p);
                    }
                    let mut denom = 0.0f32;
                    for p in probs.iter_mut().take(ctx) {
                        *p = (*p - m).exp();
                        denom += *p;
                    }
                    let out = &mut attn_out[qoff..qoff + d];
                    for (j, p) in probs.iter().enumerate().take(ctx) {
                        let w = p / denom;
                        let vals: &[f32] = if j < boundary { &v_pool.data } else { &v };
                        let voff = if j < boundary {
                            pool_at(bi, kh, j)?
                        } else {
                            (bi * s + (j - boundary)) * kvl * d + kh * d
                        };
                        for (o, vv) in out.iter_mut().zip(&vals[voff..voff + d]) {
                            *o += w * vv;
                        }
                    }
                }
            }
        }

        // output projection back to the residual width
        let mut partial =
            HostTensor::new(x.shape.clone(), matmul(&attn_out, rows, hl * d, &wo.data, h));

        if fused {
            let wg = f32_arg(module, args, 6)?;
            let wu = f32_arg(module, args, 7)?;
            let wd = f32_arg(module, args, 8)?;
            // PaLM fusion: the MLP branch reuses the shared pre-norm weights
            let mlp = self.mlp_partial(module, x, norm, wg, wu, wd)?;
            for (a, m) in partial.data.iter_mut().zip(&mlp.data) {
                *a += m;
            }
        }

        let row_shape = vec![b, s, kvl, d];
        Ok(vec![
            Value::F32(partial),
            Value::F32(HostTensor::new(row_shape.clone(), k)),
            Value::F32(HostTensor::new(row_shape, v)),
        ])
    }

    /// `lm_head`: x [B,H], norm [H], wlm [H,Vl] -> logits [B,Vl].
    fn lm_head(&self, module: &str, args: &[&Value]) -> Result<Vec<Value>> {
        if args.len() != 3 {
            bail!("{module}: want 3 args (x, norm, wlm), got {}", args.len());
        }
        let x = f32_arg(module, args, 0)?;
        let norm = f32_arg(module, args, 1)?;
        let wlm = f32_arg(module, args, 2)?;
        let [b, h] = dims2(module, "x", &x.shape)?;
        let [wh, vl] = dims2(module, "wlm", &wlm.shape)?;
        if wh != h {
            bail!("{module}: x hidden {h} vs wlm {wh}");
        }
        let y = rmsnorm(&x.data, h, &norm.data, self.cfg.norm_eps as f32);
        let logits = matmul(&y, b, h, &wlm.data, vl);
        Ok(vec![Value::F32(HostTensor::new(vec![b, vl], logits))])
    }
}

// ---------------------------------------------------------------------------
// kernels (f32, sequential accumulation: deterministic on every runtime)
// ---------------------------------------------------------------------------

/// RMSNorm over the last axis: x / rms(x) * w.
fn rmsnorm(x: &[f32], h: usize, w: &[f32], eps: f32) -> Vec<f32> {
    debug_assert_eq!(w.len(), h);
    let mut out = vec![0.0f32; x.len()];
    for (row, orow) in x.chunks_exact(h).zip(out.chunks_exact_mut(h)) {
        let mut ss = 0.0f32;
        for v in row {
            ss += v * v;
        }
        let inv = (ss / h as f32 + eps).powf(-0.5);
        for ((o, v), wi) in orow.iter_mut().zip(row).zip(w) {
            *o = v * inv * wi;
        }
    }
    out
}

/// Row-major [M,K] @ [K,N] with k-sequential f32 accumulation (i-k-j loop:
/// vectorizes over j, keeps the summation order identical to the naive
/// definition, so results are bitwise-stable across runtimes).
fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bkj) in orow.iter_mut().zip(brow) {
                *o += aik * bkj;
            }
        }
    }
    out
}

/// Llama rotate-half RoPE applied in the [row, head*d] projection layout.
/// `pos_of(b, s)` supplies the rotary position of each token.
fn rope(
    x: &mut [f32],
    b: usize,
    s: usize,
    heads: usize,
    d: usize,
    theta: f32,
    pos_of: &dyn Fn(usize, usize) -> f32,
) {
    let half = d / 2;
    let freqs: Vec<f32> = (0..half).map(|i| theta.powf(i as f32 / half as f32).recip()).collect();
    for bi in 0..b {
        for si in 0..s {
            let pos = pos_of(bi, si);
            for head in 0..heads {
                let off = (bi * s + si) * heads * d + head * d;
                for (i, f) in freqs.iter().enumerate() {
                    let angle = pos * f;
                    let (sin, cos) = angle.sin_cos();
                    let x1 = x[off + i];
                    let x2 = x[off + half + i];
                    x[off + i] = x1 * cos - x2 * sin;
                    x[off + half + i] = x2 * cos + x1 * sin;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::Backend as _;

    fn backend() -> NativeBackend {
        NativeBackend::new(LlamaConfig::builtin("tiny").unwrap())
    }

    fn f32v(t: HostTensor) -> Value {
        Value::F32(t)
    }

    #[test]
    fn embed_gathers_rows() {
        let be = backend();
        let emb = f32v(HostTensor::new(vec![4, 2], vec![0., 1., 10., 11., 20., 21., 30., 31.]));
        let toks = be.upload_i32(&[3, 0, 2, 1], &[2, 2]).unwrap();
        let out = be.run("embed__b2__s2", &[&toks, &emb]).unwrap();
        let h = out[0].to_f32().unwrap();
        assert_eq!(h.shape, vec![2, 2, 2]);
        assert_eq!(h.data, vec![30., 31., 0., 1., 20., 21., 10., 11.]);
        // out-of-range token is an error, not UB
        let bad = be.upload_i32(&[9, 0, 0, 0], &[2, 2]).unwrap();
        assert!(be.run("embed__b2__s2", &[&bad, &emb]).is_err());
    }

    #[test]
    fn rmsnorm_matches_reference_formula() {
        let out = rmsnorm(&[3.0, 4.0], 2, &[1.0, 2.0], 0.0);
        // rms = sqrt((9+16)/2); y = x/rms * w
        let rms = (12.5f32).sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-6);
        assert!((out[1] - 2.0 * 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn matmul_identity_and_shapes() {
        let a = vec![1., 2., 3., 4., 5., 6.]; // [2,3]
        let eye = vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]; // [3,3]
        assert_eq!(matmul(&a, 2, 3, &eye, 3), a);
    }

    #[test]
    fn rope_at_position_zero_is_identity() {
        let orig = vec![0.5, -1.0, 2.0, 3.0];
        let mut x = orig.clone();
        rope(&mut x, 1, 1, 1, 4, 10000.0, &|_, _| 0.0);
        assert_eq!(x, orig);
        // nonzero position rotates but preserves pairwise norms
        rope(&mut x, 1, 1, 1, 4, 10000.0, &|_, _| 3.0);
        let n = |a: f32, b: f32| (a * a + b * b).sqrt();
        assert!((n(x[0], x[2]) - n(orig[0], orig[2])).abs() < 1e-5);
        assert!((n(x[1], x[3]) - n(orig[1], orig[3])).abs() < 1e-5);
    }

    /// Teacher-forced decode must reproduce the prefill computation: running
    /// attn_prefill over s+1 tokens equals attn_prefill over s tokens
    /// followed by attn_decode of the last token (same cache discipline the
    /// engine relies on).
    #[test]
    fn decode_step_matches_prefill_extension() {
        let be = backend();
        let cfg = LlamaConfig::builtin("tiny").unwrap();
        let (h, d) = (cfg.hidden, cfg.head_dim);
        let tp = 2;
        let (hl, kvl) = (cfg.heads / tp, cfg.kv_heads / tp);
        let mut rng = crate::util::rng::Rng::new(42);
        let mut t = |r: usize, c: usize, scale: f32| {
            HostTensor::new(vec![r, c], rng.normal_vec(r * c, scale))
        };
        let norm = f32v(HostTensor::new(vec![h], vec![1.0; h]));
        let wq = f32v(t(h, hl * d, 0.1));
        let wk = f32v(t(h, kvl * d, 0.1));
        let wv = f32v(t(h, kvl * d, 0.1));
        let wo = f32v(t(hl * d, h, 0.1));
        let s = 3;
        let x_full = t(1, (s + 1) * h, 0.5).data; // [1, s+1, H] flattened
        let max_seq = 8;
        let kc0 = f32v(HostTensor::zeros(vec![1, kvl, max_seq, d]));
        let vc0 = f32v(HostTensor::zeros(vec![1, kvl, max_seq, d]));

        // one-shot prefill over s+1 tokens
        let x_a = f32v(HostTensor::new(vec![1, s + 1, h], x_full.clone()));
        let full = be
            .run("attn_prefill__tp2__b1__s4", &[&x_a, &norm, &wq, &wk, &wv, &wo, &kc0, &vc0])
            .unwrap();
        let full_partial = full[0].to_f32().unwrap();

        // prefill s tokens, then decode token s at position s
        let x_b = f32v(HostTensor::new(vec![1, s, h], x_full[..s * h].to_vec()));
        let pre = be
            .run("attn_prefill__tp2__b1__s3", &[&x_b, &norm, &wq, &wk, &wv, &wo, &kc0, &vc0])
            .unwrap();
        let kc1 = &pre[1];
        let vc1 = &pre[2];
        let x_c = f32v(HostTensor::new(vec![1, 1, h], x_full[s * h..].to_vec()));
        let lens = be.upload_i32(&[s as i32], &[1]).unwrap();
        let dec = be
            .run("attn_decode__tp2__b1", &[&x_c, &norm, &wq, &wk, &wv, &wo, kc1, vc1, &lens])
            .unwrap();
        let dec_partial = dec[0].to_f32().unwrap();

        let last_row = &full_partial.data[s * h..(s + 1) * h];
        for (a, b) in last_row.iter().zip(&dec_partial.data) {
            assert!((a - b).abs() < 1e-5, "prefill {a} vs decode {b}");
        }
    }

    #[test]
    fn decode_ignores_cache_beyond_length() {
        let be = backend();
        let cfg = LlamaConfig::builtin("tiny").unwrap();
        let (h, d) = (cfg.hidden, cfg.head_dim);
        let (hl, kvl) = (cfg.heads / 2, cfg.kv_heads / 2);
        let mut rng = crate::util::rng::Rng::new(7);
        let mut t = |r: usize, c: usize| HostTensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
        let norm = f32v(HostTensor::new(vec![h], vec![1.0; h]));
        let (wq, wk, wv, wo) =
            (f32v(t(h, hl * d)), f32v(t(h, kvl * d)), f32v(t(h, kvl * d)), f32v(t(hl * d, h)));
        let max_seq = 8;
        let x = f32v(t(1, h));
        let x = if let Value::F32(mut xt) = x {
            xt.shape = vec![1, 1, h];
            f32v(xt)
        } else {
            unreachable!()
        };
        let lens = be.upload_i32(&[2], &[1]).unwrap();
        let run_with_garbage = |fill: f32| {
            let mut kc = HostTensor::zeros(vec![1, kvl, max_seq, d]);
            let mut vcv = HostTensor::zeros(vec![1, kvl, max_seq, d]);
            // positions >= 3 hold garbage that must be masked out
            for kh in 0..kvl {
                let (lo, hi) = ((kh * max_seq + 3) * d, (kh + 1) * max_seq * d);
                kc.data[lo..hi].fill(fill);
                vcv.data[lo..hi].fill(-fill);
            }
            let (kc, vcv) = (f32v(kc), f32v(vcv));
            let out = be
                .run("attn_decode__tp2__b1", &[&x, &norm, &wq, &wk, &wv, &wo, &kc, &vcv, &lens])
                .unwrap();
            out[0].to_f32().unwrap().data
        };
        assert_eq!(run_with_garbage(0.0), run_with_garbage(1e6));
    }

    #[test]
    fn fused_is_attn_plus_mlp_with_shared_norm() {
        let be = backend();
        let cfg = LlamaConfig::builtin("tiny").unwrap();
        let (h, d, f) = (cfg.hidden, cfg.head_dim, cfg.ffn);
        let (hl, kvl, fl) = (cfg.heads / 2, cfg.kv_heads / 2, f / 2);
        let mut rng = crate::util::rng::Rng::new(3);
        let mut t = |r: usize, c: usize| HostTensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
        let norm = f32v(HostTensor::new(vec![h], rng.normal_vec(h, 1.0)));
        let (wq, wk, wv, wo) =
            (f32v(t(h, hl * d)), f32v(t(h, kvl * d)), f32v(t(h, kvl * d)), f32v(t(hl * d, h)));
        let (wg, wu, wd) = (f32v(t(h, fl)), f32v(t(h, fl)), f32v(t(fl, h)));
        let x = f32v(HostTensor::new(vec![1, 2, h], rng.normal_vec(2 * h, 0.5)));
        let kc = f32v(HostTensor::zeros(vec![1, kvl, 8, d]));
        let vc = f32v(HostTensor::zeros(vec![1, kvl, 8, d]));

        let fused = be
            .run(
                "fused_prefill__tp2__b1__s2",
                &[&x, &norm, &wq, &wk, &wv, &wo, &wg, &wu, &wd, &kc, &vc],
            )
            .unwrap();
        let attn = be
            .run("attn_prefill__tp2__b1__s2", &[&x, &norm, &wq, &wk, &wv, &wo, &kc, &vc])
            .unwrap();
        // NB: fused reuses the *attention* norm for the MLP branch
        let mlp = be.run("mlp__tp2__b1__s2", &[&x, &norm, &wg, &wu, &wd]).unwrap();
        let fused_t = fused[0].to_f32().unwrap();
        let attn_t = attn[0].to_f32().unwrap();
        let mlp_t = mlp[0].to_f32().unwrap();
        for ((f, a), m) in fused_t.data.iter().zip(&attn_t.data).zip(&mlp_t.data) {
            assert!((f - (a + m)).abs() < 1e-6);
        }
    }

    /// Scatter `[1, s, kvl, d]` fresh rows into a `[P, kvl, page, d]` pool
    /// at logical positions `start..start+s` — the host-side write the
    /// engine's rank state performs after every paged module call.
    fn scatter(pool: &mut HostTensor, rows: &HostTensor, start: usize, table: &[i32]) {
        let (kvl, page, d) = (pool.shape[1], pool.shape[2], pool.shape[3]);
        let s = rows.shape[1];
        for si in 0..s {
            let pos = start + si;
            let pg = table[pos / page] as usize;
            for kh in 0..kvl {
                let src = (si * kvl + kh) * d;
                let at = ((pg * kvl + kh) * page + pos % page) * d;
                pool.data[at..at + d].copy_from_slice(&rows.data[src..src + d]);
            }
        }
    }

    /// Chunked prefill + decode through page tables must reproduce the slab
    /// path BITWISE (same values, same accumulation order) — this is the
    /// contract that lets the fixed-slot determinism suites stay the oracle
    /// for the paged serving path.
    #[test]
    fn paged_attention_is_bitwise_identical_to_slab() {
        let be = backend();
        let cfg = LlamaConfig::builtin("tiny").unwrap();
        let (h, d) = (cfg.hidden, cfg.head_dim);
        let tp = 2;
        let (hl, kvl) = (cfg.heads / tp, cfg.kv_heads / tp);
        let mut rng = crate::util::rng::Rng::new(0x9a6e);
        let mut t = |r: usize, c: usize, scale: f32| {
            HostTensor::new(vec![r, c], rng.normal_vec(r * c, scale))
        };
        let norm = f32v(HostTensor::new(vec![h], rng.normal_vec(h, 1.0)));
        let wq = f32v(t(h, hl * d, 0.1));
        let wk = f32v(t(h, kvl * d, 0.1));
        let wv = f32v(t(h, kvl * d, 0.1));
        let wo = f32v(t(hl * d, h, 0.1));
        let prompt_len = 5;
        let x_full = t(1, (prompt_len + 1) * h, 0.5).data; // prompt + 1 decode row

        // -- slab reference: one-shot prefill over 5 rows, then a decode --
        let max_seq = 8;
        let kc0 = f32v(HostTensor::zeros(vec![1, kvl, max_seq, d]));
        let vc0 = f32v(HostTensor::zeros(vec![1, kvl, max_seq, d]));
        let x_a = f32v(HostTensor::new(vec![1, prompt_len, h], x_full[..prompt_len * h].to_vec()));
        let slab_pre = be
            .run("attn_prefill__tp2__b1__s5", &[&x_a, &norm, &wq, &wk, &wv, &wo, &kc0, &vc0])
            .unwrap();
        let slab_partial = slab_pre[0].to_f32().unwrap();
        let x_d = f32v(HostTensor::new(vec![1, 1, h], x_full[prompt_len * h..].to_vec()));
        let lens = be.upload_i32(&[prompt_len as i32], &[1]).unwrap();
        let slab_dec = be
            .run(
                "attn_decode__tp2__b1",
                &[&x_d, &norm, &wq, &wk, &wv, &wo, &slab_pre[1], &slab_pre[2], &lens],
            )
            .unwrap();
        let slab_dec_partial = slab_dec[0].to_f32().unwrap();

        // -- paged: page_size 2, prefill in chunks of 3 + 2, then decode --
        let (pages, page) = (4usize, 2usize);
        let table: Vec<i32> = vec![0, 1, 2, 3];
        let mut k_pool = HostTensor::zeros(vec![pages, kvl, page, d]);
        let mut v_pool = HostTensor::zeros(vec![pages, kvl, page, d]);
        let table_v = be.upload_i32(&table, &[1, pages]).unwrap();
        let run_chunk = |kp_h: &mut HostTensor, vp_h: &mut HostTensor, start: usize, s: usize| {
            let x = f32v(HostTensor::new(
                vec![1, s, h],
                x_full[start * h..(start + s) * h].to_vec(),
            ));
            let kp = f32v(kp_h.clone());
            let vp = f32v(vp_h.clone());
            let st = be.upload_i32(&[start as i32], &[1]).unwrap();
            let out = be
                .run(
                    &format!("attn_prefill_paged__tp2__b1__s{s}"),
                    &[&x, &norm, &wq, &wk, &wv, &wo, &kp, &vp, &table_v, &st],
                )
                .unwrap();
            let partial = out[0].to_f32().unwrap();
            scatter(kp_h, &out[1].to_f32().unwrap(), start, &table);
            scatter(vp_h, &out[2].to_f32().unwrap(), start, &table);
            partial
        };
        let chunk_a = run_chunk(&mut k_pool, &mut v_pool, 0, 3);
        let chunk_b = run_chunk(&mut k_pool, &mut v_pool, 3, 2);
        // chunk rows must equal the corresponding one-shot prefill rows,
        // bit for bit (assert_eq on f32: exact equality)
        assert_eq!(chunk_a.data[..], slab_partial.data[..3 * h]);
        assert_eq!(chunk_b.data[..], slab_partial.data[3 * h..]);

        let kp = f32v(k_pool.clone());
        let vp = f32v(v_pool.clone());
        let paged_dec = be
            .run(
                "attn_decode_paged__tp2__b1",
                &[&x_d, &norm, &wq, &wk, &wv, &wo, &kp, &vp, &table_v, &lens],
            )
            .unwrap();
        assert_eq!(paged_dec[0].to_f32().unwrap().data, slab_dec_partial.data);
        // the fresh decode rows the caller would scatter are the rotated
        // K/V of position 5 — identical to what the slab wrote there
        let slab_kc = slab_dec[1].to_f32().unwrap();
        let k_rows = paged_dec[1].to_f32().unwrap();
        for kh in 0..kvl {
            let slab_at = (kh * max_seq + prompt_len) * d;
            assert_eq!(k_rows.data[kh * d..(kh + 1) * d], slab_kc.data[slab_at..slab_at + d]);
        }
    }

    #[test]
    fn paged_decode_skips_inactive_rows() {
        let be = backend();
        let cfg = LlamaConfig::builtin("tiny").unwrap();
        let (h, d) = (cfg.hidden, cfg.head_dim);
        let (hl, kvl) = (cfg.heads / 2, cfg.kv_heads / 2);
        let mut rng = crate::util::rng::Rng::new(0x51ee);
        let mut t = |r: usize, c: usize| HostTensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
        let norm = f32v(HostTensor::new(vec![h], vec![1.0; h]));
        let (wq, wk, wv, wo) =
            (f32v(t(h, hl * d)), f32v(t(h, kvl * d)), f32v(t(h, kvl * d)), f32v(t(hl * d, h)));
        let (pages, page) = (2usize, 4usize);
        let mut k_pool = HostTensor::zeros(vec![pages, kvl, page, d]);
        let mut v_pool = HostTensor::zeros(vec![pages, kvl, page, d]);
        // seed the pool with a 2-token prefix for the active row
        let x_pre = f32v(HostTensor::new(vec![1, 2, h], rng.normal_vec(2 * h, 0.5)));
        let table1 = be.upload_i32(&[0, 1], &[1, 2]).unwrap();
        let start = be.upload_i32(&[0], &[1]).unwrap();
        let kp = f32v(k_pool.clone());
        let vp = f32v(v_pool.clone());
        let pre = be
            .run(
                "attn_prefill_paged__tp2__b1__s2",
                &[&x_pre, &norm, &wq, &wk, &wv, &wo, &kp, &vp, &table1, &start],
            )
            .unwrap();
        scatter(&mut k_pool, &pre[1].to_f32().unwrap(), 0, &[0, 1]);
        scatter(&mut v_pool, &pre[2].to_f32().unwrap(), 0, &[0, 1]);

        let x_row = rng.normal_vec(h, 0.5);
        // b=1 reference decode for the active row
        let kp = f32v(k_pool.clone());
        let vp = f32v(v_pool.clone());
        let x1 = f32v(HostTensor::new(vec![1, 1, h], x_row.clone()));
        let lens1 = be.upload_i32(&[2], &[1]).unwrap();
        let solo = be
            .run(
                "attn_decode_paged__tp2__b1",
                &[&x1, &norm, &wq, &wk, &wv, &wo, &kp, &vp, &table1, &lens1],
            )
            .unwrap();
        // b=2: row 0 inactive (lens -1, table -1), row 1 is the active row
        let mut x2 = rng.normal_vec(h, 0.5); // garbage activation, ignored
        x2.extend_from_slice(&x_row);
        let x2 = f32v(HostTensor::new(vec![2, 1, h], x2));
        let kp = f32v(k_pool.clone());
        let vp = f32v(v_pool.clone());
        let table2 = be.upload_i32(&[-1, -1, 0, 1], &[2, 2]).unwrap();
        let lens2 = be.upload_i32(&[-1, 2], &[2]).unwrap();
        let mixed = be
            .run(
                "attn_decode_paged__tp2__b2",
                &[&x2, &norm, &wq, &wk, &wv, &wo, &kp, &vp, &table2, &lens2],
            )
            .unwrap();
        let partial = mixed[0].to_f32().unwrap();
        // inactive row: all-zero attention output, no pool access
        assert!(partial.data[..h].iter().all(|&x| x == 0.0));
        // active row: bitwise equal to the b=1 run
        assert_eq!(partial.data[h..], solo[0].to_f32().unwrap().data[..]);
    }

    #[test]
    fn training_modules_name_the_xla_path() {
        let be = backend();
        let err = be.run("train_standard", &[]).unwrap_err().to_string();
        assert!(err.contains("--features xla"), "{err}");
    }
}

/// Shape helper: exactly-2D assertion with a named error.
fn dims2(module: &str, what: &str, shape: &[usize]) -> Result<[usize; 2]> {
    match shape {
        [a, b] => Ok([*a, *b]),
        _ => bail!("{module}: {what} wants 2 dims, got {shape:?}"),
    }
}

/// Typed argument accessors (errors name the module for debuggability).
/// `.copied()` drops the slice-borrow indirection so the returned reference
/// carries the values' own lifetime.
fn f32_arg<'a>(module: &str, args: &[&'a Value], i: usize) -> Result<&'a HostTensor> {
    match args.get(i).copied() {
        Some(Value::F32(t)) => Ok(t),
        Some(_) => bail!("{module}: arg {i} is not f32"),
        None => bail!("{module}: missing arg {i}"),
    }
}

fn i32_arg<'a>(module: &str, args: &[&'a Value], i: usize) -> Result<(&'a [i32], &'a [usize])> {
    match args.get(i).copied() {
        Some(Value::I32 { shape, data }) => Ok((data, shape)),
        Some(_) => bail!("{module}: arg {i} is not i32"),
        None => bail!("{module}: missing arg {i}"),
    }
}
