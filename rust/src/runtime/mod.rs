//! L3 runtime: pluggable execution backends behind the [`Backend`] trait.
//!
//! * [`native`] (default) — pure-Rust reference executor over
//!   [`HostTensor`]s; no artifacts or PJRT toolchain required.
//! * [`executable`] (`--features xla`) — AOT HLO artifacts compiled on the
//!   PJRT CPU client; the only module that touches the `xla` crate.
//!
//! [`artifact`] (manifest parsing) is backend-independent: the native
//! backend uses it opportunistically for shipped weights/goldens, the xla
//! backend requires it.
//!
//! [`HostTensor`]: crate::model::HostTensor

pub mod artifact;
pub mod backend;
pub mod native;

#[cfg(feature = "xla")]
pub mod executable;
#[cfg(feature = "xla")]
pub mod literal;

pub use artifact::{ArtifactDir, ModuleSpec};
pub use backend::{Backend, BackendKind, BackendSpec, Exec, ServingParams, Value};
pub use native::NativeBackend;

#[cfg(feature = "xla")]
pub use executable::{client, ExecCache, XlaBackend};
#[cfg(feature = "xla")]
pub use literal::{literal_f32, literal_i32, tensor_from_literal};
