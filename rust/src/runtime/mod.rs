//! L3 runtime: load AOT artifacts (HLO text + manifest) and execute them on
//! the PJRT CPU client. This is the only module that touches the `xla`
//! crate; everything above it deals in [`HostTensor`]s.
//!
//! [`HostTensor`]: crate::model::HostTensor

pub mod artifact;
pub mod executable;
pub mod literal;

pub use artifact::{ArtifactDir, ModuleSpec};
pub use executable::{client, ExecCache};
pub use literal::{literal_f32, literal_i32, tensor_from_literal};
