//! Artifact directory handling: manifest parsing + module metadata.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::model::LlamaConfig;
use crate::util::json::{parse, Json};

/// One exported HLO module's interface (from the manifest).
#[derive(Debug, Clone)]
pub struct ModuleSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// A parsed `artifacts/<config>/` directory.
pub struct ArtifactDir {
    pub dir: PathBuf,
    pub config: LlamaConfig,
    pub manifest: Json,
}

impl ArtifactDir {
    pub fn open(dir: impl AsRef<Path>) -> Result<ArtifactDir> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow!("read {manifest_path:?}: {e} — run `make artifacts` first")
        })?;
        let manifest = parse(&text)?;
        let config = LlamaConfig::from_json(manifest.get("config")?)?;
        Ok(ArtifactDir { dir, config, manifest })
    }

    /// Locate the artifact dir for a named config, trying the conventional
    /// locations relative to the working directory, the crate root, and the
    /// workspace root (cargo runs test/bench binaries with cwd = the
    /// package root `rust/`, while `make artifacts` exports to the repo
    /// root).
    pub fn open_named(name: &str) -> Result<ArtifactDir> {
        Self::open_named_opt(name)?.ok_or_else(|| {
            anyhow!("artifact config {name:?} not found; run `make artifacts`")
        })
    }

    /// Like [`open_named`], but distinguishes *absent* (`Ok(None)`) from
    /// *present but unreadable/corrupt* (`Err`) — callers that treat
    /// artifacts as optional must not silently ignore a broken directory.
    ///
    /// [`open_named`]: ArtifactDir::open_named
    pub fn open_named_opt(name: &str) -> Result<Option<ArtifactDir>> {
        let candidates = [
            PathBuf::from("artifacts").join(name),
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name),
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../artifacts").join(name),
        ];
        for c in &candidates {
            if c.join("manifest.json").exists() {
                return ArtifactDir::open(c).map(Some);
            }
        }
        Ok(None)
    }

    pub fn module(&self, name: &str) -> Result<ModuleSpec> {
        let m = self
            .manifest
            .get("modules")?
            .opt(name)
            .ok_or_else(|| anyhow!("module {name:?} not in manifest"))?;
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            m.get(key)?
                .as_arr()?
                .iter()
                .map(|t| {
                    Ok(TensorSpec {
                        name: t
                            .opt("name")
                            .and_then(|n| n.as_str().ok().map(String::from))
                            .unwrap_or_default(),
                        shape: t.get("shape")?.usize_vec()?,
                        dtype: t.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect()
        };
        Ok(ModuleSpec {
            name: name.to_string(),
            file: self.dir.join(m.get("file")?.as_str()?),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }

    pub fn module_names(&self) -> Result<Vec<String>> {
        Ok(self.manifest.get("modules")?.as_obj()?.keys().cloned().collect())
    }

    pub fn packing(&self) -> Result<&Json> {
        self.manifest.get("packing")
    }

    /// Serving export parameters (tps / batches / buckets), if present.
    pub fn serving_params(&self) -> Result<(Vec<usize>, Vec<usize>, Vec<usize>)> {
        Ok((
            self.manifest.get("tps")?.usize_vec()?,
            self.manifest.get("batches")?.usize_vec()?,
            self.manifest.get("buckets")?.usize_vec()?,
        ))
    }

    /// Read a raw little-endian f32 file from the artifact dir.
    pub fn read_f32(&self, file: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(file);
        let bytes = std::fs::read(&path).map_err(|e| anyhow!("read {path:?}: {e}"))?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Read a raw little-endian i32 file from the artifact dir.
    pub fn read_i32(&self, file: &str) -> Result<Vec<i32>> {
        let path = self.dir.join(file);
        let bytes = std::fs::read(&path).map_err(|e| anyhow!("read {path:?}: {e}"))?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}
