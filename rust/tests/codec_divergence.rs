//! The codec divergence-measurement harness (ROADMAP "compressed
//! collectives"): quantified answers to the two questions a lossy collective
//! raises, across all five arch schedulers (Standard / Ladder / Parallel /
//! Desync / Upperbound).
//!
//! **Accuracy** — real tiny-model engine runs (tp=2, sequential oracle,
//! prefill + 8 teacher-forced decode steps) per (arch, codec), reporting
//! max/mean logit drift vs the fp32 oracle. Gates: fp32 drift is exactly
//! zero, Upperbound drift is exactly zero for every codec (its collectives
//! are deleted, nothing crosses a wire), int8/int4 drift is nonzero for
//! every communicating arch (the measurement measures something) and stays
//! below a loose relative sanity bound.
//!
//! **Latency** — the deterministic perfmodel timeline at paper scale (70B,
//! TP8, bs4, prompt 1024): end-to-end generation time per (fabric, arch,
//! codec). Gates, on NvLink *and* Pcie: ladder+int8 strictly beats
//! ladder+fp32 (compression shrinks what hiding couldn't cover — the
//! trailing exposed reduces) and strictly beats standard+int8 (hiding still
//! matters after compression) — architectural overlap and wire compression
//! compound. A real-engine cross-check on a bandwidth-only fabric asserts
//! the engine's own modeled ledger agrees: int8 moves fewer bytes and
//! accrues less modeled link time than fp32 for the same schedule.
//!
//! JSON report: `$CODEC_DIVERGENCE_REPORT`, or
//! `target/tmp/CODEC_DIVERGENCE.json` by default; CI uploads it next to the
//! other stress reports.

use std::path::PathBuf;
use std::rc::Rc;

use ladder_infer::comm::{Codec, Fabric, Interconnect};
use ladder_infer::engine::{KvLayout, RuntimeKind, TpEngine};
use ladder_infer::model::{Arch, PaperModel, WeightStore};
use ladder_infer::perfmodel::timeline::simulate_generation;
use ladder_infer::perfmodel::{CostModel, H100};
use ladder_infer::runtime::Exec;
use ladder_infer::util::json::Json;

const PROMPT: usize = 16;
const DECODE_STEPS: usize = 8;
const WEIGHT_SEED: u64 = 0xD0D0;

/// The five arch schedulers under measurement (Hybrid is Ladder+Standard
/// and Desync(4) is Desync(2) with a different stride — same dispatch
/// branches).
const SCHEDULERS: [Arch; 5] =
    [Arch::Standard, Arch::Ladder, Arch::Parallel, Arch::Desync(2), Arch::Upperbound];

fn tiny_weights(exec: &Exec) -> WeightStore {
    if let Some(art) = exec.artifacts_opt() {
        if let Ok(flat) = art.read_f32("testvec_weights.f32") {
            if let Ok(w) = WeightStore::from_flat(&flat, art.packing().unwrap(), exec.cfg().layers)
            {
                return w;
            }
        }
    }
    WeightStore::random(exec.cfg(), WEIGHT_SEED)
}

/// Prefill + teacher-forced decode on the real engine; every step's logits.
fn logit_stream(arch: Arch, codec: Codec, fabric: Fabric) -> (Vec<Vec<f32>>, TpEngine) {
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = tiny_weights(&exec);
    let mut engine = TpEngine::with_codec(
        exec,
        &weights,
        2,
        arch,
        2,
        Interconnect::new(fabric),
        RuntimeKind::Sequential,
        KvLayout::Slab,
        codec,
    )
    .unwrap();
    let tokens: Vec<i32> = (0..(2 * PROMPT) as i32).map(|i| i % 13 + 1).collect();
    let mut stream = Vec::with_capacity(DECODE_STEPS + 1);
    stream.push(engine.prefill(&tokens, PROMPT, &[PROMPT, PROMPT]).unwrap().data);
    for t in 0..DECODE_STEPS as i32 {
        stream.push(engine.decode(&[t % 7 + 1, t % 5 + 2]).unwrap().data);
    }
    (stream, engine)
}

struct Drift {
    max: f64,
    mean: f64,
    /// max |oracle logit| — the scale `max` is relative to.
    oracle_scale: f64,
}

fn drift_vs_oracle(oracle: &[Vec<f32>], probe: &[Vec<f32>]) -> Drift {
    assert_eq!(oracle.len(), probe.len());
    let (mut max, mut sum, mut n, mut scale) = (0.0f64, 0.0f64, 0usize, 0.0f64);
    for (a, b) in oracle.iter().zip(probe) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!(y.is_finite(), "quantized logit is not finite");
            let d = (*x as f64 - *y as f64).abs();
            max = max.max(d);
            sum += d;
            n += 1;
            scale = scale.max(x.abs() as f64);
        }
    }
    Drift { max, mean: sum / n as f64, oracle_scale: scale }
}

#[test]
fn codec_divergence_report() {
    // ---- accuracy: real-engine logit drift vs the fp32 oracle -------------
    let mut drift_rows = Vec::new();
    for arch in SCHEDULERS {
        let (oracle, _) = logit_stream(arch, Codec::Fp32, Fabric::Local);
        for codec in [Codec::Fp32, Codec::Int8, Codec::Int4] {
            let (probe, _) = logit_stream(arch, codec, Fabric::Local);
            let d = drift_vs_oracle(&oracle, &probe);
            if codec == Codec::Fp32 {
                // same constructor, same codec: the oracle must reproduce
                assert_eq!(d.max, 0.0, "{}: fp32 run not reproducible", arch.name());
            } else if arch == Arch::Upperbound {
                // its collectives are deleted — nothing for the codec to touch
                assert_eq!(d.max, 0.0, "upperbound must not drift under {}", codec.name());
            } else {
                assert!(d.max > 0.0, "{} [{}]: drift measured as zero", arch.name(), codec.name());
                assert!(
                    d.max < 0.5 * d.oracle_scale,
                    "{} [{}]: drift {} vs logit scale {} — quantization broke the model",
                    arch.name(),
                    codec.name(),
                    d.max,
                    d.oracle_scale
                );
            }
            drift_rows.push(
                Json::obj()
                    .set("arch", arch.name())
                    .set("codec", codec.name())
                    .set("max_drift", d.max)
                    .set("mean_drift", d.mean)
                    .set("oracle_logit_scale", d.oracle_scale),
            );
        }
    }

    // ---- latency: perfmodel timeline at 70B TP8 bs4 -----------------------
    let m = *PaperModel::by_name("70B").unwrap();
    let mut latency_rows = Vec::new();
    for fabric in [Fabric::NvLink, Fabric::Pcie] {
        let e2e = |arch: Arch, codec: Codec| {
            let cm = CostModel::new(m, H100, 8, Interconnect::new(fabric)).with_codec(codec);
            simulate_generation(arch, &cm, 4, 1024, 64).total()
        };
        for arch in SCHEDULERS {
            for codec in [Codec::Fp32, Codec::Int8, Codec::Int4] {
                latency_rows.push(
                    Json::obj()
                        .set("fabric", Interconnect::new(fabric).name())
                        .set("arch", arch.name())
                        .set("codec", codec.name())
                        .set("e2e_s", e2e(arch, codec)),
                );
            }
        }
        // The compounding gates: compression shrinks the latency ladder
        // couldn't hide, and ladder still hides what compression leaves.
        let ladder_fp32 = e2e(Arch::Ladder, Codec::Fp32);
        let ladder_int8 = e2e(Arch::Ladder, Codec::Int8);
        let standard_int8 = e2e(Arch::Standard, Codec::Int8);
        assert!(
            ladder_int8 < ladder_fp32,
            "{}: ladder+int8 ({ladder_int8}) !< ladder+fp32 ({ladder_fp32})",
            Interconnect::new(fabric).name()
        );
        assert!(
            ladder_int8 < standard_int8,
            "{}: ladder+int8 ({ladder_int8}) !< standard+int8 ({standard_int8})",
            Interconnect::new(fabric).name()
        );
    }

    // ---- engine cross-check: the modeled ledger agrees --------------------
    // A bandwidth-only custom fabric (0us latency, 1 GB/s) makes modeled
    // link time proportional to wire bytes; the int8 engine must both move
    // fewer bytes and accrue strictly less modeled comm time than fp32 on
    // the identical ladder schedule.
    let (_, fp32_engine) = logit_stream(Arch::Ladder, Codec::Fp32, Fabric::Custom(0, 1));
    let (_, int8_engine) = logit_stream(Arch::Ladder, Codec::Int8, Fabric::Custom(0, 1));
    let (fs, is) = (fp32_engine.comm.stats(), int8_engine.comm.stats());
    assert_eq!(fs.allreduce_count, is.allreduce_count, "schedules diverged");
    assert_eq!(fs.bytes_raw, is.bytes_raw, "raw payload must not depend on the codec");
    assert!(is.bytes_moved < fs.bytes_moved, "int8 {} !< fp32 {}", is.bytes_moved, fs.bytes_moved);
    assert!(
        is.modeled_total < fs.modeled_total,
        "int8 modeled {:?} !< fp32 modeled {:?}",
        is.modeled_total,
        fs.modeled_total
    );

    // ---- report -----------------------------------------------------------
    let report = Json::obj()
        .set("harness", "codec_divergence")
        .set("model_drift", "tiny tp2 seq, prefill 16 + 8 teacher-forced decodes, vs fp32 oracle")
        .set("model_latency", "70B TP8 bs4 prompt 1024 gen 64, perfmodel timeline")
        .set("drift", Json::Arr(drift_rows))
        .set("e2e_latency", Json::Arr(latency_rows))
        .set(
            "engine_ledger",
            Json::obj()
                .set("fabric", "custom:0:1")
                .set("allreduces", fs.allreduce_count)
                .set("bytes_raw", fs.bytes_raw)
                .set("fp32_bytes_moved", fs.bytes_moved)
                .set("int8_bytes_moved", is.bytes_moved)
                .set("fp32_modeled_s", fs.modeled_total.as_secs_f64())
                .set("int8_modeled_s", is.modeled_total.as_secs_f64()),
        );
    let path = std::env::var("CODEC_DIVERGENCE_REPORT").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join("CODEC_DIVERGENCE.json")
    });
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(&path, report.to_string()).expect("write codec divergence report");
    println!("codec divergence report -> {}", path.display());
}

/// Threaded counterpart of the ledger cross-check: the rendezvous collective
/// charges the same compressed byte count the sequential engine does, so a
/// threaded int8 engine's ledger shows the identical compression ratio.
#[test]
fn threaded_ledger_matches_sequential_compression() {
    let run = |runtime: RuntimeKind, codec: Codec| {
        let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
        let weights = tiny_weights(&exec);
        let mut engine = TpEngine::with_codec(
            exec,
            &weights,
            2,
            Arch::Ladder,
            2,
            Interconnect::new(Fabric::Local),
            runtime,
            KvLayout::Slab,
            codec,
        )
        .unwrap();
        let tokens: Vec<i32> = (0..(2 * PROMPT) as i32).map(|i| i % 13 + 1).collect();
        engine.prefill(&tokens, PROMPT, &[PROMPT, PROMPT]).unwrap();
        for t in 0..DECODE_STEPS as i32 {
            engine.decode(&[t % 7 + 1, t % 5 + 2]).unwrap();
        }
        engine.comm.stats()
    };
    for codec in [Codec::Fp32, Codec::Int8, Codec::Int4] {
        let seq = run(RuntimeKind::Sequential, codec);
        let thr = run(RuntimeKind::Threaded, codec);
        assert_eq!(seq.allreduce_count, thr.allreduce_count, "{}", codec.name());
        assert_eq!(seq.bytes_moved, thr.bytes_moved, "{}", codec.name());
        assert_eq!(seq.bytes_raw, thr.bytes_raw, "{}", codec.name());
        if codec == Codec::Fp32 {
            assert_eq!(seq.bytes_moved, seq.bytes_raw);
        } else {
            assert!(seq.bytes_moved < seq.bytes_raw, "{}", codec.name());
        }
    }
}
