//! The paper's core claim as a wall-clock fact on the real engine:
//! with a slow fabric, Ladder hides communication that Standard exposes,
//! and the measured generation times order as
//! upperbound <= ladder < standard, with desync dropping comm entirely.
//!
//! The blocking/exposure assertions run on the sequential runtime (the
//! timing oracle); the threaded runtime gets its own Ladder-beats-Standard
//! wall-clock checks, since hiding comm behind *concurrent* rank compute is
//! exactly what that runtime exists to measure.

use std::rc::Rc;

use ladder_infer::comm::{Fabric, Interconnect};
use ladder_infer::engine::{generate, RuntimeKind, Sampler, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::Exec;

fn run_rt(arch: Arch, fabric: Fabric, runtime: RuntimeKind) -> (f64, f64, f64) {
    // native backend: wall-clock overlap is an architecture property, so no
    // artifacts (and no particular weights) are required to measure it
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = WeightStore::random(exec.cfg(), 1);
    let mut engine =
        TpEngine::with_runtime(exec, &weights, 2, arch, 2, Interconnect::new(fabric), runtime)
            .unwrap();
    let prompts = vec![vec![1i32; 16], vec![2i32; 16]];
    let report = generate::generate(&mut engine, &prompts, 8, &Sampler::Greedy).unwrap();
    (
        report.decode_time.as_secs_f64(),
        report.comm.modeled_total.as_secs_f64(),
        report.comm.exposed_total.as_secs_f64(),
    )
}

fn run(arch: Arch, fabric: Fabric) -> (f64, f64, f64) {
    run_rt(arch, fabric, RuntimeKind::Sequential)
}

/// A deliberately slow custom fabric so comm time dwarfs PJRT noise:
/// 3ms latency per AllReduce.
const SLOW: Fabric = Fabric::Custom(3000, 1);

#[test]
fn ladder_hides_comm_standard_exposes_it() {
    let (std_t, std_comm, std_exposed) = run(Arch::Standard, SLOW);
    let (lad_t, lad_comm, lad_exposed) = run(Arch::Ladder, SLOW);
    // both moved the same bytes through the same fabric
    assert!((std_comm - lad_comm).abs() / std_comm < 0.05, "{std_comm} vs {lad_comm}");
    // standard exposes nearly all of it; ladder hides a chunk behind module
    // compute (tiny modules are ~1-3ms; 3ms ARs can be partially hidden)
    assert!(std_exposed > 0.9 * std_comm, "standard exposed {std_exposed} of {std_comm}");
    assert!(lad_exposed < 0.8 * lad_comm, "ladder exposed {lad_exposed} of {lad_comm}");
    // and that shows up in wall-clock
    assert!(lad_t < std_t, "ladder {lad_t} !< standard {std_t}");
}

#[test]
fn desync_moves_fewer_bytes() {
    let (_, std_comm, _) = run(Arch::Standard, SLOW);
    let (_, d4_comm, _) = run(Arch::Desync(4), SLOW);
    assert!(
        d4_comm < 0.35 * std_comm,
        "desync4 comm {d4_comm} vs standard {std_comm}"
    );
}

#[test]
fn upperbound_is_fastest() {
    let (ub_t, ub_comm, _) = run(Arch::Upperbound, SLOW);
    let (std_t, _, _) = run(Arch::Standard, SLOW);
    assert_eq!(ub_comm, 0.0);
    assert!(ub_t < std_t);
}

#[test]
fn fast_fabric_shrinks_the_gap() {
    // On a (modeled) fast local fabric the architectures should be within
    // noise of each other — the gap is a *communication* effect.
    let (std_t, _, _) = run(Arch::Standard, Fabric::Local);
    let (lad_t, _, _) = run(Arch::Ladder, Fabric::Local);
    let ratio = std_t / lad_t;
    assert!(ratio > 0.5 && ratio < 2.0, "local-fabric ratio {ratio}");
}

// ---------------------------------------------------------------------------
// threaded runtime
// ---------------------------------------------------------------------------

#[test]
fn threaded_ladder_beats_standard_on_slow_fabric() {
    let (std_t, std_comm, std_exposed) = run_rt(Arch::Standard, SLOW, RuntimeKind::Threaded);
    let (lad_t, lad_comm, lad_exposed) = run_rt(Arch::Ladder, SLOW, RuntimeKind::Threaded);
    // same bytes through the same fabric, regardless of runtime
    assert!((std_comm - lad_comm).abs() / std_comm < 0.05, "{std_comm} vs {lad_comm}");
    // ladder hides comm behind concurrent rank compute that standard exposes
    assert!(
        lad_exposed < std_exposed,
        "threaded: ladder exposed {lad_exposed} !< standard {std_exposed}"
    );
    assert!(lad_t < std_t, "threaded: ladder {lad_t} !< standard {std_t}");
}

#[test]
fn threaded_upperbound_reports_zero_comm() {
    let (_, ub_comm, ub_exposed) = run_rt(Arch::Upperbound, SLOW, RuntimeKind::Threaded);
    assert_eq!(ub_comm, 0.0);
    assert_eq!(ub_exposed, 0.0);
}
