//! The paper's core claim as a wall-clock fact on the real engine:
//! with a slow fabric, Ladder hides communication that Standard exposes,
//! and the measured generation times order as
//! upperbound <= ladder < standard, with desync dropping comm entirely.
//!
//! The blocking/exposure assertions run on the sequential runtime (the
//! timing oracle); the threaded runtime gets its own Ladder-beats-Standard
//! wall-clock checks, since hiding comm behind *concurrent* rank compute is
//! exactly what that runtime exists to measure.

//! The split-batch overlap gates live at the bottom: standard+split4 must
//! *strictly narrow* the standard-vs-ladder wall-clock gap (TokenWeave-style
//! systems overlap recovers part of what the architecture change buys),
//! while the ladder family stays on the frontier. The sweep's JSON report
//! goes to `$OVERLAP_REPORT`, default `target/tmp/OVERLAP_WALLCLOCK.json`;
//! CI uploads the `OVERLAP_*.json` glob with the other stress reports.

use std::path::PathBuf;
use std::rc::Rc;

use ladder_infer::comm::{Codec, Fabric, Interconnect};
use ladder_infer::engine::{generate, KvLayout, OverlapMode, RuntimeKind, Sampler, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::Exec;
use ladder_infer::util::json::Json;

fn run_rt(arch: Arch, fabric: Fabric, runtime: RuntimeKind) -> (f64, f64, f64) {
    // native backend: wall-clock overlap is an architecture property, so no
    // artifacts (and no particular weights) are required to measure it
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = WeightStore::random(exec.cfg(), 1);
    let mut engine =
        TpEngine::with_runtime(exec, &weights, 2, arch, 2, Interconnect::new(fabric), runtime)
            .unwrap();
    let prompts = vec![vec![1i32; 16], vec![2i32; 16]];
    let report = generate::generate(&mut engine, &prompts, 8, &Sampler::Greedy).unwrap();
    (
        report.decode_time.as_secs_f64(),
        report.comm.modeled_total.as_secs_f64(),
        report.comm.exposed_total.as_secs_f64(),
    )
}

fn run(arch: Arch, fabric: Fabric) -> (f64, f64, f64) {
    run_rt(arch, fabric, RuntimeKind::Sequential)
}

/// A deliberately slow custom fabric so comm time dwarfs PJRT noise:
/// 3ms latency per AllReduce.
const SLOW: Fabric = Fabric::Custom(3000, 1);

#[test]
fn ladder_hides_comm_standard_exposes_it() {
    let (std_t, std_comm, std_exposed) = run(Arch::Standard, SLOW);
    let (lad_t, lad_comm, lad_exposed) = run(Arch::Ladder, SLOW);
    // both moved the same bytes through the same fabric
    assert!((std_comm - lad_comm).abs() / std_comm < 0.05, "{std_comm} vs {lad_comm}");
    // standard exposes nearly all of it; ladder hides a chunk behind module
    // compute (tiny modules are ~1-3ms; 3ms ARs can be partially hidden)
    assert!(std_exposed > 0.9 * std_comm, "standard exposed {std_exposed} of {std_comm}");
    assert!(lad_exposed < 0.8 * lad_comm, "ladder exposed {lad_exposed} of {lad_comm}");
    // and that shows up in wall-clock
    assert!(lad_t < std_t, "ladder {lad_t} !< standard {std_t}");
}

#[test]
fn desync_moves_fewer_bytes() {
    let (_, std_comm, _) = run(Arch::Standard, SLOW);
    let (_, d4_comm, _) = run(Arch::Desync(4), SLOW);
    assert!(
        d4_comm < 0.35 * std_comm,
        "desync4 comm {d4_comm} vs standard {std_comm}"
    );
}

#[test]
fn upperbound_is_fastest() {
    let (ub_t, ub_comm, _) = run(Arch::Upperbound, SLOW);
    let (std_t, _, _) = run(Arch::Standard, SLOW);
    assert_eq!(ub_comm, 0.0);
    assert!(ub_t < std_t);
}

#[test]
fn fast_fabric_shrinks_the_gap() {
    // On a (modeled) fast local fabric the architectures should be within
    // noise of each other — the gap is a *communication* effect.
    let (std_t, _, _) = run(Arch::Standard, Fabric::Local);
    let (lad_t, _, _) = run(Arch::Ladder, Fabric::Local);
    let ratio = std_t / lad_t;
    assert!(ratio > 0.5 && ratio < 2.0, "local-fabric ratio {ratio}");
}

// ---------------------------------------------------------------------------
// threaded runtime
// ---------------------------------------------------------------------------

#[test]
fn threaded_ladder_beats_standard_on_slow_fabric() {
    let (std_t, std_comm, std_exposed) = run_rt(Arch::Standard, SLOW, RuntimeKind::Threaded);
    let (lad_t, lad_comm, lad_exposed) = run_rt(Arch::Ladder, SLOW, RuntimeKind::Threaded);
    // same bytes through the same fabric, regardless of runtime
    assert!((std_comm - lad_comm).abs() / std_comm < 0.05, "{std_comm} vs {lad_comm}");
    // ladder hides comm behind concurrent rank compute that standard exposes
    assert!(
        lad_exposed < std_exposed,
        "threaded: ladder exposed {lad_exposed} !< standard {std_exposed}"
    );
    assert!(lad_t < std_t, "threaded: ladder {lad_t} !< standard {std_t}");
}

#[test]
fn threaded_upperbound_reports_zero_comm() {
    let (_, ub_comm, ub_exposed) = run_rt(Arch::Upperbound, SLOW, RuntimeKind::Threaded);
    assert_eq!(ub_comm, 0.0);
    assert_eq!(ub_exposed, 0.0);
}

// ---------------------------------------------------------------------------
// split-batch overlap: the ladder-vs-TokenWeave-style head-to-head
// ---------------------------------------------------------------------------

struct OverlapMeas {
    total: f64,
    prefill: f64,
    decode: f64,
    modeled: f64,
    exposed: f64,
}

/// Batch 4 (so split4 really pipelines 4 chunks), 8 decode steps.
fn run_overlap(
    arch: Arch,
    fabric: Interconnect,
    overlap: OverlapMode,
    runtime: RuntimeKind,
) -> OverlapMeas {
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = WeightStore::random(exec.cfg(), 1);
    let mut engine = TpEngine::with_overlap(
        exec,
        &weights,
        2,
        arch,
        4,
        fabric,
        runtime,
        KvLayout::Slab,
        Codec::Fp32,
        overlap,
    )
    .unwrap();
    let prompts: Vec<Vec<i32>> = (0..4).map(|b| vec![b as i32 + 1; 16]).collect();
    let report = generate::generate(&mut engine, &prompts, 8, &Sampler::Greedy).unwrap();
    let prefill = report.prefill_time.as_secs_f64();
    let decode = report.decode_time.as_secs_f64();
    OverlapMeas {
        total: prefill + decode,
        prefill,
        decode,
        modeled: report.comm.modeled_total.as_secs_f64(),
        exposed: report.comm.exposed_total.as_secs_f64(),
    }
}

/// One location rule for the overlap report: `$OVERLAP_REPORT` (CI) or
/// `target/tmp/OVERLAP_WALLCLOCK.json` (matching CI's `OVERLAP_*.json`
/// upload glob).
fn write_overlap_report(report: &Json) {
    let path = std::env::var("OVERLAP_REPORT").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join("OVERLAP_WALLCLOCK.json")
    });
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(&path, report.to_pretty()).expect("write overlap report");
}

/// The head-to-head gate, on both the flat slow fabric and the two-tier
/// topology that routes every AllReduce over the slow cross tier:
///
/// * standard+split4 is strictly faster than standard+none — split-batch
///   overlap hides comm behind sibling-chunk compute even without
///   touching the architecture — so the standard-vs-ladder gap strictly
///   narrows;
/// * but the ladder family stays on the frontier: the best ladder config
///   is no slower than the best standard config (2% timing slack; when
///   both are latency-locked at the AR deadline the margin is split4's
///   per-chunk overhead, which is small but systematic).
#[test]
fn split4_narrows_standard_ladder_gap_but_ladder_keeps_frontier() {
    let fabrics = [
        Interconnect::new(SLOW),
        Interconnect::parse("two_tier:local:slow:1").unwrap(),
    ];
    let overlaps = [OverlapMode::None, OverlapMode::Split2, OverlapMode::Split4];
    let mut rows = Vec::new();
    let mut gates = Vec::new();
    for fabric in fabrics {
        let mut total = |arch: Arch, ov: OverlapMode| {
            let m = run_overlap(arch, fabric, ov, RuntimeKind::Sequential);
            rows.push(
                Json::obj()
                    .set("topology", fabric.name())
                    .set("arch", arch.name())
                    .set("overlap", ov.name())
                    .set("runtime", RuntimeKind::Sequential.name())
                    .set("prefill_s", m.prefill)
                    .set("decode_s", m.decode)
                    .set("total_s", m.total)
                    .set("comm_modeled_s", m.modeled)
                    .set("comm_exposed_s", m.exposed),
            );
            m.total
        };
        let std_t: Vec<f64> = overlaps.iter().map(|&ov| total(Arch::Standard, ov)).collect();
        let lad_t: Vec<f64> = overlaps.iter().map(|&ov| total(Arch::Ladder, ov)).collect();
        let (std_none, std_s4) = (std_t[0], std_t[2]);
        let lad_none = lad_t[0];
        let gap_none = std_none - lad_none;
        let gap_s4 = std_s4 - lad_none;

        assert!(
            std_s4 < std_none,
            "{}: standard+split4 {std_s4} !< standard+none {std_none}",
            fabric.name()
        );
        assert!(
            gap_s4 < gap_none,
            "{}: split4 gap {gap_s4} !< unsplit gap {gap_none}",
            fabric.name()
        );
        assert!(gap_none > 0.0, "{}: ladder+none !< standard+none", fabric.name());
        let std_best = std_t.iter().cloned().fold(f64::INFINITY, f64::min);
        let lad_best = lad_t.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            lad_best <= std_best * 1.02,
            "{}: ladder frontier lost: best ladder {lad_best} vs best standard {std_best}",
            fabric.name()
        );
        gates.push(
            Json::obj()
                .set("topology", fabric.name())
                .set("std_none_s", std_none)
                .set("std_split4_s", std_s4)
                .set("ladder_none_s", lad_none)
                .set("gap_recovered", (std_none - std_s4) / gap_none)
                .set("ladder_frontier", lad_best <= std_best),
        );
    }
    write_overlap_report(
        &Json::obj()
            .set("harness", "overlap_wallclock")
            .set("rows", Json::Arr(rows))
            .set("gates", Json::Arr(gates)),
    );
}

/// Same narrowing on the threaded runtime: sibling-chunk compute now runs
/// on real rank workers with rendezvous deadlines, and split4 must still
/// strictly shrink standard's wall clock on the slow fabric.
#[test]
fn threaded_split4_narrows_standard_gap() {
    let fabric = Interconnect::new(SLOW);
    let std_none = run_overlap(Arch::Standard, fabric, OverlapMode::None, RuntimeKind::Threaded);
    let std_s4 = run_overlap(Arch::Standard, fabric, OverlapMode::Split4, RuntimeKind::Threaded);
    let lad_none = run_overlap(Arch::Ladder, fabric, OverlapMode::None, RuntimeKind::Threaded);
    assert!(
        std_s4.total < std_none.total,
        "threaded: standard+split4 {} !< standard+none {}",
        std_s4.total,
        std_none.total
    );
    assert!(
        lad_none.total < std_none.total,
        "threaded: ladder+none {} !< standard+none {}",
        lad_none.total,
        std_none.total
    );
    // split4 hides comm that the unsplit standard schedule exposes
    assert!(
        std_s4.exposed < std_none.exposed,
        "threaded: split4 exposed {} !< unsplit exposed {}",
        std_s4.exposed,
        std_none.exposed
    );
}
