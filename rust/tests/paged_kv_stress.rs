//! Deterministic randomized serving stress harness for the paged KV
//! batcher (the proof obligation for continuous batching over a paged
//! cache).
//!
//! A seeded workload of 200+ requests with mixed prompt lengths, random
//! mid-flight cancels and client-timeout sink drops is driven through
//! `Batcher::step` *manually*; after **every** step the harness audits the
//! `BlockAllocator`:
//!
//! * no page leaked (free + owned == total),
//! * no page double-owned,
//! * `pages_in_use * page_bytes` never exceeds the `--kv-budget` bytes,
//! * every accepted request reaches exactly one terminal event.
//!
//! A separate oracle test replays the same workload through the fixed-slot
//! batcher and asserts per-request token streams are **bitwise identical**
//! (same seeds) — and that at an equal byte budget the paged batcher admits
//! strictly more concurrent requests than the fixed-slot baseline.
//!
//! The harness writes a JSON invariant report (one entry per seed) to
//! `$PAGED_KV_REPORT`, or `target/tmp/PAGED_KV_STRESS.json` by default; CI
//! uploads it next to the BENCH_*.json artifacts.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::mpsc::{channel, Receiver};

use ladder_infer::comm::{Fabric, Interconnect};
use ladder_infer::engine::{KvLayout, RuntimeKind, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::Exec;
use ladder_infer::server::{Batcher, BatcherConfig, FinishReason, GenerationEvent, Request};
use ladder_infer::util::json::Json;
use ladder_infer::util::rng::Rng;

const BATCH: usize = 4;

fn build_engine(layout: KvLayout) -> TpEngine {
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = WeightStore::random(exec.cfg(), 0xfeed);
    TpEngine::with_layout(
        exec,
        &weights,
        2,
        Arch::Ladder,
        BATCH,
        Interconnect::new(Fabric::Local),
        RuntimeKind::default(),
        layout,
    )
    .unwrap()
}

/// One request of the generated workload.
#[derive(Clone)]
struct Job {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    /// `Some(step)`: explicitly cancelled after that scheduler step.
    cancel_at: Option<usize>,
    /// `Some(step)`: the client "times out" — its event sink is dropped
    /// after that step, and the batcher must reclaim the slot on its own.
    drop_sink_at: Option<usize>,
    /// Which scheduler step the request arrives at.
    arrive_at: usize,
}

/// Mixed-length workload: ~50% short, ~35% medium, ~15% long prompts,
/// arrivals spread over the first ~150 steps, ~8% cancels, ~5% timeouts.
fn workload(seed: u64, n: usize) -> Vec<Job> {
    let mut rng = Rng::new(seed);
    let mut arrive = 0usize;
    (0..n)
        .map(|i| {
            let len = match rng.below(100) {
                0..=49 => rng.range(1, 8),
                50..=84 => rng.range(8, 40),
                _ => rng.range(40, 90),
            };
            arrive += rng.below(3); // bursty Poisson-ish arrivals
            let cancel = rng.below(100) < 8;
            let timeout = !cancel && rng.below(100) < 5;
            Job {
                id: i as u64,
                prompt: (0..len).map(|_| rng.below(256) as i32).collect(),
                max_new: rng.range(1, 12),
                cancel_at: cancel.then(|| arrive + rng.below(30)),
                drop_sink_at: timeout.then(|| arrive + rng.below(30)),
                arrive_at: arrive,
            }
        })
        .collect()
}

/// Outcome of driving one workload to completion.
struct RunStats {
    /// id -> (tokens, finish reason); exactly one entry per request.
    finished: HashMap<u64, (Vec<i32>, FinishReason)>,
    max_live: usize,
    high_water_pages: usize,
    admission_blocked: usize,
    steps: usize,
}

/// Drive `jobs` through a batcher step by step, auditing the allocator
/// after every step. `budget_bytes` caps both the batcher config and the
/// audit; 0 disables the byte assertion.
fn drive(mut batcher: Batcher, jobs: &[Job], budget_bytes: usize) -> RunStats {
    let mut finished: HashMap<u64, (Vec<i32>, FinishReason)> = HashMap::new();
    let mut live_ids: HashSet<u64> = HashSet::new();
    let mut max_live = 0usize;
    let mut sinks: HashMap<u64, Receiver<GenerationEvent>> = HashMap::new();
    let mut submitted = 0usize;
    let mut step = 0usize;
    let mut record = |evs: Vec<GenerationEvent>, live: &mut HashSet<u64>, max: &mut usize| {
        for ev in evs {
            match ev {
                GenerationEvent::Admitted { id, .. } => {
                    live.insert(id);
                    *max = (*max).max(live.len());
                }
                GenerationEvent::Token { .. } => {}
                GenerationEvent::Finished { result } => {
                    live.remove(&result.id);
                    let dup = finished.insert(result.id, (result.tokens, result.finish_reason));
                    assert!(dup.is_none(), "request {} finished twice", result.id);
                }
            }
        }
    };
    while submitted < jobs.len() || batcher.pending() > 0 {
        assert!(step < 100_000, "workload failed to drain after {step} steps");
        // arrivals scheduled for this step
        while submitted < jobs.len() && jobs[submitted].arrive_at <= step {
            let job = &jobs[submitted];
            let request = Request::new(job.id, job.prompt.clone(), job.max_new);
            if job.drop_sink_at.is_some() {
                let (tx, rx) = channel();
                sinks.insert(job.id, rx);
                batcher.submit_streaming(request, tx);
            } else {
                batcher.submit(request);
            }
            submitted += 1;
        }
        let evs = batcher.step().expect("batcher step");
        record(evs, &mut live_ids, &mut max_live);
        // client timeouts: drop the sink, the batcher reclaims the slot
        sinks.retain(|id, _| {
            let job = &jobs[*id as usize];
            !job.drop_sink_at.is_some_and(|at| at <= step)
        });
        // explicit cancels
        for job in jobs[..submitted].iter() {
            if job.cancel_at == Some(step) {
                if let Some(ev) = batcher.cancel(job.id) {
                    record(vec![ev], &mut live_ids, &mut max_live);
                }
            }
        }
        // -- the allocator audit, the heart of this harness --
        if let Some(alloc) = batcher.allocator() {
            alloc.check().unwrap_or_else(|e| panic!("step {step}: {e}"));
            if budget_bytes > 0 {
                assert!(
                    alloc.bytes_in_use() <= budget_bytes,
                    "step {step}: {} KV bytes in use exceed the {budget_bytes} budget",
                    alloc.bytes_in_use()
                );
            }
        }
        step += 1;
    }
    let (high_water_pages, admission_blocked) = match batcher.allocator() {
        Some(alloc) => {
            // drained: every page must be back on the free list
            alloc.check().unwrap();
            assert_eq!(alloc.pages_in_use(), 0, "pages leaked after drain");
            assert_eq!(alloc.reserved_pages(), 0, "reservations leaked after drain");
            assert_eq!(alloc.free_pages(), alloc.total_pages());
            (alloc.high_water(), batcher.metrics.admission_blocked)
        }
        None => (0, 0),
    };
    RunStats { finished, max_live, high_water_pages, admission_blocked, steps: step }
}

fn assert_outcomes(jobs: &[Job], stats: &RunStats) {
    assert_eq!(stats.finished.len(), jobs.len(), "every request must reach a terminal event");
    for job in jobs {
        let (tokens, reason) = &stats.finished[&job.id];
        match reason {
            // untouched requests run to their full budget (greedy, no eos)
            FinishReason::Length => assert_eq!(
                tokens.len(),
                job.max_new,
                "request {} finished early without a cancel",
                job.id
            ),
            FinishReason::Cancelled => assert!(
                job.cancel_at.is_some() || job.drop_sink_at.is_some(),
                "request {} cancelled without a cancel/timeout plan",
                job.id
            ),
            other => panic!("request {} finished with unexpected {other:?}", job.id),
        }
    }
}

/// The tentpole harness: 3 fixed seeds x (page size, chunk, budget)
/// variations, full allocator audit every step, JSON invariant report.
#[test]
fn stress_randomized_three_seeds() {
    let configs = [
        // (seed, page_size, prefill_chunk, budget_pages)
        (0xa11ce_u64, 4usize, 0usize, 120usize),
        (0xb0b, 8, 7, 48),
        (0xc0ffee, 16, 16, 28),
    ];
    let mut entries = Vec::new();
    for (seed, page_size, chunk, budget_pages) in configs {
        let jobs = workload(seed, 200);
        let per_seq = 128usize.div_ceil(page_size);
        let alloc_pages = budget_pages.max(per_seq);
        // pool strictly larger than the byte budget, so the budget clamp
        // (not pool exhaustion) is what the harness actually audits
        let pages = alloc_pages + 8;
        let engine = build_engine(KvLayout::Paged { page_size, pages });
        let page_bytes = engine.kv_page_bytes();
        let budget_bytes = alloc_pages * page_bytes;
        let config = BatcherConfig {
            decode_burst: 1,
            kv_budget_bytes: budget_bytes,
            prefill_chunk: chunk,
        };
        let stats = drive(Batcher::new(engine, config), &jobs, budget_bytes);
        assert_outcomes(&jobs, &stats);
        let cancelled =
            stats.finished.values().filter(|(_, r)| *r == FinishReason::Cancelled).count();
        entries.push(
            Json::obj()
                .set("seed", format!("{seed:#x}"))
                .set("requests", jobs.len())
                .set("page_size", page_size)
                .set("prefill_chunk", chunk)
                .set("total_pages", pages)
                .set("page_bytes", page_bytes)
                .set("budget_bytes", budget_bytes)
                .set("steps", stats.steps)
                .set("completed", stats.finished.len())
                .set("cancelled", cancelled)
                .set("max_concurrent", stats.max_live)
                .set("kv_pages_high_water", stats.high_water_pages)
                .set("admission_blocked", stats.admission_blocked)
                .set("invariants", "no-leak, no-double-own, budget-respected, all-finished"),
        );
    }
    let report = Json::obj().set("harness", "paged_kv_stress").set("seeds", Json::Arr(entries));
    let path = std::env::var("PAGED_KV_REPORT").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join("PAGED_KV_STRESS.json")
    });
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(&path, report.to_string()).expect("write invariant report");
}

/// Acceptance oracle: under the same seeded workload (no cancels), the
/// paged batcher's per-request token streams are bitwise identical to the
/// fixed-slot batcher's — regardless of page size or prefill chunking,
/// and even though admission interleaves differently.
#[test]
fn paged_streams_bitwise_match_fixed_slot_oracle() {
    let jobs: Vec<Job> = workload(0xdead, 60)
        .into_iter()
        .map(|j| Job { cancel_at: None, drop_sink_at: None, ..j })
        .collect();
    let fixed = drive(
        Batcher::new(build_engine(KvLayout::Slab), BatcherConfig::default()),
        &jobs,
        0,
    );
    assert_outcomes(&jobs, &fixed);
    for (page_size, chunk) in [(4usize, 0usize), (16, 5)] {
        let pages = BATCH * 128usize.div_ceil(page_size);
        let engine = build_engine(KvLayout::Paged { page_size, pages });
        let config = BatcherConfig { prefill_chunk: chunk, ..BatcherConfig::default() };
        let paged = drive(Batcher::new(engine, config), &jobs, 0);
        assert_outcomes(&jobs, &paged);
        for job in &jobs {
            assert_eq!(
                paged.finished[&job.id].0, fixed.finished[&job.id].0,
                "request {} diverged from the fixed-slot oracle \
                 (page_size={page_size}, chunk={chunk})",
                job.id
            );
        }
    }
}

/// Acceptance: at the same byte budget, block-granular admission runs
/// strictly more requests concurrently than `max_seq`-sized slots — while
/// producing the same tokens.
#[test]
fn paged_admits_more_concurrent_requests_at_equal_budget() {
    // budget = 2.5 fixed slots -> the slab batcher caps at 2 concurrent
    let probe = build_engine(KvLayout::Slab);
    let budget = probe.kv_bytes_per_slot() * 5 / 2;
    let jobs: Vec<Job> = (0..8u64)
        .map(|i| Job {
            id: i,
            prompt: (0..8).map(|t| ((i * 31 + t) % 256) as i32).collect(),
            max_new: 4,
            cancel_at: None,
            drop_sink_at: None,
            arrive_at: 0,
        })
        .collect();
    let fixed = drive(
        Batcher::new(probe, BatcherConfig { kv_budget_bytes: budget, ..Default::default() }),
        &jobs,
        0,
    );
    assert_outcomes(&jobs, &fixed);
    assert_eq!(fixed.max_live, 2, "slab budget should cap at 2 slots");

    let page_size = 16;
    let engine = build_engine(KvLayout::Paged { page_size, pages: 64 });
    let page_bytes = engine.kv_page_bytes();
    let paged = drive(
        Batcher::new(engine, BatcherConfig { kv_budget_bytes: budget, ..Default::default() }),
        &jobs,
        budget,
    );
    assert_outcomes(&jobs, &paged);
    assert!(
        paged.max_live > fixed.max_live,
        "paged admitted {} concurrent vs slab {} at budget {budget} (page_bytes {page_bytes})",
        paged.max_live,
        fixed.max_live
    );
    for job in &jobs {
        assert_eq!(paged.finished[&job.id].0, fixed.finished[&job.id].0);
    }
}

/// Chunked prefill must not stall in-flight decodes: while a long prompt
/// trickles in chunk by chunk, a short request admitted earlier keeps
/// emitting a token every step.
#[test]
fn chunked_prefill_interleaves_with_decodes() {
    let engine = build_engine(KvLayout::Paged { page_size: 8, pages: 64 });
    let config = BatcherConfig { prefill_chunk: 8, ..BatcherConfig::default() };
    let mut b = Batcher::new(engine, config);
    b.submit(Request::new(1, vec![7; 4], 30));
    b.step().unwrap(); // short request admitted, first token out
    let long_prompt = vec![3i32; 80]; // 10 chunks of 8
    b.submit(Request::new(2, long_prompt, 4));
    let mut saw_interleave = 0;
    for _ in 0..8 {
        let evs = b.step().unwrap();
        let short_tokens = evs
            .iter()
            .filter(|e| matches!(e, GenerationEvent::Token { id: 1, .. }))
            .count();
        let long_tokens = evs
            .iter()
            .filter(|e| matches!(e, GenerationEvent::Token { id: 2, .. }))
            .count();
        if short_tokens > 0 && long_tokens == 0 {
            saw_interleave += 1; // long still prefilling, short still decoding
        }
    }
    assert!(
        saw_interleave >= 5,
        "short request decoded through only {saw_interleave} of the long prompt's chunk steps"
    );
    while b.pending() > 0 {
        b.step().unwrap();
    }
    b.allocator().unwrap().check().unwrap();
    assert_eq!(b.allocator().unwrap().pages_in_use(), 0);
}
