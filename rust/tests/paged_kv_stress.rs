//! Deterministic randomized serving stress harness for the paged KV
//! batcher (the proof obligation for continuous batching over a paged
//! cache).
//!
//! A seeded workload of 200+ requests with mixed prompt lengths, random
//! mid-flight cancels and client-timeout sink drops is driven through
//! `Batcher::step` *manually*; after **every** step the harness audits the
//! `BlockAllocator`:
//!
//! * no page leaked (free + owned == total),
//! * no page double-owned,
//! * `pages_in_use * page_bytes` never exceeds the `--kv-budget` bytes,
//! * every accepted request reaches exactly one terminal event.
//!
//! A separate oracle test replays the same workload through the fixed-slot
//! batcher and asserts per-request token streams are **bitwise identical**
//! (same seeds) — and that at an equal byte budget the paged batcher admits
//! strictly more concurrent requests than the fixed-slot baseline.
//!
//! The harness writes a JSON invariant report (one entry per seed) to
//! `$PAGED_KV_REPORT`, or `target/tmp/PAGED_KV_STRESS.json` by default; CI
//! uploads it next to the BENCH_*.json artifacts.
//!
//! The **shared-prefix** workloads at the bottom stress the prefix cache on
//! top of the same audits: per-step refcount/reservation checks (no shared
//! page freed or zeroed while referenced, conservation includes cached
//! chains), bitwise replay against the cache-off batcher, and the
//! acceptance numbers (>= 2x fewer prefill tokens and strictly higher
//! admitted concurrency at an equal byte budget on the 8-template
//! workload). Their JSON report goes to `$PREFIX_CACHE_REPORT`, default
//! `target/tmp/PREFIX_CACHE_STRESS.json`, uploaded next to the paged one.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::mpsc::{channel, Receiver};

use ladder_infer::comm::{Fabric, Interconnect};
use ladder_infer::engine::spill::fnv1a64_tokens;
use ladder_infer::engine::{KvLayout, RuntimeKind, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::Exec;
use ladder_infer::server::{Batcher, BatcherConfig, FinishReason, GenerationEvent, Request};
use ladder_infer::util::json::Json;
use ladder_infer::util::rng::Rng;

const BATCH: usize = 4;

fn build_engine(layout: KvLayout) -> TpEngine {
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = WeightStore::random(exec.cfg(), 0xfeed);
    TpEngine::with_layout(
        exec,
        &weights,
        2,
        Arch::Ladder,
        BATCH,
        Interconnect::new(Fabric::Local),
        RuntimeKind::default(),
        layout,
    )
    .unwrap()
}

/// One request of the generated workload.
#[derive(Clone)]
struct Job {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    /// `Some(step)`: explicitly cancelled after that scheduler step.
    cancel_at: Option<usize>,
    /// `Some(step)`: the client "times out" — its event sink is dropped
    /// after that step, and the batcher must reclaim the slot on its own.
    drop_sink_at: Option<usize>,
    /// Which scheduler step the request arrives at.
    arrive_at: usize,
}

/// Mixed-length workload: ~50% short, ~35% medium, ~15% long prompts,
/// arrivals spread over the first ~150 steps, ~8% cancels, ~5% timeouts.
fn workload(seed: u64, n: usize) -> Vec<Job> {
    let mut rng = Rng::new(seed);
    let mut arrive = 0usize;
    (0..n)
        .map(|i| {
            let len = match rng.below(100) {
                0..=49 => rng.range(1, 8),
                50..=84 => rng.range(8, 40),
                _ => rng.range(40, 90),
            };
            arrive += rng.below(3); // bursty Poisson-ish arrivals
            let cancel = rng.below(100) < 8;
            let timeout = !cancel && rng.below(100) < 5;
            Job {
                id: i as u64,
                prompt: (0..len).map(|_| rng.below(256) as i32).collect(),
                max_new: rng.range(1, 12),
                cancel_at: cancel.then(|| arrive + rng.below(30)),
                drop_sink_at: timeout.then(|| arrive + rng.below(30)),
                arrive_at: arrive,
            }
        })
        .collect()
}

/// Outcome of driving one workload to completion.
struct RunStats {
    /// id -> (tokens, finish reason); exactly one entry per request.
    finished: HashMap<u64, (Vec<i32>, FinishReason)>,
    max_live: usize,
    high_water_pages: usize,
    admission_blocked: usize,
    steps: usize,
    /// Prompt tokens actually prefilled (cache hits skip their prefix).
    prefill_tokens: usize,
    /// Prompt tokens served from the prefix cache.
    prefix_hit_tokens: usize,
    /// Cached pages evicted over the run.
    prefix_evicted: usize,
    /// Pages restored from the disk spill tier.
    prefix_disk_hits: usize,
    /// Spill files rejected at restore time (checksum/geometry/token
    /// mismatch) — each fell back to cold prefill.
    prefix_disk_rejected: usize,
    /// Bytes read back from the disk tier by successful restores.
    prefix_restore_bytes: usize,
}

/// Drive `jobs` through a batcher step by step, auditing the allocator
/// after every step. `budget_bytes` caps both the batcher config and the
/// audit; 0 disables the byte assertion.
fn drive(mut batcher: Batcher, jobs: &[Job], budget_bytes: usize) -> RunStats {
    let mut finished: HashMap<u64, (Vec<i32>, FinishReason)> = HashMap::new();
    let mut live_ids: HashSet<u64> = HashSet::new();
    let mut max_live = 0usize;
    let mut sinks: HashMap<u64, Receiver<GenerationEvent>> = HashMap::new();
    let mut submitted = 0usize;
    let mut step = 0usize;
    let mut record = |evs: Vec<GenerationEvent>, live: &mut HashSet<u64>, max: &mut usize| {
        for ev in evs {
            match ev {
                GenerationEvent::Admitted { id, .. } => {
                    live.insert(id);
                    *max = (*max).max(live.len());
                }
                GenerationEvent::Token { .. } => {}
                GenerationEvent::Finished { result } => {
                    live.remove(&result.id);
                    let dup = finished.insert(result.id, (result.tokens, result.finish_reason));
                    assert!(dup.is_none(), "request {} finished twice", result.id);
                }
                GenerationEvent::Error { id, reason, .. } => {
                    panic!("request {id} errored unexpectedly: {reason}");
                }
            }
        }
    };
    while submitted < jobs.len() || batcher.pending() > 0 {
        assert!(step < 100_000, "workload failed to drain after {step} steps");
        // arrivals scheduled for this step
        while submitted < jobs.len() && jobs[submitted].arrive_at <= step {
            let job = &jobs[submitted];
            let request = Request::new(job.id, job.prompt.clone(), job.max_new);
            if job.drop_sink_at.is_some() {
                let (tx, rx) = channel();
                sinks.insert(job.id, rx);
                batcher.submit_streaming(request, tx);
            } else {
                batcher.submit(request);
            }
            submitted += 1;
        }
        let evs = batcher.step().expect("batcher step");
        record(evs, &mut live_ids, &mut max_live);
        // client timeouts: drop the sink, the batcher reclaims the slot
        sinks.retain(|id, _| {
            let job = &jobs[*id as usize];
            !job.drop_sink_at.is_some_and(|at| at <= step)
        });
        // explicit cancels
        for job in jobs[..submitted].iter() {
            if job.cancel_at == Some(step) {
                if let Some(ev) = batcher.cancel(job.id).expect("cancel") {
                    record(vec![ev], &mut live_ids, &mut max_live);
                }
            }
        }
        // -- the allocator audit, the heart of this harness --
        if let Some(alloc) = batcher.allocator() {
            alloc.check().unwrap_or_else(|e| panic!("step {step}: {e}"));
            if budget_bytes > 0 {
                assert!(
                    alloc.bytes_in_use() <= budget_bytes,
                    "step {step}: {} KV bytes in use exceed the {budget_bytes} budget \
                     (cached chains included)",
                    alloc.bytes_in_use()
                );
            }
            // prefix-cache cross-audit: every tree page is tree-referenced
            // in the allocator, and the counts agree — a shared page can
            // therefore never have been freed or zeroed while referenced
            // (check() above already proved free/referenced exclusion)
            if let Some(tree) = batcher.prefix_tree() {
                let pages = tree.pages();
                assert_eq!(pages.len(), tree.cached_pages(), "step {step}: tree page count");
                assert_eq!(
                    pages.len(),
                    alloc.cached_pages(),
                    "step {step}: tree vs allocator cached-page count"
                );
                for p in pages {
                    assert!(alloc.is_cached(p), "step {step}: tree page {p} lost its ref");
                }
            }
        }
        step += 1;
    }
    let (high_water_pages, admission_blocked) = match batcher.allocator() {
        Some(alloc) => {
            // drained: everything still allocated must be a cached chain
            alloc.check().unwrap();
            let cached = alloc.cached_pages();
            assert_eq!(alloc.pages_in_use(), cached, "non-cached pages leaked after drain");
            assert_eq!(alloc.reserved_pages(), 0, "reservations leaked after drain");
            (alloc.high_water(), batcher.metrics.admission_blocked)
        }
        None => (0, 0),
    };
    // flushing the (now fully idle) cache must round-trip the whole pool
    // back to the free list — the no-leak proof including cached chains
    let cached = batcher.allocator().map_or(0, |a| a.cached_pages());
    assert_eq!(batcher.flush_prefix_cache().unwrap(), cached);
    if let Some(alloc) = batcher.allocator() {
        alloc.check().unwrap();
        assert_eq!(alloc.pages_in_use(), 0, "pages leaked after cache flush");
        assert_eq!(alloc.free_pages(), alloc.total_pages());
    }
    RunStats {
        finished,
        max_live,
        high_water_pages,
        admission_blocked,
        steps: step,
        prefill_tokens: batcher.metrics.prefill_tokens,
        prefix_hit_tokens: batcher.metrics.prefix_hit_tokens,
        prefix_evicted: batcher.metrics.prefix_evicted_pages,
        prefix_disk_hits: batcher.metrics.prefix_disk_hits,
        prefix_disk_rejected: batcher.metrics.prefix_disk_rejected,
        prefix_restore_bytes: batcher.metrics.prefix_restore_bytes,
    }
}

fn assert_outcomes(jobs: &[Job], stats: &RunStats) {
    assert_eq!(stats.finished.len(), jobs.len(), "every request must reach a terminal event");
    for job in jobs {
        let (tokens, reason) = &stats.finished[&job.id];
        match reason {
            // untouched requests run to their full budget (greedy, no eos)
            FinishReason::Length => assert_eq!(
                tokens.len(),
                job.max_new,
                "request {} finished early without a cancel",
                job.id
            ),
            FinishReason::Cancelled => assert!(
                job.cancel_at.is_some() || job.drop_sink_at.is_some(),
                "request {} cancelled without a cancel/timeout plan",
                job.id
            ),
            other => panic!("request {} finished with unexpected {other:?}", job.id),
        }
    }
}

/// The tentpole harness: 3 fixed seeds x (page size, chunk, budget)
/// variations, full allocator audit every step, JSON invariant report.
#[test]
fn stress_randomized_three_seeds() {
    let configs = [
        // (seed, page_size, prefill_chunk, budget_pages)
        (0xa11ce_u64, 4usize, 0usize, 120usize),
        (0xb0b, 8, 7, 48),
        (0xc0ffee, 16, 16, 28),
    ];
    let mut entries = Vec::new();
    for (seed, page_size, chunk, budget_pages) in configs {
        let jobs = workload(seed, 200);
        let per_seq = 128usize.div_ceil(page_size);
        let alloc_pages = budget_pages.max(per_seq);
        // pool strictly larger than the byte budget, so the budget clamp
        // (not pool exhaustion) is what the harness actually audits
        let pages = alloc_pages + 8;
        let engine = build_engine(KvLayout::Paged { page_size, pages });
        let page_bytes = engine.kv_page_bytes();
        let budget_bytes = alloc_pages * page_bytes;
        let config = BatcherConfig {
            decode_burst: 1,
            kv_budget_bytes: budget_bytes,
            prefill_chunk: chunk,
            ..BatcherConfig::default()
        };
        let stats = drive(Batcher::new(engine, config), &jobs, budget_bytes);
        assert_outcomes(&jobs, &stats);
        let cancelled =
            stats.finished.values().filter(|(_, r)| *r == FinishReason::Cancelled).count();
        entries.push(
            Json::obj()
                .set("seed", format!("{seed:#x}"))
                .set("requests", jobs.len())
                .set("page_size", page_size)
                .set("prefill_chunk", chunk)
                .set("total_pages", pages)
                .set("page_bytes", page_bytes)
                .set("budget_bytes", budget_bytes)
                .set("steps", stats.steps)
                .set("completed", stats.finished.len())
                .set("cancelled", cancelled)
                .set("max_concurrent", stats.max_live)
                .set("kv_pages_high_water", stats.high_water_pages)
                .set("admission_blocked", stats.admission_blocked)
                .set("invariants", "no-leak, no-double-own, budget-respected, all-finished"),
        );
    }
    let report = Json::obj().set("harness", "paged_kv_stress").set("seeds", Json::Arr(entries));
    let path = std::env::var("PAGED_KV_REPORT").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join("PAGED_KV_STRESS.json")
    });
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(&path, report.to_string()).expect("write invariant report");
}

/// Acceptance oracle: under the same seeded workload (no cancels), the
/// paged batcher's per-request token streams are bitwise identical to the
/// fixed-slot batcher's — regardless of page size or prefill chunking,
/// and even though admission interleaves differently.
#[test]
fn paged_streams_bitwise_match_fixed_slot_oracle() {
    let jobs: Vec<Job> = workload(0xdead, 60)
        .into_iter()
        .map(|j| Job { cancel_at: None, drop_sink_at: None, ..j })
        .collect();
    let fixed = drive(
        Batcher::new(build_engine(KvLayout::Slab), BatcherConfig::default()),
        &jobs,
        0,
    );
    assert_outcomes(&jobs, &fixed);
    for (page_size, chunk) in [(4usize, 0usize), (16, 5)] {
        let pages = BATCH * 128usize.div_ceil(page_size);
        let engine = build_engine(KvLayout::Paged { page_size, pages });
        let config = BatcherConfig { prefill_chunk: chunk, ..BatcherConfig::default() };
        let paged = drive(Batcher::new(engine, config), &jobs, 0);
        assert_outcomes(&jobs, &paged);
        for job in &jobs {
            assert_eq!(
                paged.finished[&job.id].0, fixed.finished[&job.id].0,
                "request {} diverged from the fixed-slot oracle \
                 (page_size={page_size}, chunk={chunk})",
                job.id
            );
        }
    }
}

/// Acceptance: at the same byte budget, block-granular admission runs
/// strictly more requests concurrently than `max_seq`-sized slots — while
/// producing the same tokens.
#[test]
fn paged_admits_more_concurrent_requests_at_equal_budget() {
    // budget = 2.5 fixed slots -> the slab batcher caps at 2 concurrent
    let probe = build_engine(KvLayout::Slab);
    let budget = probe.kv_bytes_per_slot() * 5 / 2;
    let jobs: Vec<Job> = (0..8u64)
        .map(|i| Job {
            id: i,
            prompt: (0..8).map(|t| ((i * 31 + t) % 256) as i32).collect(),
            max_new: 4,
            cancel_at: None,
            drop_sink_at: None,
            arrive_at: 0,
        })
        .collect();
    let fixed = drive(
        Batcher::new(probe, BatcherConfig { kv_budget_bytes: budget, ..Default::default() }),
        &jobs,
        0,
    );
    assert_outcomes(&jobs, &fixed);
    assert_eq!(fixed.max_live, 2, "slab budget should cap at 2 slots");

    let page_size = 16;
    let engine = build_engine(KvLayout::Paged { page_size, pages: 64 });
    let page_bytes = engine.kv_page_bytes();
    let paged = drive(
        Batcher::new(engine, BatcherConfig { kv_budget_bytes: budget, ..Default::default() }),
        &jobs,
        budget,
    );
    assert_outcomes(&jobs, &paged);
    assert!(
        paged.max_live > fixed.max_live,
        "paged admitted {} concurrent vs slab {} at budget {budget} (page_bytes {page_bytes})",
        paged.max_live,
        fixed.max_live
    );
    for job in &jobs {
        assert_eq!(paged.finished[&job.id].0, fixed.finished[&job.id].0);
    }
}

/// Chunked prefill must not stall in-flight decodes: while a long prompt
/// trickles in chunk by chunk, a short request admitted earlier keeps
/// emitting a token every step.
#[test]
fn chunked_prefill_interleaves_with_decodes() {
    let engine = build_engine(KvLayout::Paged { page_size: 8, pages: 64 });
    let config = BatcherConfig { prefill_chunk: 8, ..BatcherConfig::default() };
    let mut b = Batcher::new(engine, config);
    b.submit(Request::new(1, vec![7; 4], 30));
    b.step().unwrap(); // short request admitted, first token out
    let long_prompt = vec![3i32; 80]; // 10 chunks of 8
    b.submit(Request::new(2, long_prompt, 4));
    let mut saw_interleave = 0;
    for _ in 0..8 {
        let evs = b.step().unwrap();
        let short_tokens = evs
            .iter()
            .filter(|e| matches!(e, GenerationEvent::Token { id: 1, .. }))
            .count();
        let long_tokens = evs
            .iter()
            .filter(|e| matches!(e, GenerationEvent::Token { id: 2, .. }))
            .count();
        if short_tokens > 0 && long_tokens == 0 {
            saw_interleave += 1; // long still prefilling, short still decoding
        }
    }
    assert!(
        saw_interleave >= 5,
        "short request decoded through only {saw_interleave} of the long prompt's chunk steps"
    );
    while b.pending() > 0 {
        b.step().unwrap();
    }
    b.allocator().unwrap().check().unwrap();
    assert_eq!(b.allocator().unwrap().pages_in_use(), 0);
}

// ---------------------------------------------------------------------------
// shared-prefix workloads (the prefix-cache proof obligations)
// ---------------------------------------------------------------------------

/// Seeded 8-system-prompt workload: every prompt is one of 8 templates
/// (64 tokens = 8 full pages at page size 8) plus a short random user
/// tail; draws are skewed 70% onto the two "hot" templates, the shape the
/// cache exists for. ~10% of prompts are exactly a template (page-aligned
/// full match, exercising the copy-on-write trailing page), ~6% cancel
/// mid-flight and ~4% drop their sink.
fn template_workload(seed: u64, n: usize, tlen: usize) -> Vec<Job> {
    let mut rng = Rng::new(seed);
    let templates: Vec<Vec<i32>> =
        (0..8).map(|_| (0..tlen).map(|_| rng.below(256) as i32).collect()).collect();
    let mut arrive = 0usize;
    (0..n)
        .map(|i| {
            arrive += rng.below(2);
            let t = if rng.below(100) < 70 { rng.below(2) } else { rng.below(8) };
            let mut prompt = templates[t].clone();
            if rng.below(10) > 0 {
                let tail = rng.range(1, 11);
                prompt.extend((0..tail).map(|_| rng.below(256) as i32));
            }
            let cancel = rng.below(100) < 6;
            let timeout = !cancel && rng.below(100) < 4;
            Job {
                id: i as u64,
                prompt,
                max_new: rng.range(1, 8),
                cancel_at: cancel.then(|| arrive + rng.below(25)),
                drop_sink_at: timeout.then(|| arrive + rng.below(25)),
                arrive_at: arrive,
            }
        })
        .collect()
}

/// Drive one shared-prefix workload twice — cache on and cache off — at
/// the same byte budget, with the full per-step audits, and return both.
fn drive_on_off(jobs: &[Job], page_size: usize, budget_pages: usize) -> (RunStats, RunStats) {
    let run = |prefix_cache: bool| {
        // pool strictly larger than the byte budget so the budget clamp
        // (not pool sizing) is what admission and eviction push against
        let pages = budget_pages + 8;
        let engine = build_engine(KvLayout::Paged { page_size, pages });
        let budget_bytes = budget_pages * engine.kv_page_bytes();
        let config = BatcherConfig {
            decode_burst: 1,
            kv_budget_bytes: budget_bytes,
            prefill_chunk: 16,
            prefix_cache,
            ..BatcherConfig::default()
        };
        drive(Batcher::new(engine, config), jobs, budget_bytes)
    };
    (run(true), run(false))
}

/// Bitwise replay: every request untouched by a cancel/timeout plan must
/// produce identical tokens with the cache on and off — interleaving,
/// sharing and eviction change *when* work happens, never its bits.
fn assert_bitwise_replay(jobs: &[Job], on: &RunStats, off: &RunStats) {
    for job in jobs {
        if job.cancel_at.is_some() || job.drop_sink_at.is_some() {
            continue;
        }
        assert_eq!(
            on.finished[&job.id].0, off.finished[&job.id].0,
            "request {} diverged bitwise between cache-on and cache-off",
            job.id
        );
    }
}

/// One location rule for the prefix-cache reports: `$PREFIX_CACHE_REPORT`
/// (CI) or the cargo tmpdir, with `suffix` mapping concurrent tests onto
/// sibling files instead of racing on one object (CI uploads the
/// `PREFIX_CACHE_STRESS*.json` glob).
fn prefix_report_path(suffix: Option<&str>) -> PathBuf {
    let path = std::env::var("PREFIX_CACHE_REPORT").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join("PREFIX_CACHE_STRESS.json")
    });
    match suffix {
        Some(s) => path.with_extension(format!("{s}.json")),
        None => path,
    }
}

fn write_prefix_report(suffix: Option<&str>, report: Json) {
    let path = prefix_report_path(suffix);
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(&path, report.to_string()).expect("write prefix-cache report");
}

/// The 8-template acceptance workload: 200 requests, audits after every
/// step (via `drive`), bitwise replay against the cache-off batcher, >= 2x
/// fewer prefill tokens, and strictly higher admitted concurrency at the
/// same byte budget (34 pages: the cache-off batcher can never hold four
/// 9-page reservations, the cache-on one can once chains are shared).
#[test]
fn shared_prefix_templates_halve_prefill_and_raise_concurrency() {
    let jobs = template_workload(0x5eeded, 200, 64);
    let (on, off) = drive_on_off(&jobs, 8, 34);
    assert_outcomes(&jobs, &on);
    assert_outcomes(&jobs, &off);
    assert_bitwise_replay(&jobs, &on, &off);
    assert!(
        on.prefill_tokens * 2 <= off.prefill_tokens,
        "prefix cache saved too little prefill: {} tokens with cache vs {} without",
        on.prefill_tokens,
        off.prefill_tokens
    );
    assert!(
        on.max_live > off.max_live,
        "cache-on admitted {} concurrent vs {} cache-off at the same budget",
        on.max_live,
        off.max_live
    );
    assert!(on.prefix_hit_tokens > 0, "no request ever hit the cache");
    assert!(on.prefix_evicted > 0, "the 8-template working set must overflow 34 pages");
    let entry = Json::obj()
        .set("workload", "8_templates_x_200")
        .set("requests", jobs.len())
        .set("page_size", 8)
        .set("budget_pages", 34)
        .set("steps_on", on.steps)
        .set("steps_off", off.steps)
        .set("prefill_tokens_on", on.prefill_tokens)
        .set("prefill_tokens_off", off.prefill_tokens)
        .set("prefill_reduction", off.prefill_tokens as f64 / on.prefill_tokens.max(1) as f64)
        .set("prefix_hit_tokens", on.prefix_hit_tokens)
        .set("prefix_evicted_pages", on.prefix_evicted)
        .set("max_concurrent_on", on.max_live)
        .set("max_concurrent_off", off.max_live)
        .set("kv_pages_high_water_on", on.high_water_pages)
        .set("admission_blocked_on", on.admission_blocked)
        .set(
            "invariants",
            "refcounts-audited-per-step, bitwise-replay, no-leak-incl-cache, budget-respected",
        );
    let report = Json::obj()
        .set("harness", "prefix_cache_stress")
        .set("workloads", Json::Arr(vec![entry]));
    write_prefix_report(None, report);
}

/// Multi-turn resubmission: conversations grow their history and resubmit
/// it as the next turn's prompt. Turn k+1's prompt extends turn k's, so
/// its full prompt pages — including the pages that now hold turn k's
/// *generated* tokens, re-prefilled as prompt — come from the tree, and
/// reuse compounds turn over turn. Bitwise replay holds per turn (turn
/// k+1's prompts are built from turn k's outputs, which match bitwise).
#[test]
fn multi_turn_resubmission_reuses_grown_histories() {
    let page_size = 8usize;
    let conversations = 12usize;
    let turns = 3usize;
    let build = |prefix_cache: bool| -> (Vec<usize>, Vec<Vec<Vec<i32>>>, usize, usize) {
        // pool sized so the 12 conversations' grown histories stay cached
        // across all turns — eviction pressure is the template test's job
        let engine = build_engine(KvLayout::Paged { page_size, pages: 160 });
        let config = BatcherConfig {
            decode_burst: 1,
            kv_budget_bytes: 0,
            prefill_chunk: 16,
            prefix_cache,
            ..BatcherConfig::default()
        };
        let mut batcher = Batcher::new(engine, config);
        let mut rng = Rng::new(0x7a1e);
        let mut histories: Vec<Vec<i32>> = (0..conversations)
            .map(|_| (0..rng.range(18, 30)).map(|_| rng.below(256) as i32).collect())
            .collect();
        let mut prefill_per_turn = Vec::new();
        let mut tokens_per_turn: Vec<Vec<Vec<i32>>> = Vec::new();
        for turn in 0..turns {
            let before = batcher.metrics.prefill_tokens;
            for (c, h) in histories.iter().enumerate() {
                batcher.submit(Request::new((turn * conversations + c) as u64, h.clone(), 6));
            }
            let mut outs: Vec<Vec<i32>> = vec![Vec::new(); conversations];
            while batcher.pending() > 0 {
                for ev in batcher.step().unwrap() {
                    if let GenerationEvent::Finished { result } = ev {
                        outs[result.id as usize % conversations] = result.tokens;
                    }
                }
                batcher.allocator().unwrap().check().unwrap();
            }
            prefill_per_turn.push(batcher.metrics.prefill_tokens - before);
            // grow each history: generated tokens + a fresh user message
            for (h, out) in histories.iter_mut().zip(&outs) {
                assert_eq!(out.len(), 6);
                h.extend(out);
                h.extend((0..rng.range(6, 12)).map(|_| rng.below(256) as i32));
            }
            tokens_per_turn.push(outs);
        }
        let hits = batcher.metrics.prefix_hit_tokens;
        let prefills = batcher.metrics.prefill_tokens;
        // drain + flush round-trip, as in `drive`
        let cached = batcher.allocator().unwrap().cached_pages();
        assert_eq!(batcher.flush_prefix_cache().unwrap(), cached);
        let alloc = batcher.allocator().unwrap();
        alloc.check().unwrap();
        assert_eq!(alloc.pages_in_use(), 0);
        (prefill_per_turn, tokens_per_turn, hits, prefills)
    };
    let (on_turn, on_tokens, on_hits, on_prefill) = build(true);
    let (off_turn, off_tokens, off_hits, off_prefill) = build(false);
    assert_eq!(on_tokens, off_tokens, "multi-turn streams diverged bitwise");
    assert_eq!(off_hits, 0);
    assert!(
        on_prefill < off_prefill,
        "history reuse must shrink prefill: {on_prefill} vs {off_prefill}"
    );
    // reuse compounds: by the last turn the cache covers the whole shared
    // history, so the cache-on run prefills well under half of cold
    assert!(
        on_turn[turns - 1] * 2 < off_turn[turns - 1],
        "turn {turns}: {} prefilled with cache vs {} without",
        on_turn[turns - 1],
        off_turn[turns - 1]
    );
    assert!(on_hits > 0);
    write_prefix_report_multi_turn(on_turn, off_turn, on_hits, on_prefill, off_prefill);
}

fn write_prefix_report_multi_turn(
    on_turn: Vec<usize>,
    off_turn: Vec<usize>,
    hits: usize,
    on_prefill: usize,
    off_prefill: usize,
) {
    let arr = |v: &[usize]| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
    let entry = Json::obj()
        .set("workload", "multi_turn_3x12")
        .set("prefill_tokens_per_turn_on", arr(&on_turn))
        .set("prefill_tokens_per_turn_off", arr(&off_turn))
        .set("prefix_hit_tokens", hits)
        .set("prefill_tokens_on", on_prefill)
        .set("prefill_tokens_off", off_prefill);
    // the template test owns the bare report path; this workload writes a
    // sibling file so concurrently running tests never race on one object
    write_prefix_report(Some("multi_turn"), entry);
}

/// The `clear_slot` / release interaction (regression): after a donor
/// request finishes and its slot is released on every rank, a cache hit on
/// its published pages must decode bitwise-identically to a cold run — the
/// paged release path must never zero pool bytes the tree still
/// references.
#[test]
fn cache_hit_after_donor_finished_decodes_bitwise_identically() {
    let page_size = 8usize;
    let donor: Vec<i32> = (0..20).map(|i| (i * 7 + 3) % 256).collect();
    let mut follower = donor.clone();
    follower.extend([9, 8, 7]);
    let run = |prefix_cache: bool, submit_donor: bool| -> Vec<i32> {
        let engine = build_engine(KvLayout::Paged { page_size, pages: 32 });
        let config = BatcherConfig { prefix_cache, ..BatcherConfig::default() };
        let mut b = Batcher::new(engine, config);
        if submit_donor {
            b.submit(Request::new(1, donor.clone(), 5));
            while b.pending() > 0 {
                b.step().unwrap();
            }
            // donor finished: its slot was released on every rank, its full
            // prompt pages belong to the tree now
            if prefix_cache {
                assert_eq!(b.prefix_tree().unwrap().cached_pages(), 2);
            }
        }
        b.submit(Request::new(2, follower.clone(), 5));
        let mut tokens = Vec::new();
        while b.pending() > 0 {
            for ev in b.step().unwrap() {
                if let GenerationEvent::Finished { result } = ev {
                    if result.id == 2 {
                        tokens = result.tokens;
                    }
                }
            }
        }
        if prefix_cache && submit_donor {
            assert_eq!(
                b.metrics.prefix_hit_tokens, 16,
                "follower must reuse the donor's two full pages"
            );
        }
        tokens
    };
    let hit = run(true, true);
    let cold = run(false, false);
    assert_eq!(hit, cold, "a hit on a finished donor's pages corrupted decoding");
}

/// Corner of the copy-on-write path: when the popped trailing page is the
/// only evictable leaf, the admission's own shortfall eviction consumes it
/// (it sits outside the admission invariant once popped). The batcher must
/// fall back to re-prefilling that page cold — not die trying to copy a
/// page that was just reallocated, possibly as the copy's own destination.
#[test]
fn full_prompt_hit_survives_cow_source_eviction_on_a_full_pool() {
    let page_size = 8usize;
    // pool of exactly 2 pages; prompt fills both; max_new 0 keeps the
    // reservation at 2 pages so the never-fits check passes
    let prompt: Vec<i32> = (0..16).map(|i| (i * 11 + 5) % 256).collect();
    let run = |prefix_cache: bool, donor: bool| -> (Vec<i32>, usize) {
        let engine = build_engine(KvLayout::Paged { page_size, pages: 2 });
        let config = BatcherConfig { prefix_cache, ..BatcherConfig::default() };
        let mut b = Batcher::new(engine, config);
        if donor {
            b.submit(Request::new(1, prompt.clone(), 0));
            while b.pending() > 0 {
                b.step().unwrap();
                b.allocator().unwrap().check().unwrap();
            }
            // both pages published and idle; the free list is empty
            assert_eq!(b.allocator().unwrap().free_pages(), 0);
        }
        b.submit(Request::new(2, prompt.clone(), 0));
        let mut tokens = Vec::new();
        while b.pending() > 0 {
            for ev in b.step().expect("COW fallback must not error the step") {
                if let GenerationEvent::Finished { result } = ev {
                    if result.id == 2 {
                        tokens = result.tokens;
                    }
                }
            }
            b.allocator().unwrap().check().unwrap();
        }
        (tokens, b.metrics.prefix_hit_tokens)
    };
    let (hit, hit_tokens) = run(true, true);
    let (cold, _) = run(false, false);
    assert_eq!(hit, cold, "fallback path diverged bitwise from cold");
    // the first full page survives as a hit; the popped trailing page was
    // evicted to back the suffix, so exactly one page is re-prefilled
    assert_eq!(hit_tokens, 8, "fallback should keep the untouched prefix cached");
}

/// Regression for the match->retain window: on a pool where the cached
/// working set alone fills every page, *every* admission runs a shortfall
/// eviction while it is still holding an unretained `match_prefix` result.
/// The admission pins must keep each matched chain alive through its own
/// eviction (and release the pins on every exit path — a leaked pin would
/// wedge eviction and trip the per-step `check()` or the end-of-run
/// flush). Four 4-page templates on a 16-page budget: once all four chains
/// are published the free list is empty, so two same-step admissions per
/// scheduler step cross the window under maximum eviction pressure.
#[test]
fn tight_pool_same_step_admissions_keep_matched_chains_served() {
    let templates: Vec<Vec<i32>> = (0..4usize)
        .map(|k| (0..32usize).map(|t| ((k * 19 + t * 5 + 3) % 256) as i32).collect())
        .collect();
    let jobs: Vec<Job> = (0..40u64)
        .map(|i| {
            let mut prompt = templates[(i % 4) as usize].clone();
            prompt.extend([(i % 250) as i32 + 1, 7, 9]);
            Job {
                id: i,
                prompt,
                max_new: 3,
                cancel_at: None,
                drop_sink_at: None,
                arrive_at: (i / 2) as usize,
            }
        })
        .collect();
    let (on, off) = drive_on_off(&jobs, 8, 16);
    assert_outcomes(&jobs, &on);
    assert_outcomes(&jobs, &off);
    assert_bitwise_replay(&jobs, &on, &off);
    assert!(on.prefix_hit_tokens > 0, "matched chains must keep serving hits");
    assert!(
        on.prefix_evicted > 0,
        "the four chains must overflow the 16-page budget, or the window was never stressed"
    );
}

// ---------------------------------------------------------------------------
// disk-tier workloads (the spill/restore proof obligations; `kv_tier` in
// the name routes these to their own CI step)
// ---------------------------------------------------------------------------

/// A fresh scratch directory for one test's spill tier.
fn spill_scratch(tag: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR"))
        .join(format!("kv_tier_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create spill scratch dir");
    dir
}

/// One location rule for the disk-tier reports: `$KV_TIER_REPORT` (CI) or
/// the cargo tmpdir; `suffix` maps concurrent tests onto sibling files
/// (CI uploads the `KV_TIER_STRESS*.json` glob).
fn write_kv_tier_report(suffix: Option<&str>, report: Json) {
    let path = std::env::var("KV_TIER_REPORT").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join("KV_TIER_STRESS.json")
    });
    let path = match suffix {
        Some(s) => path.with_extension(format!("{s}.json")),
        None => path,
    };
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(&path, report.to_string()).expect("write kv-tier report");
}

/// Four 64-token templates (8 full pages at page size 8) with short random
/// user tails — the restartable analogue of `template_workload`, with the
/// template tokens reproducible from outside so a test can corrupt a
/// specific chain's spill file.
fn tier_templates() -> Vec<Vec<i32>> {
    (0..4usize)
        .map(|k| (0..64usize).map(|t| ((k * 37 + t * 3 + 11) % 256) as i32).collect())
        .collect()
}

fn tier_workload(seed: u64, base_id: u64, n: usize) -> Vec<Job> {
    let templates = tier_templates();
    let mut rng = Rng::new(seed);
    let mut arrive = 0usize;
    (0..n)
        .map(|i| {
            arrive += rng.below(2);
            let mut prompt = templates[i % templates.len()].clone();
            let tail = rng.range(1, 7);
            prompt.extend((0..tail).map(|_| rng.below(256) as i32));
            Job {
                id: base_id + i as u64,
                prompt,
                max_new: rng.range(1, 6),
                cancel_at: None,
                drop_sink_at: None,
                arrive_at: arrive,
            }
        })
        .collect()
}

/// Drive `jobs` until `finish_target` of them have finished, auditing the
/// allocator after every step, then stop — in-flight slots, queued
/// requests and the RAM cache are simply abandoned when the caller drops
/// the batcher, simulating a crash mid-batch.
fn run_until(batcher: &mut Batcher, jobs: &[Job], finish_target: usize) {
    let mut submitted = 0usize;
    let mut finished = 0usize;
    let mut step = 0usize;
    while finished < finish_target {
        assert!(step < 100_000, "failed to reach {finish_target} finishes after {step} steps");
        while submitted < jobs.len() && jobs[submitted].arrive_at <= step {
            let job = &jobs[submitted];
            batcher.submit(Request::new(job.id, job.prompt.clone(), job.max_new));
            submitted += 1;
        }
        for ev in batcher.step().expect("batcher step") {
            if matches!(ev, GenerationEvent::Finished { .. }) {
                finished += 1;
            }
        }
        batcher
            .allocator()
            .expect("paged batcher")
            .check()
            .unwrap_or_else(|e| panic!("step {step}: {e}"));
        step += 1;
    }
}

/// The snapshot/restart micro-oracle: a donor warms the cache, `snapshot`
/// persists it, the server restarts with an empty pool, and a follower's
/// prompt is served page by page from disk — bitwise identical to a fully
/// cold run, with only the fresh tail prefilled.
#[test]
fn kv_tier_snapshot_restart_restores_pages_bitwise() {
    let page_size = 8usize;
    let dir = spill_scratch("snapshot");
    let donor: Vec<i32> = (0..24).map(|i| ((i * 7 + 3) % 256) as i32).collect();
    let mut follower = donor.clone();
    follower.extend([9, 8]);
    let spill_config = || BatcherConfig {
        prefix_cache: true,
        kv_spill_dir: dir.to_string_lossy().into_owned(),
        ..BatcherConfig::default()
    };
    // turn 1: the donor publishes three full pages, snapshot spills them
    let mut b =
        Batcher::new(build_engine(KvLayout::Paged { page_size, pages: 32 }), spill_config());
    b.submit(Request::new(1, donor.clone(), 2));
    while b.pending() > 0 {
        b.step().unwrap();
    }
    let (snap_files, snap_bytes) = b.snapshot_cache().unwrap();
    assert_eq!(snap_files, 3, "three full donor pages must spill");
    assert!(snap_bytes > 0);
    drop(b);
    // turn 2: a fresh engine (empty pool, empty tree) over the same dir
    let mut b =
        Batcher::new(build_engine(KvLayout::Paged { page_size, pages: 32 }), spill_config());
    b.submit(Request::new(2, follower.clone(), 4));
    let mut warm = Vec::new();
    while b.pending() > 0 {
        for ev in b.step().unwrap() {
            if let GenerationEvent::Finished { result } = ev {
                warm = result.tokens;
            }
        }
        b.allocator().unwrap().check().unwrap();
    }
    assert_eq!(b.metrics.prefix_disk_hits, 3, "all three donor pages must restore from disk");
    assert_eq!(b.metrics.prefix_hit_tokens, 24);
    assert_eq!(b.metrics.prefill_tokens, 2, "only the fresh tail should prefill");
    assert!(b.metrics.prefix_restore_bytes > 0);
    assert_eq!(b.metrics.prefix_disk_rejected, 0);
    drop(b);
    // the cold oracle: no cache, no disk
    let mut b = Batcher::new(
        build_engine(KvLayout::Paged { page_size, pages: 32 }),
        BatcherConfig::default(),
    );
    b.submit(Request::new(2, follower, 4));
    let mut cold = Vec::new();
    while b.pending() > 0 {
        for ev in b.step().unwrap() {
            if let GenerationEvent::Finished { result } = ev {
                cold = result.tokens;
            }
        }
    }
    assert_eq!(warm, cold, "disk-restored pages must decode bitwise-identically to cold");
    write_kv_tier_report(
        Some("snapshot"),
        Json::obj()
            .set("workload", "snapshot_restart_micro")
            .set("snapshot_files", snap_files)
            .set("snapshot_bytes", snap_bytes as usize)
            .set("invariants", "bitwise-vs-cold, tail-only-prefill, no-rejections"),
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The restart-mid-workload acceptance test. Turn 1 runs on a spill-backed
/// batcher and is killed mid-batch (35 of 50 requests finished, the rest
/// abandoned with the process); `snapshot_cache` persists the live cache
/// first, as a shutting-down server would. One spill file is then
/// corrupted on disk. Turn 2 replays a same-template workload three ways —
/// warm restart over the spill dir, cold restart with a fresh cache, and
/// no cache at all — asserting: all three streams bitwise identical, the
/// warm restart prefills >= 2x less than cold *and* strictly less than
/// the cold restart, the corrupted chain is rejected (its file deleted)
/// and falls back to cold prefill, and the per-step allocator audits stay
/// green throughout (pending-page accounting included).
#[test]
fn kv_tier_restart_mid_workload_restores_warm_and_drops_corruption() {
    let page_size = 8usize;
    let pages = 48usize;
    let dir = spill_scratch("restart");
    let turn1 = tier_workload(0x0d15c0, 0, 50);
    let turn2 = tier_workload(0x0d15c1, 1000, 50);
    let spill_config = || BatcherConfig {
        decode_burst: 1,
        prefill_chunk: 16,
        prefix_cache: true,
        kv_spill_dir: dir.to_string_lossy().into_owned(),
        ..BatcherConfig::default()
    };

    // turn 1, killed mid-batch: only the disk tier survives the drop
    let mut b = Batcher::new(build_engine(KvLayout::Paged { page_size, pages }), spill_config());
    run_until(&mut b, &turn1, 35);
    let (snap_files, snap_bytes) = b.snapshot_cache().expect("snapshot");
    assert!(snap_files > 0 && snap_bytes > 0, "snapshot must persist the live cache");
    let spilled_turn1 = b.metrics.prefix_spilled_pages;
    assert!(spilled_turn1 >= snap_files, "snapshot pages count as spills");
    drop(b); // the crash: no drain, no flush

    // poison template 0's first page: the restart must reject the bad
    // checksum and re-prefill that chain cold, never serving these bytes
    let key = fnv1a64_tokens(&tier_templates()[0][..page_size]);
    let corrupt_path = dir.join(format!("{key:016x}.kvp"));
    assert!(corrupt_path.exists(), "template 0's first page must be on disk");
    let mut raw = std::fs::read(&corrupt_path).unwrap();
    let mid = raw.len() / 2;
    raw[mid] ^= 0x55;
    std::fs::write(&corrupt_path, &raw).unwrap();

    // turn 2, replayed three ways with the full per-step audits
    let warm = drive(
        Batcher::new(build_engine(KvLayout::Paged { page_size, pages }), spill_config()),
        &turn2,
        0,
    );
    let coldstart = drive(
        Batcher::new(
            build_engine(KvLayout::Paged { page_size, pages }),
            BatcherConfig {
                decode_burst: 1,
                prefill_chunk: 16,
                prefix_cache: true,
                ..BatcherConfig::default()
            },
        ),
        &turn2,
        0,
    );
    let nocache = drive(
        Batcher::new(
            build_engine(KvLayout::Paged { page_size, pages }),
            BatcherConfig { decode_burst: 1, prefill_chunk: 16, ..BatcherConfig::default() },
        ),
        &turn2,
        0,
    );
    assert_outcomes(&turn2, &warm);
    assert_outcomes(&turn2, &coldstart);
    assert_outcomes(&turn2, &nocache);
    assert_bitwise_replay(&turn2, &warm, &nocache);
    assert_bitwise_replay(&turn2, &coldstart, &nocache);

    // the acceptance number: a warm restart prefills under half of cold...
    assert!(
        warm.prefill_tokens * 2 <= nocache.prefill_tokens,
        "warm restart saved too little prefill: {} tokens vs {} cold",
        warm.prefill_tokens,
        nocache.prefill_tokens
    );
    // ...and strictly less than a cold *restart*: the disk tier is what
    // covers each template's first post-restart request
    assert!(
        warm.prefill_tokens < coldstart.prefill_tokens,
        "disk restores saved nothing over a cold restart: {} vs {}",
        warm.prefill_tokens,
        coldstart.prefill_tokens
    );
    assert!(
        warm.prefix_disk_hits >= 8,
        "at least one full template should restore from disk, got {} pages",
        warm.prefix_disk_hits
    );
    assert!(warm.prefix_restore_bytes > 0);
    assert!(
        warm.prefix_disk_rejected >= 1,
        "the corrupted page must be rejected, not served"
    );
    assert!(!corrupt_path.exists(), "a rejected spill file must be deleted from disk");
    assert_eq!(coldstart.prefix_disk_hits, 0);

    write_kv_tier_report(
        None,
        Json::obj()
            .set("harness", "kv_tier_stress")
            .set("workload", "4_templates_restart_mid_batch")
            .set("page_size", page_size)
            .set("turn1_requests", turn1.len())
            .set("turn2_requests", turn2.len())
            .set("snapshot_files", snap_files)
            .set("snapshot_bytes", snap_bytes as usize)
            .set("spilled_pages_turn1", spilled_turn1)
            .set("prefill_tokens_warm", warm.prefill_tokens)
            .set("prefill_tokens_cold_restart", coldstart.prefill_tokens)
            .set("prefill_tokens_no_cache", nocache.prefill_tokens)
            .set(
                "warm_vs_cold_reduction",
                nocache.prefill_tokens as f64 / warm.prefill_tokens.max(1) as f64,
            )
            .set("disk_hit_pages", warm.prefix_disk_hits)
            .set("disk_rejected", warm.prefix_disk_rejected)
            .set("restore_bytes", warm.prefix_restore_bytes)
            .set(
                "invariants",
                "per-step-audits, bitwise-replay-3way, corrupt-file-dropped-never-served",
            ),
    );
    let _ = std::fs::remove_dir_all(&dir);
}
