//! Deterministic randomized serving stress harness for the paged KV
//! batcher (the proof obligation for continuous batching over a paged
//! cache).
//!
//! A seeded workload of 200+ requests with mixed prompt lengths, random
//! mid-flight cancels and client-timeout sink drops is driven through
//! `Batcher::step` *manually*; after **every** step the harness audits the
//! `BlockAllocator`:
//!
//! * no page leaked (free + owned == total),
//! * no page double-owned,
//! * `pages_in_use * page_bytes` never exceeds the `--kv-budget` bytes,
//! * every accepted request reaches exactly one terminal event.
//!
//! A separate oracle test replays the same workload through the fixed-slot
//! batcher and asserts per-request token streams are **bitwise identical**
//! (same seeds) — and that at an equal byte budget the paged batcher admits
//! strictly more concurrent requests than the fixed-slot baseline.
//!
//! The harness writes a JSON invariant report (one entry per seed) to
//! `$PAGED_KV_REPORT`, or `target/tmp/PAGED_KV_STRESS.json` by default; CI
//! uploads it next to the BENCH_*.json artifacts.
//!
//! The **shared-prefix** workloads at the bottom stress the prefix cache on
//! top of the same audits: per-step refcount/reservation checks (no shared
//! page freed or zeroed while referenced, conservation includes cached
//! chains), bitwise replay against the cache-off batcher, and the
//! acceptance numbers (>= 2x fewer prefill tokens and strictly higher
//! admitted concurrency at an equal byte budget on the 8-template
//! workload). Their JSON report goes to `$PREFIX_CACHE_REPORT`, default
//! `target/tmp/PREFIX_CACHE_STRESS.json`, uploaded next to the paged one.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::mpsc::{channel, Receiver};

use ladder_infer::comm::{Fabric, Interconnect};
use ladder_infer::engine::{KvLayout, RuntimeKind, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::Exec;
use ladder_infer::server::{Batcher, BatcherConfig, FinishReason, GenerationEvent, Request};
use ladder_infer::util::json::Json;
use ladder_infer::util::rng::Rng;

const BATCH: usize = 4;

fn build_engine(layout: KvLayout) -> TpEngine {
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = WeightStore::random(exec.cfg(), 0xfeed);
    TpEngine::with_layout(
        exec,
        &weights,
        2,
        Arch::Ladder,
        BATCH,
        Interconnect::new(Fabric::Local),
        RuntimeKind::default(),
        layout,
    )
    .unwrap()
}

/// One request of the generated workload.
#[derive(Clone)]
struct Job {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    /// `Some(step)`: explicitly cancelled after that scheduler step.
    cancel_at: Option<usize>,
    /// `Some(step)`: the client "times out" — its event sink is dropped
    /// after that step, and the batcher must reclaim the slot on its own.
    drop_sink_at: Option<usize>,
    /// Which scheduler step the request arrives at.
    arrive_at: usize,
}

/// Mixed-length workload: ~50% short, ~35% medium, ~15% long prompts,
/// arrivals spread over the first ~150 steps, ~8% cancels, ~5% timeouts.
fn workload(seed: u64, n: usize) -> Vec<Job> {
    let mut rng = Rng::new(seed);
    let mut arrive = 0usize;
    (0..n)
        .map(|i| {
            let len = match rng.below(100) {
                0..=49 => rng.range(1, 8),
                50..=84 => rng.range(8, 40),
                _ => rng.range(40, 90),
            };
            arrive += rng.below(3); // bursty Poisson-ish arrivals
            let cancel = rng.below(100) < 8;
            let timeout = !cancel && rng.below(100) < 5;
            Job {
                id: i as u64,
                prompt: (0..len).map(|_| rng.below(256) as i32).collect(),
                max_new: rng.range(1, 12),
                cancel_at: cancel.then(|| arrive + rng.below(30)),
                drop_sink_at: timeout.then(|| arrive + rng.below(30)),
                arrive_at: arrive,
            }
        })
        .collect()
}

/// Outcome of driving one workload to completion.
struct RunStats {
    /// id -> (tokens, finish reason); exactly one entry per request.
    finished: HashMap<u64, (Vec<i32>, FinishReason)>,
    max_live: usize,
    high_water_pages: usize,
    admission_blocked: usize,
    steps: usize,
    /// Prompt tokens actually prefilled (cache hits skip their prefix).
    prefill_tokens: usize,
    /// Prompt tokens served from the prefix cache.
    prefix_hit_tokens: usize,
    /// Cached pages evicted over the run.
    prefix_evicted: usize,
}

/// Drive `jobs` through a batcher step by step, auditing the allocator
/// after every step. `budget_bytes` caps both the batcher config and the
/// audit; 0 disables the byte assertion.
fn drive(mut batcher: Batcher, jobs: &[Job], budget_bytes: usize) -> RunStats {
    let mut finished: HashMap<u64, (Vec<i32>, FinishReason)> = HashMap::new();
    let mut live_ids: HashSet<u64> = HashSet::new();
    let mut max_live = 0usize;
    let mut sinks: HashMap<u64, Receiver<GenerationEvent>> = HashMap::new();
    let mut submitted = 0usize;
    let mut step = 0usize;
    let mut record = |evs: Vec<GenerationEvent>, live: &mut HashSet<u64>, max: &mut usize| {
        for ev in evs {
            match ev {
                GenerationEvent::Admitted { id, .. } => {
                    live.insert(id);
                    *max = (*max).max(live.len());
                }
                GenerationEvent::Token { .. } => {}
                GenerationEvent::Finished { result } => {
                    live.remove(&result.id);
                    let dup = finished.insert(result.id, (result.tokens, result.finish_reason));
                    assert!(dup.is_none(), "request {} finished twice", result.id);
                }
                GenerationEvent::Error { id, reason, .. } => {
                    panic!("request {id} errored unexpectedly: {reason}");
                }
            }
        }
    };
    while submitted < jobs.len() || batcher.pending() > 0 {
        assert!(step < 100_000, "workload failed to drain after {step} steps");
        // arrivals scheduled for this step
        while submitted < jobs.len() && jobs[submitted].arrive_at <= step {
            let job = &jobs[submitted];
            let request = Request::new(job.id, job.prompt.clone(), job.max_new);
            if job.drop_sink_at.is_some() {
                let (tx, rx) = channel();
                sinks.insert(job.id, rx);
                batcher.submit_streaming(request, tx);
            } else {
                batcher.submit(request);
            }
            submitted += 1;
        }
        let evs = batcher.step().expect("batcher step");
        record(evs, &mut live_ids, &mut max_live);
        // client timeouts: drop the sink, the batcher reclaims the slot
        sinks.retain(|id, _| {
            let job = &jobs[*id as usize];
            !job.drop_sink_at.is_some_and(|at| at <= step)
        });
        // explicit cancels
        for job in jobs[..submitted].iter() {
            if job.cancel_at == Some(step) {
                if let Some(ev) = batcher.cancel(job.id).expect("cancel") {
                    record(vec![ev], &mut live_ids, &mut max_live);
                }
            }
        }
        // -- the allocator audit, the heart of this harness --
        if let Some(alloc) = batcher.allocator() {
            alloc.check().unwrap_or_else(|e| panic!("step {step}: {e}"));
            if budget_bytes > 0 {
                assert!(
                    alloc.bytes_in_use() <= budget_bytes,
                    "step {step}: {} KV bytes in use exceed the {budget_bytes} budget \
                     (cached chains included)",
                    alloc.bytes_in_use()
                );
            }
            // prefix-cache cross-audit: every tree page is tree-referenced
            // in the allocator, and the counts agree — a shared page can
            // therefore never have been freed or zeroed while referenced
            // (check() above already proved free/referenced exclusion)
            if let Some(tree) = batcher.prefix_tree() {
                let pages = tree.pages();
                assert_eq!(pages.len(), tree.cached_pages(), "step {step}: tree page count");
                assert_eq!(
                    pages.len(),
                    alloc.cached_pages(),
                    "step {step}: tree vs allocator cached-page count"
                );
                for p in pages {
                    assert!(alloc.is_cached(p), "step {step}: tree page {p} lost its ref");
                }
            }
        }
        step += 1;
    }
    let (high_water_pages, admission_blocked) = match batcher.allocator() {
        Some(alloc) => {
            // drained: everything still allocated must be a cached chain
            alloc.check().unwrap();
            let cached = alloc.cached_pages();
            assert_eq!(alloc.pages_in_use(), cached, "non-cached pages leaked after drain");
            assert_eq!(alloc.reserved_pages(), 0, "reservations leaked after drain");
            (alloc.high_water(), batcher.metrics.admission_blocked)
        }
        None => (0, 0),
    };
    // flushing the (now fully idle) cache must round-trip the whole pool
    // back to the free list — the no-leak proof including cached chains
    let cached = batcher.allocator().map_or(0, |a| a.cached_pages());
    assert_eq!(batcher.flush_prefix_cache().unwrap(), cached);
    if let Some(alloc) = batcher.allocator() {
        alloc.check().unwrap();
        assert_eq!(alloc.pages_in_use(), 0, "pages leaked after cache flush");
        assert_eq!(alloc.free_pages(), alloc.total_pages());
    }
    RunStats {
        finished,
        max_live,
        high_water_pages,
        admission_blocked,
        steps: step,
        prefill_tokens: batcher.metrics.prefill_tokens,
        prefix_hit_tokens: batcher.metrics.prefix_hit_tokens,
        prefix_evicted: batcher.metrics.prefix_evicted_pages,
    }
}

fn assert_outcomes(jobs: &[Job], stats: &RunStats) {
    assert_eq!(stats.finished.len(), jobs.len(), "every request must reach a terminal event");
    for job in jobs {
        let (tokens, reason) = &stats.finished[&job.id];
        match reason {
            // untouched requests run to their full budget (greedy, no eos)
            FinishReason::Length => assert_eq!(
                tokens.len(),
                job.max_new,
                "request {} finished early without a cancel",
                job.id
            ),
            FinishReason::Cancelled => assert!(
                job.cancel_at.is_some() || job.drop_sink_at.is_some(),
                "request {} cancelled without a cancel/timeout plan",
                job.id
            ),
            other => panic!("request {} finished with unexpected {other:?}", job.id),
        }
    }
}

/// The tentpole harness: 3 fixed seeds x (page size, chunk, budget)
/// variations, full allocator audit every step, JSON invariant report.
#[test]
fn stress_randomized_three_seeds() {
    let configs = [
        // (seed, page_size, prefill_chunk, budget_pages)
        (0xa11ce_u64, 4usize, 0usize, 120usize),
        (0xb0b, 8, 7, 48),
        (0xc0ffee, 16, 16, 28),
    ];
    let mut entries = Vec::new();
    for (seed, page_size, chunk, budget_pages) in configs {
        let jobs = workload(seed, 200);
        let per_seq = 128usize.div_ceil(page_size);
        let alloc_pages = budget_pages.max(per_seq);
        // pool strictly larger than the byte budget, so the budget clamp
        // (not pool exhaustion) is what the harness actually audits
        let pages = alloc_pages + 8;
        let engine = build_engine(KvLayout::Paged { page_size, pages });
        let page_bytes = engine.kv_page_bytes();
        let budget_bytes = alloc_pages * page_bytes;
        let config = BatcherConfig {
            decode_burst: 1,
            kv_budget_bytes: budget_bytes,
            prefill_chunk: chunk,
            ..BatcherConfig::default()
        };
        let stats = drive(Batcher::new(engine, config), &jobs, budget_bytes);
        assert_outcomes(&jobs, &stats);
        let cancelled =
            stats.finished.values().filter(|(_, r)| *r == FinishReason::Cancelled).count();
        entries.push(
            Json::obj()
                .set("seed", format!("{seed:#x}"))
                .set("requests", jobs.len())
                .set("page_size", page_size)
                .set("prefill_chunk", chunk)
                .set("total_pages", pages)
                .set("page_bytes", page_bytes)
                .set("budget_bytes", budget_bytes)
                .set("steps", stats.steps)
                .set("completed", stats.finished.len())
                .set("cancelled", cancelled)
                .set("max_concurrent", stats.max_live)
                .set("kv_pages_high_water", stats.high_water_pages)
                .set("admission_blocked", stats.admission_blocked)
                .set("invariants", "no-leak, no-double-own, budget-respected, all-finished"),
        );
    }
    let report = Json::obj().set("harness", "paged_kv_stress").set("seeds", Json::Arr(entries));
    let path = std::env::var("PAGED_KV_REPORT").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join("PAGED_KV_STRESS.json")
    });
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(&path, report.to_string()).expect("write invariant report");
}

/// Acceptance oracle: under the same seeded workload (no cancels), the
/// paged batcher's per-request token streams are bitwise identical to the
/// fixed-slot batcher's — regardless of page size or prefill chunking,
/// and even though admission interleaves differently.
#[test]
fn paged_streams_bitwise_match_fixed_slot_oracle() {
    let jobs: Vec<Job> = workload(0xdead, 60)
        .into_iter()
        .map(|j| Job { cancel_at: None, drop_sink_at: None, ..j })
        .collect();
    let fixed = drive(
        Batcher::new(build_engine(KvLayout::Slab), BatcherConfig::default()),
        &jobs,
        0,
    );
    assert_outcomes(&jobs, &fixed);
    for (page_size, chunk) in [(4usize, 0usize), (16, 5)] {
        let pages = BATCH * 128usize.div_ceil(page_size);
        let engine = build_engine(KvLayout::Paged { page_size, pages });
        let config = BatcherConfig { prefill_chunk: chunk, ..BatcherConfig::default() };
        let paged = drive(Batcher::new(engine, config), &jobs, 0);
        assert_outcomes(&jobs, &paged);
        for job in &jobs {
            assert_eq!(
                paged.finished[&job.id].0, fixed.finished[&job.id].0,
                "request {} diverged from the fixed-slot oracle \
                 (page_size={page_size}, chunk={chunk})",
                job.id
            );
        }
    }
}

/// Acceptance: at the same byte budget, block-granular admission runs
/// strictly more requests concurrently than `max_seq`-sized slots — while
/// producing the same tokens.
#[test]
fn paged_admits_more_concurrent_requests_at_equal_budget() {
    // budget = 2.5 fixed slots -> the slab batcher caps at 2 concurrent
    let probe = build_engine(KvLayout::Slab);
    let budget = probe.kv_bytes_per_slot() * 5 / 2;
    let jobs: Vec<Job> = (0..8u64)
        .map(|i| Job {
            id: i,
            prompt: (0..8).map(|t| ((i * 31 + t) % 256) as i32).collect(),
            max_new: 4,
            cancel_at: None,
            drop_sink_at: None,
            arrive_at: 0,
        })
        .collect();
    let fixed = drive(
        Batcher::new(probe, BatcherConfig { kv_budget_bytes: budget, ..Default::default() }),
        &jobs,
        0,
    );
    assert_outcomes(&jobs, &fixed);
    assert_eq!(fixed.max_live, 2, "slab budget should cap at 2 slots");

    let page_size = 16;
    let engine = build_engine(KvLayout::Paged { page_size, pages: 64 });
    let page_bytes = engine.kv_page_bytes();
    let paged = drive(
        Batcher::new(engine, BatcherConfig { kv_budget_bytes: budget, ..Default::default() }),
        &jobs,
        budget,
    );
    assert_outcomes(&jobs, &paged);
    assert!(
        paged.max_live > fixed.max_live,
        "paged admitted {} concurrent vs slab {} at budget {budget} (page_bytes {page_bytes})",
        paged.max_live,
        fixed.max_live
    );
    for job in &jobs {
        assert_eq!(paged.finished[&job.id].0, fixed.finished[&job.id].0);
    }
}

/// Chunked prefill must not stall in-flight decodes: while a long prompt
/// trickles in chunk by chunk, a short request admitted earlier keeps
/// emitting a token every step.
#[test]
fn chunked_prefill_interleaves_with_decodes() {
    let engine = build_engine(KvLayout::Paged { page_size: 8, pages: 64 });
    let config = BatcherConfig { prefill_chunk: 8, ..BatcherConfig::default() };
    let mut b = Batcher::new(engine, config);
    b.submit(Request::new(1, vec![7; 4], 30));
    b.step().unwrap(); // short request admitted, first token out
    let long_prompt = vec![3i32; 80]; // 10 chunks of 8
    b.submit(Request::new(2, long_prompt, 4));
    let mut saw_interleave = 0;
    for _ in 0..8 {
        let evs = b.step().unwrap();
        let short_tokens = evs
            .iter()
            .filter(|e| matches!(e, GenerationEvent::Token { id: 1, .. }))
            .count();
        let long_tokens = evs
            .iter()
            .filter(|e| matches!(e, GenerationEvent::Token { id: 2, .. }))
            .count();
        if short_tokens > 0 && long_tokens == 0 {
            saw_interleave += 1; // long still prefilling, short still decoding
        }
    }
    assert!(
        saw_interleave >= 5,
        "short request decoded through only {saw_interleave} of the long prompt's chunk steps"
    );
    while b.pending() > 0 {
        b.step().unwrap();
    }
    b.allocator().unwrap().check().unwrap();
    assert_eq!(b.allocator().unwrap().pages_in_use(), 0);
}

// ---------------------------------------------------------------------------
// shared-prefix workloads (the prefix-cache proof obligations)
// ---------------------------------------------------------------------------

/// Seeded 8-system-prompt workload: every prompt is one of 8 templates
/// (64 tokens = 8 full pages at page size 8) plus a short random user
/// tail; draws are skewed 70% onto the two "hot" templates, the shape the
/// cache exists for. ~10% of prompts are exactly a template (page-aligned
/// full match, exercising the copy-on-write trailing page), ~6% cancel
/// mid-flight and ~4% drop their sink.
fn template_workload(seed: u64, n: usize, tlen: usize) -> Vec<Job> {
    let mut rng = Rng::new(seed);
    let templates: Vec<Vec<i32>> =
        (0..8).map(|_| (0..tlen).map(|_| rng.below(256) as i32).collect()).collect();
    let mut arrive = 0usize;
    (0..n)
        .map(|i| {
            arrive += rng.below(2);
            let t = if rng.below(100) < 70 { rng.below(2) } else { rng.below(8) };
            let mut prompt = templates[t].clone();
            if rng.below(10) > 0 {
                let tail = rng.range(1, 11);
                prompt.extend((0..tail).map(|_| rng.below(256) as i32));
            }
            let cancel = rng.below(100) < 6;
            let timeout = !cancel && rng.below(100) < 4;
            Job {
                id: i as u64,
                prompt,
                max_new: rng.range(1, 8),
                cancel_at: cancel.then(|| arrive + rng.below(25)),
                drop_sink_at: timeout.then(|| arrive + rng.below(25)),
                arrive_at: arrive,
            }
        })
        .collect()
}

/// Drive one shared-prefix workload twice — cache on and cache off — at
/// the same byte budget, with the full per-step audits, and return both.
fn drive_on_off(jobs: &[Job], page_size: usize, budget_pages: usize) -> (RunStats, RunStats) {
    let run = |prefix_cache: bool| {
        // pool strictly larger than the byte budget so the budget clamp
        // (not pool sizing) is what admission and eviction push against
        let pages = budget_pages + 8;
        let engine = build_engine(KvLayout::Paged { page_size, pages });
        let budget_bytes = budget_pages * engine.kv_page_bytes();
        let config = BatcherConfig {
            decode_burst: 1,
            kv_budget_bytes: budget_bytes,
            prefill_chunk: 16,
            prefix_cache,
        };
        drive(Batcher::new(engine, config), jobs, budget_bytes)
    };
    (run(true), run(false))
}

/// Bitwise replay: every request untouched by a cancel/timeout plan must
/// produce identical tokens with the cache on and off — interleaving,
/// sharing and eviction change *when* work happens, never its bits.
fn assert_bitwise_replay(jobs: &[Job], on: &RunStats, off: &RunStats) {
    for job in jobs {
        if job.cancel_at.is_some() || job.drop_sink_at.is_some() {
            continue;
        }
        assert_eq!(
            on.finished[&job.id].0, off.finished[&job.id].0,
            "request {} diverged bitwise between cache-on and cache-off",
            job.id
        );
    }
}

/// One location rule for the prefix-cache reports: `$PREFIX_CACHE_REPORT`
/// (CI) or the cargo tmpdir, with `suffix` mapping concurrent tests onto
/// sibling files instead of racing on one object (CI uploads the
/// `PREFIX_CACHE_STRESS*.json` glob).
fn prefix_report_path(suffix: Option<&str>) -> PathBuf {
    let path = std::env::var("PREFIX_CACHE_REPORT").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join("PREFIX_CACHE_STRESS.json")
    });
    match suffix {
        Some(s) => path.with_extension(format!("{s}.json")),
        None => path,
    }
}

fn write_prefix_report(suffix: Option<&str>, report: Json) {
    let path = prefix_report_path(suffix);
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(&path, report.to_string()).expect("write prefix-cache report");
}

/// The 8-template acceptance workload: 200 requests, audits after every
/// step (via `drive`), bitwise replay against the cache-off batcher, >= 2x
/// fewer prefill tokens, and strictly higher admitted concurrency at the
/// same byte budget (34 pages: the cache-off batcher can never hold four
/// 9-page reservations, the cache-on one can once chains are shared).
#[test]
fn shared_prefix_templates_halve_prefill_and_raise_concurrency() {
    let jobs = template_workload(0x5eeded, 200, 64);
    let (on, off) = drive_on_off(&jobs, 8, 34);
    assert_outcomes(&jobs, &on);
    assert_outcomes(&jobs, &off);
    assert_bitwise_replay(&jobs, &on, &off);
    assert!(
        on.prefill_tokens * 2 <= off.prefill_tokens,
        "prefix cache saved too little prefill: {} tokens with cache vs {} without",
        on.prefill_tokens,
        off.prefill_tokens
    );
    assert!(
        on.max_live > off.max_live,
        "cache-on admitted {} concurrent vs {} cache-off at the same budget",
        on.max_live,
        off.max_live
    );
    assert!(on.prefix_hit_tokens > 0, "no request ever hit the cache");
    assert!(on.prefix_evicted > 0, "the 8-template working set must overflow 34 pages");
    let entry = Json::obj()
        .set("workload", "8_templates_x_200")
        .set("requests", jobs.len())
        .set("page_size", 8)
        .set("budget_pages", 34)
        .set("steps_on", on.steps)
        .set("steps_off", off.steps)
        .set("prefill_tokens_on", on.prefill_tokens)
        .set("prefill_tokens_off", off.prefill_tokens)
        .set("prefill_reduction", off.prefill_tokens as f64 / on.prefill_tokens.max(1) as f64)
        .set("prefix_hit_tokens", on.prefix_hit_tokens)
        .set("prefix_evicted_pages", on.prefix_evicted)
        .set("max_concurrent_on", on.max_live)
        .set("max_concurrent_off", off.max_live)
        .set("kv_pages_high_water_on", on.high_water_pages)
        .set("admission_blocked_on", on.admission_blocked)
        .set(
            "invariants",
            "refcounts-audited-per-step, bitwise-replay, no-leak-incl-cache, budget-respected",
        );
    let report = Json::obj()
        .set("harness", "prefix_cache_stress")
        .set("workloads", Json::Arr(vec![entry]));
    write_prefix_report(None, report);
}

/// Multi-turn resubmission: conversations grow their history and resubmit
/// it as the next turn's prompt. Turn k+1's prompt extends turn k's, so
/// its full prompt pages — including the pages that now hold turn k's
/// *generated* tokens, re-prefilled as prompt — come from the tree, and
/// reuse compounds turn over turn. Bitwise replay holds per turn (turn
/// k+1's prompts are built from turn k's outputs, which match bitwise).
#[test]
fn multi_turn_resubmission_reuses_grown_histories() {
    let page_size = 8usize;
    let conversations = 12usize;
    let turns = 3usize;
    let build = |prefix_cache: bool| -> (Vec<usize>, Vec<Vec<Vec<i32>>>, usize, usize) {
        // pool sized so the 12 conversations' grown histories stay cached
        // across all turns — eviction pressure is the template test's job
        let engine = build_engine(KvLayout::Paged { page_size, pages: 160 });
        let config = BatcherConfig {
            decode_burst: 1,
            kv_budget_bytes: 0,
            prefill_chunk: 16,
            prefix_cache,
        };
        let mut batcher = Batcher::new(engine, config);
        let mut rng = Rng::new(0x7a1e);
        let mut histories: Vec<Vec<i32>> = (0..conversations)
            .map(|_| (0..rng.range(18, 30)).map(|_| rng.below(256) as i32).collect())
            .collect();
        let mut prefill_per_turn = Vec::new();
        let mut tokens_per_turn: Vec<Vec<Vec<i32>>> = Vec::new();
        for turn in 0..turns {
            let before = batcher.metrics.prefill_tokens;
            for (c, h) in histories.iter().enumerate() {
                batcher.submit(Request::new((turn * conversations + c) as u64, h.clone(), 6));
            }
            let mut outs: Vec<Vec<i32>> = vec![Vec::new(); conversations];
            while batcher.pending() > 0 {
                for ev in batcher.step().unwrap() {
                    if let GenerationEvent::Finished { result } = ev {
                        outs[result.id as usize % conversations] = result.tokens;
                    }
                }
                batcher.allocator().unwrap().check().unwrap();
            }
            prefill_per_turn.push(batcher.metrics.prefill_tokens - before);
            // grow each history: generated tokens + a fresh user message
            for (h, out) in histories.iter_mut().zip(&outs) {
                assert_eq!(out.len(), 6);
                h.extend(out);
                h.extend((0..rng.range(6, 12)).map(|_| rng.below(256) as i32));
            }
            tokens_per_turn.push(outs);
        }
        let hits = batcher.metrics.prefix_hit_tokens;
        let prefills = batcher.metrics.prefill_tokens;
        // drain + flush round-trip, as in `drive`
        let cached = batcher.allocator().unwrap().cached_pages();
        assert_eq!(batcher.flush_prefix_cache().unwrap(), cached);
        let alloc = batcher.allocator().unwrap();
        alloc.check().unwrap();
        assert_eq!(alloc.pages_in_use(), 0);
        (prefill_per_turn, tokens_per_turn, hits, prefills)
    };
    let (on_turn, on_tokens, on_hits, on_prefill) = build(true);
    let (off_turn, off_tokens, off_hits, off_prefill) = build(false);
    assert_eq!(on_tokens, off_tokens, "multi-turn streams diverged bitwise");
    assert_eq!(off_hits, 0);
    assert!(
        on_prefill < off_prefill,
        "history reuse must shrink prefill: {on_prefill} vs {off_prefill}"
    );
    // reuse compounds: by the last turn the cache covers the whole shared
    // history, so the cache-on run prefills well under half of cold
    assert!(
        on_turn[turns - 1] * 2 < off_turn[turns - 1],
        "turn {turns}: {} prefilled with cache vs {} without",
        on_turn[turns - 1],
        off_turn[turns - 1]
    );
    assert!(on_hits > 0);
    write_prefix_report_multi_turn(on_turn, off_turn, on_hits, on_prefill, off_prefill);
}

fn write_prefix_report_multi_turn(
    on_turn: Vec<usize>,
    off_turn: Vec<usize>,
    hits: usize,
    on_prefill: usize,
    off_prefill: usize,
) {
    let arr = |v: &[usize]| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
    let entry = Json::obj()
        .set("workload", "multi_turn_3x12")
        .set("prefill_tokens_per_turn_on", arr(&on_turn))
        .set("prefill_tokens_per_turn_off", arr(&off_turn))
        .set("prefix_hit_tokens", hits)
        .set("prefill_tokens_on", on_prefill)
        .set("prefill_tokens_off", off_prefill);
    // the template test owns the bare report path; this workload writes a
    // sibling file so concurrently running tests never race on one object
    write_prefix_report(Some("multi_turn"), entry);
}

/// The `clear_slot` / release interaction (regression): after a donor
/// request finishes and its slot is released on every rank, a cache hit on
/// its published pages must decode bitwise-identically to a cold run — the
/// paged release path must never zero pool bytes the tree still
/// references.
#[test]
fn cache_hit_after_donor_finished_decodes_bitwise_identically() {
    let page_size = 8usize;
    let donor: Vec<i32> = (0..20).map(|i| (i * 7 + 3) % 256).collect();
    let mut follower = donor.clone();
    follower.extend([9, 8, 7]);
    let run = |prefix_cache: bool, submit_donor: bool| -> Vec<i32> {
        let engine = build_engine(KvLayout::Paged { page_size, pages: 32 });
        let config = BatcherConfig { prefix_cache, ..BatcherConfig::default() };
        let mut b = Batcher::new(engine, config);
        if submit_donor {
            b.submit(Request::new(1, donor.clone(), 5));
            while b.pending() > 0 {
                b.step().unwrap();
            }
            // donor finished: its slot was released on every rank, its full
            // prompt pages belong to the tree now
            if prefix_cache {
                assert_eq!(b.prefix_tree().unwrap().cached_pages(), 2);
            }
        }
        b.submit(Request::new(2, follower.clone(), 5));
        let mut tokens = Vec::new();
        while b.pending() > 0 {
            for ev in b.step().unwrap() {
                if let GenerationEvent::Finished { result } = ev {
                    if result.id == 2 {
                        tokens = result.tokens;
                    }
                }
            }
        }
        if prefix_cache && submit_donor {
            assert_eq!(
                b.metrics.prefix_hit_tokens, 16,
                "follower must reuse the donor's two full pages"
            );
        }
        tokens
    };
    let hit = run(true, true);
    let cold = run(false, false);
    assert_eq!(hit, cold, "a hit on a finished donor's pages corrupted decoding");
}

/// Corner of the copy-on-write path: when the popped trailing page is the
/// only evictable leaf, the admission's own shortfall eviction consumes it
/// (it sits outside the admission invariant once popped). The batcher must
/// fall back to re-prefilling that page cold — not die trying to copy a
/// page that was just reallocated, possibly as the copy's own destination.
#[test]
fn full_prompt_hit_survives_cow_source_eviction_on_a_full_pool() {
    let page_size = 8usize;
    // pool of exactly 2 pages; prompt fills both; max_new 0 keeps the
    // reservation at 2 pages so the never-fits check passes
    let prompt: Vec<i32> = (0..16).map(|i| (i * 11 + 5) % 256).collect();
    let run = |prefix_cache: bool, donor: bool| -> (Vec<i32>, usize) {
        let engine = build_engine(KvLayout::Paged { page_size, pages: 2 });
        let config = BatcherConfig { prefix_cache, ..BatcherConfig::default() };
        let mut b = Batcher::new(engine, config);
        if donor {
            b.submit(Request::new(1, prompt.clone(), 0));
            while b.pending() > 0 {
                b.step().unwrap();
                b.allocator().unwrap().check().unwrap();
            }
            // both pages published and idle; the free list is empty
            assert_eq!(b.allocator().unwrap().free_pages(), 0);
        }
        b.submit(Request::new(2, prompt.clone(), 0));
        let mut tokens = Vec::new();
        while b.pending() > 0 {
            for ev in b.step().expect("COW fallback must not error the step") {
                if let GenerationEvent::Finished { result } = ev {
                    if result.id == 2 {
                        tokens = result.tokens;
                    }
                }
            }
            b.allocator().unwrap().check().unwrap();
        }
        (tokens, b.metrics.prefix_hit_tokens)
    };
    let (hit, hit_tokens) = run(true, true);
    let (cold, _) = run(false, false);
    assert_eq!(hit, cold, "fallback path diverged bitwise from cold");
    // the first full page survives as a hit; the popped trailing page was
    // evicted to back the suffix, so exactly one page is re-prefilled
    assert_eq!(hit_tokens, 8, "fallback should keep the untouched prefix cached");
}
