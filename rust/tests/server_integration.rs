//! Integration: continuous batcher + TCP API over the real tiny engine,
//! running on the native backend (no artifacts required).
//!
//! Covers protocol v2: typed event streams (admitted/token/done frames in
//! order, monotone token indices), per-request sampling reproducibility,
//! stop-sequence / eos / cancel finish reasons, mid-flight cancellation
//! with slot re-use, dead-sink reclamation, and v1 single-object
//! compatibility for non-streaming requests.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::rc::Rc;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use ladder_infer::comm::{Fabric, Interconnect};
use ladder_infer::engine::{KvLayout, RuntimeKind, Sampler, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::Exec;
use ladder_infer::server::{
    api, api::ApiJob, batcher::DRAIN_REASON, router, Batcher, BatcherConfig, FinishReason,
    GenerationEvent, ReplicaFactory, ReplicaSlotConfig, Request, Router, RouterConfig,
    RoutingPolicy,
};
use ladder_infer::tokenizer::Tokenizer;
use ladder_infer::util::json::{parse, Json};

fn build_engine(arch: Arch, batch: usize) -> TpEngine {
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = WeightStore::random(exec.cfg(), 0xbeef);
    TpEngine::new(exec, &weights, 2, arch, batch, Interconnect::new(Fabric::Local)).unwrap()
}

fn build_paged_engine(arch: Arch, batch: usize, page_size: usize, pages: usize) -> TpEngine {
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = WeightStore::random(exec.cfg(), 0xbeef);
    TpEngine::with_layout(
        exec,
        &weights,
        2,
        arch,
        batch,
        Interconnect::new(Fabric::Local),
        RuntimeKind::default(),
        KvLayout::Paged { page_size, pages },
    )
    .unwrap()
}

fn build_batcher(arch: Arch, batch: usize) -> Batcher {
    Batcher::new(build_engine(arch, batch), BatcherConfig::default())
}

fn build_batcher_tok(arch: Arch, batch: usize) -> Batcher {
    Batcher::with_tokenizer(
        build_engine(arch, batch),
        BatcherConfig::default(),
        Tokenizer::bytes_only(256),
    )
}

/// Greedy reference output for `prompt` on a fresh engine.
fn greedy_tokens(prompt: &[i32], max_new: usize) -> Vec<i32> {
    let mut b = build_batcher(Arch::Standard, 2);
    b.submit(Request::new(0, prompt.to_vec(), max_new));
    b.run_to_completion().unwrap().remove(0).tokens
}

// ---------------------------------------------------------------------------
// batcher-level event stream
// ---------------------------------------------------------------------------

#[test]
fn batcher_completes_all_requests_fifo() {
    let mut b = build_batcher(Arch::Ladder, 2);
    for i in 0..5u64 {
        b.submit(Request::new(i, vec![1, 2, 3, (i % 4) as i32], 4));
    }
    let results = b.run_to_completion().unwrap();
    assert_eq!(results.len(), 5);
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    // each request produced exactly max_new_tokens
    for r in &results {
        assert_eq!(r.tokens.len(), 4, "request {}", r.id);
        assert_eq!(r.finish_reason, FinishReason::Length);
        assert!(r.ttft_secs > 0.0 && r.e2e_secs >= r.ttft_secs);
    }
    ids.sort();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    assert_eq!(b.metrics.completed, 5);
    assert!(b.metrics.decode_steps > 0);
    // 5 requests x 4 tokens, 3 of them decode-phase per request
    assert_eq!(b.metrics.itl_secs.count(), 15);
}

#[test]
fn batcher_oversubscription_queues_and_drains() {
    // more requests than slots: the queue must drain without starvation
    let mut b = build_batcher(Arch::Standard, 2);
    for i in 0..7u64 {
        b.submit(Request::new(i, vec![5, 6, 7], 3));
    }
    let results = b.run_to_completion().unwrap();
    assert_eq!(results.len(), 7);
    assert_eq!(b.pending(), 0);
}

#[test]
fn batcher_isolation_between_slots() {
    // the same prompt must produce the same tokens regardless of what else
    // shares the batch (KV slots must not leak across requests)
    let prompt = vec![9i32, 8, 7, 6, 5];
    let solo = greedy_tokens(&prompt, 5);
    let crowded = {
        let mut b = build_batcher(Arch::Standard, 2);
        b.submit(Request::new(0, prompt.clone(), 5));
        b.submit(Request::new(1, vec![100, 101, 102, 103, 104, 105, 106], 5));
        b.submit(Request::new(2, vec![33, 44], 5));
        let results = b.run_to_completion().unwrap();
        results.into_iter().find(|r| r.id == 0).unwrap().tokens
    };
    assert_eq!(solo, crowded, "KV slot leakage between concurrent requests");
}

/// Regression for the clear_slot fix: after a long request vacates a slot,
/// a shorter reused request must see none of its predecessor's K/V — its
/// tokens must match a fresh-engine run exactly.
#[test]
fn reused_slot_reads_no_stale_kv() {
    let prompt = vec![5i32, 9, 2];
    let fresh = greedy_tokens(&prompt, 6);
    // batch = 1: the second request provably reuses the first's slot
    let mut b = build_batcher(Arch::Standard, 1);
    let long: Vec<i32> = (0..40).map(|i| (i * 3 % 256) as i32).collect();
    b.submit(Request::new(0, long, 20));
    b.run_to_completion().unwrap();
    b.submit(Request::new(1, prompt, 6));
    let r = b.run_to_completion().unwrap().remove(0);
    assert_eq!(r.tokens, fresh, "reused slot leaked stale K/V into request 1");
}

/// Same reuse discipline on the paged layout: pages returned by a finished
/// request are handed to the next one without any clearing — masked
/// attention must keep the stale bytes invisible.
#[test]
fn paged_page_reuse_reads_no_stale_kv() {
    let prompt = vec![5i32, 9, 2];
    let fresh = greedy_tokens(&prompt, 6);
    // pool of exactly one max-length request: pages MUST be recycled
    let engine = build_paged_engine(Arch::Standard, 1, 16, 8);
    let mut b = Batcher::new(engine, BatcherConfig::default());
    let long: Vec<i32> = (0..40).map(|i| (i * 3 % 256) as i32).collect();
    b.submit(Request::new(0, long, 20));
    b.run_to_completion().unwrap();
    b.submit(Request::new(1, prompt, 6));
    let r = b.run_to_completion().unwrap().remove(0);
    assert_eq!(r.tokens, fresh, "recycled pages leaked stale K/V into request 1");
    let alloc = b.allocator().unwrap();
    alloc.check().unwrap();
    assert_eq!(alloc.pages_in_use(), 0);
}

/// Paged admission: a pool too small for two reservations serializes the
/// requests, bumps the admission-blocked counter, and still finishes both
/// with full-length outputs.
#[test]
fn paged_admission_blocks_on_reservation_and_recovers() {
    // 4 pages of 16 tokens; each request reserves ceil((4+40)/16) = 3
    let engine = build_paged_engine(Arch::Ladder, 2, 16, 4);
    let mut b = Batcher::new(engine, BatcherConfig::default());
    b.submit(Request::new(0, vec![1, 2, 3, 4], 40));
    b.submit(Request::new(1, vec![9, 8, 7, 6], 40));
    let results = b.run_to_completion().unwrap();
    assert_eq!(results.len(), 2);
    for r in &results {
        assert_eq!(r.finish_reason, FinishReason::Length);
        assert_eq!(r.tokens.len(), 40);
    }
    assert!(
        b.metrics.admission_blocked > 0,
        "second request should have waited for pages at least once"
    );
    assert!(b.metrics.kv_pages_high_water >= 3);
    assert_eq!(b.metrics.kv_pages_in_use, 0, "gauge must drop back to zero after drain");
    b.allocator().unwrap().check().unwrap();
}

/// A request id colliding with an in-flight page-table owner must fail
/// that request alone (terminal `Error` event, not retryable), never the
/// serve loop.
#[test]
fn paged_duplicate_request_id_fails_alone() {
    let engine = build_paged_engine(Arch::Standard, 2, 16, 16);
    let mut b = Batcher::new(engine, BatcherConfig::default());
    b.submit(Request::new(5, vec![1, 2, 3], 30));
    b.submit(Request::new(5, vec![4, 5, 6], 30));
    let mut finished = Vec::new();
    let mut errors = Vec::new();
    while b.pending() > 0 {
        for ev in b.step().unwrap() {
            match ev {
                GenerationEvent::Finished { result } => finished.push(result),
                GenerationEvent::Error { id, retryable, reason } => {
                    assert!(!retryable, "duplicate id is a client bug, not retryable");
                    assert!(reason.contains("duplicate"), "{reason}");
                    errors.push(id);
                }
                _ => {}
            }
        }
    }
    assert_eq!(errors, vec![5], "duplicate id must fail alone");
    assert_eq!(finished.len(), 1);
    assert_eq!(finished[0].finish_reason, FinishReason::Length);
    assert_eq!(b.metrics.errors, 1, "rejection must surface in the errors counter");
    b.allocator().unwrap().check().unwrap();
    assert_eq!(b.allocator().unwrap().pages_in_use(), 0);
}

/// A duplicate *streaming* submission must be rejected on its own sink and
/// must not hijack or orphan the original request's event stream.
#[test]
fn duplicate_streaming_id_does_not_hijack_original_stream() {
    let engine = build_paged_engine(Arch::Standard, 2, 16, 16);
    let mut b = Batcher::new(engine, BatcherConfig::default());
    let (tx1, rx1) = channel();
    b.submit_streaming(Request::new(5, vec![1, 2, 3], 4), tx1);
    let (tx2, rx2) = channel();
    b.submit_streaming(Request::new(5, vec![9, 9], 4), tx2);
    // the duplicate is rejected synchronously, on its own sink
    let Ok(GenerationEvent::Error { id, retryable, reason }) = rx2.try_recv() else {
        panic!("duplicate must be rejected immediately on its own sink");
    };
    assert_eq!(id, 5);
    assert!(!retryable, "duplicate id is a client bug, not retryable");
    assert!(reason.contains("duplicate"), "{reason}");
    while b.pending() > 0 {
        b.step().unwrap();
    }
    // the original stream is untouched: Admitted, 4 tokens, Finished(Length)
    let events: Vec<GenerationEvent> = rx1.try_iter().collect();
    assert!(matches!(events[0], GenerationEvent::Admitted { id: 5, .. }));
    let GenerationEvent::Finished { result } = events.last().unwrap() else {
        panic!("original stream must end with Finished");
    };
    assert_eq!(result.finish_reason, FinishReason::Length);
    assert_eq!(result.tokens.len(), 4);
    assert_eq!(events.len(), 6, "Admitted + 4 Tokens + Finished");
}

// ---------------------------------------------------------------------------
// graceful drain
// ---------------------------------------------------------------------------

/// Drain on the slab regime: queued requests bounce immediately with a
/// retryable `Error` event, in-flight slots run to completion, and
/// admission never reopens — a post-drain submit bounces on the next step.
#[test]
fn drain_bounces_queued_and_finishes_inflight() {
    let mut b = build_batcher(Arch::Ladder, 2);
    for i in 0..3u64 {
        b.submit(Request::new(i, vec![1, 2, 3, i as i32], 4));
    }
    b.step().unwrap(); // requests 0 and 1 take the two slots; 2 stays queued
    let bounced = b.drain();
    assert!(b.is_draining());
    assert!(!b.drained(), "two slots are still in flight");
    assert_eq!(bounced.len(), 1);
    let GenerationEvent::Error { id, retryable, reason } = &bounced[0] else {
        panic!("queued request must bounce with an Error event");
    };
    assert_eq!((*id, *retryable), (2, true), "drain bounces are retryable");
    assert_eq!(reason, DRAIN_REASON);
    // a late submission bounces on the next step, not silently queues
    b.submit(Request::new(9, vec![5, 6], 4));
    let mut finished = Vec::new();
    let mut late_bounce = None;
    while b.pending() > 0 {
        for ev in b.step().unwrap() {
            match ev {
                GenerationEvent::Finished { result } => finished.push(result),
                GenerationEvent::Error { id, retryable, reason } => {
                    assert!(retryable);
                    assert_eq!(reason, DRAIN_REASON);
                    late_bounce = Some(id);
                }
                _ => {}
            }
        }
    }
    assert_eq!(late_bounce, Some(9), "post-drain submit must bounce");
    let mut ids: Vec<u64> = finished.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, vec![0, 1], "in-flight requests run to completion");
    for r in &finished {
        assert_eq!(r.finish_reason, FinishReason::Length);
        assert_eq!(r.tokens.len(), 4);
    }
    assert!(b.drained());
    assert_eq!(b.metrics.errors, 2, "both bounces surface as errors");
}

/// Drain with chunked prefill and a COW re-prefill slot mid-flight on the
/// paged + prefix-cache regime: both finish bitwise-correctly, and the
/// allocator retires holding only the cached prefix pages
/// (`pages_in_use == cached_pages`).
#[test]
fn drain_finishes_chunked_and_cow_slots_and_retires_clean() {
    let shared: Vec<i32> = (0..16).map(|i| (i * 7 % 256) as i32).collect();
    let engine = build_paged_engine(Arch::Standard, 2, 8, 64);
    let config = BatcherConfig {
        prefill_chunk: 4,
        prefix_cache: true,
        ..BatcherConfig::default()
    };
    let mut b = Batcher::new(engine, config);
    // warm the prefix cache: the 16-token prompt fills two 8-token pages
    b.submit(Request::new(0, shared.clone(), 4));
    let warm = b.run_to_completion().unwrap().remove(0);
    let alloc = b.allocator().unwrap();
    assert!(alloc.cached_pages() > 0, "warmup must seed the prefix cache");
    // request 1 re-uses the whole cached prompt -> trailing-page COW
    // re-prefill; request 2 is a fresh 24-token prompt -> chunked prefill
    b.submit(Request::new(1, shared.clone(), 4));
    let fresh: Vec<i32> = (0..24).map(|i| (100 + i * 3 % 100) as i32).collect();
    b.submit(Request::new(2, fresh, 4));
    b.submit(Request::new(3, vec![7, 7, 7], 4)); // stays queued (batch = 2)
    b.step().unwrap(); // admit 1 + 2; request 2 is mid-chunked-prefill
    let bounced = b.drain();
    assert_eq!(bounced.len(), 1, "only the queued request bounces");
    assert!(matches!(
        &bounced[0],
        GenerationEvent::Error { id: 3, retryable: true, .. }
    ));
    let mut finished = Vec::new();
    while b.pending() > 0 {
        for ev in b.step().unwrap() {
            if let GenerationEvent::Finished { result } = ev {
                finished.push(result);
            }
        }
    }
    assert!(b.drained());
    finished.sort_by_key(|r| r.id);
    assert_eq!(finished.len(), 2);
    for r in &finished {
        assert_eq!(r.finish_reason, FinishReason::Length);
        assert_eq!(r.tokens.len(), 4, "request {}", r.id);
    }
    // the COW slot drained mid-flight must still be bitwise-correct: same
    // prompt, greedy decoding -> same tokens as the cache-cold warmup run
    assert_eq!(finished[0].tokens, warm.tokens, "COW re-prefill diverged");
    let alloc = b.allocator().unwrap();
    alloc.check().unwrap();
    assert_eq!(
        alloc.pages_in_use(),
        alloc.cached_pages(),
        "a drained allocator holds only prefix-cache pages"
    );
}

#[test]
fn kv_budget_limits_concurrency() {
    let mut b = build_batcher(Arch::Standard, 2);
    // budget for exactly one slot
    b.config.kv_budget_bytes = b.engine.kv_bytes_per_slot();
    for i in 0..3u64 {
        b.submit(Request::new(i, vec![1, 2], 2));
    }
    let results = b.run_to_completion().unwrap();
    assert_eq!(results.len(), 3);
}

#[test]
fn event_stream_is_ordered_with_monotone_indices() {
    let tok = Tokenizer::bytes_only(256);
    let mut b = build_batcher_tok(Arch::Ladder, 2);
    let (etx, erx) = channel();
    b.submit_streaming(Request::new(7, vec![1, 2, 3], 5), etx);
    while b.pending() > 0 {
        b.step().unwrap();
    }
    let events: Vec<GenerationEvent> = erx.try_iter().collect();
    assert!(matches!(events[0], GenerationEvent::Admitted { id: 7, .. }));
    let mut deltas = String::new();
    let mut next_index = 0usize;
    for ev in &events[1..events.len() - 1] {
        match ev {
            GenerationEvent::Token { id: 7, index, text_delta, .. } => {
                assert_eq!(*index, next_index, "token indices must be monotone");
                next_index += 1;
                deltas.push_str(text_delta);
            }
            other => panic!("unexpected mid-stream event {other:?}"),
        }
    }
    let GenerationEvent::Finished { result } = events.last().unwrap() else {
        panic!("stream must end with Finished");
    };
    assert_eq!(result.finish_reason, FinishReason::Length);
    assert_eq!(result.tokens.len(), 5);
    assert_eq!(next_index, 5);
    // deltas concatenate to the batch decode (minus any held-back
    // incomplete UTF-8 tail, which batch decode renders as U+FFFD)
    assert!(
        tok.decode(&result.tokens).starts_with(&deltas),
        "deltas {deltas:?} vs {:?}",
        tok.decode(&result.tokens)
    );
}

#[test]
fn finish_reason_eos_truncates() {
    let prompt = vec![4i32, 5, 6, 7];
    let base = greedy_tokens(&prompt, 6);
    let eos = base[2];
    let cut = base.iter().position(|&t| t == eos).unwrap();
    let mut b = build_batcher(Arch::Standard, 2);
    b.submit(Request::new(0, prompt, 6).with_eos(Some(eos)));
    let r = b.run_to_completion().unwrap().remove(0);
    assert_eq!(r.finish_reason, FinishReason::Eos);
    assert_eq!(r.tokens, base[..=cut].to_vec());
}

#[test]
fn finish_reason_stop_sequence() {
    let prompt = vec![11i32, 12, 13];
    let base = greedy_tokens(&prompt, 6);
    let stop = vec![base[1], base[2]];
    let cut = (1..base.len()).find(|&i| base[i - 1..=i] == stop[..]).unwrap();
    let mut b = build_batcher(Arch::Standard, 2);
    b.submit(Request::new(0, prompt, 6).with_stop(vec![stop.clone()]));
    let r = b.run_to_completion().unwrap().remove(0);
    assert_eq!(r.finish_reason, FinishReason::Stop);
    assert!(r.tokens.ends_with(&stop));
    assert_eq!(r.tokens, base[..=cut].to_vec());
}

#[test]
fn cancel_queued_and_inflight_frees_slots() {
    let mut b = build_batcher(Arch::Ladder, 2);
    for i in 0..3u64 {
        b.submit(Request::new(i, vec![1, 2, 3], 40));
    }
    // request 2 is still queued (2 slots): cancelling it must not prefill
    let Some(GenerationEvent::Finished { result }) = b.cancel(2).unwrap() else {
        panic!("queued cancel must produce a Finished event");
    };
    assert_eq!(result.finish_reason, FinishReason::Cancelled);
    assert!(result.tokens.is_empty());
    // request 0 gets a few tokens, then dies mid-flight
    b.step().unwrap();
    b.step().unwrap();
    let Some(GenerationEvent::Finished { result }) = b.cancel(0).unwrap() else {
        panic!("in-flight cancel must produce a Finished event");
    };
    assert_eq!(result.finish_reason, FinishReason::Cancelled);
    assert!(!result.tokens.is_empty(), "partial tokens survive the cancel");
    assert!(result.tokens.len() < 40);
    // the freed slot must admit new work: request 1 + a late arrival drain
    b.submit(Request::new(9, vec![5, 6], 3));
    let results = b.run_to_completion().unwrap();
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, vec![1, 9]);
    assert_eq!(b.metrics.cancelled, 2);
    assert_eq!(b.cancel(777).unwrap(), None, "unknown id");
}

#[test]
fn dead_sink_is_never_prefilled() {
    let mut b = build_batcher(Arch::Standard, 2);
    let (etx, erx) = channel();
    b.submit_streaming(Request::new(1, vec![1, 2, 3], 8), etx);
    drop(erx); // client vanished while queued
    let events = b.step().unwrap();
    assert_eq!(b.metrics.prefills, 0, "no prefill for a dead client");
    assert!(events.iter().any(|e| matches!(
        e,
        GenerationEvent::Finished { result } if result.finish_reason == FinishReason::Cancelled
    )));
    assert_eq!(b.pending(), 0);
}

#[test]
fn dead_sink_cancels_inflight_decode() {
    let mut b = build_batcher(Arch::Standard, 2);
    let (etx, erx) = channel();
    b.submit_streaming(Request::new(1, vec![1, 2, 3], 50), etx);
    b.step().unwrap();
    assert_eq!(b.metrics.prefills, 1);
    drop(erx); // client times out / disconnects mid-generation
    b.step().unwrap();
    assert_eq!(b.pending(), 0, "slot must be reclaimed, not decoded dry");
    assert_eq!(b.metrics.cancelled, 1);
}

#[test]
fn per_request_sampling_reproducible_across_batch_mixes() {
    let prompt = vec![3i32, 1, 4, 1, 5];
    let sampler = Sampler::TopK { k: 8, temperature: 1.0, seed: 1234 };
    let solo = {
        let mut b = build_batcher(Arch::Standard, 2);
        b.submit(Request::new(0, prompt.clone(), 6).with_sampler(sampler.clone()));
        b.run_to_completion().unwrap().remove(0).tokens
    };
    let crowded = {
        let mut b = build_batcher(Arch::Standard, 2);
        b.submit(Request::new(0, prompt.clone(), 6).with_sampler(sampler.clone()));
        // a second sampled request interleaves its own RNG stream
        let other = Sampler::TopK { k: 8, temperature: 1.0, seed: 999 };
        b.submit(Request::new(1, vec![9, 9, 9, 9], 6).with_sampler(other));
        let results = b.run_to_completion().unwrap();
        results.into_iter().find(|r| r.id == 0).unwrap().tokens
    };
    assert_eq!(solo, crowded, "sampled output must not depend on batch mix");
}

// ---------------------------------------------------------------------------
// TCP wire protocol
// ---------------------------------------------------------------------------

#[test]
fn tcp_api_roundtrip_v1_shape() {
    let tok = Tokenizer::bytes_only(256);
    let (jobs, port) = api::spawn_listener("127.0.0.1:0", tok).unwrap();

    // client thread: send a v1-style (non-streaming) request
    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        stream
            .write_all(b"{\"prompt\":\"hi there\",\"max_new_tokens\":3}\n")
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line
    });

    let mut b = build_batcher_tok(Arch::Ladder, 2);
    api::serve_forever(&mut b, jobs, 1).unwrap();

    let line = client.join().unwrap();
    let reply = parse(&line).unwrap();
    assert!(reply.opt("error").is_none(), "{line}");
    assert_eq!(reply.get("tokens").unwrap().as_arr().unwrap().len(), 3);
    // byte-compatible v1 reply: exactly the old key set, no event framing
    let keys: Vec<&str> = reply.as_obj().unwrap().keys().map(String::as_str).collect();
    assert_eq!(keys, ["e2e_ms", "id", "queued_ms", "text", "tokens", "ttft_ms"]);
    let e2e_ms = reply.get("e2e_ms").unwrap().as_f64().unwrap();
    assert!(e2e_ms > 0.0);
    // the batcher's measured queue wait must reach the wire alongside
    // ttft/e2e, and the latency breakdown must be internally consistent
    let queued_ms = reply.get("queued_ms").unwrap().as_f64().unwrap();
    let ttft_ms = reply.get("ttft_ms").unwrap().as_f64().unwrap();
    assert!(queued_ms >= 0.0);
    assert!(queued_ms <= ttft_ms, "queued {queued_ms} > ttft {ttft_ms}");
    assert!(ttft_ms <= e2e_ms, "ttft {ttft_ms} > e2e {e2e_ms}");
}

#[test]
fn tcp_streaming_frames_arrive_in_order() {
    let tok = Tokenizer::bytes_only(256);
    let (jobs, port) = api::spawn_listener("127.0.0.1:0", tok).unwrap();

    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        stream
            .write_all(b"{\"prompt\":\"stream me\",\"max_new_tokens\":5,\"stream\":true}\n")
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut frames = Vec::new();
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let frame = parse(&line).unwrap();
            let done = frame.get("event").unwrap().as_str().unwrap() == "done";
            frames.push(frame);
            if done {
                return frames;
            }
        }
    });

    let mut b = build_batcher_tok(Arch::Ladder, 2);
    api::serve_forever(&mut b, jobs, 1).unwrap();

    let frames = client.join().unwrap();
    assert_eq!(frames[0].get("event").unwrap().as_str().unwrap(), "admitted");
    let id = frames[0].get("id").unwrap().as_usize().unwrap();
    assert_eq!(frames.len(), 7, "admitted + 5 tokens + done");
    for (i, frame) in frames[1..6].iter().enumerate() {
        assert_eq!(frame.get("event").unwrap().as_str().unwrap(), "token");
        assert_eq!(frame.get("index").unwrap().as_usize().unwrap(), i);
        assert_eq!(frame.get("id").unwrap().as_usize().unwrap(), id);
        assert!(frame.opt("text_delta").is_some());
    }
    let done = &frames[6];
    assert_eq!(done.get("finish_reason").unwrap().as_str().unwrap(), "length");
    assert_eq!(done.get("tokens").unwrap().as_arr().unwrap().len(), 5);
    assert!(done.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);
    assert!(done.opt("itl_ms_p50").is_some());
    assert!(done.opt("queued_ms").is_some());
}

/// Protocol-v2 cancellation over real TCP, with the engine loop driven
/// manually so the interleaving is deterministic: the client provably
/// observes a token frame while the request is still live (the engine has
/// not finished it), cancels, gets `finish_reason:"cancelled"`, and the
/// freed slot (batch=1!) then serves a second request.
#[test]
fn tcp_cancel_mid_stream_reuses_slot() {
    let tok = Tokenizer::bytes_only(256);
    let (jobs, port) = api::spawn_listener("127.0.0.1:0", tok).unwrap();

    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        stream
            .write_all(b"{\"prompt\":\"cancel me\",\"max_new_tokens\":60,\"stream\":true}\n")
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut id = None;
        let mut saw_token = false;
        // read until the first token frame: generation is live
        while !saw_token {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let frame = parse(&line).unwrap();
            match frame.get("event").unwrap().as_str().unwrap() {
                "admitted" => id = Some(frame.get("id").unwrap().as_usize().unwrap()),
                "token" => saw_token = true,
                other => panic!("unexpected frame {other:?}"),
            }
        }
        let id = id.expect("admitted frame precedes tokens");
        stream.write_all(format!("{{\"cancel\":{id}}}\n").as_bytes()).unwrap();
        // drain frames until the cancelled done arrives
        let done = loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let frame = parse(&line).unwrap();
            if frame.get("event").unwrap().as_str().unwrap() == "done" {
                break frame;
            }
        };
        // slot re-use: a second request on the single-slot engine
        stream
            .write_all(b"{\"prompt\":\"after cancel\",\"max_new_tokens\":3}\n")
            .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        (done, parse(&line).unwrap())
    });

    // manual engine loop, batch = 1 so re-use is provable
    let mut b = build_batcher_tok(Arch::Standard, 1);
    match jobs.recv().unwrap() {
        ApiJob::Submit { request, respond } => b.submit_streaming(request, respond),
        _ => panic!("expected submit"),
    }
    b.step().unwrap(); // admit + first tokens stream out
    match jobs.recv().unwrap() {
        // blocks until the client has seen a token and cancelled: the
        // request is still occupying the slot at this instant
        ApiJob::Cancel { id } => {
            let ev = b.cancel(id).unwrap().expect("in-flight request must cancel");
            let GenerationEvent::Finished { result } = ev else { panic!("not finished") };
            assert_eq!(result.finish_reason, FinishReason::Cancelled);
        }
        _ => panic!("expected cancel"),
    }
    assert_eq!(b.pending(), 0, "cancel must free the only slot");
    match jobs.recv().unwrap() {
        ApiJob::Submit { request, respond } => b.submit_streaming(request, respond),
        _ => panic!("expected submit"),
    }
    while b.pending() > 0 {
        b.step().unwrap();
    }

    let (done, reply2) = client.join().unwrap();
    assert_eq!(done.get("finish_reason").unwrap().as_str().unwrap(), "cancelled");
    assert!(!done.get("tokens").unwrap().as_arr().unwrap().is_empty());
    assert!(reply2.opt("error").is_none(), "{reply2:?}");
    assert_eq!(reply2.get("tokens").unwrap().as_arr().unwrap().len(), 3);
    assert_eq!(b.metrics.cancelled, 1);
}

#[test]
fn tcp_stats_query_snapshots_metrics() {
    let tok = Tokenizer::bytes_only(256);
    let (jobs, port) = api::spawn_listener("127.0.0.1:0", tok).unwrap();

    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        stream.write_all(b"{\"prompt\":\"hello\",\"max_new_tokens\":4}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        let reply = parse(&line).unwrap();
        line.clear();
        stream.write_all(b"{\"stats\":true}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        let stats = parse(&line).unwrap();
        // a second request lets the serve loop hit its completion target
        line.clear();
        stream.write_all(b"{\"prompt\":\"bye\",\"max_new_tokens\":2}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        (reply, stats)
    });

    // paged engine end-to-end over the wire: chunked prefill + page tables
    let engine = build_paged_engine(Arch::Ladder, 2, 8, 64);
    let config = BatcherConfig { prefill_chunk: 2, ..BatcherConfig::default() };
    let mut b = Batcher::with_tokenizer(engine, config, Tokenizer::bytes_only(256));
    api::serve_forever(&mut b, jobs, 2).unwrap();

    let (reply, stats) = client.join().unwrap();
    assert!(reply.opt("error").is_none(), "{reply:?}");
    assert_eq!(reply.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    assert_eq!(stats.get("completed").unwrap().as_usize().unwrap(), 1);
    assert_eq!(stats.get("tokens_out").unwrap().as_usize().unwrap(), 4);
    assert!(stats.opt("kv_pages_in_use").is_some());
    assert!(stats.get("kv_pages_high_water").unwrap().as_usize().unwrap() >= 1);
    assert!(stats.opt("admission_blocked").is_some());
    assert!(stats.get("throughput_tok_per_s").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn tcp_rejects_bad_requests_without_dying() {
    let tok = Tokenizer::bytes_only(256);
    let (jobs, port) = api::spawn_listener("127.0.0.1:0", tok).unwrap();

    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut replies = Vec::new();
        for req in [
            "this is not json\n",
            "{\"prompt\":\"\"}\n",
            "{\"cancel\":\"nope\"}\n",
            "{\"upgrade\":{\"all\":\"arch=ladder\"}}\n",
            "{\"prompt\":\"still works\",\"max_new_tokens\":2}\n",
        ] {
            stream.write_all(req.as_bytes()).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            replies.push(parse(&line).unwrap());
        }
        replies
    });

    let mut b = build_batcher_tok(Arch::Standard, 2);
    api::serve_forever(&mut b, jobs, 1).unwrap();

    let replies = client.join().unwrap();
    assert!(replies[0].opt("error").is_some(), "bad json must error");
    assert!(replies[1].opt("error").is_some(), "empty prompt must error");
    assert!(replies[2].opt("error").is_some(), "non-numeric cancel must error");
    let upgrade_err = replies[3].get("error").unwrap().as_str().unwrap().to_string();
    assert!(
        upgrade_err.contains("fleet"),
        "serve mode must reject upgrades, pointing at fleet mode: {upgrade_err}"
    );
    assert_eq!(replies[4].get("tokens").unwrap().as_arr().unwrap().len(), 2);
}

/// Fleet mode end-to-end over TCP: `{"stats":true}` must expose each
/// replica's identity — the slot's `config` description plus the live
/// engine's `arch`/`codec`/`page_size`/`admission_blocked` and the
/// router-side `pending`/`blocked` backpressure fields — so the A/B
/// harness can attribute deltas to the right replica. A fleet booted
/// without an upgrade builder must reject `{"upgrade":...}` frames
/// without dying.
#[test]
fn tcp_fleet_stats_expose_per_replica_config() {
    let tok = Tokenizer::bytes_only(256);
    let (jobs, port) = api::spawn_listener("127.0.0.1:0", tok).unwrap();

    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        stream.write_all(b"{\"upgrade\":{\"all\":\"arch=ladder\"}}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        let upgrade_reply = parse(&line).unwrap();
        line.clear();
        stream.write_all(b"{\"stats\":true}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        let stats = parse(&line).unwrap();
        line.clear();
        // one real request lets route_forever hit its completion target
        stream.write_all(b"{\"prompt\":\"hello\",\"max_new_tokens\":2}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        (upgrade_reply, stats, parse(&line).unwrap())
    });

    // a deliberately heterogeneous pair: ladder on paged KV vs standard
    // on the legacy slab layout
    let paged: ReplicaFactory = Arc::new(|| {
        Ok(Batcher::with_tokenizer(
            build_paged_engine(Arch::Ladder, 2, 8, 64),
            BatcherConfig::default(),
            Tokenizer::bytes_only(256),
        ))
    });
    let slab: ReplicaFactory = Arc::new(|| {
        Ok(Batcher::with_tokenizer(
            build_engine(Arch::Standard, 2),
            BatcherConfig::default(),
            Tokenizer::bytes_only(256),
        ))
    });
    let slots = vec![
        ReplicaSlotConfig::with_desc(
            paged,
            Json::obj().set("arch", "ladder").set("page_size", 8usize),
        ),
        ReplicaSlotConfig::with_desc(
            slab,
            Json::obj().set("arch", "standard").set("page_size", 0usize),
        ),
    ];
    let cfg = RouterConfig {
        replicas: 2,
        policy: RoutingPolicy::RoundRobin,
        affinity_tokens: 8,
        spill_threshold: 8,
        max_retries: 2,
        retry_backoff: Duration::from_millis(2),
        dispatch_timeout: Duration::from_secs(30),
        auto_restart: true,
    };
    let r = Router::new_fleet(slots, cfg).unwrap();
    router::route_forever(&r, jobs, 1, None).unwrap();

    let (upgrade_reply, stats, reply) = client.join().unwrap();
    let upgrade_err = upgrade_reply.get("error").unwrap().as_str().unwrap();
    assert!(upgrade_err.contains("upgrade"), "{upgrade_reply:?}");
    assert!(reply.opt("error").is_none(), "{reply:?}");
    let reps = stats.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(reps.len(), 2);
    for (rep, (arch, page)) in reps.iter().zip([("ladder", 8usize), ("standard", 0usize)]) {
        let config = rep.get("config").unwrap();
        assert_eq!(config.get("arch").unwrap().as_str().unwrap(), arch);
        assert_eq!(config.get("page_size").unwrap().as_usize().unwrap(), page);
        let engine = rep.get("engine").unwrap();
        assert_eq!(engine.get("arch").unwrap().as_str().unwrap(), arch);
        assert_eq!(engine.get("codec").unwrap().as_str().unwrap(), "fp32");
        assert_eq!(engine.get("page_size").unwrap().as_usize().unwrap(), page);
        assert!(engine.opt("admission_blocked").is_some());
        assert!(rep.get("pending").unwrap().as_usize().is_ok());
        assert!(rep.get("blocked").unwrap().as_bool().is_ok());
    }
    assert!(matches!(stats.get("upgrade"), Ok(Json::Null)), "no upgrade in progress");
}

#[test]
fn wire_sampling_params_reach_the_sampler() {
    // same seed twice -> identical sampled output; the determinism comes
    // from the per-request seed on the wire, not server state
    let tok = Tokenizer::bytes_only(256);
    let (jobs, port) = api::spawn_listener("127.0.0.1:0", tok).unwrap();

    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let req = "{\"prompt\":\"sample\",\"max_new_tokens\":6,\"temperature\":1.0,\
                   \"top_k\":8,\"seed\":77}\n";
        let mut texts = Vec::new();
        for _ in 0..2 {
            stream.write_all(req.as_bytes()).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let reply = parse(&line).unwrap();
            texts.push(reply.get("tokens").unwrap().to_string());
        }
        texts
    });

    let mut b = build_batcher_tok(Arch::Standard, 2);
    api::serve_forever(&mut b, jobs, 2).unwrap();

    let texts = client.join().unwrap();
    assert_eq!(texts[0], texts[1], "same wire seed must reproduce");
}

#[test]
fn wire_stop_string_truncates() {
    // learn the greedy continuation, then stop on its 2nd-3rd characters
    let prompt_text = "hi there";
    let tok = Tokenizer::bytes_only(256);
    let base = greedy_tokens(&tok.encode(prompt_text), 6);
    let stop_text: String = tok.decode(&base[1..3]);
    // only usable when those bytes decode to clean ASCII (tiny random
    // weights often emit non-UTF8 bytes; skip the wire round-trip then)
    if tok.encode(&stop_text) != base[1..3].to_vec() {
        return;
    }
    let (jobs, port) = api::spawn_listener("127.0.0.1:0", tok).unwrap();
    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let req = format!(
            "{{\"prompt\":\"{prompt_text}\",\"max_new_tokens\":6,\"stream\":true,\
             \"stop\":[{}]}}\n",
            Json::Str(stop_text.clone()).to_string()
        );
        stream.write_all(req.as_bytes()).unwrap();
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let frame = parse(&line).unwrap();
            if frame.get("event").unwrap().as_str().unwrap() == "done" {
                return frame;
            }
        }
    });
    let mut b = build_batcher_tok(Arch::Standard, 2);
    api::serve_forever(&mut b, jobs, 1).unwrap();
    let done = client.join().unwrap();
    assert_eq!(done.get("finish_reason").unwrap().as_str().unwrap(), "stop");
    assert!(done.get("tokens").unwrap().as_arr().unwrap().len() <= 3);
}
