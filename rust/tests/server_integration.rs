//! Integration: continuous batcher + TCP API over the real tiny engine,
//! running on the native backend (no artifacts required).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::rc::Rc;

use ladder_infer::comm::{Fabric, Interconnect};
use ladder_infer::engine::TpEngine;
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::Exec;
use ladder_infer::server::{api, Batcher, BatcherConfig, Request};
use ladder_infer::tokenizer::Tokenizer;
use ladder_infer::util::json::parse;

fn build_batcher(arch: Arch, batch: usize) -> Batcher {
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = WeightStore::random(exec.cfg(), 0xbeef);
    let engine = TpEngine::new(
        exec,
        &weights,
        2,
        arch,
        batch,
        Interconnect::new(Fabric::Local),
    )
    .unwrap();
    Batcher::new(engine, BatcherConfig::default())
}

#[test]
fn batcher_completes_all_requests_fifo() {
    let mut b = build_batcher(Arch::Ladder, 2);
    for i in 0..5u64 {
        b.submit(Request::new(i, vec![1, 2, 3, (i % 4) as i32], 4));
    }
    let results = b.run_to_completion().unwrap();
    assert_eq!(results.len(), 5);
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    // each request produced exactly max_new_tokens
    for r in &results {
        assert_eq!(r.tokens.len(), 4, "request {}", r.id);
        assert!(r.ttft_secs > 0.0 && r.e2e_secs >= r.ttft_secs);
    }
    ids.sort();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    assert_eq!(b.metrics.completed, 5);
    assert!(b.metrics.decode_steps > 0);
}

#[test]
fn batcher_oversubscription_queues_and_drains() {
    // more requests than slots: the queue must drain without starvation
    let mut b = build_batcher(Arch::Standard, 2);
    for i in 0..7u64 {
        b.submit(Request::new(i, vec![5, 6, 7], 3));
    }
    let results = b.run_to_completion().unwrap();
    assert_eq!(results.len(), 7);
    assert_eq!(b.pending(), 0);
}

#[test]
fn batcher_isolation_between_slots() {
    // the same prompt must produce the same tokens regardless of what else
    // shares the batch (KV slots must not leak across requests)
    let prompt = vec![9i32, 8, 7, 6, 5];
    let solo = {
        let mut b = build_batcher(Arch::Standard, 2);
        b.submit(Request::new(0, prompt.clone(), 5));
        b.run_to_completion().unwrap().remove(0).tokens
    };
    let crowded = {
        let mut b = build_batcher(Arch::Standard, 2);
        b.submit(Request::new(0, prompt.clone(), 5));
        b.submit(Request::new(1, vec![100, 101, 102, 103, 104, 105, 106], 5));
        b.submit(Request::new(2, vec![33, 44], 5));
        let results = b.run_to_completion().unwrap();
        results.into_iter().find(|r| r.id == 0).unwrap().tokens
    };
    assert_eq!(solo, crowded, "KV slot leakage between concurrent requests");
}

#[test]
fn kv_budget_limits_concurrency() {
    let mut b = build_batcher(Arch::Standard, 2);
    // budget for exactly one slot
    b.config.kv_budget_bytes = b.engine.kv_bytes_per_slot();
    for i in 0..3u64 {
        b.submit(Request::new(i, vec![1, 2], 2));
    }
    let results = b.run_to_completion().unwrap();
    assert_eq!(results.len(), 3);
}

#[test]
fn tcp_api_roundtrip() {
    let tok = Tokenizer::bytes_only(256);
    let (jobs, port) = api::spawn_listener("127.0.0.1:0", tok).unwrap();

    // client thread: send two requests, collect replies
    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        stream
            .write_all(b"{\"prompt\":\"hi there\",\"max_new_tokens\":3}\n")
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line
    });

    let mut b = build_batcher(Arch::Ladder, 2);
    api::serve_forever(&mut b, jobs, 1).unwrap();

    let line = client.join().unwrap();
    let reply = parse(&line).unwrap();
    assert!(reply.opt("error").is_none(), "{line}");
    assert_eq!(reply.get("tokens").unwrap().as_arr().unwrap().len(), 3);
    let e2e_ms = reply.get("e2e_ms").unwrap().as_f64().unwrap();
    assert!(e2e_ms > 0.0);
    // the batcher's measured queue wait must reach the wire alongside
    // ttft/e2e, and the latency breakdown must be internally consistent
    let queued_ms = reply.get("queued_ms").unwrap().as_f64().unwrap();
    let ttft_ms = reply.get("ttft_ms").unwrap().as_f64().unwrap();
    assert!(queued_ms >= 0.0);
    assert!(queued_ms <= ttft_ms, "queued {queued_ms} > ttft {ttft_ms}");
    assert!(ttft_ms <= e2e_ms, "ttft {ttft_ms} > e2e {e2e_ms}");
}
