//! Property tests (hand-rolled harness, util::proptest) on the coordinator
//! invariants DESIGN.md §4 calls out: collectives, interconnect monotonicity,
//! timeline ordering, KV cache slots, tokenizer roundtrip.

use std::collections::HashMap;

use ladder_infer::comm::{CollectiveEngine, Fabric, Interconnect};
use ladder_infer::engine::{BlockAllocator, KvCache, PrefixTree};
use ladder_infer::model::{Arch, HostTensor};
use ladder_infer::perfmodel::costs::ModuleTimes;
use ladder_infer::perfmodel::timeline::simulate_forward;
use ladder_infer::tokenizer::Tokenizer;
use ladder_infer::util::proptest::{check, Gen, PairGen, UnicodeGen, UsizeGen, VecF32Gen};
use ladder_infer::util::rng::Rng;

struct ModuleTimesGen;

impl Gen for ModuleTimesGen {
    type Value = (usize, ModuleTimes);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let layers = rng.range(1, 12);
        let mt = ModuleTimes {
            attn: rng.f64() * 10.0 + 0.01,
            mlp: rng.f64() * 10.0 + 0.01,
            fused: 0.0,
            allreduce: rng.f64() * 20.0,
            edges: rng.f64(),
        };
        let mt = ModuleTimes { fused: mt.attn + mt.mlp, ..mt };
        (layers, mt)
    }
}

#[test]
fn prop_timeline_ordering_upperbound_ladder_standard() {
    check("ub<=ladder<=standard", 300, &ModuleTimesGen, |(layers, mt)| {
        let ub = simulate_forward(Arch::Upperbound, *layers, mt, false).total;
        let lad = simulate_forward(Arch::Ladder, *layers, mt, false).total;
        let std = simulate_forward(Arch::Standard, *layers, mt, false).total;
        ub <= lad + 1e-9 && lad <= std + 1e-9
    });
}

#[test]
fn prop_ladder_exposure_never_exceeds_total_comm() {
    check("exposed<=total", 300, &ModuleTimesGen, |(layers, mt)| {
        let r = simulate_forward(Arch::Ladder, *layers, mt, false);
        r.comm_exposed <= r.comm_total + 1e-9
    });
}

#[test]
fn prop_desync_comm_counts() {
    check("desync-comm-count", 200, &ModuleTimesGen, |(layers, mt)| {
        let full = simulate_forward(Arch::Standard, *layers, mt, false).comm_total;
        let d2 = simulate_forward(Arch::Desync(2), *layers, mt, false).comm_total;
        if mt.allreduce == 0.0 {
            return true;
        }
        // desync2 keeps exactly half of 2*layers reduces
        (d2 - full / 2.0).abs() < 1e-6 * full.max(1.0)
    });
}

#[test]
fn prop_makespan_monotone_in_link_latency() {
    check("monotone-in-ar", 200, &ModuleTimesGen, |(layers, mt)| {
        let slower = ModuleTimes { allreduce: mt.allreduce * 2.0 + 0.1, ..*mt };
        for arch in [Arch::Standard, Arch::Ladder, Arch::Parallel, Arch::Desync(2)] {
            let a = simulate_forward(arch, *layers, mt, false).total;
            let b = simulate_forward(arch, *layers, &slower, false).total;
            if b + 1e-9 < a {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_allreduce_sum_matches_scalar_sum() {
    let gen = PairGen(
        UsizeGen { lo: 1, hi: 6 },
        VecF32Gen { min_len: 1, max_len: 64, scale: 10.0 },
    );
    check("allreduce-sum", 150, &gen, |(tp, data)| {
        let ce = CollectiveEngine::new(*tp, Interconnect::new(Fabric::Local));
        let parts: Vec<HostTensor> = (0..*tp)
            .map(|t| {
                HostTensor::new(
                    vec![data.len()],
                    data.iter().map(|x| x * (t + 1) as f32).collect(),
                )
            })
            .collect();
        let (out, _) = ce.allreduce(parts).unwrap().wait();
        let factor: f32 = (1..=*tp).map(|t| t as f32).sum();
        out.data
            .iter()
            .zip(data)
            .all(|(o, d)| (o - d * factor).abs() <= 1e-3 * (1.0 + d.abs() * factor.abs()))
    });
}

#[test]
fn prop_allgather_preserves_all_elements() {
    let gen = PairGen(UsizeGen { lo: 1, hi: 5 }, UsizeGen { lo: 1, hi: 8 });
    check("allgather-elements", 100, &gen, |(tp, cols)| {
        let ce = CollectiveEngine::new(*tp, Interconnect::new(Fabric::Local));
        let shards: Vec<HostTensor> = (0..*tp)
            .map(|t| HostTensor::new(vec![2, *cols], vec![t as f32; 2 * cols]))
            .collect();
        let out = ce.allgather_concat(shards).unwrap();
        out.shape == vec![2, cols * tp] && out.data.len() == 2 * cols * tp
    });
}

#[test]
fn prop_kv_slot_writes_are_isolated() {
    let gen = PairGen(UsizeGen { lo: 1, hi: 4 }, UsizeGen { lo: 0, hi: 3 });
    check("kv-slot-isolation", 100, &gen, |(layers, slot)| {
        let batch = 4;
        let mut kv = KvCache::new(*layers, batch, 2, 8, 4);
        let stride = 2 * 8 * 4;
        let ones = HostTensor::new(vec![1, 2, 8, 4], vec![1.0; stride]);
        kv.write_slot(layers - 1, *slot, &ones, &ones).unwrap();
        // all other slots in all layers stay zero
        for l in 0..*layers {
            for b in 0..batch {
                let (k, v) = kv.read_slot(l, b);
                let expect = if l == layers - 1 && b == *slot { 1.0 } else { 0.0 };
                if k.data.iter().any(|&x| x != expect) || v.data.iter().any(|&x| x != expect) {
                    return false;
                }
            }
        }
        true
    });
}

// ---------------------------------------------------------------------------
// BlockAllocator: arbitrary admit/ensure/free sequences keep every
// structural invariant and round-trip to an empty free list
// ---------------------------------------------------------------------------

/// One allocator operation, drawn from a small owner space so sequences
/// collide on owners often.
#[derive(Clone, Debug)]
enum AllocOp {
    /// (owner, prompt tokens, extra reserve tokens)
    Admit(u64, usize, usize),
    /// (owner, tokens to grow by)
    Ensure(u64, usize),
    Free(u64),
}

struct AllocSeqGen;

impl Gen for AllocSeqGen {
    type Value = Vec<AllocOp>;
    fn generate(&self, rng: &mut Rng) -> Vec<AllocOp> {
        let n = rng.range(1, 60);
        (0..n)
            .map(|_| {
                let owner = rng.below(6) as u64;
                match rng.below(4) {
                    0 | 1 => AllocOp::Admit(owner, rng.range(1, 40), rng.below(24)),
                    2 => AllocOp::Ensure(owner, rng.range(1, 12)),
                    _ => AllocOp::Free(owner),
                }
            })
            .collect()
    }
    fn shrink(&self, v: &Vec<AllocOp>) -> Vec<Vec<AllocOp>> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[1..].to_vec());
        }
        out
    }
}

/// Apply an op sequence, auditing after every op; returns false on any
/// invariant violation. Legal-but-rejected ops (over-reservation, unknown
/// owner, double admit) must error without corrupting state.
fn apply_alloc_ops(ops: &[AllocOp], total_pages: usize, page_size: usize) -> bool {
    let mut a = BlockAllocator::new(total_pages, page_size, 64);
    for op in ops {
        match *op {
            AllocOp::Admit(owner, prompt, extra) => {
                let fits = a.table(owner).is_none() && a.can_admit(prompt + extra);
                let r = a.admit(owner, prompt, prompt + extra);
                if r.is_ok() != fits {
                    return false;
                }
            }
            AllocOp::Ensure(owner, grow) => {
                if let Some(t) = a.table(owner) {
                    let new_len = t.len + grow;
                    let within = a.pages_for(new_len) <= t.reserved_pages;
                    if a.ensure(owner, new_len).is_ok() != within {
                        return false;
                    }
                } else if a.ensure(owner, grow).is_ok() {
                    return false; // unknown owner must be rejected
                }
            }
            AllocOp::Free(owner) => {
                let held = a.table(owner).map_or(0, |t| t.pages.len());
                if a.free(owner) != held {
                    return false;
                }
            }
        }
        if a.check().is_err() {
            return false;
        }
        if a.bytes_in_use() > total_pages * 64 {
            return false;
        }
    }
    // round-trip: freeing every owner restores the full free list
    for owner in 0..6 {
        a.free(owner);
    }
    a.check().is_ok()
        && a.pages_in_use() == 0
        && a.reserved_pages() == 0
        && a.free_pages() == total_pages
}

#[test]
fn prop_block_allocator_sequences_roundtrip() {
    check("allocator-roundtrip", 300, &AllocSeqGen, |ops| apply_alloc_ops(ops, 32, 4));
    // a tighter pool exercises rejection paths far more often
    check("allocator-roundtrip-tight", 300, &AllocSeqGen, |ops| apply_alloc_ops(ops, 7, 4));
}

// ---------------------------------------------------------------------------
// PrefixTree + refcounted allocator: arbitrary interleavings of
// admit(match)/grow/finish(publish)/cancel/evict keep every invariant,
// matches return the longest page-aligned cached prefix (checked against a
// reference map), eviction never touches a referenced page, and the whole
// pool round-trips to a full free list
// ---------------------------------------------------------------------------

const PS: usize = 4;

/// One prefix-cache operation over a small owner / template space so
/// sequences collide on prefixes constantly.
#[derive(Clone, Debug)]
enum CacheOp {
    /// (owner, template, prompt len, extra reserve tokens): match the
    /// prompt against the tree, then admit on the chain (copy-on-write
    /// drop of the trailing page when the whole prompt is cached).
    Admit(u64, usize, usize, usize),
    /// (owner, extra tokens): grow within the reservation (decode).
    Grow(u64, usize),
    /// Publish full prompt pages, then free (request finished).
    Finish(u64),
    /// Free without publishing (client vanished before any page filled).
    Cancel(u64),
    /// Evict up to n pages, LRU.
    Evict(usize),
    /// Match only (lookup must agree with the reference map).
    Match(usize, usize),
}

/// Deterministic template pool: 3 bases sharing a common 2-page prefix so
/// chains fork mid-tree.
fn template(t: usize, len: usize) -> Vec<i32> {
    (0..len)
        .map(|i| {
            if i < 2 * PS {
                i as i32 // shared head
            } else {
                (100 * t + i) as i32
            }
        })
        .collect()
}

struct CacheSeqGen;

impl Gen for CacheSeqGen {
    type Value = Vec<CacheOp>;
    fn generate(&self, rng: &mut Rng) -> Vec<CacheOp> {
        let n = rng.range(1, 50);
        (0..n)
            .map(|_| {
                let owner = rng.below(5) as u64;
                let t = rng.below(3);
                match rng.below(10) {
                    0..=3 => CacheOp::Admit(owner, t, rng.range(1, 30), rng.below(12)),
                    4 => CacheOp::Grow(owner, rng.range(1, 8)),
                    5 | 6 => CacheOp::Finish(owner),
                    7 => CacheOp::Cancel(owner),
                    8 => CacheOp::Evict(rng.range(1, 6)),
                    _ => CacheOp::Match(t, rng.range(1, 30)),
                }
            })
            .collect()
    }
    fn shrink(&self, v: &Vec<CacheOp>) -> Vec<Vec<CacheOp>> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[1..].to_vec());
        }
        out
    }
}

/// The reference model for matching: every published full-page path,
/// keyed by its token prefix, mapping to the page that backs it.
type RefMap = HashMap<Vec<i32>, u32>;

fn reference_match(map: &RefMap, prompt: &[i32]) -> Vec<u32> {
    let mut chain = Vec::new();
    for i in 1..=prompt.len() / PS {
        match map.get(&prompt[..i * PS]) {
            Some(&p) => chain.push(p),
            None => break,
        }
    }
    chain
}

/// Apply one op sequence, auditing allocator + tree + reference map after
/// every op; false on any violation.
fn apply_cache_ops(ops: &[CacheOp], total_pages: usize) -> bool {
    let mut alloc = BlockAllocator::new(total_pages, PS, 64);
    let mut tree = PrefixTree::new(PS);
    let mut reference: RefMap = HashMap::new();
    // owner -> (prompt, reserve tokens)
    let mut live: HashMap<u64, (Vec<i32>, usize)> = HashMap::new();
    for op in ops {
        match *op {
            CacheOp::Admit(owner, t, plen, extra) => {
                if live.contains_key(&owner) {
                    continue;
                }
                let prompt = template(t, plen);
                let reserve = plen + extra;
                let mut chain = tree.match_prefix(&prompt);
                if reference_match(&reference, &prompt) != chain {
                    return false; // longest page-aligned prefix contract
                }
                if chain.len() * PS == plen && !chain.is_empty() {
                    chain.pop(); // copy-on-write trailing page
                }
                if !alloc.can_admit_chain(reserve, &chain) {
                    if alloc.admit_shared(owner, plen, reserve, &chain).is_ok() {
                        return false; // admission must agree with the check
                    }
                    continue;
                }
                // physical room: evict idle chains; the invariant says the
                // shortfall is always coverable
                let grow = alloc.pages_for(plen).saturating_sub(chain.len());
                let short = grow.saturating_sub(alloc.free_pages());
                if short > 0 {
                    let evicted = tree.evict(short, &mut alloc).unwrap();
                    for p in evicted {
                        reference.retain(|_, &mut v| v != p);
                    }
                }
                if alloc.admit_shared(owner, plen, reserve, &chain).is_err() {
                    return false; // checked admission may never fail
                }
                live.insert(owner, (prompt, reserve));
            }
            CacheOp::Grow(owner, extra) => {
                let Some((_, reserve)) = live.get(&owner) else { continue };
                let t = alloc.table(owner).expect("live owner has a table");
                let new_len = (t.len + extra).min(*reserve);
                let short = alloc.free_shortfall(owner, new_len);
                if short > 0 {
                    let evicted = tree.evict(short, &mut alloc).unwrap();
                    for p in evicted {
                        reference.retain(|_, &mut v| v != p);
                    }
                }
                if alloc.ensure(owner, new_len).is_err() {
                    return false; // growth within a reservation may not fail
                }
            }
            CacheOp::Finish(owner) => {
                let Some((prompt, _)) = live.remove(&owner) else { continue };
                let table = alloc.table(owner).expect("live owner").clone();
                let full = table.len.min(prompt.len()) / PS;
                if full > 0 {
                    let published =
                        tree.insert(&prompt[..full * PS], &table.pages[..full], &mut alloc);
                    if published.is_err() {
                        return false;
                    }
                    // dedup: an existing path keeps its canonical page
                    for i in 1..=full {
                        reference.entry(prompt[..i * PS].to_vec()).or_insert(table.pages[i - 1]);
                    }
                }
                alloc.free(owner);
            }
            CacheOp::Cancel(owner) => {
                live.remove(&owner);
                alloc.free(owner);
            }
            CacheOp::Evict(n) => {
                let before = tree.pages();
                let evicted = match tree.evict(n, &mut alloc) {
                    Ok(e) => e,
                    Err(_) => return false, // touched a referenced page
                };
                for p in &evicted {
                    let in_tree = before.iter().filter(|&&q| q == *p).count();
                    if alloc.req_refs(*p) > 0 || in_tree != 1 {
                        return false;
                    }
                    reference.retain(|_, &mut v| v != *p);
                }
            }
            CacheOp::Match(t, plen) => {
                let prompt = template(t, plen);
                let chain = tree.match_prefix(&prompt);
                if reference_match(&reference, &prompt) != chain {
                    return false;
                }
            }
        }
        // the full audit, after every op
        if alloc.check().is_err() {
            return false;
        }
        let pages = tree.pages();
        if pages.len() != alloc.cached_pages() || pages.iter().any(|&p| !alloc.is_cached(p)) {
            return false;
        }
        if reference.len() != pages.len() {
            return false;
        }
    }
    // round-trip: free every owner, flush the tree -> full free list
    for owner in 0..5 {
        alloc.free(owner);
    }
    tree.flush(&mut alloc).is_ok()
        && alloc.check().is_ok()
        && alloc.pages_in_use() == 0
        && alloc.reserved_pages() == 0
        && alloc.free_pages() == total_pages
        && tree.cached_pages() == 0
}

#[test]
fn prop_prefix_tree_allocator_interleavings_roundtrip() {
    check("prefix-tree-roundtrip", 250, &CacheSeqGen, |ops| apply_cache_ops(ops, 24));
    // a tight pool forces eviction into nearly every admission
    check("prefix-tree-roundtrip-tight", 250, &CacheSeqGen, |ops| apply_cache_ops(ops, 9));
}

// ---------------------------------------------------------------------------
// DecodeStream: fuzzed byte-level splits must concatenate to batch decode
// ---------------------------------------------------------------------------

/// Stream-decode `ids` one token at a time and compare the concatenated
/// deltas (plus the final flush) to the one-shot batch decode.
fn stream_matches_batch(tok: &Tokenizer, ids: &[i32]) -> bool {
    let mut stream = tok.decode_stream();
    let mut acc = String::new();
    for &id in ids {
        acc.push_str(&stream.push(id));
    }
    acc.push_str(&stream.finish());
    acc == tok.decode(ids)
}

#[test]
fn prop_decode_stream_fuzzed_unicode() {
    // byte-level vocab: every multi-byte character arrives split across
    // single-byte tokens — the maximal split of a valid UTF-8 stream
    let tok = Tokenizer::bytes_only(256);
    check("decode-stream-unicode", 400, &UnicodeGen { max_chars: 48 }, |s| {
        let ids: Vec<i32> = s.bytes().map(|b| b as i32).collect();
        stream_matches_batch(&tok, &ids)
    });
}

#[test]
fn prop_decode_stream_fuzzed_bpe_splits() {
    // BPE vocab: tokens carry multiple bytes, so splits land at arbitrary
    // merge boundaries instead of single bytes
    let corpus = "the cat sat on the mat. höwdy wörld ✓ the hat sat. ".repeat(30);
    let tok = Tokenizer::train(&corpus, 320).unwrap();
    check("decode-stream-bpe", 300, &UnicodeGen { max_chars: 32 }, |s| {
        let mut ids = tok.encode(s);
        ids.extend(tok.encode("the cat sat"));
        stream_matches_batch(&tok, &ids)
    });
}

#[test]
fn prop_decode_stream_survives_arbitrary_byte_tokens() {
    // raw random token streams: invalid and truncated UTF-8 sequences must
    // render exactly like from_utf8_lossy's maximal-subpart substitution
    struct RawBytesGen;
    impl Gen for RawBytesGen {
        type Value = Vec<i32>;
        fn generate(&self, rng: &mut Rng) -> Vec<i32> {
            let n = rng.range(0, 64);
            (0..n).map(|_| rng.below(256) as i32).collect()
        }
        fn shrink(&self, v: &Vec<i32>) -> Vec<Vec<i32>> {
            if v.is_empty() {
                Vec::new()
            } else {
                vec![v[..v.len() / 2].to_vec(), v[1..].to_vec()]
            }
        }
    }
    let tok = Tokenizer::bytes_only(256);
    check("decode-stream-raw-bytes", 500, &RawBytesGen, |ids| stream_matches_batch(&tok, ids));
}

#[test]
fn prop_tokenizer_roundtrip_ascii() {
    struct AsciiGen;
    impl Gen for AsciiGen {
        type Value = String;
        fn generate(&self, rng: &mut Rng) -> String {
            let n = rng.range(0, 60);
            (0..n).map(|_| (rng.range(32, 126) as u8) as char).collect()
        }
        fn shrink(&self, v: &String) -> Vec<String> {
            if v.is_empty() {
                vec![]
            } else {
                vec![v[..v.len() / 2].to_string(), String::new()]
            }
        }
    }
    let tok = Tokenizer::bytes_only(256);
    check("tokenizer-roundtrip", 200, &AsciiGen, |s| tok.decode(&tok.encode(s)) == *s);
}

#[test]
fn prop_interconnect_monotone() {
    let gen = PairGen(UsizeGen { lo: 2, hi: 16 }, UsizeGen { lo: 1, hi: 1 << 20 });
    check("interconnect-monotone", 200, &gen, |(n, bytes)| {
        for fabric in [Fabric::NvLink, Fabric::Pcie, Fabric::InfiniBand] {
            let ic = Interconnect::new(fabric);
            if ic.allreduce_time(*bytes * 2, *n) + 1e-15 < ic.allreduce_time(*bytes, *n) {
                return false;
            }
        }
        true
    });
}
