//! Property tests (hand-rolled harness, util::proptest) on the coordinator
//! invariants DESIGN.md §4 calls out: collectives, interconnect monotonicity,
//! timeline ordering, KV cache slots, tokenizer roundtrip.

use ladder_infer::comm::{CollectiveEngine, Fabric, Interconnect};
use ladder_infer::engine::KvCache;
use ladder_infer::model::{Arch, HostTensor};
use ladder_infer::perfmodel::costs::ModuleTimes;
use ladder_infer::perfmodel::timeline::simulate_forward;
use ladder_infer::tokenizer::Tokenizer;
use ladder_infer::util::proptest::{check, Gen, PairGen, UsizeGen, VecF32Gen};
use ladder_infer::util::rng::Rng;

struct ModuleTimesGen;

impl Gen for ModuleTimesGen {
    type Value = (usize, ModuleTimes);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let layers = rng.range(1, 12);
        let mt = ModuleTimes {
            attn: rng.f64() * 10.0 + 0.01,
            mlp: rng.f64() * 10.0 + 0.01,
            fused: 0.0,
            allreduce: rng.f64() * 20.0,
            edges: rng.f64(),
        };
        let mt = ModuleTimes { fused: mt.attn + mt.mlp, ..mt };
        (layers, mt)
    }
}

#[test]
fn prop_timeline_ordering_upperbound_ladder_standard() {
    check("ub<=ladder<=standard", 300, &ModuleTimesGen, |(layers, mt)| {
        let ub = simulate_forward(Arch::Upperbound, *layers, mt, false).total;
        let lad = simulate_forward(Arch::Ladder, *layers, mt, false).total;
        let std = simulate_forward(Arch::Standard, *layers, mt, false).total;
        ub <= lad + 1e-9 && lad <= std + 1e-9
    });
}

#[test]
fn prop_ladder_exposure_never_exceeds_total_comm() {
    check("exposed<=total", 300, &ModuleTimesGen, |(layers, mt)| {
        let r = simulate_forward(Arch::Ladder, *layers, mt, false);
        r.comm_exposed <= r.comm_total + 1e-9
    });
}

#[test]
fn prop_desync_comm_counts() {
    check("desync-comm-count", 200, &ModuleTimesGen, |(layers, mt)| {
        let full = simulate_forward(Arch::Standard, *layers, mt, false).comm_total;
        let d2 = simulate_forward(Arch::Desync(2), *layers, mt, false).comm_total;
        if mt.allreduce == 0.0 {
            return true;
        }
        // desync2 keeps exactly half of 2*layers reduces
        (d2 - full / 2.0).abs() < 1e-6 * full.max(1.0)
    });
}

#[test]
fn prop_makespan_monotone_in_link_latency() {
    check("monotone-in-ar", 200, &ModuleTimesGen, |(layers, mt)| {
        let slower = ModuleTimes { allreduce: mt.allreduce * 2.0 + 0.1, ..*mt };
        for arch in [Arch::Standard, Arch::Ladder, Arch::Parallel, Arch::Desync(2)] {
            let a = simulate_forward(arch, *layers, mt, false).total;
            let b = simulate_forward(arch, *layers, &slower, false).total;
            if b + 1e-9 < a {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_allreduce_sum_matches_scalar_sum() {
    let gen = PairGen(
        UsizeGen { lo: 1, hi: 6 },
        VecF32Gen { min_len: 1, max_len: 64, scale: 10.0 },
    );
    check("allreduce-sum", 150, &gen, |(tp, data)| {
        let ce = CollectiveEngine::new(*tp, Interconnect::new(Fabric::Local));
        let parts: Vec<HostTensor> = (0..*tp)
            .map(|t| {
                HostTensor::new(
                    vec![data.len()],
                    data.iter().map(|x| x * (t + 1) as f32).collect(),
                )
            })
            .collect();
        let (out, _) = ce.allreduce(parts).unwrap().wait();
        let factor: f32 = (1..=*tp).map(|t| t as f32).sum();
        out.data
            .iter()
            .zip(data)
            .all(|(o, d)| (o - d * factor).abs() <= 1e-3 * (1.0 + d.abs() * factor.abs()))
    });
}

#[test]
fn prop_allgather_preserves_all_elements() {
    let gen = PairGen(UsizeGen { lo: 1, hi: 5 }, UsizeGen { lo: 1, hi: 8 });
    check("allgather-elements", 100, &gen, |(tp, cols)| {
        let ce = CollectiveEngine::new(*tp, Interconnect::new(Fabric::Local));
        let shards: Vec<HostTensor> = (0..*tp)
            .map(|t| HostTensor::new(vec![2, *cols], vec![t as f32; 2 * cols]))
            .collect();
        let out = ce.allgather_concat(shards).unwrap();
        out.shape == vec![2, cols * tp] && out.data.len() == 2 * cols * tp
    });
}

#[test]
fn prop_kv_slot_writes_are_isolated() {
    let gen = PairGen(UsizeGen { lo: 1, hi: 4 }, UsizeGen { lo: 0, hi: 3 });
    check("kv-slot-isolation", 100, &gen, |(layers, slot)| {
        let batch = 4;
        let mut kv = KvCache::new(*layers, batch, 2, 8, 4);
        let stride = 2 * 8 * 4;
        let ones = HostTensor::new(vec![1, 2, 8, 4], vec![1.0; stride]);
        kv.write_slot(layers - 1, *slot, &ones, &ones).unwrap();
        // all other slots in all layers stay zero
        for l in 0..*layers {
            for b in 0..batch {
                let (k, v) = kv.read_slot(l, b);
                let expect = if l == layers - 1 && b == *slot { 1.0 } else { 0.0 };
                if k.data.iter().any(|&x| x != expect) || v.data.iter().any(|&x| x != expect) {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_tokenizer_roundtrip_ascii() {
    struct AsciiGen;
    impl Gen for AsciiGen {
        type Value = String;
        fn generate(&self, rng: &mut Rng) -> String {
            let n = rng.range(0, 60);
            (0..n).map(|_| (rng.range(32, 126) as u8) as char).collect()
        }
        fn shrink(&self, v: &String) -> Vec<String> {
            if v.is_empty() {
                vec![]
            } else {
                vec![v[..v.len() / 2].to_string(), String::new()]
            }
        }
    }
    let tok = Tokenizer::bytes_only(256);
    check("tokenizer-roundtrip", 200, &AsciiGen, |s| tok.decode(&tok.encode(s)) == *s);
}

#[test]
fn prop_interconnect_monotone() {
    let gen = PairGen(UsizeGen { lo: 2, hi: 16 }, UsizeGen { lo: 1, hi: 1 << 20 });
    check("interconnect-monotone", 200, &gen, |(n, bytes)| {
        for fabric in [Fabric::NvLink, Fabric::Pcie, Fabric::InfiniBand] {
            let ic = Interconnect::new(fabric);
            if ic.allreduce_time(*bytes * 2, *n) + 1e-15 < ic.allreduce_time(*bytes, *n) {
                return false;
            }
        }
        true
    });
}
